"""Elastic re-meshing: survive pod loss, absorb pod joins.

When the failure detector kills a pod, the job must continue on the
survivors: pick the new mesh (drop the pod axis or shrink it), recompute
every sharding for the new mesh, and re-place the restored checkpoint.
Parameters are pod-replicated by design (DESIGN.md §4), so *any* single
surviving pod holds a complete model copy — re-meshing is a resharding,
never a data loss.  The global batch is preserved by scaling the per-pod
batch (synchronous semantics unchanged; data order is deterministic in
(seed, step, host), so resume is exact).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import params_shardings
from repro.launch.mesh import make_mesh


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    npods: int
    note: str

    def build(self) -> Mesh:
        return make_mesh(self.shape, self.axes)

    def to_dict(self) -> dict:
        return {
            "shape": list(self.shape),
            "axes": list(self.axes),
            "npods": self.npods,
            "note": self.note,
        }


def plan_remesh(
    current_pods: int,
    surviving_pods: int,
    *,
    data: int,
    model: int,
) -> MeshPlan:
    """New mesh after pod loss/join.

    2 -> 1 pods collapses the pod axis (single-DC operation); N -> M keeps
    a pod axis of M.  The data/model factors within a pod are unchanged —
    intra-pod topology didn't change, only the WAN peer set did.
    """
    if surviving_pods < 1:
        raise ValueError("no survivors")
    if surviving_pods == 1:
        return MeshPlan(
            shape=(data, model), axes=("data", "model"), npods=1,
            note=f"collapsed pod axis ({current_pods}->1); WAN sync disabled",
        )
    return MeshPlan(
        shape=(surviving_pods, data, model),
        axes=("pod", "data", "model"),
        npods=surviving_pods,
        note=f"pod axis {current_pods}->{surviving_pods}",
    )


def reshard_tree(tree, new_mesh: Mesh):
    """Re-place a pytree onto a new mesh using the standard rules."""
    shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    shardings = params_shardings(shapes, new_mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), tree, shardings
    )


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str  # "pod_lost" | "pod_joined"
    pod: str
    plan: MeshPlan


class ElasticCoordinator:
    """Tracks pod membership and produces re-mesh plans on change."""

    def __init__(self, pods: List[str], *, data: int, model: int):
        self.pods = list(pods)
        self.data = data
        self.model = model
        self.events: List[ElasticEvent] = []

    def on_pod_lost(self, pod: str, step: int) -> MeshPlan:
        if pod in self.pods:
            self.pods.remove(pod)
        plan = plan_remesh(
            len(self.pods) + 1, len(self.pods), data=self.data, model=self.model
        )
        self.events.append(ElasticEvent(step=step, kind="pod_lost", pod=pod, plan=plan))
        return plan

    def on_pod_joined(self, pod: str, step: int) -> MeshPlan:
        if pod not in self.pods:
            self.pods.append(pod)
        plan = plan_remesh(
            len(self.pods) - 1, len(self.pods), data=self.data, model=self.model
        )
        self.events.append(ElasticEvent(step=step, kind="pod_joined", pod=pod, plan=plan))
        return plan
