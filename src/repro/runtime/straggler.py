"""Straggler detection and mitigation for synchronized geo-training.

Synchronous data parallelism runs at the speed of the slowest pod; over a
WAN (paper §2.1) transient slowdowns are routine (ECMP collisions, path
flaps).  This module tracks per-worker step times (EWMA + variance),
flags stragglers, and picks a mitigation:

* ``rebalance``   — re-chunk WAN flows (more QP channels, Algorithm 1
                    spreading) when slowness correlates with WAN time;
* ``local_sgd``   — drop to periodic sync (DiLoCo) when one pod is
                    persistently slow: it stops gating every step;
* ``exclude``     — declare the worker failed (hand to failure.py) when
                    slowness exceeds the dead threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class WorkerTiming:
    ewma_s: float = 0.0
    var: float = 0.0
    samples: int = 0

    def update(self, value: float, alpha: float = 0.2) -> None:
        if self.samples == 0:
            self.ewma_s = value
        else:
            delta = value - self.ewma_s
            self.ewma_s += alpha * delta
            self.var = (1 - alpha) * (self.var + alpha * delta * delta)
        self.samples += 1


@dataclasses.dataclass(frozen=True)
class StragglerReport:
    worker: str
    ratio: float  # worker ewma / median ewma
    action: str  # none | rebalance | local_sgd | exclude


class StragglerMonitor:
    def __init__(
        self,
        workers: List[str],
        *,
        slow_ratio: float = 1.5,
        persistent_ratio: float = 2.5,
        dead_ratio: float = 10.0,
        min_samples: int = 5,
    ):
        self.timings: Dict[str, WorkerTiming] = {w: WorkerTiming() for w in workers}
        self.slow_ratio = slow_ratio
        self.persistent_ratio = persistent_ratio
        self.dead_ratio = dead_ratio
        self.min_samples = min_samples

    def record(self, worker: str, step_seconds: float) -> None:
        self.timings[worker].update(step_seconds)

    def median_ewma(self) -> float:
        vals = [t.ewma_s for t in self.timings.values() if t.samples > 0]
        return float(np.median(vals)) if vals else 0.0

    def reports(self) -> List[StragglerReport]:
        med = self.median_ewma()
        out = []
        for w, t in self.timings.items():
            if t.samples < self.min_samples or med <= 0:
                continue
            ratio = t.ewma_s / med
            if ratio >= self.dead_ratio:
                action = "exclude"
            elif ratio >= self.persistent_ratio:
                action = "local_sgd"
            elif ratio >= self.slow_ratio:
                action = "rebalance"
            else:
                action = "none"
            if action != "none":
                out.append(StragglerReport(worker=w, ratio=ratio, action=action))
        return out

    def critical_path_s(self) -> float:
        vals = [t.ewma_s for t in self.timings.values() if t.samples > 0]
        return max(vals) if vals else 0.0

    def sync_efficiency(self) -> float:
        """median/max: fraction of time the fleet isn't waiting."""
        med, worst = self.median_ewma(), self.critical_path_s()
        return med / worst if worst > 0 else 1.0
