"""Training-process failure detection — the BFD insight applied upward.

The paper shows (Figs 9/13) that detection latency, not reroute cost,
dominates recovery: default BGP hold timers take 180 s while BFD's
aggressive keepalives converge in ~110 ms.  The training runtime has the
same structure: a pod that stops sending heartbeats must be declared dead
after ``interval * multiplier`` — not after an RPC timeout minutes later —
so the job can restore-and-remesh with minimal lost work.

:class:`HeartbeatMonitor` is that state machine (simulated clock, same
semantics as :class:`repro.core.bfd.BfdSession`), and
:class:`RecoveryPlan` quantifies the paper's economics: lost work =
steps since last checkpoint + detection + restore + re-mesh.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List

from repro.core.bfd import BfdSession, BfdState


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerHealth:
    name: str
    session: BfdSession
    state: WorkerState = WorkerState.HEALTHY


class HeartbeatMonitor:
    """BFD-style liveness over training workers (pods or hosts)."""

    def __init__(
        self,
        workers: List[str],
        *,
        interval_ms: float = 100.0,
        detect_mult: int = 3,
        start_ms: float = 0.0,
    ):
        self.workers: Dict[str, WorkerHealth] = {}
        for w in workers:
            s = BfdSession("monitor", w, interval_ms=interval_ms, detect_mult=detect_mult)
            s.bring_up(start_ms)
            self.workers[w] = WorkerHealth(name=w, session=s)

    def heartbeat(self, worker: str, now_ms: float) -> None:
        wh = self.workers[worker]
        wh.session.on_rx(now_ms)
        if wh.state != WorkerState.DEAD:
            wh.state = WorkerState.HEALTHY

    def poll(self, now_ms: float) -> List[str]:
        """Advance timers; returns newly dead workers."""
        newly_dead = []
        for wh in self.workers.values():
            if wh.state == WorkerState.DEAD:
                continue
            if wh.session.poll(now_ms) == BfdState.DOWN:
                wh.state = WorkerState.DEAD
                newly_dead.append(wh.name)
            elif now_ms - wh.session.last_rx_ms > wh.session.interval_ms * 1.5:
                wh.state = WorkerState.SUSPECT
            else:
                # heartbeats resumed inside the suspect window: a SUSPECT
                # worker must fall back to HEALTHY even when the rx path
                # touched the session directly rather than heartbeat().
                wh.state = WorkerState.HEALTHY
        return newly_dead

    def alive(self) -> List[str]:
        return [w for w, wh in self.workers.items() if wh.state != WorkerState.DEAD]

    def detect_time_ms(self) -> float:
        any_worker = next(iter(self.workers.values()))
        return any_worker.session.detect_time_ms


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """Quantified recovery timeline after a pod/worker failure."""

    detection_s: float
    restore_s: float
    remesh_s: float
    lost_steps: int
    step_time_s: float

    @property
    def lost_work_s(self) -> float:
        return self.lost_steps * self.step_time_s

    @property
    def total_downtime_s(self) -> float:
        return self.detection_s + self.restore_s + self.remesh_s

    @property
    def total_cost_s(self) -> float:
        return self.total_downtime_s + self.lost_work_s


def plan_recovery(
    *,
    step: int,
    last_checkpoint_step: int,
    step_time_s: float,
    detect_time_ms: float,
    checkpoint_bytes: float,
    restore_bandwidth_gbps: float = 10.0,
    remesh_s: float = 30.0,
) -> RecoveryPlan:
    """Cost model used by the trainer to choose checkpoint cadence."""
    restore_s = checkpoint_bytes * 8 / (restore_bandwidth_gbps * 1e9)
    return RecoveryPlan(
        detection_s=detect_time_ms / 1e3,
        restore_s=restore_s,
        remesh_s=remesh_s,
        lost_steps=max(step - last_checkpoint_step, 0),
        step_time_s=step_time_s,
    )


def optimal_checkpoint_interval(
    *, step_time_s: float, save_overhead_s: float, mtbf_s: float
) -> int:
    """Young/Daly optimum: sqrt(2 * delta * MTBF) in steps."""
    import math

    interval_s = math.sqrt(2.0 * save_overhead_s * mtbf_s)
    return max(1, int(interval_s / max(step_time_s, 1e-9)))
