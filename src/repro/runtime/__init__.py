from .elastic import ElasticCoordinator, MeshPlan, plan_remesh, reshard_tree
from .failure import (
    HeartbeatMonitor,
    RecoveryPlan,
    WorkerState,
    optimal_checkpoint_interval,
    plan_recovery,
)
from .straggler import StragglerMonitor, StragglerReport
from .trainer import GeoTrainer, TrainerConfig

__all__ = [
    "ElasticCoordinator",
    "GeoTrainer",
    "HeartbeatMonitor",
    "MeshPlan",
    "RecoveryPlan",
    "StragglerMonitor",
    "StragglerReport",
    "TrainerConfig",
    "WorkerState",
    "optimal_checkpoint_interval",
    "plan_recovery",
    "plan_remesh",
    "reshard_tree",
]
