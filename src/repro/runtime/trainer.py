"""GeoTrainer: the end-to-end geo-distributed training loop.

Composes every substrate: model + configs, distributed step builders
(WAN sync strategies), data pipeline, AdamW/DiLoCo, checkpointing (async,
checksummed), heartbeat failure detection, straggler monitoring, elastic
re-meshing, and the ScaleAcross fabric — which supplies the *WAN cost
model* per step, so a CPU run reports the same communication economics
the paper measures on its emulated testbed (Fig. 14).

This is the driver behind ``examples/train_geo.py`` and
``launch/train.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, CheckpointStore
from repro.core.geo import GeoFabric, SyncOptions
from repro.core.schedule import CollectiveSchedule, strategy_names
from repro.data import loader_for_model
from repro.distributed import init_train_state, make_train_step
from repro.launch.shapes import params_specs
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, DilocoConfig

from .failure import HeartbeatMonitor, optimal_checkpoint_interval, plan_recovery
from .straggler import StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 128
    global_batch: int = 8
    steps: int = 100
    strategy: str = "hier"
    num_channels: int = 4
    checkpoint_every: Optional[int] = None  # None -> Young/Daly auto
    checkpoint_keep: int = 3
    log_every: int = 10
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    diloco: DilocoConfig = dataclasses.field(default_factory=DilocoConfig)
    mtbf_s: float = 6 * 3600.0  # assumed per-pod MTBF for ckpt cadence


class GeoTrainer:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        trainer_cfg: TrainerConfig,
        checkpoint_dir: str,
        geo: Optional[GeoFabric] = None,
        scenario=None,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.tc = trainer_cfg
        self.sync_options = SyncOptions(jitter=False)
        self.scenario = scenario
        if scenario is not None:
            # declarative path (repro.scenario.Scenario): the spec supplies
            # the emulated deployment, the WAN sync strategy/cadence, the
            # step budget, the costing options, and the event script
            # (replayed at step boundaries in run()).  The spec's modeling
            # fields the trainer measures for real — compute_seconds /
            # overlap_fraction / grad_bytes / model — are not consumed
            # here; straggler events only scale modeled compute, so they
            # are skipped too.  Explicit trainer_cfg fields the spec does
            # not cover (batch shape, optimizer, checkpoint cadence) are
            # kept as passed.
            if geo is not None:
                raise ValueError("pass scenario or geo, not both")
            geo = scenario.topology.build()
            wl = scenario.workload
            if wl.strategy is not None:
                # the spec is authoritative, including an explicit steps=1
                self.tc = dataclasses.replace(
                    self.tc,
                    strategy=wl.strategy,
                    num_channels=scenario.topology.num_channels,
                    steps=wl.steps,
                )
            self.sync_options = dataclasses.replace(
                scenario.options, jitter=False
            )
        self.geo = geo or GeoFabric(num_pods=max(mesh.shape.get("pod", 1), 1) + (0 if "pod" in mesh.axis_names else 1))
        self.store = CheckpointStore(checkpoint_dir, keep=trainer_cfg.checkpoint_keep)
        self.ckpt = AsyncCheckpointer(self.store)
        pods = [f"pod{i}" for i in range(mesh.shape.get("pod", 1))] or ["pod0"]
        self.heartbeats = HeartbeatMonitor(pods, interval_ms=100.0)
        self.stragglers = StragglerMonitor(pods)
        self.metrics_log: List[Dict[str, float]] = []
        self._build()

    # -- setup -----------------------------------------------------------------

    def _build(self) -> None:
        cfg, tc = self.cfg, self.tc
        self.loader = loader_for_model(
            cfg, seq_len=tc.seq_len, global_batch=tc.global_batch, seed=tc.seed
        )
        p_shapes = params_specs(cfg)
        batch_np = self.loader.next_batch()
        self.loader.step -= 1  # peek, don't consume
        batch_shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch_np
        )
        self.step_fn, self.shardings = make_train_step(
            cfg, self.mesh,
            opt_cfg=tc.opt,
            strategy=tc.strategy,
            num_channels=tc.num_channels,
            diloco_cfg=tc.diloco,
            params_shapes=p_shapes,
            batch_shapes=batch_shapes,
            donate=False,
        )
        self.grad_bytes = sum(
            int(np.prod(s.shape)) * 4 for s in jax.tree.leaves(p_shapes)
        )

    def init_or_restore(self):
        cfg, tc = self.cfg, self.tc
        params = init_params(jax.random.PRNGKey(tc.seed), cfg)
        state = init_train_state(params, tc.opt, strategy=tc.strategy)
        start_step = 0
        latest = self.store.latest_step()
        if latest is not None:
            (params, state), meta = self.store.restore(latest, (params, state))
            start_step = int(meta.get("data_step", latest))
            self.loader.step = start_step
        return params, state, start_step

    def _ckpt_interval(self, step_time_s: float) -> int:
        if self.tc.checkpoint_every is not None:
            return self.tc.checkpoint_every
        save_overhead = max(self.grad_bytes / 1e9, 0.05)  # ~1 GB/s disk
        return optimal_checkpoint_interval(
            step_time_s=max(step_time_s, 1e-3),
            save_overhead_s=save_overhead,
            mtbf_s=self.tc.mtbf_s,
        )

    # -- the loop -----------------------------------------------------------------

    def run(
        self,
        *,
        on_step: Optional[Callable[[int, Dict[str, float]], None]] = None,
        inject_failure_at: Optional[int] = None,
    ) -> Dict[str, Any]:
        params, state, start = self.init_or_restore()
        tc = self.tc
        last_ckpt = start
        # WAN cost estimate via the schedule-strategy registry.  Note
        # make_train_step currently restricts tc.strategy to the paper five
        # (all registered), so today this always costs; the registry check
        # keeps the estimate in sync if the step builders grow strategies
        # that have no schedule (or vice versa).
        wan_cost = (
            self.geo.sync_cost(
                tc.strategy,
                self.grad_bytes,
                options=dataclasses.replace(self.sync_options, jitter=False),
            )
            if isinstance(tc.strategy, CollectiveSchedule)
            or tc.strategy in strategy_names()
            else None
        )
        recovery_drills = []
        # scenario event script, replayed at step boundaries (straggler
        # events scale *modeled* compute only, so the trainer skips them —
        # its compute is measured for real)
        events_by_step: Dict[int, list] = {}
        scenario_rollup = None
        apply_event = None
        straggler_noop: Dict[int, float] = {}
        if self.scenario is not None and self.scenario.events:
            from repro.scenario.runner import ScenarioResult, apply_event

            scenario_rollup = ScenarioResult(
                scenario=self.scenario, steps=[], sync=None, geo=self.geo
            )
            for ev in self.scenario.events:
                if ev.kind != "straggler":
                    events_by_step.setdefault(ev.at_step, []).append(ev)
        t_step_ewma = None
        # simulated heartbeat clock: one beat interval per training step, so
        # detection semantics are step-count-based (detect_mult missed
        # steps) regardless of wall-clock step duration.
        interval_ms = next(iter(self.heartbeats.workers.values())).session.interval_ms
        sim_ms = 0.0
        with self.mesh:
            for step in range(start, tc.steps):
                for ev in events_by_step.get(step, ()):
                    apply_event(ev, self.geo, scenario_rollup, straggler_noop)
                batch = {k: jnp.asarray(v) for k, v in self.loader.next_batch().items()}
                t0 = time.time()
                params, state, metrics = self.step_fn(params, state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                t_step_ewma = dt if t_step_ewma is None else 0.8 * t_step_ewma + 0.2 * dt

                sim_ms += interval_ms
                for pod in self.heartbeats.workers:
                    if inject_failure_at is not None and step >= inject_failure_at and pod == "pod1":
                        continue  # pod1 goes silent
                    self.heartbeats.heartbeat(pod, sim_ms)
                    self.stragglers.record(pod, dt)
                # +1 ms epsilon: a pod missing detect_mult consecutive beats
                # is declared dead on exactly that step
                dead = self.heartbeats.poll(sim_ms + 1.0)
                if dead:
                    plan = plan_recovery(
                        step=step,
                        last_checkpoint_step=last_ckpt,
                        step_time_s=t_step_ewma or dt,
                        detect_time_ms=self.heartbeats.detect_time_ms(),
                        checkpoint_bytes=self.grad_bytes * 3,
                    )
                    recovery_drills.append({"step": step, "dead": dead, "plan": dataclasses.asdict(plan)})
                    inject_failure_at = None  # handled

                row = {
                    "step": step,
                    "loss": loss,
                    "step_s": dt,
                    "grad_norm": float(metrics.get("grad_norm", 0.0)),
                    "wan_s_est": wan_cost.amortized_seconds if wan_cost else 0.0,
                }
                self.metrics_log.append(row)
                if on_step:
                    on_step(step, row)
                if step % tc.log_every == 0:
                    print(
                        f"step {step:5d} loss {loss:7.4f} "
                        f"({dt:5.2f}s compute, +{row['wan_s_est']:.2f}s WAN est "
                        f"[{tc.strategy}])",
                        flush=True,
                    )
                interval = self._ckpt_interval(t_step_ewma or dt)
                if (step + 1) % max(interval, 1) == 0 or step == tc.steps - 1:
                    self.ckpt.save(
                        step + 1, (params, state), metadata={"data_step": step + 1}
                    )
                    last_ckpt = step + 1
        self.ckpt.wait()
        return {
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "metrics": self.metrics_log,
            "recovery_drills": recovery_drills,
            "sync_efficiency": self.stragglers.sync_efficiency(),
            "last_checkpoint": last_ckpt,
            "wan_phases": (
                {p.name: p.duration_s for p in wan_cost.phases} if wan_cost else {}
            ),
            "scenario_recoveries": (
                [
                    {"mechanism": t.mechanism, "recovery_ms": t.recovery_ms}
                    for t in scenario_rollup.recoveries
                ]
                if scenario_rollup is not None
                else []
            ),
            "scenario_evpn_resyncs": (
                len(scenario_rollup.evpn_resyncs)
                if scenario_rollup is not None
                else 0
            ),
        }
