"""Seeded-random fallback for the ``hypothesis`` property-testing API.

The test suite uses a small slice of hypothesis: ``@given`` over
``st.integers`` / ``st.floats`` / ``st.lists`` / ``st.sampled_from`` /
``st.booleans`` / ``st.tuples`` / ``st.just`` / ``st.one_of`` /
``st.composite`` plus
``@settings(max_examples=..., deadline=...)``.  When the real package is
not installed, :func:`install` registers this module under
``sys.modules["hypothesis"]`` so the test modules import and *run* instead
of dying at collection.

Semantics: each ``@given`` test is executed ``max_examples`` times with
arguments drawn from a PRNG seeded by the test's qualified name, so runs
are deterministic across invocations.  The first two examples pin each
strategy to its low/high boundary values (where hypothesis's shrinker
would usually end up), the rest are uniform draws.  No shrinking, no
database — a deliberate trade: deterministic coverage over minimal
counterexamples.
"""

from __future__ import annotations

import functools
import random
import sys
import types
import zlib
from typing import Any, List, Optional, Sequence

DEFAULT_MAX_EXAMPLES = 50


class SearchStrategy:
    """Base strategy: boundary examples first, then seeded uniform draws."""

    def example(self, rng: random.Random, index: int) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: Optional[int] = None, max_value: Optional[int] = None):
        self.min_value = -(2**63) if min_value is None else min_value
        self.max_value = 2**63 - 1 if max_value is None else max_value

    def example(self, rng: random.Random, index: int) -> int:
        if index == 0:
            return self.min_value
        if index == 1:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class _Floats(SearchStrategy):
    def __init__(
        self,
        min_value: Optional[float] = None,
        max_value: Optional[float] = None,
        allow_nan: bool = False,
        allow_infinity: bool = False,
        **_: Any,
    ):
        self.min_value = -1e9 if min_value is None else float(min_value)
        self.max_value = 1e9 if max_value is None else float(max_value)

    def example(self, rng: random.Random, index: int) -> float:
        if index == 0:
            return self.min_value
        if index == 1:
            return self.max_value
        return rng.uniform(self.min_value, self.max_value)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example(self, rng: random.Random, index: int) -> Any:
        if index == 0:
            return self.elements[0]
        if index == 1:
            return self.elements[-1]
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(
        self,
        elements: SearchStrategy,
        min_size: int = 0,
        max_size: Optional[int] = None,
        **_: Any,
    ):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng: random.Random, index: int) -> List[Any]:
        size = self.min_size if index == 0 else (
            self.max_size if index == 1 else rng.randint(self.min_size, self.max_size)
        )
        return [self.elements.example(rng, 2 + i) for i in range(size)]


class _Booleans(SearchStrategy):
    def example(self, rng: random.Random, index: int) -> bool:
        if index in (0, 1):
            return bool(index)
        return rng.random() < 0.5


class _Tuples(SearchStrategy):
    def __init__(self, *strategies: SearchStrategy):
        self.strategies = strategies

    def example(self, rng: random.Random, index: int) -> tuple:
        return tuple(s.example(rng, index) for s in self.strategies)


class _Just(SearchStrategy):
    def __init__(self, value: Any):
        self.value = value

    def example(self, rng: random.Random, index: int) -> Any:
        return self.value


class _OneOf(SearchStrategy):
    """Uniform choice between branch strategies; boundary indices pin the
    first/last branch (where hypothesis's shrinker tends to land)."""

    def __init__(self, *strategies: SearchStrategy):
        self.strategies = strategies

    def example(self, rng: random.Random, index: int) -> Any:
        if index == 0:
            branch = self.strategies[0]
        elif index == 1:
            branch = self.strategies[-1]
        else:
            branch = rng.choice(self.strategies)
        return branch.example(rng, index)


def integers(min_value: Optional[int] = None, max_value: Optional[int] = None) -> _Integers:
    return _Integers(min_value, max_value)


def floats(*args: Any, **kwargs: Any) -> _Floats:
    return _Floats(*args, **kwargs)


def sampled_from(elements: Sequence[Any]) -> _SampledFrom:
    return _SampledFrom(elements)


def lists(elements: SearchStrategy, **kwargs: Any) -> _Lists:
    return _Lists(elements, **kwargs)


def booleans() -> _Booleans:
    return _Booleans()


def tuples(*strategies: SearchStrategy) -> _Tuples:
    return _Tuples(*strategies)


def just(value: Any) -> _Just:
    return _Just(value)


def one_of(*strategies: SearchStrategy) -> _OneOf:
    return _OneOf(*strategies)


class _CompositeStrategy(SearchStrategy):
    """Strategy built by a ``@composite`` function calling ``draw``."""

    def __init__(self, fn, args: tuple, kwargs: dict):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs

    def example(self, rng: random.Random, index: int) -> Any:
        def draw(strategy: SearchStrategy) -> Any:
            return strategy.example(rng, index)

        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    """``@st.composite``: ``fn(draw, *args)`` becomes a strategy factory.

    Matches the real API shape — the decorated function is *called* (with
    any extra arguments) to produce a strategy; inside, ``draw(strategy)``
    yields one example.  Boundary indices propagate to every inner draw,
    so index 0/1 still pin each sub-strategy to its min/max example.
    """

    @functools.wraps(fn)
    def builder(*args: Any, **kwargs: Any) -> _CompositeStrategy:
        return _CompositeStrategy(fn, args, kwargs)

    return builder


def settings(**config: Any):
    """Decorator recording execution knobs for a later ``@given``."""

    def decorate(fn):
        fn._fallback_settings = config
        return fn

    return decorate


def given(*strategies: SearchStrategy):
    """Run the test ``max_examples`` times with seeded strategy draws."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None)
            if cfg is None:
                cfg = getattr(fn, "_fallback_settings", {})
            max_examples = int(cfg.get("max_examples", DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for index in range(max_examples):
                drawn = [s.example(rng, index) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as exc:  # annotate, like hypothesis's falsifying example
                    raise AssertionError(
                        f"falsifying example (fallback, draw {index}): "
                        f"{fn.__qualname__}{tuple(drawn)!r}"
                    ) from exc

        # pytest must not treat the drawn parameters as fixtures: expose a
        # bare (*args, **kwargs) signature instead of the wrapped one.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401 — the real package wins when present

        return
    except ImportError:
        pass
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__doc__ = __doc__
    hyp.__fallback__ = True
    strat = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers",
        "floats",
        "sampled_from",
        "lists",
        "booleans",
        "tuples",
        "just",
        "one_of",
        "composite",
    ):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
