"""Test-support utilities (importable with ``PYTHONPATH=src``).

Currently hosts the seeded-random :mod:`hypothesis` fallback used by the
test suite when the real package is not installed (the container image
does not ship it); see :mod:`repro.testing.hypothesis_fallback`.
"""

from . import hypothesis_fallback

__all__ = ["hypothesis_fallback"]
