from .store import AsyncCheckpointer, CheckpointInfo, CheckpointStore

__all__ = ["AsyncCheckpointer", "CheckpointInfo", "CheckpointStore"]
