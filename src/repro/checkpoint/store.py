"""Fault-tolerant checkpointing: atomic, checksummed, async, GC'd.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json     # tree structure, leaf paths, crc32s, metadata
        arr_00000.npy ... # one file per leaf (host's shard view)
    <root>/step_000123.COMMITTED   # atomic commit marker

Writes go to ``step_X.tmp-<pid>`` and are renamed into place, then the
commit marker is written — a crashed writer can never produce a
checkpoint that ``latest_step`` would pick up.  Every leaf carries a
crc32; restore verifies and raises on corruption.  ``AsyncCheckpointer``
snapshots to host memory synchronously (cheap) and writes on a worker
thread so the train loop never blocks on disk.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    named = []
    for (path, leaf) in paths:
        key = jax.tree_util.keystr(path)
        named.append((key, np.asarray(leaf)))
    return named, treedef


@dataclasses.dataclass
class CheckpointInfo:
    step: int
    path: Path
    metadata: Dict[str, Any]


class CheckpointStore:
    """``clock`` is the store's only wall-clock seam: it stamps
    ``written_at`` in the manifest and the commit-marker content.
    Recovery drills pin it (``clock=lambda: t``) so checkpoint metadata
    is reproducible; the default is real time."""

    def __init__(
        self,
        root: str | Path,
        *,
        keep: int = 3,
        clock: Callable[[], float] = time.time,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.clock = clock

    # -- paths ---------------------------------------------------------------

    def _dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def _marker(self, step: int) -> Path:
        return self.root / f"step_{step:08d}.COMMITTED"

    def steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*.COMMITTED"):
            try:
                out.append(int(p.stem.split("_")[1].split(".")[0]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[Dict[str, Any]] = None) -> CheckpointInfo:
        named, _ = _flatten(tree)
        tmp = self.root / f"step_{step:08d}.tmp-{os.getpid()}-{threading.get_ident()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: Dict[str, Any] = {
            "step": step,
            "metadata": metadata or {},
            "leaves": [],
            "written_at": self.clock(),
        }
        for i, (key, arr) in enumerate(named):
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {
                    "key": key,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                }
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = self._dir(step)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._marker(step).write_text(str(self.clock()))
        self.gc()
        return CheckpointInfo(step=step, path=final, metadata=manifest["metadata"])

    # -- restore -----------------------------------------------------------------

    def restore(self, step: int, like) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  Verifies checksums; raises on mismatch."""
        d = self._dir(step)
        if not self._marker(step).exists():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        manifest = json.loads((d / "manifest.json").read_text())
        named_like, treedef = _flatten(
            jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), like)
        )
        by_key = {entry["key"]: entry for entry in manifest["leaves"]}
        leaves = []
        for key, placeholder in named_like:
            entry = by_key.get(key)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(d / entry["file"])
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != entry["crc32"]:
                raise IOError(
                    f"checksum mismatch for {key}: file corrupt "
                    f"({crc} != {entry['crc32']})"
                )
            if list(arr.shape) != list(placeholder.shape):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {placeholder.shape}"
                )
            leaves.append(arr)
        return jax.tree.unflatten(treedef, leaves), manifest["metadata"]

    def restore_latest(self, like):
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree, meta = self.restore(step, like)
        return step, tree, meta

    # -- gc -------------------------------------------------------------------

    def gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
            self._marker(s).unlink(missing_ok=True)


class AsyncCheckpointer:
    """Snapshot-now, write-later checkpointing on a worker thread."""

    def __init__(self, store: CheckpointStore):
        self.store = store
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, metadata=None) -> None:
        self.wait()  # one in flight at a time
        snapshot = jax.tree.map(lambda a: np.array(a, copy=True), tree)

        def work():
            try:
                self.store.save(step, snapshot, metadata)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
