"""Vectorized flow-level congestion model (paper §5.5 / Fig. 14).

The paper observes that during geo-distributed training the spine WAN
links saturate at an *effective* ~800 Mbit/s (§5.5) and that per-collective
batch times (Fig. 14) are set by how flows share those bottlenecks — not by
the fabric's ideal bisection bandwidth.  :class:`~repro.core.wan.WanTimingModel`'s
original fluid estimate divides each link's aggregate bytes by its capacity,
which is exact only when every flow on the bottleneck starts and ends
together.  This module refines that into a *flow-level* model:

* :func:`build_link_load_matrix` — turn the per-flow directed-link paths
  recorded by :meth:`repro.core.fabric.Fabric.route_flows_with_paths` into a
  factorized flow x link incidence (CSR-style membership arrays) annotated
  with per-link netem capacity and propagation;
* :func:`max_min_rates` — progressive-filling max-min fair allocation
  ("I've Got 99 Problems But FLOPS Ain't One", arXiv:2407.12819, argues WAN
  bottleneck share is the quantity that determines geo step time): every
  round all unfrozen flows rise together until the tightest link saturates,
  freezing its flows at the current level; each round is pure NumPy
  (``bincount`` / boolean masks) over the membership arrays, so 10k+ flows
  allocate in a handful of array ops per bottleneck level;
* :func:`congestion_report` — per-flow completion time
  (``bytes / fair rate`` + propagation along the recorded path, the Corning
  fiber-latency argument of arXiv:2605.19169) and per-link throughput /
  utilization, including the paper's effective-WAN-throughput observable:
  a saturated spine WAN link carries exactly its ~800 Mbit/s capacity
  no matter how many flows contend for it.

Wired into :meth:`repro.core.wan.WanTimingModel.contended_transfer_time`
(and from there ``GeoFabric.sync_cost(congestion=True)``) so Fig. 14-style
per-collective timings reflect contention rather than ideal bisection.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .fabric import Fabric, FlowPaths, Link

#: Relative tolerance for deciding a link saturated at this filling level.
_SATURATION_RTOL = 1e-9


@dataclass(frozen=True)
class LinkLoadMatrix:
    """Factorized flow x link incidence with per-link netem attributes.

    Row ``r`` says flow ``mem_flow[r]`` traverses link ``mem_link[r]``
    (an index into ``links``).  ``delay_ms`` is the one-way propagation of
    a single traversal — two netem qdisc passes, as in
    :meth:`repro.core.wan.Netem.one_way_delay_ms` (jitter-free).
    """

    mem_flow: np.ndarray  # (R,) int64
    mem_link: np.ndarray  # (R,) int64 indices into ``links``
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,) float64
    delay_ms: np.ndarray  # (L,) float64, per single traversal (2 passes)
    is_wan: np.ndarray  # (L,) bool
    num_flows: int
    hops_per_flow: np.ndarray  # (F,) int64 links traversed per flow


def build_link_load_matrix(
    fabric: Fabric, netem, paths: FlowPaths
) -> LinkLoadMatrix:
    """Factorize recorded flow paths into a :class:`LinkLoadMatrix`.

    ``netem`` is a :class:`repro.core.wan.Netem` (typed loosely to keep the
    module import-cycle-free); capacity and delay come from its per-link
    profiles, exactly as the ideal fluid model uses them.
    """
    nflows = paths.num_flows
    n = len(paths.nodes)
    keys = paths.link_u * n + paths.link_v
    uniq, mem_link = np.unique(keys, return_inverse=True)
    links = tuple(
        (paths.nodes[int(k) // n], paths.nodes[int(k) % n]) for k in uniq
    )
    capacity = np.empty(len(links))
    delay = np.empty(len(links))
    is_wan = np.zeros(len(links), dtype=bool)
    for i, (u, v) in enumerate(links):
        prof = netem.profile(u, v)
        capacity[i] = prof.bandwidth_gbps
        delay[i] = 2.0 * prof.delay_ms  # netem qdisc on both interfaces
        is_wan[i] = fabric.is_wan_link(u, v)
    hops = np.diff(paths.ptr)
    mem_flow = np.repeat(np.arange(nflows, dtype=np.int64), hops)
    return LinkLoadMatrix(
        mem_flow=mem_flow,
        mem_link=mem_link.astype(np.int64),
        links=links,
        capacity_gbps=capacity,
        delay_ms=delay,
        is_wan=is_wan,
        num_flows=nflows,
        hops_per_flow=hops.astype(np.int64),
    )


def max_min_rates(matrix: LinkLoadMatrix) -> np.ndarray:
    """Max-min fair per-flow rates (Gbit/s) by vectorized water-filling.

    Progressive filling: all unfrozen flows increase at the same rate; the
    link minimizing ``residual capacity / unfrozen flow count`` saturates
    first and freezes its flows at the current level.  Terminates in at
    most ``len(links)`` rounds (>=1 link saturates per round); each round
    is O(active memberships) in NumPy with frozen rows compacted away.
    """
    nflows, nlinks = matrix.num_flows, len(matrix.links)
    rate = np.zeros(nflows)
    mem_f, mem_l = matrix.mem_flow, matrix.mem_link
    if nflows == 0 or mem_f.size == 0:
        return rate
    resid = matrix.capacity_gbps.astype(np.float64).copy()
    level = 0.0
    for _ in range(nlinks + 1):
        if mem_f.size == 0:
            break
        n_l = np.bincount(mem_l, minlength=nlinks)
        has = n_l > 0
        share = np.full(nlinks, np.inf)
        share[has] = np.maximum(resid[has], 0.0) / n_l[has]
        step = float(share.min())
        if not np.isfinite(step):
            break
        level += step
        resid -= step * n_l
        saturated = has & (share <= step * (1.0 + _SATURATION_RTOL))
        newly = np.unique(mem_f[saturated[mem_l]])
        rate[newly] = level
        keep = ~np.isin(mem_f, newly)
        mem_f, mem_l = mem_f[keep], mem_l[keep]
    if mem_f.size:  # numerical stragglers: freeze at the final level
        rate[np.unique(mem_f)] = level
    return rate


@dataclass(frozen=True)
class CongestionReport:
    """Per-flow rates/completions and per-link throughput under contention."""

    rates_gbps: np.ndarray  # (F,) max-min fair allocation
    completion_s: np.ndarray  # (F,) transfer + propagation
    propagation_ms: np.ndarray  # (F,) one-way path propagation
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,)
    throughput_gbps: np.ndarray  # (L,) sum of allocated rates on the link
    is_wan: np.ndarray  # (L,) bool

    @property
    def seconds(self) -> float:
        """Completion time of the whole flow set (slowest flow)."""
        return float(self.completion_s.max()) if self.completion_s.size else 0.0

    @property
    def utilization(self) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(
                self.capacity_gbps > 0, self.throughput_gbps / self.capacity_gbps, 0.0
            )
        return out

    @property
    def bottleneck_link(self) -> Optional[Link]:
        if not self.links:
            return None
        return self.links[int(np.argmax(self.utilization))]

    @property
    def effective_wan_gbps(self) -> float:
        """Peak per-link WAN throughput — the paper's §5.5 observable
        (~0.8 Gbit/s on a contended spine WAN link)."""
        if not bool(self.is_wan.any()):
            return 0.0
        return float(self.throughput_gbps[self.is_wan].max())


def congestion_report(
    matrix: LinkLoadMatrix, nbytes: Sequence[int]
) -> CongestionReport:
    """Allocate rates and estimate per-flow completion + propagation.

    ``completion = bytes * 8 / rate + one-way propagation`` where the
    propagation sums the recorded path's per-link netem delays (two qdisc
    passes each) plus per-transit-switch forwarding latency — the same
    terms :func:`repro.core.wan.ping_rtt` samples, minus jitter.
    """
    from .wan import SWITCH_FORWARDING_MS  # local: wan imports this module

    nb = np.asarray(list(nbytes), dtype=np.float64)
    if nb.size != matrix.num_flows:
        raise ValueError(
            f"{nb.size} byte counts for {matrix.num_flows} recorded paths"
        )
    rate = max_min_rates(matrix)
    prop = np.zeros(matrix.num_flows)
    np.add.at(prop, matrix.mem_flow, matrix.delay_ms[matrix.mem_link])
    prop += np.maximum(matrix.hops_per_flow - 1, 0) * SWITCH_FORWARDING_MS
    with np.errstate(divide="ignore", invalid="ignore"):
        transfer = np.where(nb > 0, nb * 8.0 / (rate * 1e9), 0.0)
    throughput = np.bincount(
        matrix.mem_link, weights=rate[matrix.mem_flow], minlength=len(matrix.links)
    )
    return CongestionReport(
        rates_gbps=rate,
        completion_s=transfer + prop / 1e3,
        propagation_ms=prop,
        links=matrix.links,
        capacity_gbps=matrix.capacity_gbps,
        throughput_gbps=throughput,
        is_wan=matrix.is_wan,
    )


def route_and_analyze(
    fabric: Fabric,
    netem,
    flows: Sequence,
    *,
    check_reachability=None,
    reset_counters: bool = True,
) -> Tuple[Dict[Link, int], CongestionReport]:
    """Route ``flows`` with path recording and run the congestion model.

    Returns the batch's link byte counters (same contract as
    :func:`repro.core.flows.route_flows_batched`, including the optional
    counter reset) alongside the :class:`CongestionReport`.
    """
    flows = list(flows)  # consumed twice: routing, then per-flow byte counts
    if reset_counters:
        fabric.reset_counters()
    link_bytes, paths = fabric.route_flows_with_paths(
        flows, check_reachability=check_reachability
    )
    matrix = build_link_load_matrix(fabric, netem, paths)
    report = congestion_report(matrix, [f.nbytes for f in flows])
    return link_bytes, report
