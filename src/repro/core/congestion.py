"""Vectorized flow-level congestion model (paper §5.5 / Fig. 14).

The paper observes that during geo-distributed training the spine WAN
links saturate at an *effective* ~800 Mbit/s (§5.5) and that per-collective
batch times (Fig. 14) are set by how flows share those bottlenecks — not by
the fabric's ideal bisection bandwidth.  :class:`~repro.core.wan.WanTimingModel`'s
original fluid estimate divides each link's aggregate bytes by its capacity,
which is exact only when every flow on the bottleneck starts and ends
together.  This module refines that into a *flow-level* model:

* :func:`build_link_load_matrix` — turn the per-flow directed-link paths
  recorded by :meth:`repro.core.fabric.Fabric.route_flows_with_paths` into a
  factorized flow x link incidence (CSR-style membership arrays) annotated
  with per-link netem capacity and propagation;
* :func:`max_min_rates` — progressive-filling max-min fair allocation
  ("I've Got 99 Problems But FLOPS Ain't One", arXiv:2407.12819, argues WAN
  bottleneck share is the quantity that determines geo step time): every
  round all unfrozen flows rise together until the tightest link saturates,
  freezing its flows at the current level; each round is pure NumPy
  (``bincount`` / boolean masks) over the membership arrays, so 10k+ flows
  allocate in a handful of array ops per bottleneck level.  With a
  ``weights`` vector the filling is *weighted*: flow ``f`` rises at
  ``weights[f]`` times the common level, so its share of any saturated
  link is proportional to its weight (uniform weights reproduce the
  unweighted allocator byte-for-byte);
* :func:`ecmp_flow_weights` — ECMP-awareness for the weighted allocator
  (paper §4, §5.5): :meth:`repro.core.fabric.Fabric.route_flows_with_paths`
  records each traversal's hash-slot occupancy (how many flows of the
  batch hashed into the same :data:`repro.core.fabric.ECMP_HASH_BUCKETS`
  bucket of the same member link); flows sharing a slot are one scheduling
  entity to the switch pipeline, so a flow colliding with ``k - 1`` others
  at its worst hop carries weight ``1 / k`` — the hash-skew contention the
  paper's queue-pair-aware port allocation exists to avoid, now expressed
  as allocation weights instead of being invisible to the fair-share
  model;
* :func:`congestion_report` — per-flow completion time
  (``bytes / fair rate`` + propagation along the recorded path, the Corning
  fiber-latency argument of arXiv:2605.19169) and per-link throughput /
  utilization, including the paper's effective-WAN-throughput observable:
  a saturated spine WAN link carries exactly its ~800 Mbit/s capacity
  no matter how many flows contend for it.

* :func:`simulate_schedule` — the event-driven *time-varying* extension:
  a :class:`repro.core.schedule.CollectiveSchedule` DAG is replayed as a
  fluid simulation in which phases start when their dependencies complete,
  the max-min allocation is re-solved (over the active flows' CSR
  membership rows) at every flow arrival/completion event, and the
  :class:`ScheduleReport` carries per-phase/per-flow timelines.  A
  single-phase schedule reproduces :func:`congestion_report` exactly.

Wired into :meth:`repro.core.wan.WanTimingModel.contended_transfer_time`
/ :meth:`~repro.core.wan.WanTimingModel.contended_schedule_time` (and from
there ``GeoFabric.sync_cost(congestion=True)``) so Fig. 14-style
per-collective timings reflect contention rather than ideal bisection.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fabric import Fabric, FlowPaths, Link

#: Relative tolerance for deciding a link saturated at this filling level.
_SATURATION_RTOL = 1e-9


@dataclass(frozen=True)
class LinkLoadMatrix:
    """Factorized flow x link incidence with per-link netem attributes.

    Row ``r`` says flow ``mem_flow[r]`` traverses link ``mem_link[r]``
    (an index into ``links``).  ``delay_ms`` is the one-way propagation of
    a single traversal — two netem qdisc passes, as in
    :meth:`repro.core.wan.Netem.one_way_delay_ms` (jitter-free).

    ``slot_occ`` (row-aligned) carries the recorded ECMP hash-slot
    occupancy of each traversal when the paths were recorded by the
    batched router (ones when unavailable); ``slot_key`` the slot
    *identity* of each ECMP traversal (-1 for non-ECMP hops), letting
    occupancy be recounted over flow subsets — see
    :class:`repro.core.fabric.FlowPaths`.
    """

    mem_flow: np.ndarray  # (R,) int64
    mem_link: np.ndarray  # (R,) int64 indices into ``links``
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,) float64
    delay_ms: np.ndarray  # (L,) float64, per single traversal (2 passes)
    is_wan: np.ndarray  # (L,) bool
    num_flows: int
    hops_per_flow: np.ndarray  # (F,) int64 links traversed per flow
    slot_occ: Optional[np.ndarray] = None  # (R,) int64 hash-slot occupancy
    slot_key: Optional[np.ndarray] = None  # (R,) int64 slot identity, -1 = none

    @property
    def max_slot_occ(self) -> np.ndarray:
        """Per-link worst hash-slot occupancy — the observed ECMP hash
        imbalance (1 everywhere when no collision was recorded)."""
        out = np.ones(len(self.links), dtype=np.int64)
        if self.slot_occ is not None and self.mem_link.size:
            np.maximum.at(out, self.mem_link, self.slot_occ)
        return out


def build_link_load_matrix(
    fabric: Fabric, netem, paths: FlowPaths
) -> LinkLoadMatrix:
    """Factorize recorded flow paths into a :class:`LinkLoadMatrix`.

    ``netem`` is a :class:`repro.core.wan.Netem` (typed loosely to keep the
    module import-cycle-free); capacity and delay come from its per-link
    profiles, exactly as the ideal fluid model uses them.
    """
    nflows = paths.num_flows
    n = len(paths.nodes)
    keys = paths.link_u * n + paths.link_v
    uniq, mem_link = np.unique(keys, return_inverse=True)
    links = tuple(
        (paths.nodes[int(k) // n], paths.nodes[int(k) % n]) for k in uniq
    )
    capacity = np.empty(len(links))
    delay = np.empty(len(links))
    is_wan = np.zeros(len(links), dtype=bool)
    for i, (u, v) in enumerate(links):
        prof = netem.profile(u, v)
        capacity[i] = prof.effective_bandwidth_gbps
        delay[i] = 2.0 * prof.delay_ms  # netem qdisc on both interfaces
        is_wan[i] = fabric.is_wan_link(u, v)
    hops = np.diff(paths.ptr)
    mem_flow = np.repeat(np.arange(nflows, dtype=np.int64), hops)
    return LinkLoadMatrix(
        mem_flow=mem_flow,
        mem_link=mem_link.astype(np.int64),
        links=links,
        capacity_gbps=capacity,
        delay_ms=delay,
        is_wan=is_wan,
        num_flows=nflows,
        hops_per_flow=hops.astype(np.int64),
        slot_occ=paths.slot_occ,
        slot_key=paths.slot_key,
    )


def ecmp_flow_weights(paths) -> np.ndarray:
    """Per-flow allocation weights from observed ECMP hash imbalance.

    ``paths`` is a :class:`repro.core.fabric.FlowPaths` (or a
    :class:`LinkLoadMatrix` built from one).  A flow whose worst traversal
    shares its hash slot with ``k - 1`` other flows weighs ``1 / k``:
    same-slot flows are one entity to the switch's hash pipeline, so they
    split one slot's service among themselves wherever bandwidth gets
    scarce.  Flows that never collide weigh 1.0, and a batch with no
    collisions yields the uniform vector — whose weighted allocation is
    byte-identical to the unweighted one.
    """
    if isinstance(paths, LinkLoadMatrix):
        nflows, occ, mem_flow = paths.num_flows, paths.slot_occ, paths.mem_flow
    else:
        nflows = paths.num_flows
        occ = paths.slot_occ
        mem_flow = np.repeat(
            np.arange(nflows, dtype=np.int64), np.diff(paths.ptr)
        )
    worst = np.ones(nflows)
    if occ is not None and mem_flow.size:
        np.maximum.at(worst, mem_flow, occ.astype(np.float64))
    return 1.0 / worst


def concurrent_ecmp_flow_weights(
    matrix: LinkLoadMatrix,
    flow_phase: np.ndarray,
    concurrent: np.ndarray,
    live: Optional[np.ndarray] = None,
) -> np.ndarray:
    """:func:`ecmp_flow_weights` restricted to concurrently-active phases.

    The whole-batch derivation counts every flow of a routed schedule as a
    potential slot collider, which over-penalizes phases that never
    overlap: two serial phases re-using the same 5-tuples land in the same
    hash slots, yet their flows are never in flight together and the
    switch pipeline never queues them behind one another.  Here occupancy
    is recounted from the recorded slot *identities* (``matrix.slot_key``)
    with a phase filter: flow ``f`` of phase ``p`` counts a same-slot flow
    ``g`` of phase ``q`` iff ``concurrent[p, q]`` (a
    :meth:`repro.core.schedule.CollectiveSchedule.concurrency_matrix` —
    True iff neither phase is a DAG ancestor of the other).

    ``flow_phase`` maps each flow id to its phase index; ``live`` masks
    flows that actually transmit bytes (zero-byte chunk flows occupy no
    slot, the routing-time convention).  A single-phase schedule (or an
    all-True matrix) reproduces :func:`ecmp_flow_weights` for live flows.
    """
    nflows = matrix.num_flows
    worst = np.ones(nflows)
    keys = matrix.slot_key
    if keys is None or matrix.mem_flow.size == 0:
        return worst
    flow_phase = np.asarray(flow_phase, dtype=np.int64)
    if flow_phase.shape != (nflows,):
        raise ValueError(f"flow_phase shape {flow_phase.shape} != ({nflows},)")
    conc = np.asarray(concurrent, dtype=bool)
    live = (
        np.ones(nflows, dtype=bool)
        if live is None
        else np.asarray(live, dtype=bool)
    )
    valid = keys >= 0
    rows_f = matrix.mem_flow[valid]
    rows_p = flow_phase[rows_f]
    if rows_f.size == 0:
        return worst
    uniq, inv = np.unique(keys[valid], return_inverse=True)
    counts = np.zeros((uniq.size, conc.shape[0]))
    lr = live[rows_f]
    np.add.at(counts, (inv[lr], rows_p[lr]), 1.0)
    # occupancy seen by a row of phase p in slot s: live same-slot flows
    # of every phase that may run concurrently with p (including itself)
    occ = np.maximum((counts @ conc.T)[inv, rows_p], 1.0)
    np.maximum.at(worst, rows_f, occ)
    return 1.0 / worst


def max_min_rates(
    matrix: LinkLoadMatrix, weights: Optional[np.ndarray] = None
) -> np.ndarray:
    """Max-min fair per-flow rates (Gbit/s) by vectorized water-filling.

    Progressive filling: all unfrozen flows increase at the same rate; the
    link minimizing ``residual capacity / unfrozen flow count`` saturates
    first and freezes its flows at the current level.  Terminates in at
    most ``len(links)`` rounds (>=1 link saturates per round); each round
    is O(active memberships) in NumPy with frozen rows compacted away.

    With ``weights`` (one positive weight per flow, e.g.
    :func:`ecmp_flow_weights`) the filling is weighted: flow ``f`` rises
    at ``weights[f] * level`` and a saturated link's capacity splits
    proportionally to the weights of the flows crossing it.  ``None`` (and
    the all-ones vector, byte-for-byte) is the classic unweighted
    allocation.
    """
    return _max_min_rates_arrays(
        matrix.mem_flow,
        matrix.mem_link,
        matrix.capacity_gbps,
        matrix.num_flows,
        len(matrix.links),
        weights,
    )


def _check_weights(weights: Optional[np.ndarray], nflows: int) -> None:
    if weights is None:
        return
    if weights.shape != (nflows,):
        raise ValueError(
            f"weights shape {weights.shape} != ({nflows},) flows"
        )
    if not np.all(weights > 0):
        raise ValueError("allocation weights must be strictly positive")


def _max_min_rates_arrays(
    mem_f: np.ndarray,
    mem_l: np.ndarray,
    capacity_gbps: np.ndarray,
    nflows: int,
    nlinks: int,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """:func:`max_min_rates` over raw membership arrays.

    ``mem_f``/``mem_l`` may be any subset of a matrix's rows (the
    event-driven simulator passes only the rows of currently-active
    flows); flows with no rows get rate 0.  ``weights`` is always indexed
    by global flow id, so a rows subset composes with it unchanged.
    """
    rate = np.zeros(nflows)
    if nflows == 0 or mem_f.size == 0:
        return rate
    _check_weights(weights, nflows)
    resid = capacity_gbps.astype(np.float64).copy()
    level = 0.0
    for _ in range(nlinks + 1):
        if mem_f.size == 0:
            break
        if weights is None:
            n_l = np.bincount(mem_l, minlength=nlinks)
        else:
            n_l = np.bincount(mem_l, weights=weights[mem_f], minlength=nlinks)
        has = n_l > 0
        share = np.full(nlinks, np.inf)
        share[has] = np.maximum(resid[has], 0.0) / n_l[has]
        step = float(share.min())
        if not np.isfinite(step):
            break
        level += step
        resid -= step * n_l
        saturated = has & (share <= step * (1.0 + _SATURATION_RTOL))
        newly = np.unique(mem_f[saturated[mem_l]])
        rate[newly] = level if weights is None else level * weights[newly]
        keep = ~np.isin(mem_f, newly)
        mem_f, mem_l = mem_f[keep], mem_l[keep]
    if mem_f.size:  # numerical stragglers: freeze at the final level
        last = np.unique(mem_f)
        rate[last] = level if weights is None else level * weights[last]
    return rate


def _propagation_ms(matrix: LinkLoadMatrix) -> np.ndarray:
    """One-way path propagation per flow: per-link netem delays (two qdisc
    passes each, already folded into ``delay_ms``) + per-transit-switch
    forwarding latency."""
    from .wan import SWITCH_FORWARDING_MS  # local: wan imports this module

    prop = np.zeros(matrix.num_flows)
    np.add.at(prop, matrix.mem_flow, matrix.delay_ms[matrix.mem_link])
    prop += np.maximum(matrix.hops_per_flow - 1, 0) * SWITCH_FORWARDING_MS
    return prop


@dataclass(frozen=True)
class CongestionReport:
    """Per-flow rates/completions and per-link throughput under contention.

    ``weights`` records the allocation weights the rates were solved under
    (``None`` = unweighted); ``max_slot_occ`` the per-link worst observed
    ECMP hash-slot occupancy (``None`` when paths carried no occupancy).
    """

    rates_gbps: np.ndarray  # (F,) max-min fair allocation
    completion_s: np.ndarray  # (F,) transfer + propagation
    propagation_ms: np.ndarray  # (F,) one-way path propagation
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,)
    throughput_gbps: np.ndarray  # (L,) sum of allocated rates on the link
    is_wan: np.ndarray  # (L,) bool
    weights: Optional[np.ndarray] = None  # (F,) allocation weights
    max_slot_occ: Optional[np.ndarray] = None  # (L,) worst hash-slot occupancy

    @property
    def seconds(self) -> float:
        """Completion time of the whole flow set (slowest flow)."""
        return float(self.completion_s.max()) if self.completion_s.size else 0.0

    @property
    def utilization(self) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(
                self.capacity_gbps > 0, self.throughput_gbps / self.capacity_gbps, 0.0
            )
        return out

    @property
    def bottleneck_link(self) -> Optional[Link]:
        if not self.links:
            return None
        return self.links[int(np.argmax(self.utilization))]

    @property
    def effective_wan_gbps(self) -> float:
        """Peak per-link WAN throughput — the paper's §5.5 observable
        (~0.8 Gbit/s on a contended spine WAN link)."""
        if not bool(self.is_wan.any()):
            return 0.0
        return float(self.throughput_gbps[self.is_wan].max())


def congestion_report(
    matrix: LinkLoadMatrix,
    nbytes: Sequence[int],
    weights: Optional[np.ndarray] = None,
) -> CongestionReport:
    """Allocate rates and estimate per-flow completion + propagation.

    ``completion = bytes * 8 / rate + one-way propagation`` where the
    propagation sums the recorded path's per-link netem delays (two qdisc
    passes each) plus per-transit-switch forwarding latency — the same
    terms :func:`repro.core.wan.ping_rtt` samples, minus jitter.

    Zero-byte flows do not occupy capacity: they complete after their
    propagation alone and are excluded from the water-filling, exactly as
    the event-driven simulator drains them for free — the two allocators
    share one convention (a zero-byte chunk is an artifact of exact
    ``split_bytes`` chunking, not a bandwidth consumer).

    ``weights`` (e.g. :func:`ecmp_flow_weights`) selects the weighted
    allocation; ``None`` is the classic unweighted model.
    """
    nb = np.asarray(list(nbytes), dtype=np.float64)
    if nb.size != matrix.num_flows:
        raise ValueError(
            f"{nb.size} byte counts for {matrix.num_flows} recorded paths"
        )
    live = nb[matrix.mem_flow] > 0
    rate = _max_min_rates_arrays(
        matrix.mem_flow[live],
        matrix.mem_link[live],
        matrix.capacity_gbps,
        matrix.num_flows,
        len(matrix.links),
        weights,
    )
    prop = _propagation_ms(matrix)
    with np.errstate(divide="ignore", invalid="ignore"):
        transfer = np.where(nb > 0, nb * 8.0 / (rate * 1e9), 0.0)
    throughput = np.bincount(
        matrix.mem_link, weights=rate[matrix.mem_flow], minlength=len(matrix.links)
    )
    return CongestionReport(
        rates_gbps=rate,
        completion_s=transfer + prop / 1e3,
        propagation_ms=prop,
        links=matrix.links,
        capacity_gbps=matrix.capacity_gbps,
        throughput_gbps=throughput,
        is_wan=matrix.is_wan,
        weights=weights,
        max_slot_occ=(
            matrix.max_slot_occ if matrix.slot_occ is not None else None
        ),
    )


def route_and_analyze(
    fabric: Fabric,
    netem,
    flows: Sequence,
    *,
    check_reachability=None,
    reset_counters: bool = True,
    ecmp_weighted: bool = False,
) -> Tuple[Dict[Link, int], CongestionReport]:
    """Route ``flows`` with path recording and run the congestion model.

    Returns the batch's link byte counters (same contract as
    :func:`repro.core.flows.route_flows_batched`, including the optional
    counter reset) alongside the :class:`CongestionReport`.

    ``ecmp_weighted=True`` derives :func:`ecmp_flow_weights` from the
    recorded hash-slot occupancy and solves the weighted allocation;
    the default keeps the classic unweighted model.
    """
    flows = list(flows)  # consumed twice: routing, then per-flow byte counts
    if reset_counters:
        fabric.reset_counters()
    link_bytes, paths = fabric.route_flows_with_paths(
        flows, check_reachability=check_reachability
    )
    matrix = build_link_load_matrix(fabric, netem, paths)
    weights = ecmp_flow_weights(matrix) if ecmp_weighted else None
    report = congestion_report(matrix, [f.nbytes for f in flows], weights)
    return link_bytes, report


# -- event-driven time-varying simulation (CollectiveSchedule costing) -------

#: Drains within this relative window of the earliest one are processed as a
#: single event (merges the +/-1-byte stragglers of exact ``split_bytes``
#: chunking, which would otherwise each trigger a nanosecond-apart re-solve).
_DRAIN_GROUP_RTOL = 1e-8


@dataclass(frozen=True)
class PhaseTiming:
    """When one :class:`repro.core.schedule.Phase` ran in a simulation.

    ``flow_lo:flow_hi`` slices the report's per-flow arrays (flows are laid
    out in the schedule's topological phase order).
    """

    name: str
    start_s: float
    end_s: float
    flow_lo: int
    flow_hi: int
    wan_bytes: int
    compute_seconds: float = 0.0

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclass(frozen=True)
class ScheduleReport:
    """Per-phase/per-flow timelines of a simulated :class:`CollectiveSchedule`.

    The schedule-level counterpart of :class:`CongestionReport`: a
    single-phase schedule's report reproduces it exactly (same ``seconds``,
    completions, and peak link throughput), while multi-phase schedules add
    the time dimension — phase start/end, per-flow start/drain/completion,
    and each link's *peak* concurrent throughput across allocation epochs
    (the §5.5 effective-WAN observable generalized to time-varying load).
    """

    schedule_name: str
    phase_timings: Tuple[PhaseTiming, ...]
    flow_start_s: np.ndarray  # (F,) phase-start time of each flow
    flow_drain_s: np.ndarray  # (F,) transfer finished (capacity released)
    completion_s: np.ndarray  # (F,) drain + one-way path propagation
    propagation_ms: np.ndarray  # (F,)
    flow_bytes: np.ndarray  # (F,)
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,)
    link_total_bytes: np.ndarray  # (L,) bytes carried over the whole schedule
    peak_throughput_gbps: np.ndarray  # (L,) max concurrent allocation
    is_wan: np.ndarray  # (L,) bool
    weights: Optional[np.ndarray] = None  # (F,) allocation weights
    max_slot_occ: Optional[np.ndarray] = None  # (L,) worst hash-slot occupancy

    @property
    def seconds(self) -> float:
        """Makespan: completion of the last phase (flows + compute tails)."""
        if not self.phase_timings:
            return 0.0
        return float(max(p.end_s for p in self.phase_timings))

    @property
    def busy_seconds(self) -> np.ndarray:
        """Per-link serial drain time (``bytes * 8 / capacity``) — how long
        the link would need carrying its whole schedule load alone."""
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(
                self.capacity_gbps > 0,
                self.link_total_bytes * 8.0 / (self.capacity_gbps * 1e9),
                0.0,
            )

    @property
    def utilization(self) -> np.ndarray:
        """Time-averaged utilization over the schedule makespan."""
        total = self.seconds
        if total <= 0:
            return np.zeros(len(self.links))
        return self.busy_seconds / total

    @property
    def bottleneck_link(self) -> Optional[Link]:
        if not self.links:
            return None
        return self.links[int(np.argmax(self.busy_seconds))]

    @property
    def bottleneck_bytes(self) -> int:
        if not self.links:
            return 0
        return int(self.link_total_bytes[int(np.argmax(self.busy_seconds))])

    @property
    def bottleneck_utilization(self) -> float:
        if not self.links:
            return 0.0
        return float(self.utilization[int(np.argmax(self.busy_seconds))])

    @property
    def effective_wan_gbps(self) -> float:
        """Peak per-link WAN throughput across the schedule (§5.5)."""
        if not bool(self.is_wan.any()):
            return 0.0
        return float(self.peak_throughput_gbps[self.is_wan].max())

    def phase(self, name: str) -> PhaseTiming:
        for p in self.phase_timings:
            if p.name == name:
                return p
        raise KeyError(f"no phase {name!r} in schedule {self.schedule_name!r}")


def _phase_wan_bytes(
    matrix: LinkLoadMatrix, nb: np.ndarray, lo: int, hi: int
) -> int:
    """Bytes the phase's flows place on WAN links (per-traversal, matching
    the ``link_bytes`` WAN accounting of ``GeoFabric.sync_cost``)."""
    rows = (
        (matrix.mem_flow >= lo)
        & (matrix.mem_flow < hi)
        & matrix.is_wan[matrix.mem_link]
    )
    return int(nb[matrix.mem_flow[rows]].sum())


def simulate_schedule(
    fabric: Fabric,
    netem,
    schedule,
    *,
    check_reachability=None,
    reset_counters: bool = True,
    ecmp_weighted: bool = False,
) -> ScheduleReport:
    """Event-driven time-varying max-min simulation of a phased schedule.

    ``schedule`` is a :class:`repro.core.schedule.CollectiveSchedule`.  All
    phases' flows are routed in one batch (counters accumulate the whole
    schedule, same contract as :func:`route_and_analyze`); the simulation
    then replays the DAG as a fluid model:

    * a phase starts when its dependencies complete (+ its start offset);
      its flows join the active set;
    * the max-min fair allocation is re-solved — vectorized over the CSR
      membership rows of the *active* flows only — at every flow
      arrival/completion event, so flows arriving or leaving mid-collective
      reshape everyone's fair share (the time-varying congestion the static
      :func:`congestion_report` cannot express);
    * a flow drains when its bytes are transferred at the evolving rates
      and completes one path-propagation later; a phase completes when all
      its flows have completed and its ``compute_seconds`` have elapsed.

    A single-phase schedule takes a fast path through the static
    :func:`congestion_report` — with one allocation epoch the two models
    coincide, and the shortcut keeps the equivalence *exact* (bit-for-bit
    the ``wan_seconds`` the pre-schedule ``sync_cost`` returned) rather
    than within float tolerance of the event loop.

    ``ecmp_weighted=True`` solves every allocation epoch as a *weighted*
    max-min: single-phase schedules use the whole-batch
    :func:`ecmp_flow_weights`; multi-phase schedules use
    :func:`concurrent_ecmp_flow_weights`, which counts a hash-slot
    collision only between phases the DAG allows in flight together —
    serialized phases re-using the same slots are not down-weighted
    against each other.
    """
    phases = schedule.phases
    flows = schedule.all_flows()
    slices: List[Tuple[int, int]] = []
    lo = 0
    for p in phases:
        slices.append((lo, lo + len(p.flows)))
        lo += len(p.flows)
    if reset_counters:
        fabric.reset_counters()
    _, paths = fabric.route_flows_with_paths(
        flows, check_reachability=check_reachability
    )
    matrix = build_link_load_matrix(fabric, netem, paths)
    nb = np.asarray([f.nbytes for f in flows], dtype=np.float64)
    weights = None
    if ecmp_weighted:
        if schedule.is_single_phase:
            weights = ecmp_flow_weights(matrix)
        else:
            # multi-phase: hash collisions only matter between phases that
            # can actually be in flight together — serialized phases
            # re-using the same slots must not down-weight each other
            flow_phase = np.empty(len(flows), dtype=np.int64)
            for i, (plo, phi) in enumerate(slices):
                flow_phase[plo:phi] = i
            weights = concurrent_ecmp_flow_weights(
                matrix, flow_phase, schedule.concurrency_matrix(), live=nb > 0
            )
    nlinks = len(matrix.links)
    link_total = np.bincount(
        matrix.mem_link, weights=nb[matrix.mem_flow], minlength=nlinks
    )

    if schedule.is_single_phase:
        rep = congestion_report(matrix, nb, weights)
        drain = rep.completion_s - rep.propagation_ms / 1e3
        timing = PhaseTiming(
            name=phases[0].name,
            start_s=0.0,
            end_s=rep.seconds,
            flow_lo=0,
            flow_hi=len(flows),
            wan_bytes=_phase_wan_bytes(matrix, nb, 0, len(flows)),
        )
        return ScheduleReport(
            schedule_name=schedule.name,
            phase_timings=(timing,),
            flow_start_s=np.zeros(len(flows)),
            flow_drain_s=drain,
            completion_s=rep.completion_s,
            propagation_ms=rep.propagation_ms,
            flow_bytes=nb,
            links=matrix.links,
            capacity_gbps=matrix.capacity_gbps,
            link_total_bytes=link_total,
            peak_throughput_gbps=rep.throughput_gbps,
            is_wan=matrix.is_wan,
            weights=weights,
            max_slot_occ=rep.max_slot_occ,
        )

    return _simulate_events(schedule, matrix, nb, slices, link_total, weights)


def _simulate_events(
    schedule,
    matrix: LinkLoadMatrix,
    nb: np.ndarray,
    slices: List[Tuple[int, int]],
    link_total: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> ScheduleReport:
    import heapq

    phases = schedule.phases
    nphases = len(phases)
    nflows = int(nb.size)
    nlinks = len(matrix.links)
    mem_f, mem_l = matrix.mem_flow, matrix.mem_link
    prop_ms = _propagation_ms(matrix)
    name_to_idx = {p.name: i for i, p in enumerate(phases)}
    dependents: List[List[int]] = [[] for _ in range(nphases)]
    pending = np.zeros(nphases, dtype=np.int64)
    for i, p in enumerate(phases):
        pending[i] = len(p.deps)
        for d in p.deps:
            dependents[name_to_idx[d]].append(i)

    remaining = nb * 8.0  # bits still to transfer
    active = np.zeros(nflows, dtype=bool)
    flow_phase = np.empty(nflows, dtype=np.int64)
    for i, (plo, phi) in enumerate(slices):
        flow_phase[plo:phi] = i
    undrained = np.asarray([hi - lo for lo, hi in slices], dtype=np.int64)
    flow_start = np.zeros(nflows)
    flow_drain = np.zeros(nflows)
    flow_complete = np.zeros(nflows)
    phase_start = np.zeros(nphases)
    phase_end = np.zeros(nphases)
    peak_thr = np.zeros(nlinks)
    rates = np.zeros(nflows)

    _START, _COMPLETE = 0, 1
    heap: List[Tuple[float, int, int, int]] = []
    seq = 0
    for i, p in enumerate(phases):
        if not p.deps:
            heapq.heappush(heap, (p.start_offset_s, seq, _START, i))
            seq += 1

    def finish_phase(i: int, t: float) -> float:
        """Completion time of phase i once its last flow has drained."""
        plo, phi = slices[i]
        end = phase_start[i] + phases[i].compute_seconds
        if phi > plo:
            end = max(end, float(flow_complete[plo:phi].max()))
        return max(end, t)

    t = 0.0
    stale = True
    guard = 0
    max_events = 4 * (nflows + nphases) + 64
    while heap or bool(active.any()):
        guard += 1
        if guard > max_events:
            raise RuntimeError(
                f"schedule {schedule.name!r}: event budget exceeded "
                f"({max_events}) — simulator stuck"
            )
        act_idx = np.nonzero(active)[0]
        if stale and act_idx.size:
            rows = active[mem_f]
            rates = _max_min_rates_arrays(
                mem_f[rows], mem_l[rows], matrix.capacity_gbps, nflows, nlinks,
                weights,
            )
            thr = np.bincount(
                mem_l[rows], weights=rates[mem_f[rows]], minlength=nlinks
            )
            np.maximum(peak_thr, thr, out=peak_thr)
            stale = False
        if act_idx.size:
            with np.errstate(divide="ignore", invalid="ignore"):
                ttd = remaining[act_idx] / (rates[act_idx] * 1e9)
            t_drain = float(ttd.min())
        else:
            ttd = None
            t_drain = np.inf
        t_heap = heap[0][0] if heap else np.inf
        if not np.isfinite(t_drain) and not heap:
            raise RuntimeError(
                f"schedule {schedule.name!r}: active flows can make no "
                "progress (zero-capacity path?)"
            )
        if t_heap <= t + t_drain:
            # advance to the heap event; in-flight transfers progress
            dt = max(t_heap - t, 0.0)
            if act_idx.size and dt > 0:
                remaining[act_idx] -= rates[act_idx] * 1e9 * dt
            t = t_heap
            while heap and heap[0][0] <= t:
                _, _, kind, i = heapq.heappop(heap)
                plo, phi = slices[i]
                if kind == _START:
                    phase_start[i] = t
                    flow_start[plo:phi] = t
                    zero = plo + np.nonzero(nb[plo:phi] <= 0)[0]
                    if zero.size:
                        flow_drain[zero] = t
                        flow_complete[zero] = t + prop_ms[zero] / 1e3
                        undrained[i] -= zero.size
                    live = plo + np.nonzero(nb[plo:phi] > 0)[0]
                    if live.size:
                        active[live] = True
                        stale = True
                    if undrained[i] == 0:
                        heapq.heappush(
                            heap, (finish_phase(i, t), seq, _COMPLETE, i)
                        )
                        seq += 1
                else:  # _COMPLETE
                    phase_end[i] = t
                    for q in dependents[i]:
                        pending[q] -= 1
                        if pending[q] == 0:
                            start = (
                                max(phase_end[name_to_idx[d]] for d in phases[q].deps)
                                + phases[q].start_offset_s
                            )
                            heapq.heappush(heap, (start, seq, _START, q))
                            seq += 1
            continue
        # advance to the next drain group
        group = act_idx[ttd <= t_drain * (1.0 + _DRAIN_GROUP_RTOL) + 1e-15]
        remaining[act_idx] -= rates[act_idx] * 1e9 * t_drain
        t += t_drain
        remaining[group] = 0.0
        active[group] = False
        flow_drain[group] = t
        flow_complete[group] = t + prop_ms[group] / 1e3
        stale = True
        undrained -= np.bincount(flow_phase[group], minlength=nphases)
        for i in np.unique(flow_phase[group]).tolist():
            if undrained[i] == 0:
                heapq.heappush(heap, (finish_phase(i, t), seq, _COMPLETE, i))
                seq += 1

    timings = tuple(
        PhaseTiming(
            name=p.name,
            start_s=float(phase_start[i]),
            end_s=float(phase_end[i]),
            flow_lo=slices[i][0],
            flow_hi=slices[i][1],
            wan_bytes=_phase_wan_bytes(matrix, nb, *slices[i]),
            compute_seconds=p.compute_seconds,
        )
        for i, p in enumerate(phases)
    )
    return ScheduleReport(
        schedule_name=schedule.name,
        phase_timings=timings,
        flow_start_s=flow_start,
        flow_drain_s=flow_drain,
        completion_s=flow_complete,
        propagation_ms=prop_ms,
        flow_bytes=nb,
        links=matrix.links,
        capacity_gbps=matrix.capacity_gbps,
        link_total_bytes=link_total,
        peak_throughput_gbps=peak_thr,
        is_wan=matrix.is_wan,
        weights=weights,
        max_slot_occ=(
            matrix.max_slot_occ if matrix.slot_occ is not None else None
        ),
    )
