"""Vectorized flow-level congestion model (paper §5.5 / Fig. 14).

The paper observes that during geo-distributed training the spine WAN
links saturate at an *effective* ~800 Mbit/s (§5.5) and that per-collective
batch times (Fig. 14) are set by how flows share those bottlenecks — not by
the fabric's ideal bisection bandwidth.  :class:`~repro.core.wan.WanTimingModel`'s
original fluid estimate divides each link's aggregate bytes by its capacity,
which is exact only when every flow on the bottleneck starts and ends
together.  This module refines that into a *flow-level* model:

* :func:`build_link_load_matrix` — turn the per-flow directed-link paths
  recorded by :meth:`repro.core.fabric.Fabric.route_flows_with_paths` into a
  factorized flow x link incidence (CSR-style membership arrays) annotated
  with per-link netem capacity and propagation;
* :func:`max_min_rates` — progressive-filling max-min fair allocation
  ("I've Got 99 Problems But FLOPS Ain't One", arXiv:2407.12819, argues WAN
  bottleneck share is the quantity that determines geo step time): every
  round all unfrozen flows rise together until the tightest link saturates,
  freezing its flows at the current level; each round is pure NumPy
  (``bincount`` / boolean masks) over the membership arrays, so 10k+ flows
  allocate in a handful of array ops per bottleneck level.  With a
  ``weights`` vector the filling is *weighted*: flow ``f`` rises at
  ``weights[f]`` times the common level, so its share of any saturated
  link is proportional to its weight (uniform weights reproduce the
  unweighted allocator byte-for-byte);
* :func:`ecmp_flow_weights` — ECMP-awareness for the weighted allocator
  (paper §4, §5.5): :meth:`repro.core.fabric.Fabric.route_flows_with_paths`
  records each traversal's hash-slot occupancy (how many flows of the
  batch hashed into the same :data:`repro.core.fabric.ECMP_HASH_BUCKETS`
  bucket of the same member link); flows sharing a slot are one scheduling
  entity to the switch pipeline, so a flow colliding with ``k - 1`` others
  at its worst hop carries weight ``1 / k`` — the hash-skew contention the
  paper's queue-pair-aware port allocation exists to avoid, now expressed
  as allocation weights instead of being invisible to the fair-share
  model;
* :func:`congestion_report` — per-flow completion time
  (``bytes / fair rate`` + propagation along the recorded path, the Corning
  fiber-latency argument of arXiv:2605.19169) and per-link throughput /
  utilization, including the paper's effective-WAN-throughput observable:
  a saturated spine WAN link carries exactly its ~800 Mbit/s capacity
  no matter how many flows contend for it.

* :func:`simulate_schedule` — the event-driven *time-varying* extension:
  a :class:`repro.core.schedule.CollectiveSchedule` DAG is replayed as a
  fluid simulation in which phases start when their dependencies complete,
  the max-min allocation is re-solved (over the active flows' CSR
  membership rows) at every flow arrival/completion event, and the
  :class:`ScheduleReport` carries per-phase/per-flow timelines.  A
  single-phase schedule reproduces :func:`congestion_report` exactly.

The event loop's per-epoch allocation is **component-decomposed and
incremental** (ISSUE 9): the active flow x link membership graph is
partitioned into connected components (flows coupled only transitively
through shared directed links), every component is water-filled with its
*own* level accumulator (:func:`_multi_max_min_rates`), and across
events the default :class:`_IncrementalAllocator` re-solves only the
components whose active-flow sets an arrival/completion actually changed
— warm-starting everyone else from the previous epoch's rates, which are
bit-for-bit what a from-scratch solve would recompute for them.
``simulate_schedule(..., incremental=False)`` forces the from-scratch
oracle (:class:`_FullEpochAllocator`); the two are gated byte-identical,
the same discipline as ``Fabric._reconverge`` and
``EvpnControlPlane.resync_incremental`` before them (see
``docs/ARCHITECTURE.md``).

Wired into :meth:`repro.core.wan.WanTimingModel.contended_transfer_time`
/ :meth:`~repro.core.wan.WanTimingModel.contended_schedule_time` (and from
there ``GeoFabric.sync_cost(congestion=True)``) so Fig. 14-style
per-collective timings reflect contention rather than ideal bisection.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fabric import Fabric, FlowPaths, Link

#: Relative tolerance for deciding a link saturated at this filling level.
_SATURATION_RTOL = 1e-9


@dataclass(frozen=True)
class LinkLoadMatrix:
    """Factorized flow x link incidence with per-link netem attributes.

    Row ``r`` says flow ``mem_flow[r]`` traverses link ``mem_link[r]``
    (an index into ``links``).  ``delay_ms`` is the one-way propagation of
    a single traversal — two netem qdisc passes, as in
    :meth:`repro.core.wan.Netem.one_way_delay_ms` (jitter-free).

    ``slot_occ`` (row-aligned) carries the recorded ECMP hash-slot
    occupancy of each traversal when the paths were recorded by the
    batched router (ones when unavailable); ``slot_key`` the slot
    *identity* of each ECMP traversal (-1 for non-ECMP hops), letting
    occupancy be recounted over flow subsets — see
    :class:`repro.core.fabric.FlowPaths`.
    """

    mem_flow: np.ndarray  # (R,) int64
    mem_link: np.ndarray  # (R,) int64 indices into ``links``
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,) float64
    delay_ms: np.ndarray  # (L,) float64, per single traversal (2 passes)
    is_wan: np.ndarray  # (L,) bool
    num_flows: int
    hops_per_flow: np.ndarray  # (F,) int64 links traversed per flow
    slot_occ: Optional[np.ndarray] = None  # (R,) int64 hash-slot occupancy
    slot_key: Optional[np.ndarray] = None  # (R,) int64 slot identity, -1 = none

    @property
    def max_slot_occ(self) -> np.ndarray:
        """Per-link worst hash-slot occupancy — the observed ECMP hash
        imbalance (1 everywhere when no collision was recorded)."""
        out = np.ones(len(self.links), dtype=np.int64)
        if self.slot_occ is not None and self.mem_link.size:
            np.maximum.at(out, self.mem_link, self.slot_occ)
        return out


def build_link_load_matrix(
    fabric: Fabric, netem, paths: FlowPaths
) -> LinkLoadMatrix:
    """Factorize recorded flow paths into a :class:`LinkLoadMatrix`.

    ``netem`` is a :class:`repro.core.wan.Netem` (typed loosely to keep the
    module import-cycle-free); capacity and delay come from its per-link
    profiles, exactly as the ideal fluid model uses them.
    """
    nflows = paths.num_flows
    n = len(paths.nodes)
    keys = paths.link_u * n + paths.link_v
    uniq, mem_link = np.unique(keys, return_inverse=True)
    links = tuple(
        (paths.nodes[int(k) // n], paths.nodes[int(k) % n]) for k in uniq
    )
    capacity = np.empty(len(links))
    delay = np.empty(len(links))
    is_wan = np.zeros(len(links), dtype=bool)
    for i, (u, v) in enumerate(links):
        prof = netem.profile(u, v)
        capacity[i] = prof.effective_bandwidth_gbps
        delay[i] = 2.0 * prof.delay_ms  # netem qdisc on both interfaces
        is_wan[i] = fabric.is_wan_link(u, v)
    hops = np.diff(paths.ptr)
    mem_flow = np.repeat(np.arange(nflows, dtype=np.int64), hops)
    return LinkLoadMatrix(
        mem_flow=mem_flow,
        mem_link=mem_link.astype(np.int64),
        links=links,
        capacity_gbps=capacity,
        delay_ms=delay,
        is_wan=is_wan,
        num_flows=nflows,
        hops_per_flow=hops.astype(np.int64),
        slot_occ=paths.slot_occ,
        slot_key=paths.slot_key,
    )


def ecmp_flow_weights(paths) -> np.ndarray:
    """Per-flow allocation weights from observed ECMP hash imbalance.

    ``paths`` is a :class:`repro.core.fabric.FlowPaths` (or a
    :class:`LinkLoadMatrix` built from one).  A flow whose worst traversal
    shares its hash slot with ``k - 1`` other flows weighs ``1 / k``:
    same-slot flows are one entity to the switch's hash pipeline, so they
    split one slot's service among themselves wherever bandwidth gets
    scarce.  Flows that never collide weigh 1.0, and a batch with no
    collisions yields the uniform vector — whose weighted allocation is
    byte-identical to the unweighted one.
    """
    if isinstance(paths, LinkLoadMatrix):
        nflows, occ, mem_flow = paths.num_flows, paths.slot_occ, paths.mem_flow
    else:
        nflows = paths.num_flows
        occ = paths.slot_occ
        mem_flow = np.repeat(
            np.arange(nflows, dtype=np.int64), np.diff(paths.ptr)
        )
    worst = np.ones(nflows)
    if occ is not None and mem_flow.size:
        np.maximum.at(worst, mem_flow, occ.astype(np.float64))
    return 1.0 / worst


def concurrent_ecmp_flow_weights(
    matrix: LinkLoadMatrix,
    flow_phase: np.ndarray,
    concurrent: np.ndarray,
    live: Optional[np.ndarray] = None,
) -> np.ndarray:
    """:func:`ecmp_flow_weights` restricted to concurrently-active phases.

    The whole-batch derivation counts every flow of a routed schedule as a
    potential slot collider, which over-penalizes phases that never
    overlap: two serial phases re-using the same 5-tuples land in the same
    hash slots, yet their flows are never in flight together and the
    switch pipeline never queues them behind one another.  Here occupancy
    is recounted from the recorded slot *identities* (``matrix.slot_key``)
    with a phase filter: flow ``f`` of phase ``p`` counts a same-slot flow
    ``g`` of phase ``q`` iff ``concurrent[p, q]`` (a
    :meth:`repro.core.schedule.CollectiveSchedule.concurrency_matrix` —
    True iff neither phase is a DAG ancestor of the other).

    ``flow_phase`` maps each flow id to its phase index; ``live`` masks
    flows that actually transmit bytes (zero-byte chunk flows occupy no
    slot, the routing-time convention).  A single-phase schedule (or an
    all-True matrix) reproduces :func:`ecmp_flow_weights` for live flows.
    """
    nflows = matrix.num_flows
    worst = np.ones(nflows)
    keys = matrix.slot_key
    if keys is None or matrix.mem_flow.size == 0:
        return worst
    flow_phase = np.asarray(flow_phase, dtype=np.int64)
    if flow_phase.shape != (nflows,):
        raise ValueError(f"flow_phase shape {flow_phase.shape} != ({nflows},)")
    conc = np.asarray(concurrent, dtype=bool)
    live = (
        np.ones(nflows, dtype=bool)
        if live is None
        else np.asarray(live, dtype=bool)
    )
    valid = keys >= 0
    rows_f = matrix.mem_flow[valid]
    rows_p = flow_phase[rows_f]
    if rows_f.size == 0:
        return worst
    uniq, inv = np.unique(keys[valid], return_inverse=True)
    counts = np.zeros((uniq.size, conc.shape[0]))
    lr = live[rows_f]
    np.add.at(counts, (inv[lr], rows_p[lr]), 1.0)
    # occupancy seen by a row of phase p in slot s: live same-slot flows
    # of every phase that may run concurrently with p (including itself)
    occ = np.maximum((counts @ conc.T)[inv, rows_p], 1.0)
    np.maximum.at(worst, rows_f, occ)
    return 1.0 / worst


def max_min_rates(
    matrix: LinkLoadMatrix, weights: Optional[np.ndarray] = None
) -> np.ndarray:
    """Max-min fair per-flow rates (Gbit/s) by vectorized water-filling.

    Progressive filling: all unfrozen flows increase at the same rate; the
    link minimizing ``residual capacity / unfrozen flow count`` saturates
    first and freezes its flows at the current level.  Terminates in at
    most ``len(links)`` rounds (>=1 link saturates per round); each round
    is O(active memberships) in NumPy with frozen rows compacted away.

    With ``weights`` (one positive weight per flow, e.g.
    :func:`ecmp_flow_weights`) the filling is weighted: flow ``f`` rises
    at ``weights[f] * level`` and a saturated link's capacity splits
    proportionally to the weights of the flows crossing it.  ``None`` (and
    the all-ones vector, byte-for-byte) is the classic unweighted
    allocation.
    """
    return _max_min_rates_arrays(
        matrix.mem_flow,
        matrix.mem_link,
        matrix.capacity_gbps,
        matrix.num_flows,
        len(matrix.links),
        weights,
    )


def _check_weights(weights: Optional[np.ndarray], nflows: int) -> None:
    if weights is None:
        return
    if weights.shape != (nflows,):
        raise ValueError(
            f"weights shape {weights.shape} != ({nflows},) flows"
        )
    if not np.all(weights > 0):
        raise ValueError("allocation weights must be strictly positive")


def _max_min_rates_arrays(
    mem_f: np.ndarray,
    mem_l: np.ndarray,
    capacity_gbps: np.ndarray,
    nflows: int,
    nlinks: int,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """:func:`max_min_rates` over raw membership arrays.

    **The weighted max-min definition.**  An allocation is (weighted)
    max-min fair when no flow's rate can be raised without lowering the
    rate of another flow whose *normalized* rate (``rate / weight``) is
    already no larger.  Progressive filling computes exactly that fixed
    point: every unfrozen flow ``f`` rises at ``weights[f] * level`` for
    one common scalar ``level``; when a link's residual capacity hits
    zero it *saturates* and freezes every flow crossing it at the current
    level; the loop repeats on the survivors.  Each round the binding
    link is the one minimizing ``residual / (sum of unfrozen member
    weights)``, so a round costs a ``bincount`` + a min over links, and
    the whole solve is ``O(bottleneck levels x active memberships)`` in
    pure NumPy.  ``weights=None`` (or all-ones, byte-for-byte) is the
    classic unweighted allocation.

    **The CSR membership layout.**  ``mem_f``/``mem_l`` are the
    row-aligned halves of a flow x link incidence in coordinate form: row
    ``r`` says flow ``mem_f[r]`` traverses link ``mem_l[r]``.  Rows are
    laid out flow-major in ascending flow order (the
    :func:`build_link_load_matrix` construction:
    ``mem_flow = repeat(arange(F), hops_per_flow)``), so flow ``f``'s
    rows are the contiguous slice ``row_ptr[f]:row_ptr[f+1]`` with
    ``row_ptr = cumsum(hops_per_flow)`` — the property the incremental
    event-loop allocator uses to gather any flow subset's rows in one
    vectorized ragged gather.  ``mem_f``/``mem_l`` may be any subset of a
    matrix's rows (the event-driven simulator passes only the rows of
    currently-active flows); flows with no rows get rate 0.  ``weights``
    is always indexed by global flow id, so a rows subset composes with
    it unchanged.  Summation order matters for bit-identity: NumPy's
    ``bincount`` accumulates in row order, so any two solvers that feed a
    link the same rows in the same ascending order produce bitwise-equal
    per-link sums — the invariant the incremental/full equivalence gate
    rests on.

    This single-level solver is the *static* allocator
    (:func:`congestion_report` and the single-phase fast path).  The
    event loop instead uses the component-decomposed
    :func:`_multi_max_min_rates`: one shared scalar level couples every
    component's float rounding (each round's step is the min over *all*
    links), whereas per-component levels make disjoint subproblems price
    independently — the property that lets an incremental solver reuse
    untouched components' rates bit-for-bit.  The two differ only in
    float rounding (same fixed point, different summation partitions).
    """
    rate = np.zeros(nflows)
    if nflows == 0 or mem_f.size == 0:
        return rate
    _check_weights(weights, nflows)
    resid = capacity_gbps.astype(np.float64).copy()
    level = 0.0
    for _ in range(nlinks + 1):
        if mem_f.size == 0:
            break
        if weights is None:
            n_l = np.bincount(mem_l, minlength=nlinks)
        else:
            n_l = np.bincount(mem_l, weights=weights[mem_f], minlength=nlinks)
        has = n_l > 0
        share = np.full(nlinks, np.inf)
        share[has] = np.maximum(resid[has], 0.0) / n_l[has]
        step = float(share.min())
        if not np.isfinite(step):
            break
        level += step
        resid -= step * n_l
        saturated = has & (share <= step * (1.0 + _SATURATION_RTOL))
        newly = np.unique(mem_f[saturated[mem_l]])
        rate[newly] = level if weights is None else level * weights[newly]
        keep = ~np.isin(mem_f, newly)
        mem_f, mem_l = mem_f[keep], mem_l[keep]
    if mem_f.size:  # numerical stragglers: freeze at the final level
        last = np.unique(mem_f)
        rate[last] = level if weights is None else level * weights[last]
    return rate


# -- component-decomposed epoch allocation (incremental event loop) ----------


def _label_components(
    mem_f: np.ndarray, mem_l: np.ndarray, nflows: int, nlinks: int
) -> Tuple[np.ndarray, int]:
    """Connected components of the flow x link membership rows.

    Two flows are in the same component when they are coupled through a
    chain of shared *directed* links — exactly the transitive "affected
    frontier" of the incremental allocator: a rate change can only ever
    propagate along shared links, so components are the unit of re-solve.
    Labels spread by min-label propagation (scatter-min flow -> link ->
    flow until a fixed point, ``O(diameter)`` vectorized passes).

    Returns ``(comp, ncomp)`` where ``comp`` is a full ``(nflows,)``
    array of compact component ids in ``[0, ncomp)`` (``-1`` for flows
    with no rows present).  Compact ids are ordered by each component's
    minimum flow id, so the labeling is a pure function of the row set.
    """
    comp = np.full(nflows, -1, dtype=np.int64)
    if mem_f.size == 0:
        return comp, 0
    sentinel = np.iinfo(np.int64).max
    flow_lab = np.full(nflows, sentinel, dtype=np.int64)
    present = np.unique(mem_f)
    flow_lab[present] = present
    link_lab = np.full(nlinks, sentinel, dtype=np.int64)
    while True:
        np.minimum.at(link_lab, mem_l, flow_lab[mem_f])
        prev = flow_lab[present].copy()
        np.minimum.at(flow_lab, mem_f, link_lab[mem_l])
        if np.array_equal(flow_lab[present], prev):
            break
    uniq, inv = np.unique(flow_lab[present], return_inverse=True)
    comp[present] = inv
    return comp, int(uniq.size)


def _multi_max_min_rates(
    mem_f: np.ndarray,
    mem_l: np.ndarray,
    capacity_gbps: np.ndarray,
    nflows: int,
    nlinks: int,
    comp_f: np.ndarray,
    ncomp: int,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Per-component weighted max-min over membership rows (the epoch solver).

    Runs :func:`_max_min_rates_arrays`'s progressive filling on every
    connected component *simultaneously*, each with its own level
    accumulator: per round, each component's unfrozen flows rise by that
    component's own min share (a segment-min over its links), its links'
    residuals drop by exactly that step, and its newly saturated links
    freeze their flows at the component level.  Because every operation
    is elementwise per link / per component, a component's float
    trajectory is *independent of which other components are present in
    the call* — solving the full active set and solving any union of
    whole components give bitwise-identical rates for those components.

    That locality is the whole correctness argument for the incremental
    event loop (the "frontier re-freeze" argument): an arrival/completion
    changes the active-flow sets of the links on the affected flows'
    paths only; components not sharing any of those links keep an
    identical row multiset, and since this solver is a pure function of a
    component's rows (in ascending row order — ``bincount`` sums in row
    order), their previous epoch's rates ARE this epoch's from-scratch
    answer, bit for bit.  Only the dirtied components (changed links plus
    everything transitively attached — after re-labeling, since removals
    can split a component and arrivals can merge several) need re-solving.

    ``comp_f``/``ncomp`` come from :func:`_label_components` on the same
    rows.  Flows with no rows get rate 0.
    """
    rate = np.zeros(nflows)
    if nflows == 0 or mem_f.size == 0:
        return rate
    _check_weights(weights, nflows)
    resid = capacity_gbps.astype(np.float64).copy()
    level = np.zeros(ncomp)
    # link -> component (consistent across a component's rows by definition;
    # links never change component within one solve)
    comp_l = np.full(nlinks, -1, dtype=np.int64)
    comp_l[mem_l] = comp_f[mem_f]
    for _ in range(nlinks + 1):
        if mem_f.size == 0:
            break
        if weights is None:
            n_l = np.bincount(mem_l, minlength=nlinks)
        else:
            n_l = np.bincount(mem_l, weights=weights[mem_f], minlength=nlinks)
        has = np.nonzero(n_l > 0)[0]
        if has.size == 0:
            break
        share = np.full(nlinks, np.inf)
        share[has] = np.maximum(resid[has], 0.0) / n_l[has]
        step_c = np.full(ncomp, np.inf)
        np.minimum.at(step_c, comp_l[has], share[has])
        act = np.isfinite(step_c)
        if not act.any():
            break
        level[act] += step_c[act]
        step_l = np.zeros(nlinks)
        step_l[has] = step_c[comp_l[has]]
        resid -= step_l * n_l
        saturated = np.zeros(nlinks, dtype=bool)
        saturated[has] = share[has] <= step_l[has] * (1.0 + _SATURATION_RTOL)
        newly = np.unique(mem_f[saturated[mem_l]])
        if newly.size:
            lv = level[comp_f[newly]]
            rate[newly] = lv if weights is None else lv * weights[newly]
            keep = ~np.isin(mem_f, newly)
            mem_f, mem_l = mem_f[keep], mem_l[keep]
    if mem_f.size:  # numerical stragglers: freeze at the component level
        last = np.unique(mem_f)
        lv = level[comp_f[last]]
        rate[last] = lv if weights is None else lv * weights[last]
    return rate


class _FullEpochAllocator:
    """From-scratch per-epoch oracle: relabel + re-solve every component.

    The reference implementation the incremental allocator is gated
    byte-identical against (``simulate_schedule(..., incremental=False)``
    and the ``bench_scenarios.py`` SCALED64 speedup gate's slow side):
    each epoch it recomputes the component partition of the full active
    row set and water-fills all components with
    :func:`_multi_max_min_rates`, ``O(active memberships)`` per event
    with no state carried across epochs.
    """

    def __init__(self, matrix: LinkLoadMatrix, weights: Optional[np.ndarray]):
        self._mem_f = matrix.mem_flow
        self._mem_l = matrix.mem_link
        self._caps = matrix.capacity_gbps
        self._nflows = matrix.num_flows
        self._nlinks = len(matrix.links)
        self._weights = weights
        self.rates = np.zeros(self._nflows)
        self.peak = np.zeros(self._nlinks)

    def update(
        self, active: np.ndarray, added: np.ndarray, removed: np.ndarray
    ) -> None:
        rows = active[self._mem_f]
        rf, rl = self._mem_f[rows], self._mem_l[rows]
        comp_f, ncomp = _label_components(rf, rl, self._nflows, self._nlinks)
        self.rates = _multi_max_min_rates(
            rf, rl, self._caps, self._nflows, self._nlinks, comp_f, ncomp,
            self._weights,
        )
        thr = np.bincount(rl, weights=self.rates[rf], minlength=self._nlinks)
        np.maximum(self.peak, thr, out=self.peak)


class _IncrementalAllocator:
    """Warm-started epoch allocator: re-freeze only the affected frontier.

    Maintains across allocation epochs: the component id of every active
    flow and link, each component's member list, every flow's solved
    rate, and every link's summed throughput.  On an event batch
    (``added`` flows entering at a phase start / ``removed`` flows whose
    transfers drained):

    1. the *dirty* component set = the components of every removed flow
       plus every component owning a link that an added flow's path
       touches — exactly the links whose active-flow sets changed, plus
       everything transitively attached through shared links;
    2. dirty members and arrivals are re-labeled from scratch
       (:func:`_label_components` on their rows only — removals can split
       a component, arrivals can merge several);
    3. :func:`_multi_max_min_rates` re-solves just those rows; everyone
       else keeps the previous epoch's rates, which are bitwise what a
       full re-solve would return for them (see the locality argument on
       :func:`_multi_max_min_rates`);
    4. per-link throughput / the running peak are patched on the dirtied
       links only (a clean link's stored sum was computed from the same
       rows and rates a recomputation would use).

    Per event this costs ``O(dirty memberships + nflows)`` instead of the
    oracle's ``O(levels x active memberships)`` — on workloads whose DC
    pairs are independent (the common geo case: per-pair WAN paths share
    no directed link) an event re-solves one pair's flows instead of
    100k.  Gated byte-identical to :class:`_FullEpochAllocator` in
    ``tests/test_incremental_maxmin.py`` (random DAGs) and
    ``benchmarks/bench_scenarios.py`` (library scenarios + SCALED64).
    """

    def __init__(self, matrix: LinkLoadMatrix, weights: Optional[np.ndarray]):
        self._mem_f = matrix.mem_flow
        self._mem_l = matrix.mem_link
        self._caps = matrix.capacity_gbps
        self._nflows = matrix.num_flows
        self._nlinks = len(matrix.links)
        self._weights = weights
        self._hops = matrix.hops_per_flow
        self._row_ptr = np.zeros(self._nflows + 1, dtype=np.int64)
        np.cumsum(self._hops, out=self._row_ptr[1:])
        self._comp_of_flow = np.full(self._nflows, -1, dtype=np.int64)
        self._link_comp = np.full(self._nlinks, -1, dtype=np.int64)
        self._members: Dict[int, np.ndarray] = {}
        self._next_label = 0
        self._thr = np.zeros(self._nlinks)
        self.rates = np.zeros(self._nflows)
        self.peak = np.zeros(self._nlinks)

    def _rows_of(self, flows: np.ndarray) -> np.ndarray:
        """Row indices of ``flows`` (ascending flow ids -> ascending rows)."""
        counts = self._hops[flows]
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, dtype=np.int64)
        starts = np.repeat(self._row_ptr[flows], counts)
        ends = np.cumsum(counts)
        offsets = np.arange(total, dtype=np.int64) - np.repeat(
            ends - counts, counts
        )
        return starts + offsets

    def update(
        self, active: np.ndarray, added: np.ndarray, removed: np.ndarray
    ) -> None:
        dirty: List[int] = []
        if removed.size:
            dirty.extend(
                int(c) for c in np.unique(self._comp_of_flow[removed])
            )
            self._comp_of_flow[removed] = -1
            self.rates[removed] = 0.0
        if added.size:
            added = np.unique(added)  # sorted, for the ragged row gather
            touched = self._link_comp[self._mem_l[self._rows_of(added)]]
            touched = np.unique(touched[touched >= 0])
            dirty.extend(int(c) for c in touched if int(c) not in dirty)
        stale_members = [self._members.pop(c) for c in dirty if c in self._members]
        parts = stale_members + ([added] if added.size else [])
        if not parts:
            return
        cand = np.unique(np.concatenate(parts))
        if removed.size:
            affected = cand[~np.isin(cand, removed)]
        else:
            affected = cand
        # links whose active-flow sets changed: everything on the paths of
        # the re-solved + departed flows.  Reset, then repatch below.
        reset = affected if not removed.size else np.unique(
            np.concatenate([affected, np.asarray(removed, dtype=np.int64)])
        )
        old_links = np.unique(self._mem_l[self._rows_of(reset)])
        self._link_comp[old_links] = -1
        self._thr[old_links] = 0.0
        if affected.size:
            rows = self._rows_of(affected)
            rf, rl = self._mem_f[rows], self._mem_l[rows]
            comp_f, ncomp = _label_components(
                rf, rl, self._nflows, self._nlinks
            )
            rates = _multi_max_min_rates(
                rf, rl, self._caps, self._nflows, self._nlinks, comp_f, ncomp,
                self._weights,
            )
            self.rates[affected] = rates[affected]
            self._comp_of_flow[affected] = comp_f[affected] + self._next_label
            order = np.argsort(comp_f[affected], kind="stable")
            grouped = affected[order]
            labels = comp_f[affected][order]
            bounds = np.nonzero(np.diff(labels))[0] + 1
            for cid, grp in zip(
                labels[np.concatenate([[0], bounds])] if labels.size else (),
                np.split(grouped, bounds),
            ):
                self._members[int(cid) + self._next_label] = grp
            self._next_label += ncomp
            self._link_comp[rl] = self._comp_of_flow[rf]
            thr = np.bincount(rl, weights=self.rates[rf], minlength=self._nlinks)
            self._thr[old_links] = thr[old_links]
        self.peak[old_links] = np.maximum(
            self.peak[old_links], self._thr[old_links]
        )


def _propagation_ms(matrix: LinkLoadMatrix) -> np.ndarray:
    """One-way path propagation per flow: per-link netem delays (two qdisc
    passes each, already folded into ``delay_ms``) + per-transit-switch
    forwarding latency."""
    from .wan import SWITCH_FORWARDING_MS  # local: wan imports this module

    prop = np.zeros(matrix.num_flows)
    np.add.at(prop, matrix.mem_flow, matrix.delay_ms[matrix.mem_link])
    prop += np.maximum(matrix.hops_per_flow - 1, 0) * SWITCH_FORWARDING_MS
    return prop


@dataclass(frozen=True)
class CongestionReport:
    """Per-flow rates/completions and per-link throughput under contention.

    ``weights`` records the allocation weights the rates were solved under
    (``None`` = unweighted); ``max_slot_occ`` the per-link worst observed
    ECMP hash-slot occupancy (``None`` when paths carried no occupancy).
    """

    rates_gbps: np.ndarray  # (F,) max-min fair allocation
    completion_s: np.ndarray  # (F,) transfer + propagation
    propagation_ms: np.ndarray  # (F,) one-way path propagation
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,)
    throughput_gbps: np.ndarray  # (L,) sum of allocated rates on the link
    is_wan: np.ndarray  # (L,) bool
    weights: Optional[np.ndarray] = None  # (F,) allocation weights
    max_slot_occ: Optional[np.ndarray] = None  # (L,) worst hash-slot occupancy

    @property
    def seconds(self) -> float:
        """Completion time of the whole flow set (slowest flow)."""
        return float(self.completion_s.max()) if self.completion_s.size else 0.0

    @property
    def utilization(self) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(
                self.capacity_gbps > 0, self.throughput_gbps / self.capacity_gbps, 0.0
            )
        return out

    @property
    def bottleneck_link(self) -> Optional[Link]:
        if not self.links:
            return None
        return self.links[int(np.argmax(self.utilization))]

    @property
    def effective_wan_gbps(self) -> float:
        """Peak per-link WAN throughput — the paper's §5.5 observable
        (~0.8 Gbit/s on a contended spine WAN link)."""
        if not bool(self.is_wan.any()):
            return 0.0
        return float(self.throughput_gbps[self.is_wan].max())


def congestion_report(
    matrix: LinkLoadMatrix,
    nbytes: Sequence[int],
    weights: Optional[np.ndarray] = None,
) -> CongestionReport:
    """Allocate rates and estimate per-flow completion + propagation.

    ``completion = bytes * 8 / rate + one-way propagation`` where the
    propagation sums the recorded path's per-link netem delays (two qdisc
    passes each) plus per-transit-switch forwarding latency — the same
    terms :func:`repro.core.wan.ping_rtt` samples, minus jitter.

    Zero-byte flows do not occupy capacity: they complete after their
    propagation alone and are excluded from the water-filling, exactly as
    the event-driven simulator drains them for free — the two allocators
    share one convention (a zero-byte chunk is an artifact of exact
    ``split_bytes`` chunking, not a bandwidth consumer).

    ``weights`` (e.g. :func:`ecmp_flow_weights`) selects the weighted
    allocation; ``None`` is the classic unweighted model.

    This is the repo's *static* allocator: one allocation epoch, every
    live flow present from t=0 to its own completion, solved by the
    single-level :func:`_max_min_rates_arrays` water-filling.  It is the
    exact model behind ``sync_cost``-style single-collective pricing and
    the single-phase fast path of :func:`simulate_schedule` — those
    numbers are pinned bit-for-bit across PRs, which is why this function
    deliberately does NOT share the event loop's component-decomposed
    solver (:func:`_multi_max_min_rates`): the two reach the same
    weighted max-min fixed point but partition their float summations
    differently (one global level accumulator vs one per component), and
    repartitioning would move the pinned values by ulps.  Anything that
    needs rates *changing over time* — phases arriving, flows draining —
    belongs in :func:`simulate_schedule` instead.
    """
    nb = np.asarray(list(nbytes), dtype=np.float64)
    if nb.size != matrix.num_flows:
        raise ValueError(
            f"{nb.size} byte counts for {matrix.num_flows} recorded paths"
        )
    live = nb[matrix.mem_flow] > 0
    rate = _max_min_rates_arrays(
        matrix.mem_flow[live],
        matrix.mem_link[live],
        matrix.capacity_gbps,
        matrix.num_flows,
        len(matrix.links),
        weights,
    )
    prop = _propagation_ms(matrix)
    with np.errstate(divide="ignore", invalid="ignore"):
        transfer = np.where(nb > 0, nb * 8.0 / (rate * 1e9), 0.0)
    throughput = np.bincount(
        matrix.mem_link, weights=rate[matrix.mem_flow], minlength=len(matrix.links)
    )
    return CongestionReport(
        rates_gbps=rate,
        completion_s=transfer + prop / 1e3,
        propagation_ms=prop,
        links=matrix.links,
        capacity_gbps=matrix.capacity_gbps,
        throughput_gbps=throughput,
        is_wan=matrix.is_wan,
        weights=weights,
        max_slot_occ=(
            matrix.max_slot_occ if matrix.slot_occ is not None else None
        ),
    )


def route_and_analyze(
    fabric: Fabric,
    netem,
    flows: Sequence,
    *,
    check_reachability=None,
    reset_counters: bool = True,
    ecmp_weighted: bool = False,
) -> Tuple[Dict[Link, int], CongestionReport]:
    """Route ``flows`` with path recording and run the congestion model.

    Returns the batch's link byte counters (same contract as
    :func:`repro.core.flows.route_flows_batched`, including the optional
    counter reset) alongside the :class:`CongestionReport`.

    ``ecmp_weighted=True`` derives :func:`ecmp_flow_weights` from the
    recorded hash-slot occupancy and solves the weighted allocation;
    the default keeps the classic unweighted model.
    """
    flows = list(flows)  # consumed twice: routing, then per-flow byte counts
    if reset_counters:
        fabric.reset_counters()
    link_bytes, paths = fabric.route_flows_with_paths(
        flows, check_reachability=check_reachability
    )
    matrix = build_link_load_matrix(fabric, netem, paths)
    weights = ecmp_flow_weights(matrix) if ecmp_weighted else None
    report = congestion_report(matrix, [f.nbytes for f in flows], weights)
    return link_bytes, report


# -- event-driven time-varying simulation (CollectiveSchedule costing) -------

#: Drains within this relative window of the earliest one are processed as a
#: single event (merges the +/-1-byte stragglers of exact ``split_bytes``
#: chunking, which would otherwise each trigger a nanosecond-apart re-solve).
_DRAIN_GROUP_RTOL = 1e-8

#: Default allocator for :func:`simulate_schedule`'s event loop.  ``True``
#: selects the warm-started :class:`_IncrementalAllocator`; ``False`` the
#: from-scratch :class:`_FullEpochAllocator` oracle.  Flip it (or pass
#: ``simulate_schedule(..., incremental=...)``) to A/B the two — they are
#: gated byte-identical, so everything downstream must be unchanged.
INCREMENTAL_EVENT_LOOP = True


def _event_budget(nflows: int, nphases: int) -> int:
    """Max events :func:`_simulate_events` may process before declaring the
    simulator stuck.  Every flow contributes at most one arrival and one
    drain, every phase one start and one completion; the 4x headroom covers
    drain-group fragmentation.  Separate (and monkeypatchable) so the guard
    itself can be regression-tested without building a pathological
    schedule."""
    return 4 * (nflows + nphases) + 64


@dataclass(frozen=True)
class PhaseTiming:
    """When one :class:`repro.core.schedule.Phase` ran in a simulation.

    ``flow_lo:flow_hi`` slices the report's per-flow arrays (flows are laid
    out in the schedule's topological phase order).
    """

    name: str
    start_s: float
    end_s: float
    flow_lo: int
    flow_hi: int
    wan_bytes: int
    compute_seconds: float = 0.0

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclass(frozen=True)
class ScheduleReport:
    """Per-phase/per-flow timelines of a simulated :class:`CollectiveSchedule`.

    The schedule-level counterpart of :class:`CongestionReport`: a
    single-phase schedule's report reproduces it exactly (same ``seconds``,
    completions, and peak link throughput), while multi-phase schedules add
    the time dimension — phase start/end, per-flow start/drain/completion,
    and each link's *peak* concurrent throughput across allocation epochs
    (the §5.5 effective-WAN observable generalized to time-varying load).
    """

    schedule_name: str
    phase_timings: Tuple[PhaseTiming, ...]
    flow_start_s: np.ndarray  # (F,) phase-start time of each flow
    flow_drain_s: np.ndarray  # (F,) transfer finished (capacity released)
    completion_s: np.ndarray  # (F,) drain + one-way path propagation
    propagation_ms: np.ndarray  # (F,)
    flow_bytes: np.ndarray  # (F,)
    links: Tuple[Link, ...]
    capacity_gbps: np.ndarray  # (L,)
    link_total_bytes: np.ndarray  # (L,) bytes carried over the whole schedule
    peak_throughput_gbps: np.ndarray  # (L,) max concurrent allocation
    is_wan: np.ndarray  # (L,) bool
    weights: Optional[np.ndarray] = None  # (F,) allocation weights
    max_slot_occ: Optional[np.ndarray] = None  # (L,) worst hash-slot occupancy

    @property
    def seconds(self) -> float:
        """Makespan: completion of the last phase (flows + compute tails)."""
        if not self.phase_timings:
            return 0.0
        return float(max(p.end_s for p in self.phase_timings))

    @property
    def busy_seconds(self) -> np.ndarray:
        """Per-link serial drain time (``bytes * 8 / capacity``) — how long
        the link would need carrying its whole schedule load alone."""
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(
                self.capacity_gbps > 0,
                self.link_total_bytes * 8.0 / (self.capacity_gbps * 1e9),
                0.0,
            )

    @property
    def utilization(self) -> np.ndarray:
        """Time-averaged utilization over the schedule makespan."""
        total = self.seconds
        if total <= 0:
            return np.zeros(len(self.links))
        return self.busy_seconds / total

    @property
    def bottleneck_link(self) -> Optional[Link]:
        if not self.links:
            return None
        return self.links[int(np.argmax(self.busy_seconds))]

    @property
    def bottleneck_bytes(self) -> int:
        if not self.links:
            return 0
        return int(self.link_total_bytes[int(np.argmax(self.busy_seconds))])

    @property
    def bottleneck_utilization(self) -> float:
        if not self.links:
            return 0.0
        return float(self.utilization[int(np.argmax(self.busy_seconds))])

    @property
    def effective_wan_gbps(self) -> float:
        """Peak per-link WAN throughput across the schedule (§5.5)."""
        if not bool(self.is_wan.any()):
            return 0.0
        return float(self.peak_throughput_gbps[self.is_wan].max())

    def phase(self, name: str) -> PhaseTiming:
        for p in self.phase_timings:
            if p.name == name:
                return p
        raise KeyError(f"no phase {name!r} in schedule {self.schedule_name!r}")


def _phase_wan_bytes(
    matrix: LinkLoadMatrix, nb: np.ndarray, lo: int, hi: int
) -> int:
    """Bytes the phase's flows place on WAN links (per-traversal, matching
    the ``link_bytes`` WAN accounting of ``GeoFabric.sync_cost``)."""
    rows = (
        (matrix.mem_flow >= lo)
        & (matrix.mem_flow < hi)
        & matrix.is_wan[matrix.mem_link]
    )
    return int(nb[matrix.mem_flow[rows]].sum())


def simulate_schedule(
    fabric: Fabric,
    netem,
    schedule,
    *,
    check_reachability=None,
    reset_counters: bool = True,
    ecmp_weighted: bool = False,
    incremental: Optional[bool] = None,
) -> ScheduleReport:
    """Event-driven time-varying max-min simulation of a phased schedule.

    ``schedule`` is a :class:`repro.core.schedule.CollectiveSchedule`.  All
    phases' flows are routed in one batch (counters accumulate the whole
    schedule, same contract as :func:`route_and_analyze`); the simulation
    then replays the DAG as a fluid model:

    * a phase starts when its dependencies complete (+ its start offset);
      its flows join the active set;
    * the max-min fair allocation is re-solved — vectorized over the CSR
      membership rows of the *active* flows only — at every flow
      arrival/completion event, so flows arriving or leaving mid-collective
      reshape everyone's fair share (the time-varying congestion the static
      :func:`congestion_report` cannot express);
    * a flow drains when its bytes are transferred at the evolving rates
      and completes one path-propagation later; a phase completes when all
      its flows have completed and its ``compute_seconds`` have elapsed.

    A single-phase schedule takes a fast path through the static
    :func:`congestion_report` — with one allocation epoch the two models
    coincide, and the shortcut keeps the equivalence *exact* (bit-for-bit
    the ``wan_seconds`` the pre-schedule ``sync_cost`` returned) rather
    than within float tolerance of the event loop.

    ``ecmp_weighted=True`` solves every allocation epoch as a *weighted*
    max-min: single-phase schedules use the whole-batch
    :func:`ecmp_flow_weights`; multi-phase schedules use
    :func:`concurrent_ecmp_flow_weights`, which counts a hash-slot
    collision only between phases the DAG allows in flight together —
    serialized phases re-using the same slots are not down-weighted
    against each other.

    ``incremental`` selects the multi-phase epoch allocator:
    ``True`` -> :class:`_IncrementalAllocator` (warm-started, the default),
    ``False`` -> :class:`_FullEpochAllocator` (from-scratch oracle),
    ``None`` -> the module flag :data:`INCREMENTAL_EVENT_LOOP`.  The two are
    byte-identical by construction (see :func:`_multi_max_min_rates`), so
    this knob only trades wall-clock, never results.
    """
    phases = schedule.phases
    flows = schedule.all_flows()
    slices = schedule.flow_slices()
    if reset_counters:
        fabric.reset_counters()
    _, paths = fabric.route_flows_with_paths(
        flows, check_reachability=check_reachability
    )
    matrix = build_link_load_matrix(fabric, netem, paths)
    nb = np.asarray([f.nbytes for f in flows], dtype=np.float64)
    weights = None
    if ecmp_weighted:
        if schedule.is_single_phase:
            weights = ecmp_flow_weights(matrix)
        else:
            # multi-phase: hash collisions only matter between phases that
            # can actually be in flight together — serialized phases
            # re-using the same slots must not down-weight each other
            flow_phase = np.empty(len(flows), dtype=np.int64)
            for i, (plo, phi) in enumerate(slices):
                flow_phase[plo:phi] = i
            weights = concurrent_ecmp_flow_weights(
                matrix, flow_phase, schedule.concurrency_matrix(), live=nb > 0
            )
    nlinks = len(matrix.links)
    link_total = np.bincount(
        matrix.mem_link, weights=nb[matrix.mem_flow], minlength=nlinks
    )

    if schedule.is_single_phase:
        rep = congestion_report(matrix, nb, weights)
        drain = rep.completion_s - rep.propagation_ms / 1e3
        timing = PhaseTiming(
            name=phases[0].name,
            start_s=0.0,
            end_s=rep.seconds,
            flow_lo=0,
            flow_hi=len(flows),
            wan_bytes=_phase_wan_bytes(matrix, nb, 0, len(flows)),
        )
        return ScheduleReport(
            schedule_name=schedule.name,
            phase_timings=(timing,),
            flow_start_s=np.zeros(len(flows)),
            flow_drain_s=drain,
            completion_s=rep.completion_s,
            propagation_ms=rep.propagation_ms,
            flow_bytes=nb,
            links=matrix.links,
            capacity_gbps=matrix.capacity_gbps,
            link_total_bytes=link_total,
            peak_throughput_gbps=rep.throughput_gbps,
            is_wan=matrix.is_wan,
            weights=weights,
            max_slot_occ=rep.max_slot_occ,
        )

    if incremental is None:
        incremental = INCREMENTAL_EVENT_LOOP
    return _simulate_events(
        schedule, matrix, nb, slices, link_total, weights,
        incremental=incremental,
    )


def _simulate_events(
    schedule,
    matrix: LinkLoadMatrix,
    nb: np.ndarray,
    slices: List[Tuple[int, int]],
    link_total: np.ndarray,
    weights: Optional[np.ndarray] = None,
    incremental: bool = True,
) -> ScheduleReport:
    import heapq

    phases = schedule.phases
    nphases = len(phases)
    nflows = int(nb.size)
    prop_ms = _propagation_ms(matrix)
    name_to_idx = {p.name: i for i, p in enumerate(phases)}
    dependents: List[List[int]] = [[] for _ in range(nphases)]
    pending = np.zeros(nphases, dtype=np.int64)
    for i, p in enumerate(phases):
        pending[i] = len(p.deps)
        for d in p.deps:
            dependents[name_to_idx[d]].append(i)

    remaining = nb * 8.0  # bits still to transfer
    active = np.zeros(nflows, dtype=bool)
    flow_phase = np.empty(nflows, dtype=np.int64)
    for i, (plo, phi) in enumerate(slices):
        flow_phase[plo:phi] = i
    undrained = np.asarray([hi - lo for lo, hi in slices], dtype=np.int64)
    flow_start = np.zeros(nflows)
    flow_drain = np.zeros(nflows)
    flow_complete = np.zeros(nflows)
    phase_start = np.zeros(nphases)
    phase_end = np.zeros(nphases)
    alloc_cls = _IncrementalAllocator if incremental else _FullEpochAllocator
    alloc = alloc_cls(matrix, weights)
    rates = alloc.rates
    # flows that joined/left the active set since the last allocation epoch —
    # handed to the allocator as one batch at the next stale re-solve
    pend_add: List[np.ndarray] = []
    pend_rm: List[np.ndarray] = []
    _empty = np.empty(0, dtype=np.int64)

    _START, _COMPLETE = 0, 1
    heap: List[Tuple[float, int, int, int]] = []
    seq = 0
    for i, p in enumerate(phases):
        if not p.deps:
            heapq.heappush(heap, (p.start_offset_s, seq, _START, i))
            seq += 1

    def finish_phase(i: int, t: float) -> float:
        """Completion time of phase i once its last flow has drained."""
        plo, phi = slices[i]
        end = phase_start[i] + phases[i].compute_seconds
        if phi > plo:
            end = max(end, float(flow_complete[plo:phi].max()))
        return max(end, t)

    t = 0.0
    stale = True
    guard = 0
    max_events = _event_budget(nflows, nphases)
    while heap or bool(active.any()):
        guard += 1
        if guard > max_events:
            raise RuntimeError(
                f"schedule {schedule.name!r}: event budget exceeded "
                f"({max_events}) — simulator stuck"
            )
        act_idx = np.nonzero(active)[0]
        if stale and act_idx.size:
            added = np.concatenate(pend_add) if pend_add else _empty
            removed = np.concatenate(pend_rm) if pend_rm else _empty
            pend_add.clear()
            pend_rm.clear()
            alloc.update(active, added, removed)
            rates = alloc.rates
            stale = False
        if act_idx.size:
            with np.errstate(divide="ignore", invalid="ignore"):
                ttd = remaining[act_idx] / (rates[act_idx] * 1e9)
            t_drain = float(ttd.min())
        else:
            ttd = None
            t_drain = np.inf
        t_heap = heap[0][0] if heap else np.inf
        if not np.isfinite(t_drain) and not heap:
            raise RuntimeError(
                f"schedule {schedule.name!r}: active flows can make no "
                "progress (zero-capacity path?)"
            )
        if t_heap <= t + t_drain:
            # advance to the heap event; in-flight transfers progress
            dt = max(t_heap - t, 0.0)
            if act_idx.size and dt > 0:
                remaining[act_idx] -= rates[act_idx] * 1e9 * dt
            t = t_heap
            while heap and heap[0][0] <= t:
                _, _, kind, i = heapq.heappop(heap)
                plo, phi = slices[i]
                if kind == _START:
                    phase_start[i] = t
                    flow_start[plo:phi] = t
                    zero = plo + np.nonzero(nb[plo:phi] <= 0)[0]
                    if zero.size:
                        flow_drain[zero] = t
                        flow_complete[zero] = t + prop_ms[zero] / 1e3
                        undrained[i] -= zero.size
                    live = plo + np.nonzero(nb[plo:phi] > 0)[0]
                    if live.size:
                        active[live] = True
                        pend_add.append(live)
                        stale = True
                    if undrained[i] == 0:
                        heapq.heappush(
                            heap, (finish_phase(i, t), seq, _COMPLETE, i)
                        )
                        seq += 1
                else:  # _COMPLETE
                    phase_end[i] = t
                    for q in dependents[i]:
                        pending[q] -= 1
                        if pending[q] == 0:
                            start = (
                                max(phase_end[name_to_idx[d]] for d in phases[q].deps)
                                + phases[q].start_offset_s
                            )
                            heapq.heappush(heap, (start, seq, _START, q))
                            seq += 1
            continue
        # advance to the next drain group
        group = act_idx[ttd <= t_drain * (1.0 + _DRAIN_GROUP_RTOL) + 1e-15]
        remaining[act_idx] -= rates[act_idx] * 1e9 * t_drain
        t += t_drain
        remaining[group] = 0.0
        active[group] = False
        flow_drain[group] = t
        flow_complete[group] = t + prop_ms[group] / 1e3
        pend_rm.append(group)
        stale = True
        undrained -= np.bincount(flow_phase[group], minlength=nphases)
        for i in np.unique(flow_phase[group]).tolist():
            if undrained[i] == 0:
                heapq.heappush(heap, (finish_phase(i, t), seq, _COMPLETE, i))
                seq += 1

    timings = tuple(
        PhaseTiming(
            name=p.name,
            start_s=float(phase_start[i]),
            end_s=float(phase_end[i]),
            flow_lo=slices[i][0],
            flow_hi=slices[i][1],
            wan_bytes=_phase_wan_bytes(matrix, nb, *slices[i]),
            compute_seconds=p.compute_seconds,
        )
        for i, p in enumerate(phases)
    )
    return ScheduleReport(
        schedule_name=schedule.name,
        phase_timings=timings,
        flow_start_s=flow_start,
        flow_drain_s=flow_drain,
        completion_s=flow_complete,
        propagation_ms=prop_ms,
        flow_bytes=nb,
        links=matrix.links,
        capacity_gbps=matrix.capacity_gbps,
        link_total_bytes=link_total,
        peak_throughput_gbps=alloc.peak,
        is_wan=matrix.is_wan,
        weights=weights,
        max_slot_occ=(
            matrix.max_slot_occ if matrix.slot_occ is not None else None
        ),
    )
