"""Failure detection and convergence timing (paper §3.4, §5.3).

Two detection regimes, both as explicit state machines over a simulated
clock so the experiments are deterministic:

* :class:`BfdSession` — Bidirectional Forwarding Detection (RFC 5880)
  async mode: a failure is declared after ``detect_mult`` consecutive missed
  control packets, i.e. ``detect_time = detect_mult * interval``.  With the
  paper's settings (10 ms interval, 3 retries) detection takes ~30 ms and
  end-to-end recovery — detection + BGP withdrawal propagation + FIB
  reprogram — lands near the ~110 ms the paper measures (Fig. 9).

* :class:`BgpHoldTimer` — default BGP keepalive/hold timers (60 s / 180 s):
  the session only drops after the 180 s hold timer expires (Fig. 13).

:class:`FailureDetector` wires either regime to the fabric+EVPN pair and
reports the recovery timeline; ``runtime/failure.py`` reuses the same state
machine for training-process heartbeats (the TPU-side adaptation).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .evpn import EvpnControlPlane, EvpnResyncStats
from .fabric import Fabric, RerouteStats


class BfdState(enum.Enum):
    ADMIN_DOWN = "AdminDown"
    DOWN = "Down"
    INIT = "Init"
    UP = "Up"


@dataclass
class BfdSession:
    """RFC 5880 async-mode session between two directly connected peers."""

    local: str
    remote: str
    interval_ms: float = 10.0
    detect_mult: int = 3
    state: BfdState = BfdState.DOWN
    last_rx_ms: float = 0.0

    @property
    def detect_time_ms(self) -> float:
        return self.interval_ms * self.detect_mult

    def bring_up(self, now_ms: float) -> None:
        # three-way handshake: Down -> Init -> Up; we collapse the handshake
        # (sub-interval) and record the session live.
        self.state = BfdState.UP
        self.last_rx_ms = now_ms

    def on_rx(self, now_ms: float) -> None:
        if self.state != BfdState.ADMIN_DOWN:
            self.state = BfdState.UP
            self.last_rx_ms = now_ms

    def poll(self, now_ms: float) -> BfdState:
        """Advance the detection timer; returns the (possibly new) state."""
        if self.state == BfdState.UP and now_ms - self.last_rx_ms > self.detect_time_ms:
            self.state = BfdState.DOWN
        return self.state

    def time_to_detect(self, failure_at_ms: float) -> float:
        """Absolute time at which this session declares the peer down."""
        # last control packet arrives just before the failure
        return failure_at_ms + self.detect_time_ms


@dataclass
class BgpHoldTimer:
    """Default-timer BGP session: death only via hold-timer expiry."""

    local: str
    remote: str
    keepalive_s: float = 60.0
    hold_s: float = 180.0

    def time_to_detect(self, failure_at_ms: float) -> float:
        return failure_at_ms + self.hold_s * 1e3


#: Empirical constants for the post-detection pipeline, calibrated so that
#: the default BFD configuration reproduces the paper's ~110 ms recovery:
#: 30 ms detection + withdrawal propagation + best-path rerun + FIB update.
WITHDRAWAL_PROPAGATION_MS_PER_HOP = 12.0
BEST_PATH_RERUN_MS = 25.0
FIB_UPDATE_MS = 18.0


@dataclass
class RecoveryTimeline:
    failure_at_ms: float
    detected_at_ms: float
    converged_at_ms: float
    mechanism: str
    events: List[Tuple[float, str]] = field(default_factory=list)
    #: what the FIB reprogram actually did: incremental re-convergence
    #: stats from the fabric (None for timelines built before any reroute).
    reroute: Optional[RerouteStats] = None
    #: what the control plane did alongside: incremental EVPN resync stats
    #: (None when no EVPN control plane is attached).
    evpn_resync: Optional[EvpnResyncStats] = None

    @property
    def recovery_ms(self) -> float:
        return self.converged_at_ms - self.failure_at_ms


class FailureDetector:
    """Drives link failure -> detection -> EVPN withdrawal -> reroute."""

    def __init__(self, fabric: Fabric, evpn: Optional[EvpnControlPlane] = None):
        self.fabric = fabric
        self.evpn = evpn

    def fail_and_recover(
        self,
        link: Tuple[str, str],
        *,
        mechanism: str = "bfd",
        failure_at_ms: float = 0.0,
        bfd_interval_ms: float = 10.0,
        bfd_detect_mult: int = 3,
        bgp_hold_s: float = 180.0,
        propagation_hops: int = 3,
    ) -> RecoveryTimeline:
        """Fail ``link`` and compute the convergence timeline.

        ``propagation_hops`` — BGP withdrawal hops to the farthest affected
        speaker (leaf -> spine -> remote spine -> remote leaf = 3 in the
        paper's topology).
        """
        u, v = link
        events: List[Tuple[float, str]] = [(failure_at_ms, f"link {u}<->{v} down")]
        if mechanism == "bfd":
            session = BfdSession(u, v, interval_ms=bfd_interval_ms, detect_mult=bfd_detect_mult)
            session.bring_up(failure_at_ms)
            detected = session.time_to_detect(failure_at_ms)
            events.append((detected, f"BFD detect ({session.detect_time_ms:.0f} ms timer)"))
        elif mechanism == "bgp":
            timer = BgpHoldTimer(u, v, hold_s=bgp_hold_s)
            detected = timer.time_to_detect(failure_at_ms)
            events.append((detected, f"BGP hold timer expiry ({bgp_hold_s:.0f} s)"))
        else:
            raise ValueError(f"unknown mechanism {mechanism!r}")

        # the routing system reacts identically once the session is down
        t = detected
        t += WITHDRAWAL_PROPAGATION_MS_PER_HOP * propagation_hops
        events.append((t, f"withdrawals propagated ({propagation_hops} hops)"))
        t += BEST_PATH_RERUN_MS
        events.append((t, "best-path recomputed"))
        t += FIB_UPDATE_MS

        # apply to the live emulation: the fabric re-converges incrementally,
        # touching only the destinations whose shortest-path DAG crossed the
        # failed link — the emulation analogue of a surgical FIB update
        # (full-table reprogramming is what made BFD-cadence flaps
        # intractable on scaled topologies).
        stats = self.fabric.fail_link(u, v)
        events.append(
            (
                t,
                "FIB reprogrammed; traffic rerouted "
                f"(incremental: {stats.patched} tables patched in place, "
                f"{stats.rebuilt} rebuilt, {stats.retained} untouched)",
            )
        )
        evpn_stats: Optional[EvpnResyncStats] = None
        if self.evpn is not None:
            # control plane re-converges as surgically as the FIB: only
            # VTEPs whose route reachability crossed the failed link.
            evpn_stats = self.evpn.resync_incremental(stats)
            events.append(
                (
                    t,
                    "EVPN resynced incrementally "
                    f"({evpn_stats.patched} RIBs patched, "
                    f"{evpn_stats.rebuilt} VTEP tables rebuilt, "
                    f"{evpn_stats.retained} speakers untouched)",
                )
            )
        return RecoveryTimeline(
            failure_at_ms=failure_at_ms,
            detected_at_ms=detected,
            converged_at_ms=t,
            mechanism=mechanism,
            events=events,
            reroute=stats,
            evpn_resync=evpn_stats,
        )

    def restore(self, link: Tuple[str, str]) -> RerouteStats:
        stats = self.fabric.restore_link(*link)
        if self.evpn is not None:
            self.evpn.resync_incremental(stats)
        return stats

    def fail_group(
        self,
        links: Sequence[Tuple[str, str]],
        *,
        mechanism: str = "bfd",
        failure_at_ms: float = 0.0,
        label: str = "group",
        bfd_interval_ms: float = 10.0,
        bfd_detect_mult: int = 3,
        bgp_hold_s: float = 180.0,
        propagation_hops: int = 3,
    ) -> Tuple[RecoveryTimeline, List[RerouteStats], List[EvpnResyncStats]]:
        """Fail several links *atomically* — one shared-cause event.

        Models a spine/leaf switch death or an SRLG fiber cut: every
        member link's BFD session times out in parallel (one detection
        window, not one per link), the withdrawal/best-path/FIB pipeline
        runs once, and the per-link re-convergence + EVPN resync are
        applied in deterministic (sorted-input) order.  The routing state
        after the group failure is byte-for-byte what sequential
        :meth:`Fabric.fail_link` calls in the same order produce — the
        incremental re-converger composes — which the
        ``bench_resilience`` SRLG gate pins.

        Returns the single shared :class:`RecoveryTimeline` (its
        ``reroute``/``evpn_resync`` fields stay ``None``; the per-link
        stats come back as lists so callers don't double-count).
        """
        links = [tuple(l) for l in links]
        if not links:
            raise ValueError(f"{label}: no links to fail")
        u, v = links[0]
        events: List[Tuple[float, str]] = [
            (failure_at_ms, f"{label}: {len(links)} links down")
        ]
        if mechanism == "bfd":
            session = BfdSession(
                u, v, interval_ms=bfd_interval_ms, detect_mult=bfd_detect_mult
            )
            session.bring_up(failure_at_ms)
            detected = session.time_to_detect(failure_at_ms)
            events.append(
                (
                    detected,
                    f"BFD detect on all {len(links)} sessions "
                    f"({session.detect_time_ms:.0f} ms timer, parallel)",
                )
            )
        elif mechanism == "bgp":
            timer = BgpHoldTimer(u, v, hold_s=bgp_hold_s)
            detected = timer.time_to_detect(failure_at_ms)
            events.append((detected, f"BGP hold timer expiry ({bgp_hold_s:.0f} s)"))
        else:
            raise ValueError(f"unknown mechanism {mechanism!r}")

        t = detected
        t += WITHDRAWAL_PROPAGATION_MS_PER_HOP * propagation_hops
        events.append((t, f"withdrawals propagated ({propagation_hops} hops)"))
        t += BEST_PATH_RERUN_MS
        events.append((t, "best-path recomputed"))
        t += FIB_UPDATE_MS

        reroutes: List[RerouteStats] = []
        resyncs: List[EvpnResyncStats] = []
        for lu, lv in links:
            stats = self.fabric.fail_link(lu, lv)
            reroutes.append(stats)
            events.append(
                (
                    t,
                    f"FIB reprogrammed for {lu}<->{lv} "
                    f"({stats.patched} patched, {stats.rebuilt} rebuilt, "
                    f"{stats.retained} untouched)",
                )
            )
            if self.evpn is not None:
                es = self.evpn.resync_incremental(stats)
                resyncs.append(es)
        timeline = RecoveryTimeline(
            failure_at_ms=failure_at_ms,
            detected_at_ms=detected,
            converged_at_ms=t,
            mechanism=mechanism,
            events=events,
        )
        return timeline, reroutes, resyncs

    def restore_group(
        self, links: Sequence[Tuple[str, str]]
    ) -> List[RerouteStats]:
        """Restore several links in deterministic input order (each with
        its incremental EVPN resync), the inverse of :meth:`fail_group`."""
        return [self.restore(tuple(l)) for l in links]
