"""Gray-failure detection: SLA probes over per-pair rate/RTT observations.

BFD (:mod:`repro.core.bfd`) answers "is the link *up*?" — its keepalives
are a few bytes every 10 ms, so a bandwidth brownout, a loss spike, or
latency inflation sails straight through it: the session stays UP while
the WAN silently eats the training budget.  This module is the sibling
state machine for the *gray* regime, with the same simulated-clock
discipline as :class:`~repro.core.bfd.BfdSession`:

* :class:`SlaProbe` — threshold-with-hysteresis over an observed
  per-DC-pair transfer rate and RTT: ``trip_after`` consecutive breaching
  observations trip the probe to DEGRADED, ``recover_after`` consecutive
  healthy ones recover it — a single noisy sample moves nothing in either
  direction.

* :class:`SlaProbeBank` — one probe per monitored DC pair, calibrated
  against a healthy-fabric baseline (thresholds are *fractions* of the
  calibrated rate/RTT, so one knob set covers asymmetric per-pair WANs),
  recording every state transition as a :class:`ProbeTransition`.

The scenario runner feeds the bank from the congestion reports of each
step's costed schedule (per-pair achieved WAN rate) plus the jitter-free
leader RTT, and a :class:`~repro.scenario.spec.DegradationPolicy` reacts
to trips — see :func:`repro.scenario.runner.run_scenario`.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = [
    "ProbeState",
    "ProbeTransition",
    "SlaProbe",
    "SlaProbeBank",
]


class ProbeState(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"


@dataclass(frozen=True)
class ProbeTransition:
    """One probe state change: which pair, when, to what, on which sample."""

    pair: Tuple[int, int]
    at_ms: float
    state: ProbeState
    rate_gbps: float
    rtt_ms: float

    def to_dict(self) -> Dict[str, object]:
        return {
            "pair": list(self.pair),
            "at_ms": float(self.at_ms),
            "state": self.state.value,
            "rate_gbps": float(self.rate_gbps),
            "rtt_ms": float(self.rtt_ms),
        }


@dataclass
class SlaProbe:
    """Threshold-with-hysteresis gray-failure detector for one DC pair.

    An observation *breaches* when the rate falls below ``rate_floor_gbps``
    (0 disables the rate check — e.g. a pair that carries no baseline
    traffic) or the RTT exceeds ``rtt_ceiling_ms`` (``inf`` disables it).
    ``trip_after`` consecutive breaches trip HEALTHY -> DEGRADED;
    ``recover_after`` consecutive clean observations recover it.  The
    simulated clock must advance monotonically, exactly like
    :class:`~repro.core.bfd.BfdSession`.
    """

    pair: Tuple[int, int]
    rate_floor_gbps: float = 0.0
    rtt_ceiling_ms: float = math.inf
    trip_after: int = 2
    recover_after: int = 2
    state: ProbeState = ProbeState.HEALTHY
    bad_streak: int = 0
    good_streak: int = 0
    last_observed_ms: float = -math.inf
    last_rate_gbps: float = math.nan
    last_rtt_ms: float = math.nan

    def __post_init__(self):
        if self.trip_after < 1:
            raise ValueError("trip_after must be >= 1")
        if self.recover_after < 1:
            raise ValueError("recover_after must be >= 1")
        if self.rate_floor_gbps < 0.0:
            raise ValueError("rate_floor_gbps must be >= 0")

    def breaches(self, *, rate_gbps: float, rtt_ms: float) -> bool:
        return rate_gbps < self.rate_floor_gbps or rtt_ms > self.rtt_ceiling_ms

    def observe(self, now_ms: float, *, rate_gbps: float, rtt_ms: float) -> ProbeState:
        """Feed one measurement; returns the (possibly new) state."""
        if now_ms < self.last_observed_ms:
            raise ValueError(
                f"probe clock moved backwards ({now_ms} < {self.last_observed_ms})"
            )
        self.last_observed_ms = now_ms
        self.last_rate_gbps = rate_gbps
        self.last_rtt_ms = rtt_ms
        if self.breaches(rate_gbps=rate_gbps, rtt_ms=rtt_ms):
            self.bad_streak += 1
            self.good_streak = 0
            if self.state == ProbeState.HEALTHY and self.bad_streak >= self.trip_after:
                self.state = ProbeState.DEGRADED
        else:
            self.good_streak += 1
            self.bad_streak = 0
            if self.state == ProbeState.DEGRADED and self.good_streak >= self.recover_after:
                self.state = ProbeState.HEALTHY
        return self.state


@dataclass
class SlaProbeBank:
    """One :class:`SlaProbe` per monitored DC pair, relative thresholds.

    :meth:`calibrate` fixes a pair's healthy baseline ``(rate, rtt)`` and
    instantiates its probe with absolute thresholds
    ``rate_floor_frac * rate`` / ``rtt_ceiling_frac * rtt``; a pair
    observed before calibration self-calibrates on its first sample (the
    probe learns steady state, then watches for deviation).  Every state
    change lands in ``transitions``.
    """

    rate_floor_frac: float = 0.5
    rtt_ceiling_frac: float = 2.0
    trip_after: int = 2
    recover_after: int = 2
    probes: Dict[Tuple[int, int], SlaProbe] = field(default_factory=dict)
    baselines: Dict[Tuple[int, int], Tuple[float, float]] = field(default_factory=dict)
    transitions: List[ProbeTransition] = field(default_factory=list)

    def __post_init__(self):
        if not 0.0 <= self.rate_floor_frac <= 1.0:
            raise ValueError("rate_floor_frac must be in [0, 1]")
        if self.rtt_ceiling_frac < 1.0:
            raise ValueError("rtt_ceiling_frac must be >= 1")

    @property
    def pairs(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted(self.probes))

    def calibrate(
        self, pair: Tuple[int, int], *, rate_gbps: float, rtt_ms: float
    ) -> SlaProbe:
        pair = tuple(pair)
        if pair in self.probes:
            raise ValueError(f"pair {pair} already calibrated")
        self.baselines[pair] = (float(rate_gbps), float(rtt_ms))
        probe = SlaProbe(
            pair=pair,
            rate_floor_gbps=self.rate_floor_frac * rate_gbps,
            rtt_ceiling_ms=(
                self.rtt_ceiling_frac * rtt_ms if rtt_ms > 0 else math.inf
            ),
            trip_after=self.trip_after,
            recover_after=self.recover_after,
        )
        self.probes[pair] = probe
        return probe

    def observe(
        self, pair: Tuple[int, int], now_ms: float, *, rate_gbps: float, rtt_ms: float
    ) -> ProbeState:
        pair = tuple(pair)
        probe = self.probes.get(pair)
        if probe is None:
            probe = self.calibrate(pair, rate_gbps=rate_gbps, rtt_ms=rtt_ms)
        before = probe.state
        after = probe.observe(now_ms, rate_gbps=rate_gbps, rtt_ms=rtt_ms)
        if after != before:
            self.transitions.append(
                ProbeTransition(
                    pair=pair,
                    at_ms=now_ms,
                    state=after,
                    rate_gbps=rate_gbps,
                    rtt_ms=rtt_ms,
                )
            )
        return after

    def tripped(self) -> Tuple[Tuple[int, int], ...]:
        """DC pairs currently DEGRADED, sorted."""
        return tuple(
            p for p in self.pairs if self.probes[p].state == ProbeState.DEGRADED
        )

    @property
    def any_degraded(self) -> bool:
        return any(p.state == ProbeState.DEGRADED for p in self.probes.values())

    def probe(self, pair: Tuple[int, int]) -> Optional[SlaProbe]:
        return self.probes.get(tuple(pair))
