"""GeoFabric — the facade joining the emulated WAN fabric to JAX training.

A :class:`GeoFabric` owns one :class:`~repro.core.fabric.Fabric` (+ EVPN +
netem) configured for ``num_pods`` data centers and exposes the quantities
the training runtime and benchmarks need:

* per-sync-strategy communication time for a gradient of ``B`` bytes —
  any name in the :func:`repro.core.schedule.register_strategy` registry
  (the paper's ``allreduce`` | ``ps`` | ``hier`` | ``hier_int8`` |
  ``local_sgd`` plus the phased/overlapped schedules) or a
  :class:`~repro.core.schedule.CollectiveSchedule` built directly —
  obtained by synthesizing the QP flows per phase, routing them through
  the emulated fabric, and costing the phase DAG with the fluid timing
  model or the event-driven congestion simulator — i.e. the Fig. 14
  pipeline, generalized to phased schedules;
* RTT and failover numbers for the runtime's failure handling;
* the WAN roofline term for multi-pod dry-runs (bytes / DCI bandwidth).

The per-host mapping: each emulated host stands for one data-center DCI
endpoint (in a real pod, the reduction result of the pod's ICI fabric), so
"worker" below = one pod's egress aggregate, matching how hierarchical
collectives concentrate WAN traffic on pod leaders.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .bfd import FailureDetector, RecoveryTimeline
from .congestion import PhaseTiming
from .evpn import EvpnControlPlane
from .fabric import Fabric, FabricConfig
from .metrics import LoadFactorResult, load_factor
from .schedule import (
    CollectiveSchedule,
    StrategyContext,
    build_schedule,
    with_compute_overlap,
)
from .tenancy import TenancyManager
from .wan import (
    Netem,
    NetemProfile,
    PAPER_LAN,
    PAPER_WAN,
    TransferResult,
    WanTimingModel,
    ping_rtt,
)


@dataclass(frozen=True)
class SyncOptions:
    """Consolidated costing knobs for :meth:`GeoFabric.sync_cost` /
    :meth:`GeoFabric.step_time`.

    One value object instead of five orthogonal kwargs threaded through
    every benchmark and example — the :mod:`repro.scenario` spec carries
    it verbatim.  Defaults are exactly the historical keyword defaults,
    and the keyword path stays available: ``sync_cost(s, B, jitter=False)``
    and ``sync_cost(s, B, options=SyncOptions(jitter=False))`` are pinned
    bit-for-bit identical (including the jitter RNG stream, which is
    sampled at the same point either way).

    ``sync_every``/``int8_ratio`` parameterize the strategy *builder*
    (local-SGD amortization, int8 WAN compression); ``jitter``/
    ``congestion``/``ecmp_weighted`` select the costing model.
    """

    sync_every: int = 8
    int8_ratio: float = 0.25
    jitter: bool = True
    congestion: bool = False
    ecmp_weighted: bool = False

    def __post_init__(self):
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if not 0.0 < self.int8_ratio <= 1.0:
            raise ValueError("int8_ratio must be in (0, 1]")

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "SyncOptions":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(
                f"unknown SyncOptions key(s) {unknown}; valid: {sorted(fields)}"
            )
        return cls(**d)

    @classmethod
    def merge(cls, options: Optional["SyncOptions"], kwargs: Dict[str, object]) -> "SyncOptions":
        """Resolve the ``options=`` / legacy-keyword dual API.

        Exactly one of the two may be used per call; mixing them raises
        (silent precedence would make ``sync_cost(o, jitter=False)`` a
        footgun), and unknown keywords raise ``TypeError`` just as the old
        explicit signature did.
        """
        if not kwargs:
            return options if options is not None else cls()
        if options is not None:
            raise TypeError(
                f"pass options=SyncOptions(...) or legacy keywords, not both "
                f"(got options and {sorted(kwargs)})"
            )
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(kwargs) - fields
        if unknown:
            raise TypeError(f"unknown sync option(s): {sorted(unknown)}")
        return cls(**kwargs)


@dataclass
class SyncCost:
    strategy: str
    wan_seconds: float
    wan_bytes: int
    bottleneck_link: Optional[Tuple[str, str]]
    load: LoadFactorResult
    sync_every: int = 1  # local_sgd amortization
    bottleneck_bytes: int = 0
    bottleneck_utilization: float = 0.0
    phases: Tuple[PhaseTiming, ...] = ()

    @property
    def amortized_seconds(self) -> float:
        return self.wan_seconds / self.sync_every


class GeoFabric:
    """Emulated geo-distributed deployment for ``num_pods`` data centers."""

    def __init__(
        self,
        num_pods: int = 2,
        workers_per_pod: int = 2,
        *,
        wan: NetemProfile = PAPER_WAN,
        lan: NetemProfile = PAPER_LAN,
        wan_pairs: Optional[Dict[Tuple[int, int], NetemProfile]] = None,
        num_channels: int = 4,
        port_scheme: str = "qp_aware",
        seed: int = 0,
        config: Optional[FabricConfig] = None,
        default_tenant: Optional[str] = "training",
    ):
        if config is not None:
            # raw-topology override (scaled scenario studies): num_pods /
            # workers_per_pod are derived from the config, not the defaults
            self.config = config
            num_pods = config.num_dcs
        else:
            hosts_per_leaf = tuple(
                tuple(
                    workers_per_pod // 3 + (1 if i < workers_per_pod % 3 else 0)
                    for i in range(3)
                )
                for _ in range(num_pods)
            )
            self.config = FabricConfig(num_dcs=num_pods, hosts_per_leaf=hosts_per_leaf)
        self.fabric = Fabric(self.config)
        self.evpn = EvpnControlPlane(self.fabric)
        self.tenancy = TenancyManager(self.fabric, self.evpn)
        self.netem = Netem(
            self.fabric, wan=wan, lan=lan, seed=seed, wan_pairs=wan_pairs
        )
        self.timing = WanTimingModel(self.netem)
        self.detector = FailureDetector(self.fabric, self.evpn)
        self.num_pods = num_pods
        self.num_channels = num_channels
        self.port_scheme = port_scheme
        # attach every host to the training tenant by default; tenancy
        # scenarios pass default_tenant=None and lay out their own VNIs
        if default_tenant is not None:
            self.tenancy.create_tenant(default_tenant, vni=100)
            for name in sorted(self.fabric.hosts):
                self.tenancy.attach(default_tenant, name)

    # -- host roles ----------------------------------------------------------

    def workers(self, pod: Optional[int] = None) -> List[str]:
        names = sorted(self.fabric.hosts)
        if pod is None:
            return names
        return [n for n in names if self.fabric.hosts[n].dc == pod]

    def pod_leaders(self) -> List[str]:
        """First host of each DC acts as the WAN/DCI endpoint."""
        return [self.workers(pod)[0] for pod in range(1, self.num_pods + 1)]

    # -- paper metrics -------------------------------------------------------

    def rtt_ms(self, count: int = 32) -> np.ndarray:
        leaders = self.pod_leaders()
        if len(leaders) < 2:
            return np.zeros(count)
        return ping_rtt(self.netem, leaders[0], leaders[1], count=count)

    def failover(self, *, mechanism: str = "bfd", **kw) -> RecoveryTimeline:
        wan_link = sorted(self.fabric.wan_links[0])
        return self.detector.fail_and_recover((wan_link[0], wan_link[1]), mechanism=mechanism, **kw)

    # -- sync-strategy costing (Fig. 14 pipeline + beyond-paper schedules) ---

    def strategy_context(self, exclude_pods: Tuple[int, ...] = ()) -> StrategyContext:
        """Topology facts for :mod:`repro.core.schedule` strategy builders.

        ``exclude_pods`` drops dead pods from the context (post-remesh
        graceful degradation: survivors keep synchronizing among
        themselves); excluding every pod raises.
        """
        dead = set(exclude_pods)
        pods = [p for p in range(1, self.num_pods + 1) if p not in dead]
        if not pods:
            raise ValueError("cannot exclude every pod from the strategy context")
        return StrategyContext(
            pod_workers=tuple(tuple(self.workers(pod)) for pod in pods),
            num_channels=self.num_channels,
            port_scheme=self.port_scheme,
        )

    def build_schedule(
        self,
        strategy: Union[str, CollectiveSchedule],
        grad_bytes: int = 0,
        *,
        options: Optional[SyncOptions] = None,
        **kwargs,
    ) -> CollectiveSchedule:
        """Resolve ``strategy`` to a :class:`CollectiveSchedule`.

        A string is looked up in the :func:`repro.core.schedule.register_strategy`
        registry and built against this fabric's topology; a schedule
        object passes through untouched.  Builder knobs come from
        ``options`` (a :class:`SyncOptions`) or the legacy ``sync_every``/
        ``int8_ratio`` keywords.
        """
        opts = SyncOptions.merge(options, kwargs)
        if isinstance(strategy, CollectiveSchedule):
            return strategy
        if grad_bytes <= 0:
            raise ValueError(
                f"strategy {strategy!r} needs grad_bytes > 0, got {grad_bytes}"
            )
        return build_schedule(
            strategy,
            self.strategy_context(),
            grad_bytes,
            sync_every=opts.sync_every,
            int8_ratio=opts.int8_ratio,
        )

    def sync_cost(
        self,
        strategy: Union[str, CollectiveSchedule],
        grad_bytes: int = 0,
        *,
        options: Optional[SyncOptions] = None,
        **kwargs,
    ) -> SyncCost:
        """Cost one gradient synchronization under ``strategy``.

        ``strategy`` is either a registered strategy name (the paper's
        ``allreduce`` | ``ps`` | ``hier`` | ``hier_int8`` | ``local_sgd``
        plus the phased schedules — ``rs_ag_overlap``, ``rs_then_ag``,
        ``ps_phased``, ``alltoall``, ``hier_alltoall``, and anything added
        via :func:`repro.core.schedule.register_strategy`) or a
        :class:`CollectiveSchedule` built directly.  The schedule's phase
        DAG is costed end-to-end; ``SyncCost.phases`` carries the
        per-phase timeline.

        Costing knobs travel in ``options`` (one :class:`SyncOptions`
        value, the declarative-scenario path) or as the legacy keywords
        ``sync_every`` / ``int8_ratio`` / ``jitter`` / ``congestion`` /
        ``ecmp_weighted`` — the two spellings are pinned bit-for-bit
        identical, including the jitter RNG stream; mixing them raises.

        ``congestion=False`` (default) applies the fluid estimate per
        phase — each phase finishes with its most-loaded link, phases
        compose along the DAG critical path (identical to the historical
        single-flow-set costing for the paper strategies).
        ``congestion=True`` runs the event-driven time-varying max-min
        model (:meth:`~repro.core.wan.WanTimingModel.contended_schedule_time`):
        flows enter as their phase's dependencies complete, fair shares are
        re-solved at every arrival/completion, and per-flow path
        propagation is already included (so no separate RTT term).

        ``ecmp_weighted=True`` (congestion branch only) solves *weighted*
        max-min fair shares: the router's recorded hash-slot occupancy
        down-weights hash-collided flows
        (:func:`repro.core.congestion.ecmp_flow_weights`; for multi-phase
        schedules the derivation is restricted to concurrently-active
        phases — :func:`repro.core.congestion.concurrent_ecmp_flow_weights`),
        and the returned ``bottleneck_utilization`` reflects the weighted
        allocation.  The default keeps the unweighted model (bit-identical
        to the historical congestion branch).
        """
        opts = SyncOptions.merge(options, kwargs)
        schedule = self.build_schedule(strategy, grad_bytes, options=opts)
        jit = float(self.netem.rng.uniform(0, 2.0)) if opts.jitter else 0.0
        if opts.congestion:
            report = self.timing.contended_schedule_time(
                schedule,
                check_reachability=self.tenancy.reachable,
                ecmp_weighted=opts.ecmp_weighted,
            )
            link_bytes = dict(self.fabric.link_bytes)
            seconds = report.seconds + jit / 1e3
            bottleneck = report.bottleneck_link
            bottleneck_bytes = report.bottleneck_bytes
            bottleneck_util = report.bottleneck_utilization
            phase_costs = report.phase_timings
        else:
            seconds, phase_costs, result = self._fluid_schedule_cost(schedule, jit)
            link_bytes = dict(self.fabric.link_bytes)
            bottleneck = result.bottleneck_link
            bottleneck_bytes = result.bottleneck_bytes
            cap = (
                self.netem.profile(*bottleneck).effective_bandwidth_gbps
                if bottleneck is not None
                else 0.0
            )
            busy = bottleneck_bytes * 8.0 / (cap * 1e9) if cap > 0 else 0.0
            bottleneck_util = busy / seconds if seconds > 0 else 0.0
        wan_bytes = sum(
            b for (u, v), b in link_bytes.items() if self.fabric.is_wan_link(u, v)
        )
        return SyncCost(
            strategy=schedule.name,
            wan_seconds=seconds,
            wan_bytes=wan_bytes,
            bottleneck_link=bottleneck,
            load=load_factor({k: v for k, v in link_bytes.items()}),
            sync_every=schedule.sync_every,
            bottleneck_bytes=bottleneck_bytes,
            bottleneck_utilization=bottleneck_util,
            phases=phase_costs,
        )

    def _fluid_schedule_cost(
        self, schedule: CollectiveSchedule, jit_ms: float
    ) -> Tuple[float, Tuple[PhaseTiming, ...], TransferResult]:
        """Fluid (uncontended) costing of a schedule's phase DAG.

        Each flow phase is routed through the vectorized batched engine
        (byte-identical to the sequential walk, ~25x faster at scaled
        topologies) and costed as ``most-loaded-link seconds``, plus the
        leader WAN RTT for phases whose flows actually cross the WAN;
        phase ends compose along the DAG (dependencies' ends + start
        offset, and at least ``compute_seconds`` long).  The jitter sample
        and the bottleneck-link attribution over the aggregate counters
        match the historical single-phase behavior exactly.
        """
        rtt_ms = (
            self.netem.base_rtt_ms(self.pod_leaders()[0], self.pod_leaders()[-1])
            if self.num_pods > 1
            else 0.0
        )
        self.fabric.reset_counters()
        end: Dict[str, float] = {}
        phase_costs = []
        flow_lo = 0
        for phase in schedule.phases:  # topological order
            inc = self.fabric.route_flows_batched(
                phase.flows, check_reachability=self.tenancy.reachable
            )
            start = max((end[d] for d in phase.deps), default=0.0)
            start += phase.start_offset_s
            wan_inc = sum(
                b for (u, v), b in inc.items() if self.fabric.is_wan_link(u, v)
            )
            duration = 0.0
            if phase.flows:
                # LAN-only phases (e.g. hier_alltoall dispatch) don't pay
                # the inter-DC RTT
                duration = self.timing.transfer_time(
                    inc, rtt_ms=rtt_ms if wan_inc else 0.0
                ).seconds
            duration = max(duration, phase.compute_seconds)
            end[phase.name] = start + duration
            phase_costs.append(
                PhaseTiming(
                    name=phase.name,
                    start_s=start,
                    end_s=end[phase.name],
                    flow_lo=flow_lo,
                    flow_hi=flow_lo + len(phase.flows),
                    wan_bytes=wan_inc,
                    compute_seconds=phase.compute_seconds,
                )
            )
            flow_lo += len(phase.flows)
        seconds = max(end.values()) + jit_ms / 1e3
        # bottleneck attribution over the schedule-aggregate counters
        result = self.timing.transfer_time(dict(self.fabric.link_bytes))
        return seconds, tuple(phase_costs), result

    def step_time(
        self,
        strategy: Union[str, CollectiveSchedule],
        grad_bytes: int,
        compute_seconds: float,
        *,
        overlap_fraction: float = 0.0,
        options: Optional[SyncOptions] = None,
        **kwargs,
    ) -> float:
        """Per-step wall time with compute/communication overlap as DAG
        structure.

        The strategy's schedule is composed with a ``compute_seconds``
        phase (:func:`repro.core.schedule.with_compute_overlap`):
        communication may begin once the non-overlappable head of compute
        — ``(1 - overlap_fraction) * compute_seconds`` — has elapsed, and
        the step ends when both finish.  Unlike the old scalar
        ``(1 - overlap) * comm`` discount, communication can never be
        overlapped below its bandwidth floor: with full overlap the step
        costs ``max(compute, comm)``, not ``compute``.  The comm time left
        exposed beyond compute is amortized by the schedule's
        ``sync_every`` (local-SGD-style strategies).

        Knobs travel in ``options`` (:class:`SyncOptions`) or the legacy
        keywords, exactly as :meth:`sync_cost`.
        """
        opts = SyncOptions.merge(options, kwargs)
        schedule = self.build_schedule(strategy, grad_bytes, options=opts)
        overlapped = with_compute_overlap(
            schedule, compute_seconds, overlap_fraction
        )
        cost = self.sync_cost(overlapped, options=opts)
        exposed = max(cost.wan_seconds - compute_seconds, 0.0)
        return compute_seconds + exposed / cost.sync_every

    # -- roofline hook --------------------------------------------------------

    def wan_roofline_seconds(self, cross_pod_bytes_per_chip: float, chips_per_pod: int) -> float:
        """WAN term for the multi-pod roofline: the pod's aggregate cross-pod
        bytes squeezed through the DC-pair's WAN links.

        Each WAN link contributes the bandwidth its *resolved* profile
        grants (``netem.profile(u, v)`` — per-pair overrides included), not
        the class default; the uniform case keeps the historical
        ``bandwidth * n_links`` product bit-for-bit.
        """
        total_bytes = cross_pod_bytes_per_chip * chips_per_pod
        link_gbps = [
            self.netem.profile(*sorted(link)).effective_bandwidth_gbps
            for link in self.fabric.wan_links
        ]
        if not link_gbps:
            link_gbps = [self.netem.wan.effective_bandwidth_gbps]
        if all(g == link_gbps[0] for g in link_gbps):
            # uniform profiles: the historical product, bit-for-bit
            aggregate_bytes_s = link_gbps[0] * 1e9 / 8.0 * len(link_gbps)
        else:
            aggregate_bytes_s = sum(g * 1e9 / 8.0 for g in link_gbps)
        return total_bytes / aggregate_bytes_s
