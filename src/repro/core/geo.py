"""GeoFabric — the facade joining the emulated WAN fabric to JAX training.

A :class:`GeoFabric` owns one :class:`~repro.core.fabric.Fabric` (+ EVPN +
netem) configured for ``num_pods`` data centers and exposes the quantities
the training runtime and benchmarks need:

* per-sync-strategy communication time for a gradient of ``B`` bytes
  (``allreduce`` | ``ps`` | ``hier`` | ``hier_int8`` | ``local_sgd``),
  obtained by synthesizing the QP flows, routing them through the emulated
  fabric, and applying the fluid timing model — i.e. the Fig. 14 pipeline;
* RTT and failover numbers for the runtime's failure handling;
* the WAN roofline term for multi-pod dry-runs (bytes / DCI bandwidth).

The per-host mapping: each emulated host stands for one data-center DCI
endpoint (in a real pod, the reduction result of the pod's ICI fabric), so
"worker" below = one pod's egress aggregate, matching how hierarchical
collectives concentrate WAN traffic on pod leaders.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bfd import FailureDetector, RecoveryTimeline
from .evpn import EvpnControlPlane
from .fabric import Fabric, FabricConfig
from .flows import (
    hierarchical_flows,
    parameter_server_flows,
    ring_allreduce_flows,
    route_flows,
)
from .metrics import LoadFactorResult, load_factor
from .tenancy import TenancyManager
from .wan import (
    Netem,
    NetemProfile,
    PAPER_LAN,
    PAPER_WAN,
    TransferResult,
    WanTimingModel,
    ping_rtt,
)

SYNC_STRATEGIES = ("allreduce", "ps", "hier", "hier_int8", "local_sgd")


@dataclass
class SyncCost:
    strategy: str
    wan_seconds: float
    wan_bytes: int
    bottleneck_link: Optional[Tuple[str, str]]
    load: LoadFactorResult
    sync_every: int = 1  # local_sgd amortization

    @property
    def amortized_seconds(self) -> float:
        return self.wan_seconds / self.sync_every


class GeoFabric:
    """Emulated geo-distributed deployment for ``num_pods`` data centers."""

    def __init__(
        self,
        num_pods: int = 2,
        workers_per_pod: int = 2,
        *,
        wan: NetemProfile = PAPER_WAN,
        lan: NetemProfile = PAPER_LAN,
        num_channels: int = 4,
        port_scheme: str = "qp_aware",
        seed: int = 0,
    ):
        hosts_per_leaf = tuple(
            tuple(
                workers_per_pod // 3 + (1 if i < workers_per_pod % 3 else 0) for i in range(3)
            )
            for _ in range(num_pods)
        )
        self.config = FabricConfig(num_dcs=num_pods, hosts_per_leaf=hosts_per_leaf)
        self.fabric = Fabric(self.config)
        self.evpn = EvpnControlPlane(self.fabric)
        self.tenancy = TenancyManager(self.fabric, self.evpn)
        self.netem = Netem(self.fabric, wan=wan, lan=lan, seed=seed)
        self.timing = WanTimingModel(self.netem)
        self.detector = FailureDetector(self.fabric, self.evpn)
        self.num_pods = num_pods
        self.num_channels = num_channels
        self.port_scheme = port_scheme
        # attach every host to the training tenant by default
        self.tenancy.create_tenant("training", vni=100)
        for name in sorted(self.fabric.hosts):
            self.tenancy.attach("training", name)

    # -- host roles ----------------------------------------------------------

    def workers(self, pod: Optional[int] = None) -> List[str]:
        names = sorted(self.fabric.hosts)
        if pod is None:
            return names
        return [n for n in names if self.fabric.hosts[n].dc == pod]

    def pod_leaders(self) -> List[str]:
        """First host of each DC acts as the WAN/DCI endpoint."""
        return [self.workers(pod)[0] for pod in range(1, self.num_pods + 1)]

    # -- paper metrics -------------------------------------------------------

    def rtt_ms(self, count: int = 32) -> np.ndarray:
        leaders = self.pod_leaders()
        if len(leaders) < 2:
            return np.zeros(count)
        return ping_rtt(self.netem, leaders[0], leaders[1], count=count)

    def failover(self, *, mechanism: str = "bfd", **kw) -> RecoveryTimeline:
        wan_link = sorted(self.fabric.wan_links[0])
        return self.detector.fail_and_recover((wan_link[0], wan_link[1]), mechanism=mechanism, **kw)

    # -- sync-strategy costing (Fig. 14 pipeline + beyond-paper schedules) ---

    def sync_cost(
        self,
        strategy: str,
        grad_bytes: int,
        *,
        sync_every: int = 8,
        int8_ratio: float = 0.25,  # fp32 -> int8 + per-block scales
        jitter: bool = True,
        congestion: bool = False,
    ) -> SyncCost:
        """Cost one gradient synchronization under ``strategy``.

        ``allreduce`` — flat ring over all workers in all DCs (paper M2);
        ``ps``        — central server in DC1, push+pull (paper M1);
        ``hier``      — intra-pod reduce-scatter (LAN, overlapped/ignored at
                        WAN granularity) + leader ring carrying 1/n_local of
                        the bytes over the WAN + intra-pod all-gather;
        ``hier_int8`` — ``hier`` with the WAN payload int8-compressed;
        ``local_sgd`` — ``hier`` executed once every ``sync_every`` steps.

        ``congestion=True`` swaps the ideal aggregate-bytes fluid estimate
        for the flow-level max-min model
        (:meth:`~repro.core.wan.WanTimingModel.contended_transfer_time`):
        the sync finishes with its slowest contended flow, with per-flow
        path propagation already included (so no separate RTT term).
        """
        if strategy not in SYNC_STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; want one of {SYNC_STRATEGIES}")
        kw = dict(
            num_channels=self.num_channels,
            scheme=self.port_scheme,
        )
        every = 1
        if strategy == "allreduce":
            flows = ring_allreduce_flows(self.workers(), grad_bytes, **kw)
        elif strategy == "ps":
            workers = self.workers()
            flows = parameter_server_flows(workers[0], workers[1:], grad_bytes, **kw)
        else:
            n_local = max(len(self.workers(1)), 1)
            shard = grad_bytes // n_local
            if strategy == "hier_int8":
                shard = int(shard * int8_ratio)
            if strategy == "local_sgd":
                every = sync_every
            flows = hierarchical_flows(self.pod_leaders(), shard, **kw)
        jit = float(self.netem.rng.uniform(0, 2.0)) if jitter else 0.0
        if congestion:
            report = self.timing.contended_transfer_time(
                flows, check_reachability=self.tenancy.reachable
            )
            link_bytes = dict(self.fabric.link_bytes)
            result = TransferResult(
                seconds=report.seconds + jit / 1e3,
                bottleneck_link=report.bottleneck_link,
                bottleneck_bytes=0,
            )
        else:
            link_bytes = route_flows(
                self.fabric, flows, check_reachability=self.tenancy.reachable
            )
            rtt = (
                self.netem.base_rtt_ms(self.pod_leaders()[0], self.pod_leaders()[-1])
                if self.num_pods > 1
                else 0.0
            )
            result = self.timing.transfer_time(link_bytes, rtt_ms=rtt, jitter_sample_ms=jit)
        wan_bytes = sum(
            b for (u, v), b in link_bytes.items() if self.fabric.is_wan_link(u, v)
        )
        wan_links = [
            b for (u, v), b in link_bytes.items() if self.fabric.is_wan_link(u, v)
        ]
        return SyncCost(
            strategy=strategy,
            wan_seconds=result.seconds,
            wan_bytes=wan_bytes,
            bottleneck_link=result.bottleneck_link,
            load=load_factor({k: v for k, v in link_bytes.items()}),
            sync_every=every,
        )

    def step_time(
        self,
        strategy: str,
        grad_bytes: int,
        compute_seconds: float,
        *,
        overlap_fraction: float = 0.0,
        **kw,
    ) -> float:
        """Per-step wall time = compute + (1 - overlap) * amortized comm."""
        cost = self.sync_cost(strategy, grad_bytes, **kw)
        comm = cost.amortized_seconds * (1.0 - overlap_fraction)
        return compute_seconds + comm

    # -- roofline hook --------------------------------------------------------

    def wan_roofline_seconds(self, cross_pod_bytes_per_chip: float, chips_per_pod: int) -> float:
        """WAN term for the multi-pod roofline: the pod's aggregate cross-pod
        bytes squeezed through the DC-pair's WAN links."""
        total_bytes = cross_pod_bytes_per_chip * chips_per_pod
        wan_bw_bytes = self.netem.wan.bandwidth_gbps * 1e9 / 8.0
        n_links = max(len(self.fabric.wan_links), 1)
        return total_bytes / (wan_bw_bytes * n_links)
