"""WAN link emulation (netem semantics) and deterministic transfer timing.

The paper injects a fixed 5 ms delay + 1 ms jitter per inter-DC link with
ContainerLab's ``netem`` and measures ~22 ms host-to-host RTT (Fig. 8) and
~800 Mbit/s effective spine-link throughput during training (§5.5).  This
module reproduces both:

* :class:`Netem` — per-link profile resolution (``profile(u, v)``): link-class
  delay/jitter/bandwidth/loss defaults, overridable per DC pair
  (``wan_pairs`` — the asymmetric-WAN axis) and per individual link;
* :func:`ping_rtt` — RTT samples along a fabric path (Fig. 8);
* :class:`WanTimingModel` — deterministic per-collective transfer times used
  by the Fig. 14 reproduction and by the geo-runtime's step-time estimator:
  ``time = bytes_on_bottleneck / bw + propagation + jitter`` — plus
  :meth:`WanTimingModel.contended_transfer_time`, which replaces the ideal
  aggregate-bytes fluid estimate with the flow-level max-min congestion
  model of :mod:`repro.core.congestion` (paper §5.5's ~800 Mbit/s
  effective spine throughput emerges from it rather than being assumed),
  and :meth:`WanTimingModel.contended_schedule_time`, its event-driven
  generalization to phased :class:`repro.core.schedule.CollectiveSchedule`
  DAGs with time-varying flow sets.

All randomness flows through a seeded ``numpy`` Generator: runs are
bit-reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .fabric import Fabric, Link


@dataclass(frozen=True)
class NetemProfile:
    """netem parameters for one link class.

    As in the paper's ContainerLab setup, ``netem`` qdiscs sit on *both*
    interfaces of a link, so one link traversal pays the delay (and samples
    the jitter) twice — this is what turns the paper's "5 ms per link" into
    the observed ~22 ms host-to-host RTT across a single WAN link (Fig. 8).
    """

    delay_ms: float = 0.0
    jitter_ms: float = 0.0
    bandwidth_gbps: float = 10.0
    loss: float = 0.0

    @property
    def effective_bandwidth_gbps(self) -> float:
        """Goodput after loss-induced retransmission: ``bw * (1 - loss)``.

        The fluid/congestion models consume this, not the raw line rate, so
        a loss spike injected by a gray-failure event shows up as a
        bandwidth brownout without a packet-level model.  ``loss=0`` keeps
        the historical value bit-for-bit (``bw * 1.0``).
        """
        return self.bandwidth_gbps * (1.0 - self.loss)


def degraded_profile(
    base: NetemProfile,
    *,
    bandwidth_fraction: float = 1.0,
    extra_delay_ms: float = 0.0,
    extra_loss: float = 0.0,
) -> NetemProfile:
    """``base`` under a gray failure: a bandwidth brownout, latency
    inflation, and/or a loss spike — always derived from the pristine
    profile, so re-degrading replaces rather than compounds."""
    if not 0.0 < bandwidth_fraction <= 1.0:
        raise ValueError(
            f"bandwidth_fraction must be in (0, 1], got {bandwidth_fraction}"
        )
    if extra_delay_ms < 0.0:
        raise ValueError("extra_delay_ms must be >= 0")
    if not 0.0 <= extra_loss < 1.0:
        raise ValueError("extra_loss must be in [0, 1)")
    return NetemProfile(
        delay_ms=base.delay_ms + extra_delay_ms,
        jitter_ms=base.jitter_ms,
        bandwidth_gbps=base.bandwidth_gbps * bandwidth_fraction,
        loss=min(base.loss + extra_loss, 0.999),
    )


#: Paper defaults: WAN links get 5 ms +/- 1 ms per interface; LAN links are
#: effectively free at ping granularity; the *effective* WAN throughput
#: observed during training was ~800 Mbit/s (§5.5).
PAPER_WAN = NetemProfile(delay_ms=5.0, jitter_ms=1.0, bandwidth_gbps=0.8)
PAPER_LAN = NetemProfile(delay_ms=0.02, jitter_ms=0.005, bandwidth_gbps=10.0)
#: A modern DCI profile for the TPU-scale what-if studies (EXPERIMENTS §Perf):
#: dedicated 9 GB/s/direction per DC pair, ~10 ms one-way.
TPU_DCI = NetemProfile(delay_ms=10.0, jitter_ms=0.5, bandwidth_gbps=72.0)

#: Store-and-forward + pipeline latency per transit switch (FRR software
#: forwarding in the emulation; sub-ms, calibrated against Fig. 8).
SWITCH_FORWARDING_MS = 0.25

#: Per-DC-pair WAN profile overrides, keyed by (dc_i, dc_j) with i < j after
#: normalization: real geo deployments are asymmetric (per-pair fiber RTT is
#: the axis Papavasileiou et al. sweep), so one ``wan`` class profile is only
#: the *default*, not the whole map.
WanPairMap = Dict[Tuple[int, int], NetemProfile]


def normalize_wan_pairs(
    wan_pairs: Optional[WanPairMap], num_dcs: Optional[int] = None
) -> Dict[Tuple[int, int], NetemProfile]:
    """Validate and key-normalize a per-DC-pair profile map.

    Keys are unordered DC pairs — ``(2, 1)`` and ``(1, 2)`` name the same
    fiber bundle — stored as ``(lo, hi)``.  Self-pairs, duplicate keys
    (after normalization), and pairs outside ``1..num_dcs`` (when known)
    raise; an empty/None map normalizes to ``{}``, the symmetric default.
    """
    out: Dict[Tuple[int, int], NetemProfile] = {}
    for key, prof in (wan_pairs or {}).items():
        i, j = int(key[0]), int(key[1])
        if i == j:
            raise ValueError(f"wan_pairs key {key!r} is not a DC *pair*")
        lo, hi = (i, j) if i < j else (j, i)
        if lo < 1 or (num_dcs is not None and hi > num_dcs):
            raise ValueError(
                f"wan_pairs key {key!r} outside DCs 1..{num_dcs}"
            )
        if (lo, hi) in out:
            raise ValueError(
                f"wan_pairs keys {key!r} and {(lo, hi)!r} name the same pair"
            )
        if not isinstance(prof, NetemProfile):
            raise TypeError(f"wan_pairs[{key!r}] must be a NetemProfile")
        out[(lo, hi)] = prof
    return out


class Netem:
    """Per-link profile resolution over a :class:`Fabric`.

    :meth:`profile` is the single source of truth every consumer (fluid
    timing, congestion matrix, RTT sampling, roofline) resolves link
    parameters through.  Resolution order:

    1. an explicit per-link override (:meth:`override_link`);
    2. for WAN links, the per-DC-pair map ``wan_pairs`` — the asymmetric-WAN
       axis (one profile per inter-DC fiber bundle);
    3. the link-class defaults ``wan`` / ``lan``.

    With no overrides this is exactly the historical two-class behavior —
    byte-identical, including the jitter RNG stream, which is untouched by
    the resolution layer.
    """

    def __init__(
        self,
        fabric: Fabric,
        wan: NetemProfile = PAPER_WAN,
        lan: NetemProfile = PAPER_LAN,
        seed: int = 0,
        *,
        wan_pairs: Optional[WanPairMap] = None,
        link_overrides: Optional[Dict[Tuple[str, str], NetemProfile]] = None,
    ):
        self.fabric = fabric
        self.wan = wan
        self.lan = lan
        self.rng = np.random.default_rng(seed)
        self.wan_pairs = normalize_wan_pairs(wan_pairs, fabric.config.num_dcs)
        self._link_overrides: Dict[frozenset, NetemProfile] = {}
        # gray-failure bookkeeping: what each degraded link/pair resolved to
        # *before* its first degradation, so restore is exact and repeated
        # degradations compose on the pristine base, never on each other
        self._degraded_links: Dict[frozenset, Tuple[Optional[NetemProfile], NetemProfile]] = {}
        self._degraded_pairs: Dict[Tuple[int, int], Optional[NetemProfile]] = {}
        for (u, v), prof in (link_overrides or {}).items():
            self.override_link(u, v, prof)

    def override_link(self, u: str, v: str, profile: NetemProfile) -> None:
        """Pin one specific link (either endpoint order) to ``profile``."""
        if not isinstance(profile, NetemProfile):
            raise TypeError("link override must be a NetemProfile")
        self._link_overrides[frozenset((u, v))] = profile

    def profile(self, u: str, v: str) -> NetemProfile:
        if self._link_overrides:
            override = self._link_overrides.get(frozenset((u, v)))
            if override is not None:
                return override
        if self.fabric.is_wan_link(u, v):
            if self.wan_pairs:
                pair = self.wan_pairs.get(self.fabric.wan_pair(u, v))
                if pair is not None:
                    return pair
            return self.wan
        return self.lan

    # -- gray-failure injection ----------------------------------------------

    def _resolve_base(self, u: str, v: str) -> NetemProfile:
        """Profile resolution ignoring any per-link override (the class/pair
        layers only) — the pristine base a link degradation derives from."""
        if self.fabric.is_wan_link(u, v):
            if self.wan_pairs:
                pair = self.wan_pairs.get(self.fabric.wan_pair(u, v))
                if pair is not None:
                    return pair
            return self.wan
        return self.lan

    def degrade_link(
        self,
        u: str,
        v: str,
        *,
        bandwidth_fraction: float = 1.0,
        extra_delay_ms: float = 0.0,
        extra_loss: float = 0.0,
    ) -> NetemProfile:
        """Brownout one link: install a degraded per-link override.

        The base is whatever the link resolved to before its *first*
        degradation (a manual :meth:`override_link`, the pair map, or the
        class default) — re-degrading an already-degraded link replaces the
        degradation relative to that base instead of compounding.
        :meth:`restore_link_profile` undoes it exactly.
        """
        key = frozenset((u, v))
        if key in self._degraded_links:
            base = self._degraded_links[key][1]
        else:
            saved = self._link_overrides.get(key)
            base = saved if saved is not None else self._resolve_base(u, v)
            self._degraded_links[key] = (saved, base)
        prof = degraded_profile(
            base,
            bandwidth_fraction=bandwidth_fraction,
            extra_delay_ms=extra_delay_ms,
            extra_loss=extra_loss,
        )
        self._link_overrides[key] = prof
        return prof

    def restore_link_profile(self, u: str, v: str) -> None:
        """Undo :meth:`degrade_link` exactly (pre-degradation override or
        class/pair resolution, whichever held before)."""
        key = frozenset((u, v))
        if key not in self._degraded_links:
            raise ValueError(f"link {u}<->{v} is not degraded")
        saved, _ = self._degraded_links.pop(key)
        if saved is None:
            self._link_overrides.pop(key, None)
        else:
            self._link_overrides[key] = saved

    def degrade_pair(
        self,
        i: int,
        j: int,
        *,
        bandwidth_fraction: float = 1.0,
        extra_delay_ms: float = 0.0,
        extra_loss: float = 0.0,
    ) -> NetemProfile:
        """Brownout every link of one inter-DC fiber bundle: install a
        degraded ``wan_pairs`` entry for DC pair ``(i, j)``.

        Per-link overrides still win (resolution order unchanged); the base
        is the pair's pristine entry or the ``wan`` class default, and
        re-degrading replaces rather than compounds — same contract as
        :meth:`degrade_link`.
        """
        a, b = int(i), int(j)
        if a == b:
            raise ValueError(f"({i}, {j}) is not a DC *pair*")
        lo, hi = (a, b) if a < b else (b, a)
        num_dcs = self.fabric.config.num_dcs
        if lo < 1 or hi > num_dcs:
            raise ValueError(f"DC pair ({lo}, {hi}) outside DCs 1..{num_dcs}")
        pair = (lo, hi)
        if pair not in self._degraded_pairs:
            self._degraded_pairs[pair] = self.wan_pairs.get(pair)
        base = self._degraded_pairs[pair]
        if base is None:
            base = self.wan
        prof = degraded_profile(
            base,
            bandwidth_fraction=bandwidth_fraction,
            extra_delay_ms=extra_delay_ms,
            extra_loss=extra_loss,
        )
        self.wan_pairs[pair] = prof
        return prof

    def restore_pair(self, i: int, j: int) -> None:
        """Undo :meth:`degrade_pair` exactly."""
        a, b = int(i), int(j)
        pair = (a, b) if a < b else (b, a)
        if pair not in self._degraded_pairs:
            raise ValueError(f"DC pair {pair} is not degraded")
        original = self._degraded_pairs.pop(pair)
        if original is None:
            self.wan_pairs.pop(pair, None)
        else:
            self.wan_pairs[pair] = original

    @property
    def degraded_pairs(self) -> Tuple[Tuple[int, int], ...]:
        """Currently degraded DC pairs, sorted."""
        return tuple(sorted(self._degraded_pairs))

    @property
    def degraded_links(self) -> Tuple[Tuple[str, str], ...]:
        """Currently degraded individual links, sorted."""
        return tuple(sorted(tuple(sorted(k)) for k in self._degraded_links))

    def one_way_delay_ms(self, path_links: Sequence[Tuple[str, str, bool]]) -> float:
        """One jittered one-way delay sample along (u, v, is_wan) links.

        Each link contributes two netem qdisc passes (one per interface),
        each transit switch contributes forwarding latency.
        """
        total = 0.0
        for u, v, _ in path_links:
            p = self.profile(u, v)
            for _interface in range(2):
                jitter = self.rng.uniform(-p.jitter_ms, p.jitter_ms) if p.jitter_ms else 0.0
                total += max(p.delay_ms + jitter, 0.0)
        n_switches = max(len(path_links) - 1, 0)
        total += n_switches * SWITCH_FORWARDING_MS
        return total

    def base_rtt_ms(self, src_host: str, dst_host: str) -> float:
        """Jitter-free RTT (per-interface delays + forwarding, both ways)."""
        links = self.fabric.rtt_path(src_host, dst_host)
        one_way = 2.0 * sum(self.profile(u, v).delay_ms for u, v, _ in links)
        one_way += max(len(links) - 1, 0) * SWITCH_FORWARDING_MS
        return 2.0 * one_way


def ping_rtt(
    netem: Netem, src_host: str, dst_host: str, count: int = 100
) -> np.ndarray:
    """RTT samples (ms), the Fig. 8 experiment."""
    links = netem.fabric.rtt_path(src_host, dst_host)
    out = np.empty(count)
    for i in range(count):
        out[i] = netem.one_way_delay_ms(links) + netem.one_way_delay_ms(links)
    return out


@dataclass
class TransferResult:
    seconds: float
    bottleneck_link: Optional[Link]
    bottleneck_bytes: int
    per_link_seconds: Dict[Link, float] = field(default_factory=dict)


class WanTimingModel:
    """Deterministic completion-time model for a set of concurrent flows.

    Each flow is routed through the fabric (updating byte counters); the
    completion time of the whole set is driven by the most-loaded link:
    ``max_l bytes(l)/bw(l) + 2*propagation + jitter_sample``.  This is the
    standard fluid approximation; it is what lets the Fig. 14 reproduction
    produce per-batch times without packet-level simulation.
    """

    def __init__(self, netem: Netem):
        self.netem = netem
        self.fabric = netem.fabric

    def transfer_time(
        self,
        flow_bytes: Dict[Link, int],
        rtt_ms: float = 0.0,
        jitter_sample_ms: float = 0.0,
    ) -> TransferResult:
        per_link: Dict[Link, float] = {}
        worst: Tuple[float, Optional[Link], int] = (0.0, None, 0)
        for (u, v), nbytes in flow_bytes.items():
            bw = self.netem.profile(u, v).effective_bandwidth_gbps
            secs = nbytes * 8.0 / (bw * 1e9)
            per_link[(u, v)] = secs
            if secs > worst[0]:
                worst = (secs, (u, v), nbytes)
        total = worst[0] + (rtt_ms + jitter_sample_ms) / 1e3
        return TransferResult(
            seconds=total,
            bottleneck_link=worst[1],
            bottleneck_bytes=worst[2],
            per_link_seconds=per_link,
        )

    def contended_transfer_time(
        self,
        flows: Sequence,
        *,
        check_reachability=None,
        reset_counters: bool = True,
        ecmp_weighted: bool = False,
    ):
        """Flow-level contended timing for a set of concurrent flows.

        Routes ``flows`` through the fabric with per-flow path recording
        (resetting counters first by default, like
        :func:`repro.core.flows.route_flows_batched`), then applies the
        max-min congestion model: each flow finishes at
        ``bytes / fair_share + path propagation``, so a collective's time
        is its slowest contended flow, not the ideal aggregate-bytes
        estimate of :meth:`transfer_time`.  Returns the
        :class:`repro.core.congestion.CongestionReport` (``.seconds`` is
        the completion time; propagation is already included per flow).

        ``ecmp_weighted=True`` weights the fair shares by the observed
        ECMP hash-slot occupancy
        (:func:`repro.core.congestion.ecmp_flow_weights`).
        """
        from .congestion import route_and_analyze  # congestion imports wan

        _, report = route_and_analyze(
            self.fabric,
            self.netem,
            flows,
            check_reachability=check_reachability,
            reset_counters=reset_counters,
            ecmp_weighted=ecmp_weighted,
        )
        return report

    def contended_schedule_time(
        self,
        schedule,
        *,
        check_reachability=None,
        reset_counters: bool = True,
        ecmp_weighted: bool = False,
        incremental=None,
    ):
        """Contended timing for a phased :class:`CollectiveSchedule`.

        Routes every phase's flows (one batch, counters accumulate the
        whole schedule) and runs the event-driven time-varying max-min
        simulation of :func:`repro.core.congestion.simulate_schedule`:
        phases enter the active set as their dependencies complete, the
        fair-share allocation is re-solved at every arrival/completion
        event, and the returned
        :class:`repro.core.congestion.ScheduleReport` carries per-phase
        and per-flow timelines (``.seconds`` is the makespan).  For a
        single-phase schedule this is exactly
        :meth:`contended_transfer_time` on its flow set.

        ``incremental`` passes through to the simulator's epoch-allocator
        choice (warm-started vs from-scratch oracle — byte-identical, see
        ``simulate_schedule``); ``None`` defers to the module default.
        """
        from .congestion import simulate_schedule  # congestion imports wan

        return simulate_schedule(
            self.fabric,
            self.netem,
            schedule,
            check_reachability=check_reachability,
            reset_counters=reset_counters,
            ecmp_weighted=ecmp_weighted,
            incremental=incremental,
        )
