"""Multi-tenancy via VXLAN VNIs (paper §2.4, §5.4, Table 1).

Each training job is assigned a VNI; hosts attach to exactly one VNI.  The
EVPN RT import policy already guarantees control-plane isolation; this
module adds the job-level registry, attachment workflow, and the
reachability matrix the paper reports in Table 1.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from .evpn import EvpnControlPlane
from .fabric import Fabric, UnreachableError


@dataclass
class Tenant:
    name: str
    vni: int
    hosts: List[str] = field(default_factory=list)


class TenancyManager:
    """VNI registry + host attachment over fabric/EVPN."""

    def __init__(self, fabric: Fabric, evpn: EvpnControlPlane):
        self.fabric = fabric
        self.evpn = evpn
        self.tenants: Dict[str, Tenant] = {}
        self._vni_to_tenant: Dict[int, str] = {}

    def create_tenant(self, name: str, vni: int) -> Tenant:
        if vni in self._vni_to_tenant:
            raise ValueError(f"VNI {vni} already assigned to {self._vni_to_tenant[vni]}")
        if not (1 <= vni <= (1 << 24) - 1):
            raise ValueError("VNI must fit in 24 bits")  # 16M VNIs vs 4096 VLANs (§3.1)
        tenant = Tenant(name=name, vni=vni)
        self.tenants[name] = tenant
        self._vni_to_tenant[vni] = name
        return tenant

    def attach(self, tenant_name: str, host: str) -> None:
        tenant = self.tenants[tenant_name]
        h = self.fabric.hosts[host]
        if h.vni is not None and h.vni != tenant.vni:
            raise ValueError(f"{host} already attached to VNI {h.vni}")
        self.evpn.learn_host(host, tenant.vni)
        if host not in tenant.hosts:
            tenant.hosts.append(host)

    def detach(self, tenant_name: str, host: str) -> None:
        """Detach ``host`` from its tenant (the churn counterpart of
        :meth:`attach`): its Type-2 routes are withdrawn fabric-wide and
        its VNI binding cleared, so both directions go unreachable."""
        tenant = self.tenants[tenant_name]
        if host not in tenant.hosts:
            raise ValueError(f"{host} is not attached to tenant {tenant_name!r}")
        self.evpn.withdraw_host(host)
        tenant.hosts.remove(host)

    def reachable(self, src: str, dst: str) -> bool:
        return self.evpn.reachable(src, dst)

    def ping(self, src: str, dst: str, nbytes: int = 64) -> bool:
        """Data-plane reachability probe (Table 1 semantics)."""
        try:
            self.fabric.send(src, dst, nbytes, src_port=49192, check_reachability=self.reachable)
            return True
        except UnreachableError:
            return False

    def isolation_matrix(self, hosts: Sequence[str]) -> Dict[Tuple[str, str], bool]:
        """Full pairwise reachability matrix for Table 1 reproduction."""
        out: Dict[Tuple[str, str], bool] = {}
        for a in hosts:
            for b in hosts:
                if a != b:
                    out[(a, b)] = self.reachable(a, b)
        return out

    def verify_isolation(self) -> None:
        """Assert the Table-1 invariant across all tenants.

        Intra-tenant pairs must be reachable; inter-tenant pairs must not.
        Raises AssertionError with the offending pair otherwise.
        """
        for ta in self.tenants.values():
            for tb in self.tenants.values():
                for ha in ta.hosts:
                    for hb in tb.hosts:
                        if ha == hb:
                            continue
                        want = ta.vni == tb.vni
                        got = self.reachable(ha, hb)
                        assert got == want, (
                            f"isolation violation: {ha}(vni={ta.vni}) -> "
                            f"{hb}(vni={tb.vni}) reachable={got}, want {want}"
                        )
