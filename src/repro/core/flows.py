"""Collective operation -> queue-pair flow synthesis (paper §3.3, §5.5).

NCCL-style collectives chunk a tensor across ``num_channels`` independent
queue pairs per peer connection ("a 4 GB gradient using four channels is
divided into four 1 GB chunks, where each chunk is assigned to a separate
QP" — §3.3).  This module turns a logical collective among fabric hosts
into the concrete set of (src, dst, bytes, QP) flows the fabric routes.

Patterns (each emits the same :class:`Flow` records, so the QP-aware vs.
baseline port-allocation comparison runs unchanged across all of them):

* :func:`ring_allreduce_flows` — bidirectional ring; each worker ships
  ``2*(N-1)/N * B`` bytes to its ring successor across the whole op;
* :func:`reduce_scatter_flows` / :func:`all_gather_flows` — the two ring
  phases individually (``(N-1)/N * B`` per worker each), for schedules
  that overlap them with compute;
* :func:`parameter_server_flows` — push (worker->PS, B bytes each) and pull
  (PS->worker, B bytes each);
* :func:`all_to_all_flows` — MoE expert-parallel dispatch/combine
  (``B/N`` from every worker to every other worker), the pattern that
  stresses WAN fabrics very differently from rings (arXiv 2407.12819);
* :func:`pipeline_p2p_flows` — GeoPipe-style stage-to-stage activation
  traffic between pipeline stages (arXiv 2510.12064);
* :func:`hierarchical_flows` — the beyond-paper geo schedule: only the
  1/N_local shard crosses the WAN between DC leaders;
* :func:`hierarchical_all_to_all_flows` — two-phase MoE all-to-all
  (intra-DC dispatch to the pod leader, leader-only WAN combine), built
  for the :mod:`repro.core.schedule` phased scheduler.

Per-pattern byte totals are exact: remainders from integer division are
spread one byte at a time over the first channels (see
:func:`split_bytes`), never silently dropped.

Routing: :func:`route_flows` walks the fabric per flow (reference);
:func:`route_flows_batched` drives
:meth:`repro.core.fabric.Fabric.route_flows_batched`, the vectorized
engine, and produces byte-identical link counters.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple, Union

from .fabric import Fabric, FlowPaths, Link
from .ports import QueuePair, allocate_ports


@dataclass(frozen=True)
class Flow:
    src: str
    dst: str
    nbytes: int
    qp: QueuePair
    src_port: int


def split_bytes(total: int, parts: int) -> List[int]:
    """Split ``total`` bytes into ``parts`` near-equal chunks, exactly.

    The first ``total % parts`` chunks carry one extra byte, so
    ``sum(split_bytes(B, n)) == B`` always — no silent truncation.
    """
    if parts < 1:
        raise ValueError(f"parts must be >= 1, got {parts}")
    base, rem = divmod(int(total), parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def _qps_for_pair(
    pair_id: int,
    num_channels: int,
    scheme: str,
    k_bins: int,
    base_qpn: int,
    qp_stride: int,
) -> List[Tuple[QueuePair, int]]:
    qps = [
        QueuePair(index=i, number=(base_qpn + pair_id * 131 + i * qp_stride) & 0xFFFFFFFF)
        for i in range(num_channels)
    ]
    ports = allocate_ports(qps, scheme=scheme, k=k_bins)
    return list(zip(qps, ports))


def _pair_flows(
    src: str,
    dst: str,
    pair_id: int,
    total_bytes: int,
    num_channels: int,
    scheme: str,
    k_bins: int,
    base_qpn: int,
    qp_stride: int,
) -> List[Flow]:
    """One peer connection: ``total_bytes`` striped exactly over channels."""
    chunks = split_bytes(total_bytes, num_channels)
    return [
        Flow(src=src, dst=dst, nbytes=chunk, qp=qp, src_port=port)
        for chunk, (qp, port) in zip(
            chunks, _qps_for_pair(pair_id, num_channels, scheme, k_bins, base_qpn, qp_stride)
        )
    ]


def open_loop_flows(
    src: str,
    dst: str,
    flow_id: int,
    nbytes: int,
    *,
    num_channels: int = 1,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x5E0000,
    qp_stride: int = 1,
) -> List[Flow]:
    """One open-loop transfer (a serving request's KV handoff or a session
    migration): ``nbytes`` from ``src`` to ``dst`` as its own peer
    connection.

    ``flow_id`` plays the role the collectives' ``pair_id`` plays — it
    seeds the QPN so every request hashes independently under ECMP.  The
    ``base_qpn`` default puts serving QPs in a plane disjoint from the
    collectives' ``0x11`` so co-scheduled traffic never collides on a
    queue pair number.
    """
    if nbytes <= 0:
        return []
    return _pair_flows(
        src, dst, int(flow_id), int(nbytes), num_channels, scheme, k_bins,
        base_qpn, qp_stride,
    )


def ring_allreduce_flows(
    workers: Sequence[str],
    total_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Ring all-reduce: reduce-scatter + all-gather = 2*(N-1)/N * B per hop."""
    n = len(workers)
    if n < 2:
        return []
    per_link_bytes = (2 * (n - 1) * int(total_bytes)) // n
    flows: List[Flow] = []
    for i, src in enumerate(workers):
        dst = workers[(i + 1) % n]
        flows += _pair_flows(
            src, dst, i, per_link_bytes, num_channels, scheme, k_bins, base_qpn, qp_stride
        )
    return flows


def reduce_scatter_flows(
    workers: Sequence[str],
    total_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Ring reduce-scatter: each worker ships (N-1)/N * B to its successor."""
    n = len(workers)
    if n < 2:
        return []
    per_link_bytes = ((n - 1) * int(total_bytes)) // n
    flows: List[Flow] = []
    for i, src in enumerate(workers):
        dst = workers[(i + 1) % n]
        flows += _pair_flows(
            src, dst, i, per_link_bytes, num_channels, scheme, k_bins, base_qpn, qp_stride
        )
    return flows


def all_gather_flows(
    workers: Sequence[str],
    total_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Ring all-gather: same wire volume as reduce-scatter, distinct QPs.

    ``base_qpn`` is offset past the entire QP-number span a same-sized
    reduce-scatter would use (pair ids stride by 131, channels by
    ``qp_stride``), so a reduce-scatter + all-gather pair composed by a
    scheduler uses disjoint connection groups (as NCCL does) at any
    worker count.
    """
    rs_span = 131 * len(workers) + num_channels * max(qp_stride, 1)
    return reduce_scatter_flows(
        workers,
        total_bytes,
        num_channels=num_channels,
        scheme=scheme,
        k_bins=k_bins,
        base_qpn=base_qpn + rs_span,
        qp_stride=qp_stride,
    )


def parameter_server_flows(
    server: str,
    workers: Sequence[str],
    grad_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
    direction: str = "both",
) -> List[Flow]:
    """PS push+pull: every worker sends B to the server and receives B back.

    ``direction`` selects the ``"push"`` (worker -> server) or ``"pull"``
    (server -> worker) half individually so a phased scheduler can compose
    push-then-pull as two dependent phases; ``"both"`` (default) emits the
    full concurrent set with identical QPs/ports either way.
    """
    if direction not in ("both", "push", "pull"):
        raise ValueError(f"direction must be both|push|pull, got {direction!r}")
    flows: List[Flow] = []
    for wi, worker in enumerate(workers):
        if direction in ("both", "push"):
            flows += _pair_flows(
                worker, server, wi, grad_bytes, num_channels, scheme, k_bins,
                base_qpn, qp_stride,
            )
        if direction in ("both", "pull"):
            flows += _pair_flows(
                server, worker, 1000 + wi, grad_bytes, num_channels, scheme, k_bins,
                base_qpn, qp_stride,
            )
    return flows


def all_to_all_flows(
    workers: Sequence[str],
    total_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """MoE expert-parallel all-to-all: B/N from every worker to every peer.

    Models the dispatch (or combine) phase of expert parallelism — e.g. the
    shipped ``mixtral_8x22b`` / ``arctic_480b`` configs — where each worker
    scatters an equal token shard to every other worker.  N*(N-1) peer
    connections x ``num_channels`` QPs; per-connection bytes are
    ``split_bytes(B, N)[j]`` so the total dispatched per worker is exactly
    ``B`` minus the self-shard (which never hits the wire).
    """
    n = len(workers)
    if n < 2:
        return []
    shards = split_bytes(int(total_bytes), n)
    flows: List[Flow] = []
    for i, src in enumerate(workers):
        for j, dst in enumerate(workers):
            if i == j:
                continue
            flows += _pair_flows(
                src, dst, i * n + j, shards[j], num_channels, scheme, k_bins,
                base_qpn, qp_stride,
            )
    return flows


def pipeline_p2p_flows(
    stages: Sequence[Union[str, Sequence[str]]],
    activation_bytes: int,
    *,
    num_microbatches: int = 1,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """GeoPipe-style pipeline-parallel point-to-point stage traffic.

    ``stages`` is an ordered list of pipeline stages, each either one host
    or a list of hosts (tensor-parallel ranks within the stage).  Each rank
    of stage ``s`` streams ``activation_bytes * num_microbatches`` to the
    same-index rank of stage ``s+1`` (ranks pair round-robin when stage
    widths differ) — the WAN-crossing activation/gradient traffic of
    pipeline parallelism across DCs (arXiv 2510.12064).
    """
    norm: List[List[str]] = [
        [st] if isinstance(st, str) else list(st) for st in stages
    ]
    if any(not st for st in norm):
        raise ValueError("every pipeline stage needs at least one host")
    if len(norm) < 2:
        return []
    per_rank = int(activation_bytes) * int(num_microbatches)
    flows: List[Flow] = []
    pair_id = 0
    for s in range(len(norm) - 1):
        cur, nxt = norm[s], norm[s + 1]
        width = max(len(cur), len(nxt))
        for r in range(width):
            src = cur[r % len(cur)]
            dst = nxt[r % len(nxt)]
            flows += _pair_flows(
                src, dst, pair_id, per_rank, num_channels, scheme, k_bins,
                base_qpn, qp_stride,
            )
            pair_id += 1
    return flows


def hierarchical_all_to_all_flows(
    pods: Sequence[Sequence[str]],
    total_bytes: int,
    *,
    phase: str = "both",
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Hierarchical MoE all-to-all: intra-DC dispatch + leader-only WAN combine.

    ``pods`` is one worker list per DC (first member is the pod leader).
    Each worker holds ``total_bytes`` of expert-bound tokens split uniformly
    across pods (:func:`split_bytes`, so totals are exact); the flat
    all-to-all would push every worker's remote shard straight across the
    WAN.  The hierarchical schedule instead runs two phases:

    * ``"dispatch"`` — every non-leader worker forwards its remote-destined
      bytes (``total_bytes`` minus its own pod's shard) to the pod leader
      over the local fabric;
    * ``"combine"`` — leaders exchange the pod-aggregated shards
      (``n_local * shard`` per destination pod) as a leader-only all-to-all,
      the only traffic that crosses the WAN.

    ``phase`` selects one half for a phased scheduler (QP numbering is
    stable across selections, so dispatch/combine flows built separately are
    identical to the matching halves of ``"both"``); the per-pod WAN volume
    is ``n_local * (P-1)/P * B`` concentrated on the leader, versus the flat
    all-to-all's identical volume spread over ``n_local`` distinct
    host-level WAN paths — same bytes, fewer contending WAN flows.
    """
    if phase not in ("both", "dispatch", "combine"):
        raise ValueError(f"phase must be both|dispatch|combine, got {phase!r}")
    norm: List[List[str]] = [list(p) for p in pods]
    if any(not p for p in norm):
        raise ValueError("every pod needs at least one worker")
    n_pods = len(norm)
    if n_pods < 2:
        return []
    shards = split_bytes(int(total_bytes), n_pods)
    flows: List[Flow] = []
    pair_id = 0
    for p, members in enumerate(norm):
        leader = members[0]
        remote_bytes = int(total_bytes) - shards[p]
        for worker in members[1:]:
            if phase in ("both", "dispatch"):
                flows += _pair_flows(
                    worker, leader, pair_id, remote_bytes, num_channels, scheme,
                    k_bins, base_qpn, qp_stride,
                )
            pair_id += 1  # advances regardless of phase: stable QP identity
    pair_id = 100_000  # combine pair ids disjoint from any dispatch count
    for p, members in enumerate(norm):
        for q in range(n_pods):
            if p == q:
                continue
            if phase in ("both", "combine"):
                flows += _pair_flows(
                    members[0], norm[q][0], pair_id, len(members) * shards[q],
                    num_channels, scheme, k_bins, base_qpn, qp_stride,
                )
            pair_id += 1
    return flows


def hierarchical_flows(
    dc_leaders: Sequence[str],
    shard_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Cross-DC leader ring over the WAN carrying only the local shard.

    Models the geo-hierarchical schedule: intra-DC reduce-scatter happens on
    the (fast) local fabric; only ``shard_bytes = B / n_local`` per leader
    crosses the WAN, as a ring among DC leaders.
    """
    return ring_allreduce_flows(
        dc_leaders,
        shard_bytes,
        num_channels=num_channels,
        scheme=scheme,
        k_bins=k_bins,
        base_qpn=base_qpn,
        qp_stride=qp_stride,
    )


def route_flows(
    fabric: Fabric,
    flows: Sequence[Flow],
    *,
    check_reachability=None,
) -> Dict[Link, int]:
    """Route every flow through the fabric; returns the link byte counters.

    Reference per-flow path — byte-identical to
    :func:`route_flows_batched`, which should be preferred for anything
    beyond Fig. 1 scale.
    """
    fabric.reset_counters()
    for flow in flows:
        fabric.send(
            flow.src,
            flow.dst,
            flow.nbytes,
            src_port=flow.src_port,
            check_reachability=check_reachability,
        )
    return dict(fabric.link_bytes)


def route_flows_batched(
    fabric: Fabric,
    flows: Sequence[Flow],
    *,
    check_reachability=None,
) -> Dict[Link, int]:
    """Vectorized counterpart of :func:`route_flows` (same contract).

    Resets the fabric counters, then routes the whole batch through
    :meth:`Fabric.route_flows_batched`.  Unlike the sequential path, an
    unreachable flow raises *before* any counter is touched.
    """
    fabric.reset_counters()
    return fabric.route_flows_batched(flows, check_reachability=check_reachability)


def route_flows_with_paths(
    fabric: Fabric,
    flows: Sequence[Flow],
    *,
    check_reachability=None,
) -> Tuple[Dict[Link, int], FlowPaths]:
    """:func:`route_flows_batched` plus per-flow path recording.

    Same reset-and-route contract; additionally returns the CSR
    :class:`repro.core.fabric.FlowPaths` consumed by the flow-level
    congestion model (:mod:`repro.core.congestion`).
    """
    fabric.reset_counters()
    return fabric.route_flows_with_paths(flows, check_reachability=check_reachability)
