"""Collective operation -> queue-pair flow synthesis (paper §3.3, §5.5).

NCCL-style collectives chunk a tensor across ``num_channels`` independent
queue pairs per peer connection ("a 4 GB gradient using four channels is
divided into four 1 GB chunks, where each chunk is assigned to a separate
QP" — §3.3).  This module turns a logical collective among fabric hosts
into the concrete set of (src, dst, bytes, QP) flows the fabric routes:

* :func:`ring_allreduce_flows` — bidirectional ring; each worker ships
  ``2*(N-1)/N * B`` bytes to its ring successor across the whole op;
* :func:`parameter_server_flows` — push (worker->PS, B bytes each) and pull
  (PS->worker, B bytes each);
* :func:`hierarchical_flows` — the beyond-paper geo schedule: only the
  1/N_local shard crosses the WAN between DC leaders.

Driving these through :class:`~repro.core.fabric.Fabric` yields link byte
counters for the load-factor experiments and the Fig. 14 timing model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .fabric import Fabric, Link
from .ports import QueuePair, allocate_ports


@dataclass(frozen=True)
class Flow:
    src: str
    dst: str
    nbytes: int
    qp: QueuePair
    src_port: int


def _qps_for_pair(
    pair_id: int,
    num_channels: int,
    scheme: str,
    k_bins: int,
    base_qpn: int,
    qp_stride: int,
) -> List[Tuple[QueuePair, int]]:
    qps = [
        QueuePair(index=i, number=(base_qpn + pair_id * 131 + i * qp_stride) & 0xFFFFFFFF)
        for i in range(num_channels)
    ]
    ports = allocate_ports(qps, scheme=scheme, k=k_bins)
    return list(zip(qps, ports))


def ring_allreduce_flows(
    workers: Sequence[str],
    total_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Ring all-reduce: reduce-scatter + all-gather = 2*(N-1)/N * B per hop."""
    n = len(workers)
    if n < 2:
        return []
    per_link_bytes = int(2 * (n - 1) / n * total_bytes)
    chunk = per_link_bytes // num_channels
    flows: List[Flow] = []
    for i, src in enumerate(workers):
        dst = workers[(i + 1) % n]
        for qp, port in _qps_for_pair(i, num_channels, scheme, k_bins, base_qpn, qp_stride):
            flows.append(Flow(src=src, dst=dst, nbytes=chunk, qp=qp, src_port=port))
    return flows


def parameter_server_flows(
    server: str,
    workers: Sequence[str],
    grad_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """PS push+pull: every worker sends B to the server and receives B back."""
    chunk = grad_bytes // num_channels
    flows: List[Flow] = []
    for wi, worker in enumerate(workers):
        pair_qps = _qps_for_pair(wi, num_channels, scheme, k_bins, base_qpn, qp_stride)
        for qp, port in pair_qps:
            flows.append(Flow(src=worker, dst=server, nbytes=chunk, qp=qp, src_port=port))
        pull_qps = _qps_for_pair(
            1000 + wi, num_channels, scheme, k_bins, base_qpn, qp_stride
        )
        for qp, port in pull_qps:
            flows.append(Flow(src=server, dst=worker, nbytes=chunk, qp=qp, src_port=port))
    return flows


def hierarchical_flows(
    dc_leaders: Sequence[str],
    shard_bytes: int,
    *,
    num_channels: int = 4,
    scheme: str = "qp_aware",
    k_bins: int = 4,
    base_qpn: int = 0x11,
    qp_stride: int = 1,
) -> List[Flow]:
    """Cross-DC leader ring over the WAN carrying only the local shard.

    Models the geo-hierarchical schedule: intra-DC reduce-scatter happens on
    the (fast) local fabric; only ``shard_bytes = B / n_local`` per leader
    crosses the WAN, as a ring among DC leaders.
    """
    return ring_allreduce_flows(
        dc_leaders,
        shard_bytes,
        num_channels=num_channels,
        scheme=scheme,
        k_bins=k_bins,
        base_qpn=base_qpn,
        qp_stride=qp_stride,
    )


def route_flows(
    fabric: Fabric,
    flows: Sequence[Flow],
    *,
    check_reachability=None,
) -> Dict[Link, int]:
    """Route every flow through the fabric; returns the link byte counters."""
    fabric.reset_counters()
    for flow in flows:
        fabric.send(
            flow.src,
            flow.dst,
            flow.nbytes,
            src_port=flow.src_port,
            check_reachability=check_reachability,
        )
    return dict(fabric.link_bytes)
