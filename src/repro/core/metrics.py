"""Traffic-distribution metrics (paper §5.2).

The headline metric is the CONGA-style load factor (Eq. 12):

    LoadFactor = (U_max - U_min) / U_avg

computed over *active* links only — a link counts as used when its byte
counter exceeds ``threshold``, preventing idle links from flattering the
ratio (the paper is explicit about this guard).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

Link = Tuple[str, str]


@dataclass
class LoadFactorResult:
    load_factor: float
    u_max: float
    u_min: float
    u_avg: float
    active_links: int
    total_links: int


def load_factor(
    link_bytes: Mapping[Link, int] | Sequence[int],
    threshold: int = 1,
) -> LoadFactorResult:
    """Eq. 12 over active links (bytes > threshold)."""
    if isinstance(link_bytes, Mapping):
        values = np.array(list(link_bytes.values()), dtype=np.float64)
    else:
        values = np.asarray(link_bytes, dtype=np.float64)
    total = len(values)
    active = values[values > threshold]
    if active.size == 0:
        return LoadFactorResult(0.0, 0.0, 0.0, 0.0, 0, total)
    u_max, u_min, u_avg = float(active.max()), float(active.min()), float(active.mean())
    lf = (u_max - u_min) / u_avg if u_avg > 0 else 0.0
    return LoadFactorResult(lf, u_max, u_min, u_avg, int(active.size), total)


def flow_entropy(path_counts: Sequence[int]) -> float:
    """Shannon entropy (bits) of the flow->path assignment distribution."""
    counts = np.asarray(path_counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts[counts > 0] / total
    return float(-(p * np.log2(p)).sum())


def utilization_per_link(
    link_bytes: Mapping[Link, int],
    window_s: float,
    bw_gbps: Mapping[Link, float] | float,
) -> Dict[Link, float]:
    """Fraction of capacity used by each link over a window."""
    out: Dict[Link, float] = {}
    for link, nbytes in link_bytes.items():
        bw = bw_gbps if isinstance(bw_gbps, (int, float)) else bw_gbps[link]
        cap = bw * 1e9 / 8.0 * window_s
        out[link] = nbytes / cap if cap > 0 else 0.0
    return out
