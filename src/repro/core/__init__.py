"""ScaleAcross core: emulated EVPN-VXLAN geo-distributed training fabric.

The paper's primary contribution — an emulation framework for studying
geo-distributed AI training over EVPN-VXLAN WAN overlays, plus the
queue-pair-aware ECMP source-port allocator (Algorithm 1) — lives here.

Synchronization costing is organized around phased schedules: collective
patterns (:mod:`repro.core.flows`) are composed into
:class:`~repro.core.schedule.CollectiveSchedule` DAGs by registered
strategy builders (:mod:`repro.core.schedule`), routed through the
vectorized ECMP engine (:mod:`repro.core.fabric`), and costed either with
the fluid per-link model (:mod:`repro.core.wan`) or the event-driven
time-varying max-min congestion simulator (:mod:`repro.core.congestion`) —
``GeoFabric.sync_cost`` (:mod:`repro.core.geo`) is the facade over the
whole pipeline.
"""

from .bfd import BfdSession, BgpHoldTimer, FailureDetector, RecoveryTimeline
from .collision import (
    collision_index,
    collision_reduction,
    compare_schemes,
    expected_collisions,
    monte_carlo_collisions,
)
from .congestion import (
    CongestionReport,
    LinkLoadMatrix,
    PhaseTiming,
    ScheduleReport,
    build_link_load_matrix,
    concurrent_ecmp_flow_weights,
    congestion_report,
    ecmp_flow_weights,
    max_min_rates,
    route_and_analyze,
    simulate_schedule,
)
from .evpn import EvpnControlPlane, EvpnResyncStats, RouteType2, RouteType3
from .fabric import (
    ECMP_HASH_BUCKETS,
    Fabric,
    FabricConfig,
    FiveTuple,
    FlowPaths,
    RerouteStats,
    UnreachableError,
    ecmp_hash,
)
from .flows import (
    Flow,
    all_gather_flows,
    all_to_all_flows,
    hierarchical_all_to_all_flows,
    hierarchical_flows,
    open_loop_flows,
    parameter_server_flows,
    pipeline_p2p_flows,
    reduce_scatter_flows,
    ring_allreduce_flows,
    route_flows,
    route_flows_batched,
    route_flows_with_paths,
    split_bytes,
)
from .geo import GeoFabric, SyncCost, SyncOptions
from .schedule import (
    SYNC_STRATEGIES,
    CollectiveSchedule,
    Phase,
    StrategyContext,
    build_schedule,
    get_strategy,
    register_strategy,
    strategy_names,
    with_compute_overlap,
)
from .metrics import LoadFactorResult, flow_entropy, load_factor
from .ports import (
    ALIASING_STRIDE,
    ALIASING_STRIDE_STRONG,
    NUM_PORT_OFFSETS,
    ROCE_V2_BASE_PORT,
    QueuePair,
    allocate_ports,
    hash_32,
    make_correlated_queue_pairs,
    make_queue_pairs,
    qp_aware_port,
    rxe_baseline_port,
)
from .slaprobe import ProbeState, ProbeTransition, SlaProbe, SlaProbeBank
from .tenancy import TenancyManager, Tenant
from .wan import (
    Netem,
    NetemProfile,
    PAPER_LAN,
    PAPER_WAN,
    TPU_DCI,
    WanTimingModel,
    degraded_profile,
    ping_rtt,
)

__all__ = [
    "ALIASING_STRIDE",
    "BfdSession",
    "BgpHoldTimer",
    "CollectiveSchedule",
    "CongestionReport",
    "ECMP_HASH_BUCKETS",
    "EvpnControlPlane",
    "EvpnResyncStats",
    "Fabric",
    "FabricConfig",
    "FailureDetector",
    "FiveTuple",
    "Flow",
    "FlowPaths",
    "GeoFabric",
    "LinkLoadMatrix",
    "LoadFactorResult",
    "Netem",
    "NetemProfile",
    "NUM_PORT_OFFSETS",
    "PAPER_LAN",
    "PAPER_WAN",
    "Phase",
    "PhaseTiming",
    "ProbeState",
    "ProbeTransition",
    "QueuePair",
    "RecoveryTimeline",
    "RerouteStats",
    "RouteType2",
    "RouteType3",
    "SYNC_STRATEGIES",
    "ScheduleReport",
    "SlaProbe",
    "SlaProbeBank",
    "StrategyContext",
    "SyncCost",
    "SyncOptions",
    "TenancyManager",
    "Tenant",
    "TPU_DCI",
    "UnreachableError",
    "WanTimingModel",
    "all_gather_flows",
    "all_to_all_flows",
    "allocate_ports",
    "build_link_load_matrix",
    "build_schedule",
    "collision_index",
    "collision_reduction",
    "compare_schemes",
    "concurrent_ecmp_flow_weights",
    "congestion_report",
    "degraded_profile",
    "ecmp_flow_weights",
    "ecmp_hash",
    "expected_collisions",
    "flow_entropy",
    "get_strategy",
    "hash_32",
    "hierarchical_all_to_all_flows",
    "hierarchical_flows",
    "open_loop_flows",
    "load_factor",
    "make_correlated_queue_pairs",
    "make_queue_pairs",
    "max_min_rates",
    "monte_carlo_collisions",
    "parameter_server_flows",
    "ping_rtt",
    "pipeline_p2p_flows",
    "qp_aware_port",
    "reduce_scatter_flows",
    "register_strategy",
    "ring_allreduce_flows",
    "route_and_analyze",
    "route_flows",
    "route_flows_batched",
    "route_flows_with_paths",
    "rxe_baseline_port",
    "simulate_schedule",
    "split_bytes",
    "strategy_names",
    "with_compute_overlap",
    "ROCE_V2_BASE_PORT",
]
