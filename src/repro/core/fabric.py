"""Emulated multi-data-center spine-leaf fabric (ScaleAcross §4).

Pure-Python, byte-accurate (not packet-accurate) model of the topology in
Fig. 1 of the paper: ``num_dcs`` data centers, each a spine-leaf Clos
(``spines_per_dc`` × ``leaves_per_dc``), hosts attached to leaves, and
full-bipartite spine↔spine WAN links between data centers.

Responsibilities:

* underlay graph + equal-cost shortest-path routing with per-hop ECMP
  (5-tuple CRC hash, per-switch seed — the paper's commodity pipeline);
* VXLAN data plane: host frames are encapsulated at the ingress leaf (VTEP),
  routed leaf→leaf through the underlay, decapsulated at the egress leaf —
  reachability is governed by the EVPN control plane (``evpn.py``);
* per-directed-link byte counters, from which the load factor (Eq. 12) and
  path-distribution skew (Eqs. 3–11) are computed.

Node naming follows the paper: ``d{i}s{j}`` spines, ``d{i}l{j}`` leaves,
``d{i}h{j}`` hosts (1-based, e.g. ``d1l1`` = leaf 1 of DC 1).
"""

from __future__ import annotations

import zlib
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

Link = Tuple[str, str]  # directed (u, v)


@dataclass(frozen=True)
class FiveTuple:
    """Packet 5-tuple as hashed by commodity ECMP pipelines."""

    src_ip: str
    dst_ip: str
    src_port: int
    dst_port: int
    proto: int = 17  # UDP (RoCEv2 / VXLAN)

    def key_bytes(self) -> bytes:
        return f"{self.src_ip}|{self.dst_ip}|{self.src_port}|{self.dst_port}|{self.proto}".encode()


def ecmp_hash(tup: FiveTuple, seed: int, num_choices: int) -> int:
    """CRC-32 5-tuple hash with a per-switch seed, modulo the fan-out.

    Commodity switches hash the same fields but mix in a chip-specific seed
    so consecutive hops do not make perfectly correlated decisions; we model
    that with the seed argument.
    """
    h = zlib.crc32(tup.key_bytes(), seed & 0xFFFFFFFF)
    return h % num_choices


# VXLAN outer UDP destination port (RFC 7348) and RoCEv2 destination port.
VXLAN_DST_PORT = 4789
ROCE_DST_PORT = 4791


def vxlan_outer_tuple(inner: FiveTuple, src_vtep_ip: str, dst_vtep_ip: str) -> FiveTuple:
    """Outer header built by the ingress VTEP.

    Per RFC 7348 the VTEP derives the outer UDP source port from a hash of
    the inner frame so that inner-flow entropy survives encapsulation; the
    inner RoCEv2 source port therefore still steers ECMP in the underlay.
    """
    entropy = zlib.crc32(inner.key_bytes()) & 0x3FFF
    return FiveTuple(
        src_ip=src_vtep_ip,
        dst_ip=dst_vtep_ip,
        src_port=0xC000 + entropy,
        dst_port=VXLAN_DST_PORT,
    )


@dataclass(frozen=True)
class FabricConfig:
    """Topology knobs.  Defaults mirror the paper's Fig. 1."""

    num_dcs: int = 2
    spines_per_dc: int = 2
    leaves_per_dc: int = 3
    # hosts per leaf, per DC; paper: DC1 = 5 hosts, DC2 = 4 hosts over 3 leaves.
    hosts_per_leaf: Tuple[Tuple[int, ...], ...] = ((2, 2, 1), (2, 2, 0))
    link_gbps: float = 10.0
    wan_gbps: float = 0.8  # paper measured ~800 Mbit/s effective on spine WAN links

    def validate(self) -> None:
        if len(self.hosts_per_leaf) != self.num_dcs:
            raise ValueError("hosts_per_leaf must have one tuple per DC")
        for dc, per_leaf in enumerate(self.hosts_per_leaf):
            if len(per_leaf) != self.leaves_per_dc:
                raise ValueError(f"DC{dc + 1}: expected {self.leaves_per_dc} leaf host counts")


@dataclass
class Host:
    name: str
    dc: int  # 1-based
    leaf: str
    ip: str
    mac: str
    vni: Optional[int] = None


class Fabric:
    """The emulated underlay + VXLAN data plane."""

    def __init__(self, config: FabricConfig | None = None):
        self.config = config or FabricConfig()
        self.config.validate()
        self._adj: Dict[str, List[str]] = defaultdict(list)
        self._links: set[FrozenSet[str]] = set()
        self._down_links: set[FrozenSet[str]] = set()
        self.link_bytes: Dict[Link, int] = defaultdict(int)
        self.hosts: Dict[str, Host] = {}
        self.leaves: List[str] = []
        self.spines: List[str] = []
        self.wan_links: List[FrozenSet[str]] = []
        self._switch_seed: Dict[str, int] = {}
        self._dist_cache: Dict[str, Dict[str, int]] = {}
        self._build()

    # -- construction -------------------------------------------------------

    def _add_link(self, u: str, v: str) -> None:
        key = frozenset((u, v))
        if key in self._links:
            return
        self._links.add(key)
        self._adj[u].append(v)
        self._adj[v].append(u)

    def _build(self) -> None:
        cfg = self.config
        for dc in range(1, cfg.num_dcs + 1):
            spines = [f"d{dc}s{j}" for j in range(1, cfg.spines_per_dc + 1)]
            leaves = [f"d{dc}l{j}" for j in range(1, cfg.leaves_per_dc + 1)]
            self.spines.extend(spines)
            self.leaves.extend(leaves)
            for leaf in leaves:
                for spine in spines:  # full bipartite leaf-spine Clos
                    self._add_link(leaf, spine)
            host_idx = 1
            for li, leaf in enumerate(leaves):
                for _ in range(cfg.hosts_per_leaf[dc - 1][li]):
                    name = f"d{dc}h{host_idx}"
                    host = Host(
                        name=name,
                        dc=dc,
                        leaf=leaf,
                        ip=f"192.168.{dc}.{host_idx}",
                        mac=f"aa:bb:{dc:02x}:{dc:02x}:{host_idx:02x}:{host_idx:02x}",
                    )
                    self.hosts[name] = host
                    self._add_link(leaf, name)
                    host_idx += 1
        # WAN: full bipartite spine<->spine between DC pairs (paper: each spine
        # has one link to every spine of the remote DC -> 4 WAN links for 2 DCs).
        for dc_a in range(1, cfg.num_dcs + 1):
            for dc_b in range(dc_a + 1, cfg.num_dcs + 1):
                for ja in range(1, cfg.spines_per_dc + 1):
                    for jb in range(1, cfg.spines_per_dc + 1):
                        u, v = f"d{dc_a}s{ja}", f"d{dc_b}s{jb}"
                        self._add_link(u, v)
                        self.wan_links.append(frozenset((u, v)))
        for i, node in enumerate(sorted(self._adj)):
            self._switch_seed[node] = zlib.crc32(node.encode()) ^ (i * 0x9E3779B9)

    # -- link state ---------------------------------------------------------

    def all_links(self) -> List[FrozenSet[str]]:
        return sorted(self._links, key=sorted)

    def is_wan_link(self, u: str, v: str) -> bool:
        return frozenset((u, v)) in set(self.wan_links)

    def link_up(self, u: str, v: str) -> bool:
        return frozenset((u, v)) not in self._down_links

    def fail_link(self, u: str, v: str) -> None:
        key = frozenset((u, v))
        if key not in self._links:
            raise KeyError(f"no such link {u}<->{v}")
        self._down_links.add(key)
        self._dist_cache.clear()

    def restore_link(self, u: str, v: str) -> None:
        self._down_links.discard(frozenset((u, v)))
        self._dist_cache.clear()

    def neighbors(self, node: str) -> List[str]:
        return [v for v in self._adj[node] if self.link_up(node, v)]

    # -- routing ------------------------------------------------------------

    def _distances_to(self, dst: str) -> Dict[str, int]:
        """BFS hop distances toward dst over live links (hosts non-transit)."""
        cached = self._dist_cache.get(dst)
        if cached is not None:
            return cached
        dist = {dst: 0}
        frontier = [dst]
        while frontier:
            nxt: List[str] = []
            for node in frontier:
                # hosts never forward traffic for others
                if node in self.hosts and node != dst:
                    continue
                for nb in self.neighbors(node):
                    if nb not in dist:
                        dist[nb] = dist[node] + 1
                        nxt.append(nb)
            frontier = nxt
        self._dist_cache[dst] = dist
        return dist

    def next_hops(self, node: str, dst: str) -> List[str]:
        """Equal-cost next hops from ``node`` toward ``dst`` (sorted, stable)."""
        dist = self._distances_to(dst)
        if node not in dist:
            return []
        return sorted(
            nb for nb in self.neighbors(node) if dist.get(nb, 1 << 30) == dist[node] - 1
        )

    def route_flow(self, tup: FiveTuple, src: str, dst: str) -> List[str]:
        """Hop-by-hop ECMP walk; returns the node path (src..dst)."""
        path = [src]
        node = src
        hops = 0
        while node != dst:
            choices = self.next_hops(node, dst)
            if not choices:
                raise RuntimeError(f"no route {src}->{dst} at {node} (link failures?)")
            pick = choices[ecmp_hash(tup, self._switch_seed[node], len(choices))]
            path.append(pick)
            node = pick
            hops += 1
            if hops > 64:
                raise RuntimeError("routing loop detected")
        return path

    # -- data plane ---------------------------------------------------------

    def vtep_ip(self, leaf: str) -> str:
        # loopback VTEP addressing mirrors the paper (1.1.10.1 style)
        dc = int(leaf[1])
        idx = int(leaf[3:])
        return f"{dc}.{dc}.10.{idx}"

    def send(
        self,
        src_host: str,
        dst_host: str,
        nbytes: int,
        src_port: int,
        dst_port: int = ROCE_DST_PORT,
        *,
        check_reachability=None,
    ) -> List[str]:
        """Send ``nbytes`` from host to host; updates link byte counters.

        ``check_reachability`` is an optional callable (src, dst) -> bool
        supplied by the EVPN/tenancy layer; when it returns False the frame
        is dropped at the ingress VTEP (destination host unreachable).
        Returns the underlay node path taken.
        """
        src, dst = self.hosts[src_host], self.hosts[dst_host]
        if check_reachability is not None and not check_reachability(src_host, dst_host):
            raise UnreachableError(f"{dst_host} unreachable from {src_host} (VNI isolation)")
        inner = FiveTuple(src.ip, dst.ip, src_port, dst_port)
        self._count(src_host, src.leaf, nbytes)
        if src.leaf == dst.leaf:
            self._count(dst.leaf, dst_host, nbytes)
            return [src_host, src.leaf, dst_host]
        outer = vxlan_outer_tuple(inner, self.vtep_ip(src.leaf), self.vtep_ip(dst.leaf))
        path = self.route_flow(outer, src.leaf, dst.leaf)
        for u, v in zip(path, path[1:]):
            self._count(u, v, nbytes)
        self._count(dst.leaf, dst_host, nbytes)
        return [src_host] + path + [dst_host]

    def _count(self, u: str, v: str, nbytes: int) -> None:
        self.link_bytes[(u, v)] += nbytes

    def reset_counters(self) -> None:
        self.link_bytes.clear()

    # -- observability ------------------------------------------------------

    def uplink_bytes(self, node: str, toward: str = "spine") -> Dict[Link, int]:
        """Byte counters on a node's egress links toward spines or WAN."""
        out: Dict[Link, int] = {}
        for (u, v), b in self.link_bytes.items():
            if u != node:
                continue
            if toward == "spine" and v in self.spines and not self.is_wan_link(u, v):
                out[(u, v)] = b
            elif toward == "wan" and self.is_wan_link(u, v):
                out[(u, v)] = b
        return out

    def rtt_path(self, src_host: str, dst_host: str) -> List[Tuple[str, str, bool]]:
        """One representative forward path as (u, v, is_wan) link triples."""
        src, dst = self.hosts[src_host], self.hosts[dst_host]
        links: List[Tuple[str, str, bool]] = [(src_host, src.leaf, False)]
        if src.leaf != dst.leaf:
            tup = FiveTuple(src.ip, dst.ip, 49192, ROCE_DST_PORT)
            outer = vxlan_outer_tuple(tup, self.vtep_ip(src.leaf), self.vtep_ip(dst.leaf))
            path = self.route_flow(outer, src.leaf, dst.leaf)
            links += [(u, v, self.is_wan_link(u, v)) for u, v in zip(path, path[1:])]
        links.append((dst.leaf, dst_host, False))
        return links


class UnreachableError(RuntimeError):
    """Destination host unreachable (missing EVPN route or VNI mismatch)."""
