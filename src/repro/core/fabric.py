"""Emulated multi-data-center spine-leaf fabric (ScaleAcross §4).

Pure-Python, byte-accurate (not packet-accurate) model of the topology in
Fig. 1 of the paper: ``num_dcs`` data centers, each a spine-leaf Clos
(``spines_per_dc`` × ``leaves_per_dc``), hosts attached to leaves, and
full-bipartite spine↔spine WAN links between data centers.

Responsibilities:

* underlay graph + equal-cost shortest-path routing with per-hop ECMP
  (5-tuple CRC hash, per-switch seed — the paper's commodity pipeline);
* VXLAN data plane: host frames are encapsulated at the ingress leaf (VTEP),
  routed leaf→leaf through the underlay, decapsulated at the egress leaf —
  reachability is governed by the EVPN control plane (``evpn.py``);
* per-directed-link byte counters, from which the load factor (Eq. 12) and
  path-distribution skew (Eqs. 3–11) are computed.

Two routing engines share the same hash semantics:

* :meth:`Fabric.send` / :meth:`Fabric.route_flow` — per-flow hop-by-hop
  Python walk (reference path, fine for the paper's 9-host Fig. 1 scale);
* :meth:`Fabric.route_flows_batched` — the production-scale engine: the
  BFS DAGs from ``_distances_to`` are compiled into per-destination
  integer next-hop tables, and the per-switch-seeded CRC-32 hash is
  vectorized over all flows at once via CRC linearity
  (``crc32(key, seed) == crc32(key, 0) ^ crc32(0^len, seed) ^
  crc32(0^len, 0)``), so one ``zlib.crc32`` per flow plus NumPy
  XOR/mod/gather replaces per-hop dict lookups and ``sorted()`` calls.
  Byte-identical to the sequential walk (asserted in
  ``tests/test_flows_batched.py``) and >=10x faster on >=10k-flow
  workloads (``benchmarks/bench_collectives.py``).

Incremental failover re-convergence (paper §5.3, Fig. 9 at scale): a link
flap does **not** flush the routing state wholesale.  While compiling
``_distances_to(dst)`` the fabric records a reverse *link -> destination*
dependency index: a destination depends on a live link iff the link lies
on its BFS shortest-path DAG (``|dist[u] - dist[v]| == 1``), and on a
down link iff restoring it would shorten a distance or add an equal-cost
choice.  ``fail_link``/``restore_link`` consult that index and touch only
the dependent destinations — and when the flap provably leaves every BFS
distance unchanged (the far endpoint keeps another equal-cost next hop),
the cached next-hop table is patched *in place* (one row) instead of being
rebuilt.  The interned pair registry, template CRCs and per-switch
seed-XOR columns are never invalidated, so ``route_flows_batched`` stays
warm across BFD-cadence flap storms (``benchmarks/bench_failover.py``
gates >=10x re-convergence speedup vs. full invalidation, byte-identical
counters as the check).

:meth:`Fabric.route_flows_with_paths` additionally records every flow's
directed-link path (CSR :class:`FlowPaths`) — the input to the
flow-level congestion model in :mod:`repro.core.congestion`.

Node naming follows the paper: ``d{i}s{j}`` spines, ``d{i}l{j}`` leaves,
``d{i}h{j}`` hosts (1-based, e.g. ``d1l1`` = leaf 1 of DC 1).
"""

from __future__ import annotations

import zlib
from collections import defaultdict
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import numpy as np

Link = Tuple[str, str]  # directed (u, v)


@dataclass(frozen=True)
class FiveTuple:
    """Packet 5-tuple as hashed by commodity ECMP pipelines."""

    src_ip: str
    dst_ip: str
    src_port: int
    dst_port: int
    proto: int = 17  # UDP (RoCEv2 / VXLAN)

    def key_bytes(self) -> bytes:
        return f"{self.src_ip}|{self.dst_ip}|{self.src_port}|{self.dst_port}|{self.proto}".encode()


def ecmp_hash(tup: FiveTuple, seed: int, num_choices: int) -> int:
    """CRC-32 5-tuple hash with a per-switch seed, modulo the fan-out.

    Commodity switches hash the same fields but mix in a chip-specific seed
    so consecutive hops do not make perfectly correlated decisions; we model
    that with the seed argument.
    """
    h = zlib.crc32(tup.key_bytes(), seed & 0xFFFFFFFF)
    return h % num_choices


# VXLAN outer UDP destination port (RFC 7348) and RoCEv2 destination port.
VXLAN_DST_PORT = 4789
ROCE_DST_PORT = 4791

#: ECMP member-table bucket space per (switch, destination) group.  Commodity
#: ASICs resolve the 5-tuple hash into a small per-group member table (tens to
#: a few hundred buckets) before mapping buckets onto egress members; two
#: flows whose hashes land in the same bucket are indistinguishable to the
#: pipeline — they always pick the same member and share its scheduling slot.
#: ``route_flows_with_paths`` records that slot occupancy per traversal, the
#: observable the weighted congestion model derives allocation weights from.
ECMP_HASH_BUCKETS = 64

@lru_cache(maxsize=64)
def _digit_gamma(tail: int) -> "np.ndarray":
    """CRC-32 contribution of decimal digit ``d`` placed ``tail`` bytes
    before the end of a message.

    CRC-32 is linear over GF(2), so flipping one byte changes the checksum
    by a value that depends only on the byte's XOR delta and its distance
    from the end: ``crc32(msg_with_d) == crc32(msg_with_'0') ^ gamma[d]``
    (digit chars are ``0x30 + d``, so the delta is ``d`` itself).  This is
    what lets the batched router evaluate the five-tuple hash for every
    flow without calling ``zlib.crc32`` per flow.
    """
    zeros = b"\x00" * tail
    base = zlib.crc32(b"\x00" + zeros)
    return np.array(
        [zlib.crc32(bytes((d,)) + zeros) ^ base for d in range(10)],
        dtype=np.uint32,
    )


@lru_cache(maxsize=16)
def _gamma_block(suffix_len: int) -> "np.ndarray":
    """(5, 10) digit-gamma table for a 5-digit port followed by a suffix."""
    return np.stack([_digit_gamma(suffix_len + (4 - k)) for k in range(5)])


def vxlan_outer_tuple(inner: FiveTuple, src_vtep_ip: str, dst_vtep_ip: str) -> FiveTuple:
    """Outer header built by the ingress VTEP.

    Per RFC 7348 the VTEP derives the outer UDP source port from a hash of
    the inner frame so that inner-flow entropy survives encapsulation; the
    inner RoCEv2 source port therefore still steers ECMP in the underlay.
    """
    entropy = zlib.crc32(inner.key_bytes()) & 0x3FFF
    return FiveTuple(
        src_ip=src_vtep_ip,
        dst_ip=dst_vtep_ip,
        src_port=0xC000 + entropy,
        dst_port=VXLAN_DST_PORT,
    )


@dataclass(frozen=True)
class FabricConfig:
    """Topology knobs.  Defaults mirror the paper's Fig. 1."""

    num_dcs: int = 2
    spines_per_dc: int = 2
    leaves_per_dc: int = 3
    # hosts per leaf, per DC; paper: DC1 = 5 hosts, DC2 = 4 hosts over 3 leaves.
    hosts_per_leaf: Tuple[Tuple[int, ...], ...] = ((2, 2, 1), (2, 2, 0))
    link_gbps: float = 10.0
    wan_gbps: float = 0.8  # paper measured ~800 Mbit/s effective on spine WAN links
    #: ECMP member-table bucket space per (switch, destination) group — the
    #: per-switch realism knob for hash-slot collision modeling (see
    #: :data:`ECMP_HASH_BUCKETS`, the default matching commodity ASICs).
    #: Smaller values model cheaper pipelines with denser hash collisions.
    ecmp_hash_buckets: int = ECMP_HASH_BUCKETS

    def validate(self) -> None:
        if len(self.hosts_per_leaf) != self.num_dcs:
            raise ValueError("hosts_per_leaf must have one tuple per DC")
        for dc, per_leaf in enumerate(self.hosts_per_leaf):
            if len(per_leaf) != self.leaves_per_dc:
                raise ValueError(f"DC{dc + 1}: expected {self.leaves_per_dc} leaf host counts")
        if self.ecmp_hash_buckets < 1:
            raise ValueError("ecmp_hash_buckets must be >= 1")


@dataclass
class Host:
    name: str
    dc: int  # 1-based
    leaf: str
    ip: str
    mac: str
    vni: Optional[int] = None


@dataclass(frozen=True)
class RerouteStats:
    """What one ``fail_link``/``restore_link`` did to the routing state.

    ``patched``  — compiled next-hop tables repaired in place (one row);
    ``rebuilt``  — cached destinations evicted for a full BFS rebuild;
    ``retained`` — cached destinations left untouched (unaffected by the
    flap, or affected but carrying no compiled table to edit).

    ``affected_dsts`` names the destinations that were patched or evicted —
    the data plane's blast radius, emitted so observability layers (the
    failover benchmark's storm accounting, recovery-timeline reporting)
    don't re-derive it from the dependency index.  The EVPN control plane
    piggybacks on the *stats object itself*
    (:meth:`repro.core.evpn.EvpnControlPlane.resync_incremental` consumes
    ``link``/``action``): BGP flood reachability is a session-graph
    property, so the control plane diffs session-graph components rather
    than underlay routing destinations.
    """

    link: Tuple[str, str]
    action: str  # "fail" | "restore"
    patched: int
    rebuilt: int
    retained: int
    affected_dsts: Tuple[str, ...] = ()

    @property
    def touched(self) -> int:
        return self.patched + self.rebuilt


@dataclass(frozen=True)
class FlowPaths:
    """Per-flow directed-link paths in CSR form (``route_flows_with_paths``).

    Flow ``i`` traverses the directed links
    ``(link_u[k], link_v[k]) for k in range(ptr[i], ptr[i + 1])`` in hop
    order, as integer node ids decodable through ``nodes``.  This is the
    flow x link incidence the congestion model's max-min allocation
    consumes without any per-flow Python loop.

    ``slot_occ`` (row-aligned with ``link_u``/``link_v``) is the ECMP
    hash-slot occupancy of each traversal: how many flows of the batch
    hashed into the same bucket of the same member link at that decision
    point (bucket space per (switch, destination) group =
    ``FabricConfig.ecmp_hash_buckets``, default
    :data:`ECMP_HASH_BUCKETS`; occupancy is 1 for non-ECMP hops such as
    host attachments or single-choice forwarding).  Values > 1 are
    observed hash collisions — the imbalance the weighted congestion
    model (:func:`repro.core.congestion.ecmp_flow_weights`) turns into
    per-flow allocation weights.

    ``slot_key`` (row-aligned) is the *identity* of the hash slot each
    ECMP traversal landed in — one integer per (destination group,
    member link, bucket), ``-1`` for non-ECMP hops.  Two rows share a
    slot key iff their flows are indistinguishable to that switch's hash
    pipeline, which is what lets consumers recount occupancy over an
    arbitrary flow subset (e.g. only the concurrently-active phases of a
    schedule — :func:`repro.core.congestion.concurrent_ecmp_flow_weights`)
    without re-routing.
    """

    link_u: "np.ndarray"  # (R,) int64 node ids
    link_v: "np.ndarray"  # (R,) int64 node ids
    ptr: "np.ndarray"  # (F + 1,) int64 CSR offsets
    nodes: Tuple[str, ...]  # node id -> name
    slot_occ: Optional["np.ndarray"] = None  # (R,) int64 hash-slot occupancy
    slot_key: Optional["np.ndarray"] = None  # (R,) int64 slot identity, -1 = none

    @property
    def num_flows(self) -> int:
        return len(self.ptr) - 1

    def flow_links(self, i: int) -> List[Link]:
        lo, hi = int(self.ptr[i]), int(self.ptr[i + 1])
        return [
            (self.nodes[int(u)], self.nodes[int(v)])
            for u, v in zip(self.link_u[lo:hi], self.link_v[lo:hi])
        ]


class Fabric:
    """The emulated underlay + VXLAN data plane."""

    def __init__(self, config: FabricConfig | None = None):
        self.config = config or FabricConfig()
        self.config.validate()
        self._adj: Dict[str, List[str]] = defaultdict(list)
        self._links: set[FrozenSet[str]] = set()
        self._down_links: set[FrozenSet[str]] = set()
        self.link_bytes: Dict[Link, int] = defaultdict(int)
        self.hosts: Dict[str, Host] = {}
        self.leaves: List[str] = []
        self.spines: List[str] = []
        self.wan_links: List[FrozenSet[str]] = []
        self._node_dc: Dict[str, int] = {}
        self._switch_seed: Dict[str, int] = {}
        self._dist_cache: Dict[str, Dict[str, int]] = {}
        # incremental re-convergence: reverse link -> destination dependency
        # index (built while compiling _distances_to) plus the forward map
        # used to unregister a destination when its entry is evicted.
        self._link_deps: Dict[FrozenSet[str], set] = {}
        self._dst_dep_links: Dict[str, List[FrozenSet[str]]] = {}
        self.last_reroute: Optional[RerouteStats] = None
        # batched-engine state: node<->id maps, per-destination next-hop
        # tables, and per-key-length CRC seed columns (see route_flows_batched)
        self._wan_link_set: set[FrozenSet[str]] = set()
        self._nh_cache: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        self._zcol_cache: Dict[int, np.ndarray] = {}
        # interned (src, dst, dst_port) host pairs: node ids, template CRCs
        # and egress-leaf group — immutable after _build, so never evicted.
        self._pair_cache: Dict[Tuple[str, str, int], int] = {}
        self._pair_rows: List[Tuple] = []
        self._pair_cols: Optional[Dict[str, np.ndarray]] = None
        self._leaf_gid: Dict[str, int] = {}
        self._gid_leaf: List[str] = []
        self._build()

    # -- construction -------------------------------------------------------

    def _add_link(self, u: str, v: str) -> None:
        key = frozenset((u, v))
        if key in self._links:
            return
        self._links.add(key)
        self._adj[u].append(v)
        self._adj[v].append(u)

    def _build(self) -> None:
        cfg = self.config
        for dc in range(1, cfg.num_dcs + 1):
            spines = [f"d{dc}s{j}" for j in range(1, cfg.spines_per_dc + 1)]
            leaves = [f"d{dc}l{j}" for j in range(1, cfg.leaves_per_dc + 1)]
            self.spines.extend(spines)
            self.leaves.extend(leaves)
            for sw in spines + leaves:
                self._node_dc[sw] = dc
            for leaf in leaves:
                for spine in spines:  # full bipartite leaf-spine Clos
                    self._add_link(leaf, spine)
            host_idx = 1
            for li, leaf in enumerate(leaves):
                for _ in range(cfg.hosts_per_leaf[dc - 1][li]):
                    name = f"d{dc}h{host_idx}"
                    host = Host(
                        name=name,
                        dc=dc,
                        leaf=leaf,
                        ip=f"192.168.{dc}.{host_idx}",
                        mac=f"aa:bb:{dc:02x}:{dc:02x}:{host_idx:02x}:{host_idx:02x}",
                    )
                    self.hosts[name] = host
                    self._node_dc[name] = dc
                    self._add_link(leaf, name)
                    host_idx += 1
        # WAN: full bipartite spine<->spine between DC pairs (paper: each spine
        # has one link to every spine of the remote DC -> 4 WAN links for 2 DCs).
        for dc_a in range(1, cfg.num_dcs + 1):
            for dc_b in range(dc_a + 1, cfg.num_dcs + 1):
                for ja in range(1, cfg.spines_per_dc + 1):
                    for jb in range(1, cfg.spines_per_dc + 1):
                        u, v = f"d{dc_a}s{ja}", f"d{dc_b}s{jb}"
                        self._add_link(u, v)
                        self.wan_links.append(frozenset((u, v)))
        for i, node in enumerate(sorted(self._adj)):
            self._switch_seed[node] = zlib.crc32(node.encode()) ^ (i * 0x9E3779B9)
        self._wan_link_set = set(self.wan_links)
        # lexicographic ids: sorting id arrays == sorting node names, so the
        # batched tables inherit next_hops()' stable ECMP choice order.
        self._node_order: List[str] = sorted(self._adj)
        self._node_id: Dict[str, int] = {n: i for i, n in enumerate(self._node_order)}
        self._seed_arr = np.array(
            [self._switch_seed[n] & 0xFFFFFFFF for n in self._node_order],
            dtype=np.uint32,
        )
        # Routing-loop guard derived from the topology instead of a magic
        # constant: an ECMP walk strictly decreases the BFS distance toward
        # the destination every hop, and that distance is bounded by the
        # switch-graph diameter — which under an arbitrary failure set is at
        # most the switch count (a shortest path never revisits a switch).
        # Anything longer is a genuine loop, at 8-DC scale included.
        self._hop_limit = len(self.spines) + len(self.leaves) + 2

    # -- link state ---------------------------------------------------------

    def all_links(self) -> List[FrozenSet[str]]:
        return sorted(self._links, key=sorted)

    def is_wan_link(self, u: str, v: str) -> bool:
        return frozenset((u, v)) in self._wan_link_set

    def node_dc(self, name: str) -> int:
        """1-based data center of a switch or host."""
        return self._node_dc[name]

    def wan_pair(self, u: str, v: str) -> Tuple[int, int]:
        """Normalized (lo, hi) DC pair a WAN link spans."""
        a, b = self._node_dc[u], self._node_dc[v]
        return (a, b) if a <= b else (b, a)

    def link_up(self, u: str, v: str) -> bool:
        return frozenset((u, v)) not in self._down_links

    def fail_link(self, u: str, v: str) -> RerouteStats:
        """Take a link down, re-converging only the dependent destinations."""
        key = frozenset((u, v))
        if key not in self._links:
            raise KeyError(f"no such link {u}<->{v}")
        if key in self._down_links:  # already down: nothing can change
            stats = RerouteStats((u, v), "fail", 0, 0, len(self._dist_cache))
            self.last_reroute = stats
            return stats
        self._down_links.add(key)
        return self._reconverge(key, (u, v), "fail")

    def restore_link(self, u: str, v: str) -> RerouteStats:
        """Bring a link back up, re-converging only the dependent destinations.

        Unlike the original full-invalidation path, an unknown link raises
        ``KeyError`` (symmetrically with :meth:`fail_link`) instead of being
        silently discarded.
        """
        key = frozenset((u, v))
        if key not in self._links:
            raise KeyError(f"no such link {u}<->{v}")
        if key not in self._down_links:  # already up: nothing can change
            stats = RerouteStats((u, v), "restore", 0, 0, len(self._dist_cache))
            self.last_reroute = stats
            return stats
        self._down_links.discard(key)
        return self._reconverge(key, (u, v), "restore")

    def flush_routing_state(self) -> None:
        """Full invalidation: drop every cached distance map, next-hop table
        and dependency record (the pre-incremental behavior; the failover
        benchmark uses it as the re-convergence baseline).  The interned
        pair registry and CRC/seed state survive — they are topology-only.
        """
        self._dist_cache.clear()
        self._nh_cache.clear()
        self._link_deps.clear()
        self._dst_dep_links.clear()

    def compile_routes(self, dsts: Iterable[str]) -> None:
        """Eagerly (re)build the per-destination routing tables.

        After a flap this materializes any lazily evicted rebuilds, so
        benchmarks can measure re-convergence separately from routing.
        """
        for dst in dsts:
            self._next_hop_table(dst)

    # -- incremental re-convergence -----------------------------------------

    def _index_deps(self, dst: str, dist: Dict[str, int]) -> None:
        """Register ``dst`` in the reverse link->destination index.

        Sensitivity is a pure function of the cached distance map:

        * a *live* link matters iff it is a DAG edge
          (``|dist[u] - dist[v]| == 1``) — failing anything else can change
          neither a distance nor an equal-cost choice set;
        * a *down* link matters iff restoring it would reconnect an
          unreachable endpoint or create a shorter/equal-cost path
          (``dist[u] != dist[v]`` or exactly one endpoint reachable).

        In-place row patches never change distances, so registrations stay
        exact across patches and only need rebuilding on eviction.
        """
        self._unindex(dst)
        deps: List[FrozenSet[str]] = []
        down = self._down_links
        hosts = self.hosts
        for key in self._links:
            u, v = tuple(key)
            if (u in hosts and u != dst) or (v in hosts and v != dst):
                # host attachment links never carry transit traffic, so they
                # cannot affect tables toward any other destination — without
                # this, a single host-NIC flap would degenerate to full
                # invalidation (every reachable host sits one BFS level past
                # its leaf, which looks like a DAG edge).
                continue
            du, dv = dist.get(u), dist.get(v)
            if key in down:
                sensitive = (du is None) != (dv is None) or (
                    du is not None and dv is not None and du != dv
                )
            else:
                sensitive = du is not None and dv is not None and abs(du - dv) == 1
            if sensitive:
                self._link_deps.setdefault(key, set()).add(dst)
                deps.append(key)
        self._dst_dep_links[dst] = deps

    def _unindex(self, dst: str) -> None:
        for key in self._dst_dep_links.pop(dst, ()):
            bucket = self._link_deps.get(key)
            if bucket is not None:
                bucket.discard(dst)

    def _evict(self, dst: str) -> None:
        self._dist_cache.pop(dst, None)
        self._nh_cache.pop(dst, None)
        self._unindex(dst)

    def _patch_row(self, dst: str, node: str) -> bool:
        """Recompute one node's row of the cached next-hop table in place.

        Returns True iff a compiled table existed and was actually edited."""
        cached = self._nh_cache.get(dst)
        if cached is None:
            return False  # distances unchanged and no table compiled yet
        nh, counts = cached
        i = self._node_id[node]
        if node in self.hosts and node != dst:
            row: List[int] = []  # hosts never forward
        else:
            row = [self._node_id[c] for c in self.next_hops(node, dst)]
        if len(row) > nh.shape[1]:  # restore added a choice beyond the width
            pad = np.full((nh.shape[0], len(row) - nh.shape[1]), -1, dtype=np.int64)
            nh = np.hstack([nh, pad])
        nh[i, :] = -1
        if row:
            nh[i, : len(row)] = row
        counts[i] = len(row)
        self._nh_cache[dst] = (nh, counts)
        return True

    def _reconverge(
        self, key: FrozenSet[str], link: Tuple[str, str], action: str
    ) -> RerouteStats:
        """Patch or evict exactly the destinations that depend on ``key``.

        For each dependent destination the cached distances decide the
        cheap case: if the flapped link connects adjacent BFS levels and
        the far endpoint still has (fail) / merely gains (restore) an
        equal-cost choice, no distance anywhere can change — only the far
        endpoint's ECMP choice row, which is rewritten in place.  Anything
        else (lost last next hop, reconnection, shortcut) evicts that one
        destination for a lazy BFS rebuild.  Every other cached
        destination — and the pair/CRC/seed state — is untouched.
        """
        cached_before = len(self._dist_cache)
        affected = sorted(self._link_deps.get(key, ()))
        patched = rebuilt = 0
        touched_dsts: List[str] = []
        for dst in affected:
            dist = self._dist_cache.get(dst)
            if dist is None:  # stale index entry; nothing cached to fix
                self._evict(dst)
                continue
            u, v = link
            du, dv = dist.get(u), dist.get(v)
            if du is not None and dv is not None and abs(du - dv) == 1:
                far = u if du > dv else v
                if action == "restore" or any(
                    dist.get(nb) == dist[far] - 1 for nb in self.neighbors(far)
                ):
                    # distances provably unchanged: the flap only edits the
                    # far endpoint's equal-cost choice set.  A destination
                    # with a cached distance map but no compiled table needs
                    # no edit at all and stays in the retained count.
                    if self._patch_row(dst, far):
                        patched += 1
                        touched_dsts.append(dst)
                    continue
            self._evict(dst)
            rebuilt += 1
            touched_dsts.append(dst)
        stats = RerouteStats(
            link, action, patched, rebuilt, cached_before - patched - rebuilt,
            affected_dsts=tuple(touched_dsts),
        )
        self.last_reroute = stats
        return stats

    def neighbors(self, node: str) -> List[str]:
        return [v for v in self._adj[node] if self.link_up(node, v)]

    # -- routing ------------------------------------------------------------

    def _distances_to(self, dst: str) -> Dict[str, int]:
        """BFS hop distances toward dst over live links (hosts non-transit)."""
        cached = self._dist_cache.get(dst)
        if cached is not None:
            return cached
        dist = {dst: 0}
        frontier = [dst]
        while frontier:
            nxt: List[str] = []
            for node in frontier:
                # hosts never forward traffic for others
                if node in self.hosts and node != dst:
                    continue
                for nb in self.neighbors(node):
                    if nb not in dist:
                        dist[nb] = dist[node] + 1
                        nxt.append(nb)
            frontier = nxt
        self._dist_cache[dst] = dist
        self._index_deps(dst, dist)
        return dist

    def next_hops(self, node: str, dst: str) -> List[str]:
        """Equal-cost next hops from ``node`` toward ``dst`` (sorted, stable)."""
        dist = self._distances_to(dst)
        if node not in dist:
            return []
        return sorted(
            nb for nb in self.neighbors(node) if dist.get(nb, 1 << 30) == dist[node] - 1
        )

    def route_flow(self, tup: FiveTuple, src: str, dst: str) -> List[str]:
        """Hop-by-hop ECMP walk; returns the node path (src..dst)."""
        path = [src]
        node = src
        hops = 0
        while node != dst:
            choices = self.next_hops(node, dst)
            if not choices:
                raise RuntimeError(f"no route {src}->{dst} at {node} (link failures?)")
            pick = choices[ecmp_hash(tup, self._switch_seed[node], len(choices))]
            path.append(pick)
            node = pick
            hops += 1
            if hops > self._hop_limit:
                raise RuntimeError("routing loop detected")
        return path

    # -- batched routing engine ---------------------------------------------

    def _next_hop_table(self, dst: str) -> Tuple[np.ndarray, np.ndarray]:
        """Per-destination ECMP table: (nh[node, choice], count[node]).

        Row ``i`` holds the ids of node i's equal-cost next hops toward
        ``dst`` in the exact order :meth:`next_hops` yields them (sorted by
        name == sorted by id), padded with -1.  Cached until a link fails
        or is restored.
        """
        cached = self._nh_cache.get(dst)
        if cached is not None:
            return cached
        n = len(self._node_order)
        counts = np.zeros(n, dtype=np.int64)
        rows: List[List[int]] = [[] for _ in range(n)]
        for i, node in enumerate(self._node_order):
            if node in self.hosts and node != dst:
                continue  # hosts never forward; their rows stay empty
            choices = self.next_hops(node, dst)
            rows[i] = [self._node_id[c] for c in choices]
            counts[i] = len(choices)
        width = max(1, int(counts.max()))
        nh = np.full((n, width), -1, dtype=np.int64)
        for i, row in enumerate(rows):
            if row:
                nh[i, : len(row)] = row
        self._nh_cache[dst] = (nh, counts)
        return nh, counts

    def _seed_xor_column(self, key_len: int) -> np.ndarray:
        """CRC seed-mixing column: Z[i] for key length L such that
        ``crc32(key, seed_i) == crc32(key, 0) ^ Z[i]`` (CRC-32 is linear
        over GF(2), so the seed's contribution depends only on len(key))."""
        col = self._zcol_cache.get(key_len)
        if col is None:
            zeros = b"\x00" * key_len
            base = zlib.crc32(zeros)
            col = np.array(
                [zlib.crc32(zeros, int(s)) ^ base for s in self._seed_arr],
                dtype=np.uint32,
            )
            self._zcol_cache[key_len] = col
        return col

    def _register_pair(self, src_name: str, dst_name: str, dst_port: int) -> int:
        """Intern a (src, dst, dst_port) host pair for the batched router.

        Stores node ids plus the CRC-32 of the inner/outer key *templates*
        (port digits zeroed): with the digit-gamma tables, the hash of any
        concrete port then falls out of pure XOR arithmetic.
        """
        nid = self._node_id
        src = self.hosts[src_name]
        dst = self.hosts[dst_name]
        if src.leaf == dst.leaf:
            row = (nid[src_name], nid[src.leaf], nid[dst_name], nid[dst.leaf],
                   True, 0, 0, 0, -1, src_name, dst_name)
        else:
            gid = self._leaf_gid.get(dst.leaf)
            if gid is None:
                gid = len(self._gid_leaf)
                self._leaf_gid[dst.leaf] = gid
                self._gid_leaf.append(dst.leaf)
            inner_t = f"{src.ip}|{dst.ip}|00000|{dst_port}|17".encode()
            outer_t = (
                f"{self.vtep_ip(src.leaf)}|{self.vtep_ip(dst.leaf)}"
                f"|00000|{VXLAN_DST_PORT}|17"
            ).encode()
            row = (nid[src_name], nid[src.leaf], nid[dst_name], nid[dst.leaf],
                   False, zlib.crc32(inner_t), zlib.crc32(outer_t), len(outer_t),
                   gid, src_name, dst_name)
        self._pair_rows.append(row)
        idx = len(self._pair_rows) - 1
        self._pair_cache[(src_name, dst_name, dst_port)] = idx
        self._pair_cols = None
        return idx

    def _pair_columns(self) -> Dict[str, np.ndarray]:
        """Column arrays over the interned pair registry (rebuilt on growth)."""
        cols = self._pair_cols
        if cols is None:
            rows = self._pair_rows
            cols = {
                "src_host": np.array([r[0] for r in rows], dtype=np.int64),
                "src_leaf": np.array([r[1] for r in rows], dtype=np.int64),
                "dst_host": np.array([r[2] for r in rows], dtype=np.int64),
                "dst_leaf": np.array([r[3] for r in rows], dtype=np.int64),
                "same_leaf": np.array([r[4] for r in rows], dtype=bool),
                "cti": np.array([r[5] for r in rows], dtype=np.uint32),
                "cto": np.array([r[6] for r in rows], dtype=np.uint32),
                "outer_len": np.array([r[7] for r in rows], dtype=np.int64),
                "gid": np.array([r[8] for r in rows], dtype=np.int64),
            }
            self._pair_cols = cols
        return cols

    def _walk_group(
        self,
        counters: np.ndarray,
        touched: np.ndarray,
        dst_leaf: str,
        c0: np.ndarray,
        lens: np.ndarray,
        cur: np.ndarray,
        nb: np.ndarray,
        dst_hosts: np.ndarray,
        flow_ids: Optional[np.ndarray] = None,
        rec: Optional[List] = None,
    ) -> None:
        """Advance every flow bound for ``dst_leaf`` one hop per NumPy step."""
        nh, cnt = self._next_hop_table(dst_leaf)
        nbuckets = self.config.ecmp_hash_buckets
        uniq_lens = np.unique(lens)
        zmat = np.stack([self._seed_xor_column(int(L)) for L in uniq_lens])
        len_slot = np.searchsorted(uniq_lens, lens)
        dst_id = self._node_id[dst_leaf]
        active = np.nonzero(cur != dst_id)[0]
        # per-hop ECMP fragments of this group: (flow_ids, seq, ci, pick,
        # bucket, fan, live) — buckets feed the hash-slot occupancy computed
        # once the whole group has walked (collisions span hops: two flows
        # meeting at the same switch at different depths still share the
        # bucket).
        grec: List[Tuple] = []
        for _hop in range(self._hop_limit):
            if active.size == 0:
                break
            ci = cur[active]
            fan = cnt[ci]
            if np.any(fan == 0):
                bad = self._node_order[int(ci[np.argmax(fan == 0)])]
                raise RuntimeError(f"no route ->{dst_leaf} at {bad} (link failures?)")
            h = c0[active] ^ zmat[len_slot[active], ci]
            pick = nh[ci, h.astype(np.int64) % fan]
            np.add.at(counters, (ci, pick), nb[active])
            touched[ci, pick] = True
            if rec is not None:
                bucket = (h % np.uint32(nbuckets)).astype(np.int64)
                grec.append(
                    (flow_ids[active], _hop + 1, ci, pick, bucket, fan,
                     nb[active] > 0)
                )
            cur[active] = pick
            active = active[pick != dst_id]
        else:
            raise RuntimeError("routing loop detected")
        if rec is not None and grec:
            # hash-slot occupancy over the whole group: flows sharing the
            # same (switch, member link, bucket) occupy one scheduling slot.
            # Zero-byte chunk flows transmit nothing, so they occupy no
            # slot (same convention as the congestion allocators, which
            # drain them for free); fan-1 forwarding involves no hash
            # decision, so its occupancy stays 1 no matter how many flows
            # cross the link.
            n = len(self._node_order)
            ug = np.concatenate([g[2] for g in grec])
            vg = np.concatenate([g[3] for g in grec])
            bg = np.concatenate([g[4] for g in grec])
            fg = np.concatenate([g[5] for g in grec])
            live = np.concatenate([g[6] for g in grec])
            key = (ug * n + vg) * nbuckets + bg
            _, inv = np.unique(key, return_inverse=True)
            live_counts = np.bincount(inv, weights=live.astype(np.int64))
            occ = np.where(fg > 1, np.maximum(live_counts[inv], 1), 1).astype(
                np.int64
            )
            # slot identity: member tables are per (switch, destination
            # group), so fold the group's egress leaf in; -1 marks fan-1
            # forwarding, which involves no hash decision and thus no slot.
            skey = np.where(
                fg > 1, key + np.int64(dst_id) * (n * n * nbuckets), np.int64(-1)
            )
            lo = 0
            for ids, seq, ci, pick, _, _, _ in grec:
                rec.append(
                    (ids, seq, ci, pick, occ[lo : lo + ids.size],
                     skey[lo : lo + ids.size])
                )
                lo += ids.size
        egress = np.full(dst_hosts.size, dst_id)
        np.add.at(counters, (egress, dst_hosts), nb)
        touched[egress, dst_hosts] = True
        if rec is not None:
            rec.append((flow_ids, self._hop_limit + 2, egress, dst_hosts, None, None))

    def route_flows_batched(
        self,
        flows: Iterable,
        *,
        dst_port: int = ROCE_DST_PORT,
        check_reachability=None,
    ) -> Dict[Link, int]:
        """Route many host-to-host flows at once; updates ``link_bytes``.

        ``flows`` is any iterable of records with ``src``, ``dst``,
        ``nbytes`` and ``src_port`` attributes (e.g.
        :class:`repro.core.flows.Flow`).  Byte-identical to calling
        :meth:`send` per flow, but everything beyond a thin interning loop
        runs in NumPy:

        * the five-tuple CRC is evaluated from per-pair key-template CRCs
          plus per-digit gamma tables (CRC-32 is GF(2)-linear), so steady
          state needs zero ``zlib.crc32`` calls per flow;
        * the per-switch hash seed folds in via the same linearity
          (``_seed_xor_column``);
        * flows group by egress leaf and advance one hop per vectorized
          step through the precomputed next-hop tables;
        * byte counters accumulate via ``np.add.at`` into a dense
          node x node matrix merged back into ``link_bytes`` at the end.

        Returns the link byte increments contributed by this batch.  Unlike
        the sequential path, an unreachable flow raises before any counter
        is touched.
        """
        out, _ = self._route_batch(flows, dst_port, check_reachability, False)
        return out

    def route_flows_with_paths(
        self,
        flows: Iterable,
        *,
        dst_port: int = ROCE_DST_PORT,
        check_reachability=None,
    ) -> Tuple[Dict[Link, int], FlowPaths]:
        """:meth:`route_flows_batched` plus per-flow path recording.

        Returns ``(link byte increments, FlowPaths)``; the paths feed the
        flow-level congestion model (:mod:`repro.core.congestion`), which
        needs to know *which* flows share a link, not just the aggregate
        bytes.  Counter semantics are identical to the plain batched call.
        """
        out, paths = self._route_batch(flows, dst_port, check_reachability, True)
        assert paths is not None
        return out, paths

    def _route_batch(
        self,
        flows: Iterable,
        dst_port: int,
        check_reachability,
        collect_paths: bool,
    ) -> Tuple[Dict[Link, int], Optional[FlowPaths]]:
        pair_cache = self._pair_cache
        register = self._register_pair
        pidx_l: List[int] = []
        ports_l: List[int] = []
        nb_l: List[int] = []
        for flow in flows:
            if check_reachability is not None and not check_reachability(
                flow.src, flow.dst
            ):
                raise UnreachableError(
                    f"{flow.dst} unreachable from {flow.src} (VNI isolation)"
                )
            idx = pair_cache.get((flow.src, flow.dst, dst_port))
            if idx is None:
                idx = register(flow.src, flow.dst, dst_port)
            pidx_l.append(idx)
            ports_l.append(flow.src_port)
            nb_l.append(flow.nbytes)
        empty = np.empty(0, dtype=np.int64)
        if not pidx_l:
            paths = (
                FlowPaths(empty, empty, np.zeros(1, dtype=np.int64),
                          tuple(self._node_order), empty, empty)
                if collect_paths else None
            )
            return {}, paths
        n = len(self._node_order)
        counters = np.zeros((n, n), dtype=np.int64)
        # links traversed, independent of byte count: send() records a
        # counter entry even for zero-byte frames, and byte-identical
        # includes those zero-valued keys.
        touched = np.zeros((n, n), dtype=bool)
        cols = self._pair_columns()
        pidx = np.asarray(pidx_l, dtype=np.int64)
        ports = np.asarray(ports_l, dtype=np.int64)
        nb = np.asarray(nb_l, dtype=np.int64)

        # per-flow (flow id, hop seq, u, v, slot occupancy, slot key)
        # fragments for FlowPaths assembly (occupancy None = non-ECMP hop,
        # occupancy 1, key -1)
        rec: Optional[List] = [] if collect_paths else None
        nflows = pidx.size
        np.add.at(counters, (cols["src_host"][pidx], cols["src_leaf"][pidx]), nb)
        touched[cols["src_host"][pidx], cols["src_leaf"][pidx]] = True
        if rec is not None:
            rec.append(
                (
                    np.arange(nflows),
                    0,
                    cols["src_host"][pidx],
                    cols["src_leaf"][pidx],
                    None,
                    None,
                )
            )
        same = cols["same_leaf"][pidx]
        si = np.nonzero(same)[0]
        if si.size:  # same-leaf local bridging: leaf -> dst host, no underlay
            sp = pidx[si]
            np.add.at(counters, (cols["dst_leaf"][sp], cols["dst_host"][sp]), nb[si])
            touched[cols["dst_leaf"][sp], cols["dst_host"][sp]] = True
            if rec is not None:
                rec.append(
                    (si, 1, cols["dst_leaf"][sp], cols["dst_host"][sp], None, None)
                )
        ri = np.nonzero(~same)[0]
        if ri.size:
            rp = pidx[ri]
            rports = ports[ri]
            c0 = np.empty(ri.size, dtype=np.uint32)
            five = (rports >= 10000) & (rports <= 99999)
            v = np.nonzero(five)[0]
            if v.size:
                # inner key hash -> 14-bit entropy -> outer VXLAN source
                # port (0xC000 + entropy, always 5 digits) -> outer key
                # hash, all via template CRCs + digit gammas.
                g_in = _gamma_block(len(f"|{dst_port}|17"))
                g_out = _gamma_block(len(f"|{VXLAN_DST_PORT}|17"))
                pv = rports[v]
                inner = cols["cti"][rp[v]].copy()
                for k in range(5):
                    inner ^= g_in[k][(pv // 10 ** (4 - k)) % 10]
                op = (inner & np.uint32(0x3FFF)).astype(np.int64) + 0xC000
                outer = cols["cto"][rp[v]].copy()
                for k in range(5):
                    outer ^= g_out[k][(op // 10 ** (4 - k)) % 10]
                c0[v] = outer
            for i in np.nonzero(~five)[0].tolist():
                # rare: source port outside the 5-digit range; take the
                # reference string path for these flows only.
                row = self._pair_rows[int(rp[i])]
                src, dsth = self.hosts[row[9]], self.hosts[row[10]]
                outer_tup = vxlan_outer_tuple(
                    FiveTuple(src.ip, dsth.ip, int(rports[i]), dst_port),
                    self.vtep_ip(src.leaf),
                    self.vtep_ip(dsth.leaf),
                )
                c0[i] = zlib.crc32(outer_tup.key_bytes())
            gids = cols["gid"][rp]
            lens = cols["outer_len"][rp]
            cur = cols["src_leaf"][rp]
            dst_hosts = cols["dst_host"][rp]
            rnb = nb[ri]
            for g in np.unique(gids).tolist():
                m = np.nonzero(gids == g)[0]
                self._walk_group(
                    counters, touched, self._gid_leaf[g],
                    c0[m], lens[m], cur[m], rnb[m], dst_hosts[m],
                    flow_ids=ri[m] if rec is not None else None, rec=rec,
                )

        out: Dict[Link, int] = {}
        us, vs = np.nonzero(touched)
        order = self._node_order
        for u, v in zip(us.tolist(), vs.tolist()):
            b = int(counters[u, v])
            out[(order[u], order[v])] = b
            self.link_bytes[(order[u], order[v])] += b
        paths: Optional[FlowPaths] = None
        if rec is not None:
            fl = np.concatenate([np.asarray(r[0], dtype=np.int64) for r in rec])
            seq = np.concatenate(
                [np.full(len(r[0]), r[1], dtype=np.int64) for r in rec]
            )
            lu = np.concatenate([np.asarray(r[2], dtype=np.int64) for r in rec])
            lv = np.concatenate([np.asarray(r[3], dtype=np.int64) for r in rec])
            occ = np.concatenate(
                [
                    np.asarray(r[4], dtype=np.int64)
                    if r[4] is not None
                    else np.ones(len(r[0]), dtype=np.int64)
                    for r in rec
                ]
            )
            skey = np.concatenate(
                [
                    np.asarray(r[5], dtype=np.int64)
                    if r[5] is not None
                    else np.full(len(r[0]), -1, dtype=np.int64)
                    for r in rec
                ]
            )
            sort = np.lexsort((seq, fl))  # group by flow, hop order within
            ptr = np.zeros(nflows + 1, dtype=np.int64)
            np.cumsum(np.bincount(fl, minlength=nflows), out=ptr[1:])
            paths = FlowPaths(
                lu[sort], lv[sort], ptr, tuple(order), occ[sort], skey[sort]
            )
        return out, paths

    # -- data plane ---------------------------------------------------------

    def vtep_ip(self, leaf: str) -> str:
        # loopback VTEP addressing mirrors the paper (1.1.10.1 style);
        # split on the 'l' separator of d{dc}l{idx} rather than slicing at
        # fixed offsets so multi-digit DC ids (SCALED64) parse too
        dc_s, idx_s = leaf[1:].split("l", 1)
        dc, idx = int(dc_s), int(idx_s)
        return f"{dc}.{dc}.10.{idx}"

    def send(
        self,
        src_host: str,
        dst_host: str,
        nbytes: int,
        src_port: int,
        dst_port: int = ROCE_DST_PORT,
        *,
        check_reachability=None,
    ) -> List[str]:
        """Send ``nbytes`` from host to host; updates link byte counters.

        ``check_reachability`` is an optional callable (src, dst) -> bool
        supplied by the EVPN/tenancy layer; when it returns False the frame
        is dropped at the ingress VTEP (destination host unreachable).
        Returns the underlay node path taken.
        """
        src, dst = self.hosts[src_host], self.hosts[dst_host]
        if check_reachability is not None and not check_reachability(src_host, dst_host):
            raise UnreachableError(f"{dst_host} unreachable from {src_host} (VNI isolation)")
        inner = FiveTuple(src.ip, dst.ip, src_port, dst_port)
        self._count(src_host, src.leaf, nbytes)
        if src.leaf == dst.leaf:
            self._count(dst.leaf, dst_host, nbytes)
            return [src_host, src.leaf, dst_host]
        outer = vxlan_outer_tuple(inner, self.vtep_ip(src.leaf), self.vtep_ip(dst.leaf))
        path = self.route_flow(outer, src.leaf, dst.leaf)
        for u, v in zip(path, path[1:]):
            self._count(u, v, nbytes)
        self._count(dst.leaf, dst_host, nbytes)
        return [src_host] + path + [dst_host]

    def _count(self, u: str, v: str, nbytes: int) -> None:
        self.link_bytes[(u, v)] += nbytes

    def reset_counters(self) -> None:
        self.link_bytes.clear()

    # -- observability ------------------------------------------------------

    def uplink_bytes(self, node: str, toward: str = "spine") -> Dict[Link, int]:
        """Byte counters on a node's egress links toward spines or WAN."""
        out: Dict[Link, int] = {}
        for (u, v), b in self.link_bytes.items():
            if u != node:
                continue
            if toward == "spine" and v in self.spines and not self.is_wan_link(u, v):
                out[(u, v)] = b
            elif toward == "wan" and self.is_wan_link(u, v):
                out[(u, v)] = b
        return out

    def rtt_path(self, src_host: str, dst_host: str) -> List[Tuple[str, str, bool]]:
        """One representative forward path as (u, v, is_wan) link triples."""
        src, dst = self.hosts[src_host], self.hosts[dst_host]
        links: List[Tuple[str, str, bool]] = [(src_host, src.leaf, False)]
        if src.leaf != dst.leaf:
            tup = FiveTuple(src.ip, dst.ip, 49192, ROCE_DST_PORT)
            outer = vxlan_outer_tuple(tup, self.vtep_ip(src.leaf), self.vtep_ip(dst.leaf))
            path = self.route_flow(outer, src.leaf, dst.leaf)
            links += [(u, v, self.is_wan_link(u, v)) for u, v in zip(path, path[1:])]
        links.append((dst.leaf, dst_host, False))
        return links


class UnreachableError(RuntimeError):
    """Destination host unreachable (missing EVPN route or VNI mismatch)."""
