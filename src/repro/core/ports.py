"""Source-port allocation for RoCEv2 queue pairs (ScaleAcross §3.3).

Implements both allocators studied in the paper:

* :func:`rxe_baseline_port` — the stock Soft-RoCE (``rdma-rxe``) behaviour:
  the 32-bit QP number is hashed with the Linux kernel's multiplicative
  ``hash_32`` into a 14-bit offset above the RoCEv2 base port 49192.

* :func:`qp_aware_port` — the paper's Algorithm 1 ("Queue-Pair-Aware Source
  Port Allocation"): the 16384-offset dynamic range is partitioned into
  ``k`` non-overlapping bins of width ``W_b = floor(16384/k)``; a QP is
  deterministically assigned bin ``B_i = I_QP mod k`` and the original hash
  is preserved *within* the bin via ``o_b = o_r mod W_b``.

The two-stage design guarantees that any ``k`` QPs with consecutive indices
occupy pairwise-distinct port sub-ranges, so correlated QP numbers can no
longer produce identical packet 5-tuples — the production pathology reported
by Gangidi et al. (SIGCOMM'24) and reproduced here in
``tests/test_ports.py::test_baseline_aliasing_stride``.
"""

from __future__ import annotations

import math

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence

# RoCEv2 dynamic source-port range used by Soft-RoCE (paper §3.3).
ROCE_V2_BASE_PORT = 49192
PORT_OFFSET_BITS = 14
NUM_PORT_OFFSETS = 1 << PORT_OFFSET_BITS  # 16384
MAX_PORT = ROCE_V2_BASE_PORT + NUM_PORT_OFFSETS - 1  # 65535

# Linux kernel GOLDEN_RATIO_32 (include/linux/hash.h) used by hash_32().
_GOLDEN_RATIO_32 = 0x61C88647
_U32 = 0xFFFFFFFF


def hash_32(val: int, bits: int) -> int:
    """The Linux kernel's multiplicative hash: top ``bits`` of val*phi32."""
    return ((val * _GOLDEN_RATIO_32) & _U32) >> (32 - bits)


def rxe_baseline_port(qp_number: int) -> int:
    """Stock rdma-rxe source port: base + hash_32(qp_num, 14)."""
    return ROCE_V2_BASE_PORT + hash_32(qp_number & _U32, PORT_OFFSET_BITS)


@dataclass(frozen=True)
class QueuePair:
    """A queue pair as seen by the allocator.

    ``index`` is the QP's ordinal within its connection group (NCCL channel
    id); ``number`` is the driver-assigned 32-bit QP number, which in
    production may be correlated across QPs of the same GPU pair.
    """

    index: int
    number: int


def qp_aware_port(qp: QueuePair, k: int = 4) -> int:
    """Algorithm 1 from the paper, line for line.

    1. ``P_base = 49192``; bin width ``W_b = floor(16384 / k)``.
    2. ``o_r = Hash32(QP.number, 14)`` (unchanged Soft-RoCE hash).
    3. ``B_i = I_QP mod k`` (deterministic bin from the QP *index*).
    4. ``o_b = o_r mod W_b`` (hash constrained to the bin).
    5. ``P_s = P_base + B_i * W_b + o_b``.
    """
    if k < 1:
        raise ValueError(f"bin count k must be >= 1, got {k}")
    w_b = NUM_PORT_OFFSETS // k
    o_r = hash_32(qp.number & _U32, PORT_OFFSET_BITS)
    b_i = qp.index % k
    o_b = o_r % w_b
    return ROCE_V2_BASE_PORT + b_i * w_b + o_b


def baseline_ports(qps: Iterable[QueuePair]) -> List[int]:
    return [rxe_baseline_port(qp.number) for qp in qps]


def qp_aware_ports(qps: Iterable[QueuePair], k: int = 4) -> List[int]:
    return [qp_aware_port(qp, k=k) for qp in qps]


# ---------------------------------------------------------------------------
# QP-number allocation models (how drivers hand out qp numbers in practice).
# ---------------------------------------------------------------------------

#: Stride for which hash_32 provably aliases: 75025 = F(25), a Fibonacci
#: number, makes ``d * GOLDEN_RATIO_32 mod 2^32`` = 11703 — far below the
#: 2^18 bucket width of the 14-bit extraction — so runs of ~22 consecutive
#: QP numbers spaced by it receive *identical* 14-bit port offsets from
#: hash_32 (verified in tests/test_ports.py).  This is the concrete form of
#: the "different QPs receive identical source ports" production scenario
#: cited in §3.3 of the paper (Gangidi et al. observed it at Meta scale).
ALIASING_STRIDE = 75025
#: An even stronger alias (offsets identical for 40+ consecutive QPs).
ALIASING_STRIDE_STRONG = 328757


def make_queue_pairs(
    num_qps: int,
    *,
    base_number: int = 0x11,
    stride: int = 1,
) -> List[QueuePair]:
    """QPs with indices 0..n-1 and driver numbers base + i*stride."""
    return [QueuePair(index=i, number=(base_number + i * stride) & _U32) for i in range(num_qps)]


def make_correlated_queue_pairs(
    num_qps: int,
    *,
    base_number: int = 0x11,
    distinct_offsets: Optional[int] = None,
) -> List[QueuePair]:
    """QP numbers with the *partial* port aliasing seen in production.

    The §3.3 pathology in its realistic form: an n-QP connection set maps
    onto only ``u`` distinct hash_32 offsets (u grows with n — more
    channels add natural entropy, which is why the paper's gains shrink at
    32 QPs).  Constructed as ``base + (i mod u)*17 + (i div u)*S`` with S
    the strong aliasing stride, so QPs sharing ``i mod u`` share a source
    port under the default allocator, while Algorithm 1's index-keyed bins
    still separate them.
    """
    if distinct_offsets is None:
        u = math.isqrt(2 * num_qps)
        u += 1 - (u % 2)  # odd: avoids artificial resonance with k=4 bins
        u = max(3, u)
    else:
        u = distinct_offsets
    return [
        QueuePair(
            index=i,
            number=(base_number + (i % u) * 17 + (i // u) * ALIASING_STRIDE_STRONG) & _U32,
        )
        for i in range(num_qps)
    ]


def allocate_ports(
    qps: Sequence[QueuePair],
    *,
    scheme: str = "qp_aware",
    k: int = 4,
) -> List[int]:
    """Dispatch on allocation scheme name ("baseline" | "qp_aware")."""
    if scheme == "baseline":
        return baseline_ports(qps)
    if scheme == "qp_aware":
        return qp_aware_ports(qps, k=k)
    raise ValueError(f"unknown port allocation scheme: {scheme!r}")
