"""MP-BGP EVPN control plane for the emulated VXLAN fabric (paper §3.2, §4.2).

Models the route types the paper exercises:

* **Type-3 IMET** (Inclusive Multicast Ethernet Tag) — a VTEP advertises
  (VTEP-IP, VNI) membership; builds per-VNI flood lists and enables remote
  VTEP discovery.
* **Type-2 MAC/IP** — a leaf that learns a host (via ARP snooping in the
  paper) advertises (MAC, IP, VNI, VTEP-IP); builds the overlay forwarding
  tables that make cross-DC hosts mutually reachable.

Routes carry Route Distinguishers and Route Targets; import policy is
RT-based, which is what enforces multi-tenancy at the control-plane level.
Propagation follows the paper's BGP session graph: leaves peer with their
local spines (route reflectors), spines of different DCs peer over the WAN.
Withdrawal (on BFD-detected failure) removes routes and flood-list entries.

Incremental resync (the control-plane twin of the data plane's incremental
re-convergence, "I've Got 99 Problems But FLOPS Ain't One"-style
control-plane cost accounting): a BFD flap used to trigger
:meth:`EvpnControlPlane.resync` — flush every speaker's RIB and re-flood
the whole route log.  :meth:`EvpnControlPlane.resync_incremental`
piggybacks on the fabric's :class:`~repro.core.fabric.RerouteStats`
instead: a single-link flap can only move routes whose *origin VTEP's
flood reachability crossed that link*, so the control plane diffs the BGP
session graph's connected components before/after the flap and edits
exactly the speakers whose membership relative to an origin changed —
surfacing ``patched`` / ``rebuilt`` / ``retained`` counts symmetrically
with the data plane.  The resulting session state is byte-identical to a
full resync (gated in ``benchmarks/bench_failover.py``), while the
typical non-partitioning flap touches zero VTEPs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from .fabric import Fabric, RerouteStats


@dataclass(frozen=True)
class RouteType3:
    """IMET route: VTEP membership in a VNI."""

    rd: str
    vni: int
    vtep_ip: str
    origin_leaf: str

    @property
    def rt(self) -> str:
        return f"target:65000:{self.vni}"


@dataclass(frozen=True)
class RouteType2:
    """MAC/IP advertisement route."""

    rd: str
    vni: int
    mac: str
    ip: str
    vtep_ip: str
    origin_leaf: str

    @property
    def rt(self) -> str:
        return f"target:65000:{self.vni}"


@dataclass(frozen=True)
class EvpnResyncStats:
    """What one incremental EVPN resync did to control-plane state.

    The control-plane mirror of :class:`repro.core.fabric.RerouteStats`:

    ``patched``  — spine (route-reflector) RIBs edited in place;
    ``rebuilt``  — leaf VTEPs whose RIB changed, forcing their derived
    MAC/IP/flood tables to be re-imported;
    ``retained`` — speakers whose sessions and RIBs were left untouched.

    ``origins_recomputed`` counts the origin VTEPs whose flood
    reachability had to be re-derived (0 for the common flap that
    partitions nothing).
    """

    link: Tuple[str, str]
    action: str  # "fail" | "restore"
    patched: int
    rebuilt: int
    retained: int
    origins_recomputed: int = 0
    total_vteps: int = 0

    @property
    def touched(self) -> int:
        return self.patched + self.rebuilt

    @property
    def total_speakers(self) -> int:
        return self.patched + self.rebuilt + self.retained

    @property
    def vtep_touched_frac(self) -> float:
        """Fraction of leaf VTEPs whose tables had to be rebuilt."""
        if self.total_vteps <= 0:
            return 0.0
        return self.rebuilt / self.total_vteps


@dataclass
class BgpSpeaker:
    name: str
    asn: int
    router_id: str
    is_route_reflector: bool = False
    peers: List[str] = field(default_factory=list)
    rib: Set[object] = field(default_factory=set)


class EvpnControlPlane:
    """BGP session graph + route propagation over a :class:`Fabric`."""

    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        self.speakers: Dict[str, BgpSpeaker] = {}
        # per-leaf derived state
        self.mac_table: Dict[str, Dict[Tuple[int, str], str]] = {}  # leaf -> (vni, mac) -> vtep
        self.ip_table: Dict[str, Dict[Tuple[int, str], str]] = {}  # leaf -> (vni, ip) -> vtep
        self.flood_list: Dict[str, Dict[int, Set[str]]] = {}  # leaf -> vni -> vtep set
        self.local_vnis: Dict[str, Set[int]] = {}  # leaf -> VNIs configured
        self._route_log: List[object] = []
        self.last_resync: Optional[EvpnResyncStats] = None
        self._build_sessions()

    # -- session graph -------------------------------------------------------

    def _build_sessions(self) -> None:
        for i, node in enumerate(sorted(self.fabric.spines + self.fabric.leaves)):
            dc = int(node[1])
            self.speakers[node] = BgpSpeaker(
                name=node,
                asn=65000 + dc,
                router_id=f"10.{dc}.0.{i + 1}",
                is_route_reflector=node in self.fabric.spines,
            )
        for leaf in self.fabric.leaves:
            self.mac_table[leaf] = {}
            self.ip_table[leaf] = {}
            self.flood_list[leaf] = {}
            self.local_vnis[leaf] = set()
            dc = leaf[:2]
            for spine in self.fabric.spines:
                if spine.startswith(dc):
                    self._peer(leaf, spine)
        # inter-DC spine peering over WAN links
        for link in self.fabric.wan_links:
            u, v = sorted(link)
            self._peer(u, v)

    def _peer(self, a: str, b: str) -> None:
        if b not in self.speakers[a].peers:
            self.speakers[a].peers.append(b)
        if a not in self.speakers[b].peers:
            self.speakers[b].peers.append(a)

    def session_up(self, a: str, b: str) -> bool:
        """A BGP session is up iff the underlay link is up."""
        return self.fabric.link_up(a, b)

    # -- advertisement -------------------------------------------------------

    def configure_vni(self, leaf: str, vni: int) -> RouteType3:
        """Configure a VNI on a leaf VTEP -> originate a Type-3 IMET route."""
        self.local_vnis[leaf].add(vni)
        self.flood_list[leaf].setdefault(vni, set())
        route = RouteType3(
            rd=f"{self.speakers[leaf].router_id}:{vni}",
            vni=vni,
            vtep_ip=self.fabric.vtep_ip(leaf),
            origin_leaf=leaf,
        )
        self._propagate(route)
        return route

    def learn_host(self, host_name: str, vni: int) -> RouteType2:
        """Leaf snoops the host's ARP -> originate a Type-2 MAC/IP route."""
        host = self.fabric.hosts[host_name]
        leaf = host.leaf
        if vni not in self.local_vnis.get(leaf, set()):
            self.configure_vni(leaf, vni)
        host.vni = vni
        route = RouteType2(
            rd=f"{self.speakers[leaf].router_id}:{vni}",
            vni=vni,
            mac=host.mac,
            ip=host.ip,
            vtep_ip=self.fabric.vtep_ip(leaf),
            origin_leaf=leaf,
        )
        self._propagate(route)
        return route

    def _propagate(self, route: object) -> None:
        """Flood through the BGP session graph (RR semantics collapsed to a
        loop-free flood over live sessions), then run import policy."""
        self._route_log.append(route)
        origin = route.origin_leaf  # type: ignore[attr-defined]
        seen = {origin}
        frontier = [origin]
        self.speakers[origin].rib.add(route)
        while frontier:
            nxt: List[str] = []
            for node in frontier:
                for peer in self.speakers[node].peers:
                    if peer in seen or not self.session_up(node, peer):
                        continue
                    seen.add(peer)
                    self.speakers[peer].rib.add(route)
                    nxt.append(peer)
            frontier = nxt
        self._reimport()

    def _reimport(self, leaves: Optional[Iterable[str]] = None) -> None:
        """Rebuild leaf tables from RIBs with RT import filtering.

        ``leaves`` restricts the rebuild to the given VTEPs (the
        incremental resync passes exactly the leaves whose RIB changed);
        ``None`` rebuilds every leaf, the full-resync behavior.
        """
        for leaf in self.fabric.leaves if leaves is None else leaves:
            mac: Dict[Tuple[int, str], str] = {}
            ip: Dict[Tuple[int, str], str] = {}
            flood: Dict[int, Set[str]] = {v: set() for v in self.local_vnis[leaf]}
            my_vteps = self.fabric.vtep_ip(leaf)
            for route in self.speakers[leaf].rib:
                vni = route.vni  # type: ignore[attr-defined]
                if vni not in self.local_vnis[leaf]:
                    continue  # RT import policy: only locally configured VNIs
                if isinstance(route, RouteType3) and route.vtep_ip != my_vteps:
                    flood[vni].add(route.vtep_ip)
                elif isinstance(route, RouteType2):
                    mac[(vni, route.mac)] = route.vtep_ip
                    ip[(vni, route.ip)] = route.vtep_ip
            self.mac_table[leaf] = mac
            self.ip_table[leaf] = ip
            self.flood_list[leaf] = flood

    # -- withdrawal ----------------------------------------------------------

    def withdraw_host(self, host_name: str) -> None:
        """Withdraw one host's Type-2 MAC/IP routes (tenant detach churn).

        The withdrawn routes also leave the route log, so neither a full
        :meth:`resync` nor :meth:`resync_incremental` can resurrect them;
        the host's VNI binding is cleared, making it unreachable until the
        next :meth:`learn_host`.
        """
        host = self.fabric.hosts[host_name]

        def _is_host_route(r: object) -> bool:
            return (
                isinstance(r, RouteType2)
                and r.mac == host.mac
                and r.ip == host.ip
            )

        for sp in self.speakers.values():
            sp.rib = {r for r in sp.rib if not _is_host_route(r)}
        self._route_log = [r for r in self._route_log if not _is_host_route(r)]
        host.vni = None
        self._reimport()

    def withdraw_leaf(self, leaf: str) -> None:
        """Withdraw every route originated by ``leaf`` (e.g. leaf isolated).

        The withdrawn routes also leave the route log, so neither a full
        :meth:`resync` nor :meth:`resync_incremental` can resurrect them.
        """
        for sp in self.speakers.values():
            sp.rib = {r for r in sp.rib if getattr(r, "origin_leaf", None) != leaf}
        self._route_log = [
            r for r in self._route_log if getattr(r, "origin_leaf", None) != leaf
        ]
        self._reimport()

    def resync(self) -> None:
        """Re-flood every logged route (after topology repair)."""
        routes, self._route_log = self._route_log, []
        for sp in self.speakers.values():
            sp.rib.clear()
        for r in routes:
            self._propagate(r)

    # -- incremental resync ---------------------------------------------------

    def _session_live(
        self,
        a: str,
        b: str,
        override: Optional[Tuple[FrozenSet[str], bool]] = None,
    ) -> bool:
        if override is not None and frozenset((a, b)) == override[0]:
            return override[1]
        return self.session_up(a, b)

    def _components(
        self, override: Optional[Tuple[FrozenSet[str], bool]] = None
    ) -> Dict[str, int]:
        """Connected components of the live BGP session graph.

        ``override`` forces one link's session state, letting the
        incremental resync reconstruct the pre-flap graph without
        replaying history (a :class:`~repro.core.fabric.RerouteStats`
        describes exactly one link transition).
        """
        comp: Dict[str, int] = {}
        cid = 0
        for s in self.speakers:
            if s in comp:
                continue
            cid += 1
            comp[s] = cid
            stack = [s]
            while stack:
                node = stack.pop()
                for peer in self.speakers[node].peers:
                    if peer not in comp and self._session_live(
                        node, peer, override
                    ):
                        comp[peer] = cid
                        stack.append(peer)
        return comp

    def resync_incremental(self, reroute: RerouteStats) -> EvpnResyncStats:
        """Resync only the VTEPs whose route reachability crossed a flap.

        Piggybacks on the data plane's :class:`~repro.core.fabric.RerouteStats`
        (the fabric has already applied the flap): a route's placement —
        RIB ``s`` holds origin ``o``'s routes iff ``s`` can be flooded
        from ``o`` over live sessions — can only change for speakers whose
        session-graph component relative to ``o`` changed across the flap.
        The common case (multihomed leaf/spine fabrics survive single-link
        flaps connected) diffs to the empty set and the whole control
        plane is ``retained``; a genuine partition withdraws/re-floods
        exactly the affected origins' routes at exactly the affected
        speakers, and only those leaves re-import their MAC/IP/flood
        tables.  Byte-identical to :meth:`resync` provided every flap is
        synced through here (or :meth:`resync` re-baselines).

        Host-attachment flaps and links that carry no BGP session diff to
        the empty set automatically.
        """
        u, v = reroute.link
        key = frozenset((u, v))
        after = self._components()
        # pre-flap graph: this link forced to its pre-transition state
        before = self._components(override=(key, reroute.action == "fail"))
        edited: Set[str] = set()
        recomputed = 0
        if before != after:
            by_origin: Dict[str, List[object]] = {}
            for r in self._route_log:
                origin = getattr(r, "origin_leaf", None)
                if origin is not None:
                    by_origin.setdefault(origin, []).append(r)
            for origin, routes in sorted(by_origin.items()):
                if origin not in self.speakers:
                    continue
                ob, oa = before[origin], after[origin]
                moved = [
                    s
                    for s in self.speakers
                    if (before[s] == ob) != (after[s] == oa)
                ]
                if not moved:
                    continue
                recomputed += 1
                rset = set(routes)
                for s in moved:
                    sp = self.speakers[s]
                    if after[s] == oa:  # gained reachability from origin
                        if not rset <= sp.rib:
                            sp.rib |= rset
                            edited.add(s)
                    else:  # lost reachability: withdraw origin's routes
                        kept = {
                            r
                            for r in sp.rib
                            if getattr(r, "origin_leaf", None) != origin
                        }
                        if len(kept) != len(sp.rib):
                            sp.rib = kept
                            edited.add(s)
        leaf_set = set(self.fabric.leaves)
        edited_leaves = sorted(edited & leaf_set)
        if edited_leaves:
            self._reimport(edited_leaves)
        stats = EvpnResyncStats(
            link=(u, v),
            action=reroute.action,
            patched=len(edited) - len(edited_leaves),
            rebuilt=len(edited_leaves),
            retained=len(self.speakers) - len(edited),
            origins_recomputed=recomputed,
            total_vteps=len(self.fabric.leaves),
        )
        self.last_resync = stats
        return stats

    # -- queries -------------------------------------------------------------

    def reachable(self, src_host: str, dst_host: str) -> bool:
        """Overlay reachability: same VNI + Type-2 route present at ingress."""
        src = self.fabric.hosts[src_host]
        dst = self.fabric.hosts[dst_host]
        if src.vni is None or dst.vni is None or src.vni != dst.vni:
            return False
        entry = self.ip_table.get(src.leaf, {}).get((src.vni, dst.ip))
        if src.leaf == dst.leaf:
            return True  # local bridging
        return entry == self.fabric.vtep_ip(dst.leaf)

    def route_count(self, node: str) -> Dict[str, int]:
        rib = self.speakers[node].rib
        return {
            "type2": sum(isinstance(r, RouteType2) for r in rib),
            "type3": sum(isinstance(r, RouteType3) for r in rib),
        }
