"""MP-BGP EVPN control plane for the emulated VXLAN fabric (paper §3.2, §4.2).

Models the route types the paper exercises:

* **Type-3 IMET** (Inclusive Multicast Ethernet Tag) — a VTEP advertises
  (VTEP-IP, VNI) membership; builds per-VNI flood lists and enables remote
  VTEP discovery.
* **Type-2 MAC/IP** — a leaf that learns a host (via ARP snooping in the
  paper) advertises (MAC, IP, VNI, VTEP-IP); builds the overlay forwarding
  tables that make cross-DC hosts mutually reachable.

Routes carry Route Distinguishers and Route Targets; import policy is
RT-based, which is what enforces multi-tenancy at the control-plane level.
Propagation follows the paper's BGP session graph: leaves peer with their
local spines (route reflectors), spines of different DCs peer over the WAN.
Withdrawal (on BFD-detected failure) removes routes and flood-list entries.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .fabric import Fabric


@dataclass(frozen=True)
class RouteType3:
    """IMET route: VTEP membership in a VNI."""

    rd: str
    vni: int
    vtep_ip: str
    origin_leaf: str

    @property
    def rt(self) -> str:
        return f"target:65000:{self.vni}"


@dataclass(frozen=True)
class RouteType2:
    """MAC/IP advertisement route."""

    rd: str
    vni: int
    mac: str
    ip: str
    vtep_ip: str
    origin_leaf: str

    @property
    def rt(self) -> str:
        return f"target:65000:{self.vni}"


@dataclass
class BgpSpeaker:
    name: str
    asn: int
    router_id: str
    is_route_reflector: bool = False
    peers: List[str] = field(default_factory=list)
    rib: Set[object] = field(default_factory=set)


class EvpnControlPlane:
    """BGP session graph + route propagation over a :class:`Fabric`."""

    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        self.speakers: Dict[str, BgpSpeaker] = {}
        # per-leaf derived state
        self.mac_table: Dict[str, Dict[Tuple[int, str], str]] = {}  # leaf -> (vni, mac) -> vtep
        self.ip_table: Dict[str, Dict[Tuple[int, str], str]] = {}  # leaf -> (vni, ip) -> vtep
        self.flood_list: Dict[str, Dict[int, Set[str]]] = {}  # leaf -> vni -> vtep set
        self.local_vnis: Dict[str, Set[int]] = {}  # leaf -> VNIs configured
        self._route_log: List[object] = []
        self._build_sessions()

    # -- session graph -------------------------------------------------------

    def _build_sessions(self) -> None:
        for i, node in enumerate(sorted(self.fabric.spines + self.fabric.leaves)):
            dc = int(node[1])
            self.speakers[node] = BgpSpeaker(
                name=node,
                asn=65000 + dc,
                router_id=f"10.{dc}.0.{i + 1}",
                is_route_reflector=node in self.fabric.spines,
            )
        for leaf in self.fabric.leaves:
            self.mac_table[leaf] = {}
            self.ip_table[leaf] = {}
            self.flood_list[leaf] = {}
            self.local_vnis[leaf] = set()
            dc = leaf[:2]
            for spine in self.fabric.spines:
                if spine.startswith(dc):
                    self._peer(leaf, spine)
        # inter-DC spine peering over WAN links
        for link in self.fabric.wan_links:
            u, v = sorted(link)
            self._peer(u, v)

    def _peer(self, a: str, b: str) -> None:
        if b not in self.speakers[a].peers:
            self.speakers[a].peers.append(b)
        if a not in self.speakers[b].peers:
            self.speakers[b].peers.append(a)

    def session_up(self, a: str, b: str) -> bool:
        """A BGP session is up iff the underlay link is up."""
        return self.fabric.link_up(a, b)

    # -- advertisement -------------------------------------------------------

    def configure_vni(self, leaf: str, vni: int) -> RouteType3:
        """Configure a VNI on a leaf VTEP -> originate a Type-3 IMET route."""
        self.local_vnis[leaf].add(vni)
        self.flood_list[leaf].setdefault(vni, set())
        route = RouteType3(
            rd=f"{self.speakers[leaf].router_id}:{vni}",
            vni=vni,
            vtep_ip=self.fabric.vtep_ip(leaf),
            origin_leaf=leaf,
        )
        self._propagate(route)
        return route

    def learn_host(self, host_name: str, vni: int) -> RouteType2:
        """Leaf snoops the host's ARP -> originate a Type-2 MAC/IP route."""
        host = self.fabric.hosts[host_name]
        leaf = host.leaf
        if vni not in self.local_vnis.get(leaf, set()):
            self.configure_vni(leaf, vni)
        host.vni = vni
        route = RouteType2(
            rd=f"{self.speakers[leaf].router_id}:{vni}",
            vni=vni,
            mac=host.mac,
            ip=host.ip,
            vtep_ip=self.fabric.vtep_ip(leaf),
            origin_leaf=leaf,
        )
        self._propagate(route)
        return route

    def _propagate(self, route: object) -> None:
        """Flood through the BGP session graph (RR semantics collapsed to a
        loop-free flood over live sessions), then run import policy."""
        self._route_log.append(route)
        origin = route.origin_leaf  # type: ignore[attr-defined]
        seen = {origin}
        frontier = [origin]
        self.speakers[origin].rib.add(route)
        while frontier:
            nxt: List[str] = []
            for node in frontier:
                for peer in self.speakers[node].peers:
                    if peer in seen or not self.session_up(node, peer):
                        continue
                    seen.add(peer)
                    self.speakers[peer].rib.add(route)
                    nxt.append(peer)
            frontier = nxt
        self._reimport()

    def _reimport(self) -> None:
        """Rebuild leaf tables from RIBs with RT import filtering."""
        for leaf in self.fabric.leaves:
            mac: Dict[Tuple[int, str], str] = {}
            ip: Dict[Tuple[int, str], str] = {}
            flood: Dict[int, Set[str]] = {v: set() for v in self.local_vnis[leaf]}
            my_vteps = self.fabric.vtep_ip(leaf)
            for route in self.speakers[leaf].rib:
                vni = route.vni  # type: ignore[attr-defined]
                if vni not in self.local_vnis[leaf]:
                    continue  # RT import policy: only locally configured VNIs
                if isinstance(route, RouteType3) and route.vtep_ip != my_vteps:
                    flood[vni].add(route.vtep_ip)
                elif isinstance(route, RouteType2):
                    mac[(vni, route.mac)] = route.vtep_ip
                    ip[(vni, route.ip)] = route.vtep_ip
            self.mac_table[leaf] = mac
            self.ip_table[leaf] = ip
            self.flood_list[leaf] = flood

    # -- withdrawal ----------------------------------------------------------

    def withdraw_leaf(self, leaf: str) -> None:
        """Withdraw every route originated by ``leaf`` (e.g. leaf isolated)."""
        for sp in self.speakers.values():
            sp.rib = {r for r in sp.rib if getattr(r, "origin_leaf", None) != leaf}
        self._reimport()

    def resync(self) -> None:
        """Re-flood every logged route (after topology repair)."""
        routes, self._route_log = self._route_log, []
        for sp in self.speakers.values():
            sp.rib.clear()
        for r in routes:
            self._propagate(r)

    # -- queries -------------------------------------------------------------

    def reachable(self, src_host: str, dst_host: str) -> bool:
        """Overlay reachability: same VNI + Type-2 route present at ingress."""
        src = self.fabric.hosts[src_host]
        dst = self.fabric.hosts[dst_host]
        if src.vni is None or dst.vni is None or src.vni != dst.vni:
            return False
        entry = self.ip_table.get(src.leaf, {}).get((src.vni, dst.ip))
        if src.leaf == dst.leaf:
            return True  # local bridging
        return entry == self.fabric.vtep_ip(dst.leaf)

    def route_count(self, node: str) -> Dict[str, int]:
        rib = self.speakers[node].rib
        return {
            "type2": sum(isinstance(r, RouteType2) for r in rib),
            "type3": sum(isinstance(r, RouteType3) for r in rib),
        }
