"""Analytical ECMP collision model (paper §3.3.2, Eqs. 3–11).

For ``N`` concurrent flows over ``K`` equal-cost paths with path-selection
distribution ``p``:

    E[C] = C(N,2) * sum_l p_l**2                      (Eq. 5)

The queue-pair-aware allocator helps exactly when it lowers the collision
index ``sum_l p_l**2`` (Eq. 11), i.e. when it makes the induced path
distribution closer to uniform.  This module provides the closed forms and
a Monte-Carlo estimator that drives real allocators through the real fabric
hash so the two can be cross-checked (tests assert they agree).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Sequence

import numpy as np

from .fabric import FiveTuple, ecmp_hash
from .ports import NUM_PORT_OFFSETS, ROCE_V2_BASE_PORT, allocate_ports, make_queue_pairs


@lru_cache(maxsize=32)
def _port_path_table(
    src_ip: str, dst_ip: str, dst_port: int, switch_seed: int, num_paths: int
) -> np.ndarray:
    """ECMP path for every RoCEv2 source port, precomputed.

    A connection's QPs share the 5-tuple except for the source port
    (§3.3), so one pass over the 16384-port dynamic range turns the
    per-trial hash loop into a NumPy table lookup — same ``ecmp_hash``,
    just evaluated once per port instead of once per (trial, QP)."""
    return np.array(
        [
            ecmp_hash(
                FiveTuple(src_ip, dst_ip, ROCE_V2_BASE_PORT + off, dst_port),
                switch_seed,
                num_paths,
            )
            for off in range(NUM_PORT_OFFSETS)
        ],
        dtype=np.int64,
    )


def collision_index(p: Sequence[float]) -> float:
    """``sum_l p_l**2`` — minimized (=1/K) by the uniform distribution."""
    arr = np.asarray(p, dtype=np.float64)
    if not np.isclose(arr.sum(), 1.0):
        raise ValueError(f"path distribution must sum to 1, got {arr.sum()}")
    return float(np.sum(arr**2))


def expected_collisions(num_flows: int, p: Sequence[float]) -> float:
    """Eq. 5: E[C] = C(N,2) * sum p^2."""
    return math.comb(num_flows, 2) * collision_index(p)


def collision_reduction(p_base: Sequence[float], p_prop: Sequence[float]) -> float:
    """Eq. 10: Delta_C = 1 - sum(p_prop^2)/sum(p_base^2)."""
    return 1.0 - collision_index(p_prop) / collision_index(p_base)


@dataclass
class MonteCarloResult:
    mean_pairwise_collisions: float
    path_distribution: np.ndarray  # pooled over trials
    empirical_index: float  # sum p^2 of the pooled distribution
    analytic_expected: float  # Eq. 5 on the pooled distribution
    #: Eq. 5 evaluated on each trial's own induced distribution, then
    #: averaged — the paper's setting is a fixed workload whose QP set
    #: induces a persistent p, so the per-trial form is the right
    #: cross-check against the Monte-Carlo collision count.
    analytic_expected_per_trial: float = 0.0
    per_trial_index: float = 0.0


def monte_carlo_collisions(
    *,
    num_qps: int,
    num_paths: int,
    scheme: str,
    trials: int = 2000,
    k_bins: int = 4,
    qp_stride: int = 1,
    seed: int = 0,
    src_ip: str = "192.168.1.1",
    dst_ip: str = "192.168.2.1",
    dst_port: int = 4791,
) -> MonteCarloResult:
    """Drive an allocator through the ECMP hash and count path collisions.

    Each trial draws a random base QP number (as a fresh connection setup
    would), allocates ports for ``num_qps`` QPs spaced ``qp_stride`` apart,
    hashes the resulting 5-tuples onto ``num_paths`` paths, and counts
    pairwise collisions.  The empirical path distribution (pooled over
    trials) feeds the analytic Eq. 5 for cross-checking.
    """
    rng = np.random.default_rng(seed)
    switch_seed = 0x5EED
    table = _port_path_table(src_ip, dst_ip, dst_port, switch_seed, num_paths)
    path_counts = np.zeros(num_paths, dtype=np.int64)
    total_collisions = 0
    per_trial_expected = 0.0
    per_trial_index = 0.0
    for _ in range(trials):
        base = int(rng.integers(0, 2**31))
        qps = make_queue_pairs(num_qps, base_number=base, stride=qp_stride)
        ports = allocate_ports(qps, scheme=scheme, k=k_bins)
        paths = table[np.asarray(ports, dtype=np.int64) - ROCE_V2_BASE_PORT]
        counts = np.bincount(paths, minlength=num_paths)
        path_counts += counts
        total_collisions += int(np.sum(counts * (counts - 1) // 2))
        p_trial = counts / num_qps
        idx = float(np.sum(p_trial**2))
        per_trial_index += idx
        per_trial_expected += math.comb(num_qps, 2) * idx
    p = path_counts / path_counts.sum()
    return MonteCarloResult(
        mean_pairwise_collisions=total_collisions / trials,
        path_distribution=p,
        empirical_index=collision_index(p),
        analytic_expected=expected_collisions(num_qps, p),
        analytic_expected_per_trial=per_trial_expected / trials,
        per_trial_index=per_trial_index / trials,
    )


def compare_schemes(
    *,
    num_qps: int,
    num_paths: int = 4,
    trials: int = 2000,
    qp_stride: int = 1,
    seed: int = 0,
) -> Dict[str, object]:
    """Baseline vs QP-aware: Monte-Carlo collisions + analytic Delta_C."""
    base = monte_carlo_collisions(
        num_qps=num_qps, num_paths=num_paths, scheme="baseline",
        trials=trials, qp_stride=qp_stride, seed=seed,
    )
    prop = monte_carlo_collisions(
        num_qps=num_qps, num_paths=num_paths, scheme="qp_aware",
        trials=trials, qp_stride=qp_stride, seed=seed,
    )
    # Eq. 10 on the per-trial (workload-induced) collision indices — the
    # pooled distributions are both ~uniform by symmetry and would hide
    # the correlation the mechanism removes.
    delta_c_analytic = 1.0 - prop.per_trial_index / base.per_trial_index
    delta_c_empirical = (
        1.0 - prop.mean_pairwise_collisions / base.mean_pairwise_collisions
        if base.mean_pairwise_collisions > 0
        else 0.0
    )
    return {
        "baseline": base,
        "proposed": prop,
        "delta_c_analytic": delta_c_analytic,
        "delta_c_empirical": delta_c_empirical,
    }
