"""CollectiveSchedule: phased, overlappable synchronization schedules.

The paper's Fig. 14 costing treats one synchronization as a single static
flow set, but real geo-training schedules are *phased*: reduce-scatter
overlapping all-gather, PS push then pull, MoE dispatch/combine, compute
overlapping WAN transfer (arXiv 2605.19169 argues fiber-latency/overlap
modeling is exactly where multi-DC training wins or loses; arXiv
2407.12819 shows MoE all-to-all stresses the WAN in yet another phase
structure).  This module makes the schedule a first-class value:

* :class:`Phase` — one named step of a schedule: a flow set (synthesized
  by :mod:`repro.core.flows`), the names of phases it depends on, an
  optional start offset past its dependencies, and an optional compute
  duration (a flowless compute phase models overlap-with-backprop);
* :class:`CollectiveSchedule` — a validated DAG of phases plus the
  ``sync_every`` amortization factor (local-SGD-style schedules run once
  every N steps);
* a **strategy registry** (:func:`register_strategy` /
  :func:`get_strategy`) replacing the closed ``if/elif`` that used to
  live in ``GeoFabric.sync_cost``: every paper strategy is a registered
  builder, and new overlapped schedules (``rs_ag_overlap``,
  ``hier_alltoall``, ...) plug in without touching the costing engine;
* :func:`with_compute_overlap` — graft a compute phase onto any schedule
  so overlap is a DAG property, not a scalar ``overlap_fraction`` hack.

Builders receive a :class:`StrategyContext` (the topology facts a
schedule needs: worker rosters per pod, channel count, port scheme) so
this module stays independent of :class:`repro.core.geo.GeoFabric`; the
costing itself — fluid critical path or the event-driven time-varying
max-min simulator — lives in :mod:`repro.core.geo` and
:func:`repro.core.congestion.simulate_schedule`.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .flows import (
    Flow,
    all_gather_flows,
    all_to_all_flows,
    hierarchical_all_to_all_flows,
    hierarchical_flows,
    parameter_server_flows,
    reduce_scatter_flows,
    ring_allreduce_flows,
)

#: The paper's Fig. 14 strategy set (kept for back-compat; the registry
#: below is the extensible superset).
SYNC_STRATEGIES = ("allreduce", "ps", "hier", "hier_int8", "local_sgd")


@dataclass(frozen=True)
class Phase:
    """One step of a :class:`CollectiveSchedule`.

    A phase *starts* once every phase named in ``deps`` has completed,
    plus ``start_offset_s``; it *completes* when all its flows have
    finished (transfer + path propagation) and ``compute_seconds`` have
    elapsed since its start.  A flowless phase with ``compute_seconds``
    models computation; a phase with both models compute that must finish
    before dependents start even if its flows drain early.
    """

    name: str
    flows: Tuple[Flow, ...] = ()
    deps: Tuple[str, ...] = ()
    start_offset_s: float = 0.0
    compute_seconds: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "flows", tuple(self.flows))
        object.__setattr__(self, "deps", tuple(self.deps))
        if not self.name:
            raise ValueError("phase name must be non-empty")
        if self.start_offset_s < 0 or self.compute_seconds < 0:
            raise ValueError(
                f"phase {self.name!r}: offsets/durations must be >= 0"
            )


@dataclass(frozen=True)
class CollectiveSchedule:
    """A validated DAG of :class:`Phase`\\ s.

    ``phases`` are stored in a topological order (validation rejects
    duplicate names, unknown dependencies, and cycles), so consumers can
    fold over them front-to-back.  ``sync_every`` is the amortization
    factor the strategy implies (``local_sgd`` syncs once every N steps).
    """

    name: str
    phases: Tuple[Phase, ...]
    sync_every: int = 1

    def __post_init__(self):
        object.__setattr__(self, "phases", tuple(self.phases))
        if not self.phases:
            raise ValueError(f"schedule {self.name!r} has no phases")
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        names = [p.name for p in self.phases]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate phase names in {self.name!r}: {names}")
        object.__setattr__(self, "phases", self._topo_sorted())

    def _topo_sorted(self) -> Tuple[Phase, ...]:
        by_name = {p.name: p for p in self.phases}
        for p in self.phases:
            for d in p.deps:
                if d not in by_name:
                    raise ValueError(
                        f"phase {p.name!r} depends on unknown phase {d!r}"
                    )
        done: Dict[str, Phase] = {}
        visiting: set = set()

        def visit(p: Phase) -> None:
            if p.name in done:
                return
            if p.name in visiting:
                raise ValueError(
                    f"dependency cycle through phase {p.name!r} in {self.name!r}"
                )
            visiting.add(p.name)
            for d in p.deps:
                visit(by_name[d])
            visiting.discard(p.name)
            done[p.name] = p

        for p in self.phases:
            visit(p)
        return tuple(done.values())

    # -- conveniences --------------------------------------------------------

    @property
    def phase_names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.phases)

    def phase(self, name: str) -> Phase:
        for p in self.phases:
            if p.name == name:
                return p
        raise KeyError(f"no phase {name!r} in schedule {self.name!r}")

    def all_flows(self) -> List[Flow]:
        """Every flow of every phase, in topological phase order."""
        return [f for p in self.phases for f in p.flows]

    def flow_slices(self) -> List[Tuple[int, int]]:
        """Per-phase ``(lo, hi)`` index ranges into :meth:`all_flows`.

        The epoch bookkeeping contract with the event-driven simulator
        (:func:`repro.core.congestion.simulate_schedule`): phase ``i``'s
        flows occupy the contiguous global-flow-id block
        ``flow_slices()[i]``, in the schedule's topological phase order.
        The simulator's per-flow report arrays, its allocator's CSR row
        blocks, and :class:`~repro.core.congestion.PhaseTiming`'s
        ``flow_lo:flow_hi`` all index by this layout.
        """
        slices: List[Tuple[int, int]] = []
        lo = 0
        for p in self.phases:
            slices.append((lo, lo + len(p.flows)))
            lo += len(p.flows)
        return slices

    def concurrency_matrix(self) -> "np.ndarray":
        """(P, P) bool: may phases i and j ever be in flight together?

        Two phases can only coexist when neither is a DAG ancestor of the
        other — a dependency (direct or transitive) serializes them, so
        their flows never contend and must not count as ECMP hash-slot
        colliders against each other
        (:func:`repro.core.congestion.concurrent_ecmp_flow_weights`).
        The diagonal is True (a phase always overlaps itself).
        """
        import numpy as np  # local: schedule stays numpy-free otherwise

        n = len(self.phases)
        idx = {p.name: i for i, p in enumerate(self.phases)}
        anc = np.zeros((n, n), dtype=bool)  # anc[i, j]: i is an ancestor of j
        for j, p in enumerate(self.phases):  # topological order
            for d in p.deps:
                i = idx[d]
                anc[i, j] = True
                anc[:, j] |= anc[:, i]
        conc = ~(anc | anc.T)
        np.fill_diagonal(conc, True)
        return conc

    @property
    def is_single_phase(self) -> bool:
        """True when the schedule is one flow phase starting at t=0 — the
        shape whose contended cost is exactly the static
        :func:`repro.core.congestion.congestion_report`."""
        return (
            len(self.phases) == 1
            and not self.phases[0].deps
            and self.phases[0].start_offset_s == 0.0
            and self.phases[0].compute_seconds == 0.0
        )

    @classmethod
    def single(
        cls, name: str, flows: Sequence[Flow], *, sync_every: int = 1
    ) -> "CollectiveSchedule":
        """One flow set, all at t=0 — today's static costing as a schedule."""
        return cls(name, (Phase(name, tuple(flows)),), sync_every=sync_every)

    @classmethod
    def serial(
        cls,
        name: str,
        named_flow_sets: Sequence[Tuple[str, Sequence[Flow]]],
        *,
        sync_every: int = 1,
    ) -> "CollectiveSchedule":
        """Chain flow sets back-to-back (each phase depends on the previous)."""
        phases: List[Phase] = []
        for pname, flows in named_flow_sets:
            deps = (phases[-1].name,) if phases else ()
            phases.append(Phase(pname, tuple(flows), deps=deps))
        return cls(name, tuple(phases), sync_every=sync_every)


def with_compute_overlap(
    schedule: CollectiveSchedule,
    compute_seconds: float,
    overlap_fraction: float = 1.0,
    *,
    compute_name: str = "compute",
) -> CollectiveSchedule:
    """Overlap ``schedule`` with a compute phase, as DAG structure.

    Adds a flowless ``compute_seconds`` phase starting at t=0 and delays
    every root phase of the communication schedule by the non-overlappable
    head of compute, ``(1 - overlap_fraction) * compute_seconds`` (e.g. the
    backward pass must produce gradients before their sync can start).
    With ``overlap_fraction=0`` the result degenerates to compute followed
    by the untouched schedule; with 1.0 comm and compute run fully
    concurrently and the makespan is what the congestion engine says it is
    — communication can no longer be "overlapped away" below its bandwidth
    floor, unlike the old scalar ``(1 - overlap) * comm`` estimate.
    """
    if not 0.0 <= overlap_fraction <= 1.0:
        raise ValueError(f"overlap_fraction must be in [0, 1], got {overlap_fraction}")
    if compute_seconds < 0:
        raise ValueError("compute_seconds must be >= 0")
    if any(p.name == compute_name for p in schedule.phases):
        raise ValueError(f"schedule already has a phase named {compute_name!r}")
    head = (1.0 - overlap_fraction) * compute_seconds
    phases: List[Phase] = [Phase(compute_name, compute_seconds=compute_seconds)]
    for p in schedule.phases:
        if not p.deps:
            p = replace(p, start_offset_s=p.start_offset_s + head)
        phases.append(p)
    return CollectiveSchedule(
        f"{schedule.name}+compute", tuple(phases), sync_every=schedule.sync_every
    )


# -- strategy registry --------------------------------------------------------


@dataclass(frozen=True)
class StrategyContext:
    """Topology facts a strategy builder needs, decoupled from GeoFabric.

    ``pod_workers`` lists every pod's workers (first member = pod leader,
    the DCI endpoint); ``num_channels``/``port_scheme`` parameterize the
    QP flow synthesis exactly as ``GeoFabric.sync_cost`` always has.
    """

    pod_workers: Tuple[Tuple[str, ...], ...]
    num_channels: int = 4
    port_scheme: str = "qp_aware"

    @property
    def workers(self) -> Tuple[str, ...]:
        return tuple(w for pod in self.pod_workers for w in pod)

    @property
    def pod_leaders(self) -> Tuple[str, ...]:
        return tuple(pod[0] for pod in self.pod_workers if pod)

    @property
    def n_local(self) -> int:
        """Workers in the first pod (the hierarchical-shard divisor)."""
        return max(len(self.pod_workers[0]) if self.pod_workers else 0, 1)

    @property
    def flow_kw(self) -> Dict[str, object]:
        return {"num_channels": self.num_channels, "scheme": self.port_scheme}


#: builder(ctx, grad_bytes, **kw) -> CollectiveSchedule
StrategyBuilder = Callable[..., CollectiveSchedule]

_REGISTRY: Dict[str, StrategyBuilder] = {}


def register_strategy(
    name: str, builder: Optional[StrategyBuilder] = None, *, overwrite: bool = False
):
    """Register a schedule builder under ``name`` (usable as a decorator).

    Builders are called as ``builder(ctx, grad_bytes, **kw)`` with a
    :class:`StrategyContext` and should accept (and may ignore) the keyword
    knobs ``sync_every`` and ``int8_ratio`` that ``GeoFabric.sync_cost``
    forwards.  Re-registering an existing name raises unless
    ``overwrite=True``, so typos don't silently shadow paper strategies.
    """

    def _register(b: StrategyBuilder) -> StrategyBuilder:
        if not overwrite and name in _REGISTRY:
            raise ValueError(f"strategy {name!r} already registered")
        _REGISTRY[name] = b
        return b

    return _register if builder is None else _register(builder)


def get_strategy(name: str) -> StrategyBuilder:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {strategy_names()}"
        ) from None


def strategy_names() -> Tuple[str, ...]:
    """All registered strategy names, paper strategies first."""
    extras = tuple(sorted(n for n in _REGISTRY if n not in SYNC_STRATEGIES))
    return tuple(n for n in SYNC_STRATEGIES if n in _REGISTRY) + extras


def build_schedule(
    strategy: str, ctx: StrategyContext, grad_bytes: int, **kw
) -> CollectiveSchedule:
    """Look up ``strategy`` in the registry and build its schedule."""
    return get_strategy(strategy)(ctx, grad_bytes, **kw)


# -- builders: the paper's Fig. 14 strategies (single-phase, back-compat) ----


@register_strategy("allreduce")
def _allreduce(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Flat ring over all workers in all DCs (paper M2)."""
    return CollectiveSchedule.single(
        "allreduce", ring_allreduce_flows(list(ctx.workers), grad_bytes, **ctx.flow_kw)
    )


@register_strategy("ps")
def _ps(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Central server in DC1, concurrent push+pull (paper M1)."""
    workers = list(ctx.workers)
    return CollectiveSchedule.single(
        "ps",
        parameter_server_flows(workers[0], workers[1:], grad_bytes, **ctx.flow_kw),
    )


def _hier_schedule(
    name: str, ctx: StrategyContext, grad_bytes: int, *, scale: float = 1.0,
    sync_every: int = 1,
) -> CollectiveSchedule:
    shard = int((grad_bytes // ctx.n_local) * scale)
    return CollectiveSchedule.single(
        name,
        hierarchical_flows(list(ctx.pod_leaders), shard, **ctx.flow_kw),
        sync_every=sync_every,
    )


@register_strategy("hier")
def _hier(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Intra-pod reduce-scatter (LAN, free at WAN granularity) + leader ring."""
    return _hier_schedule("hier", ctx, grad_bytes)


@register_strategy("hier_int8")
def _hier_int8(
    ctx: StrategyContext, grad_bytes: int, *, int8_ratio: float = 0.25, **_
) -> CollectiveSchedule:
    """``hier`` with the WAN payload int8-compressed (+ per-block scales)."""
    return _hier_schedule("hier_int8", ctx, grad_bytes, scale=int8_ratio)


@register_strategy("local_sgd")
def _local_sgd(
    ctx: StrategyContext, grad_bytes: int, *, sync_every: int = 8, **_
) -> CollectiveSchedule:
    """``hier`` executed once every ``sync_every`` steps (DiLoCo-style)."""
    return _hier_schedule("local_sgd", ctx, grad_bytes, sync_every=sync_every)


# -- builders: phased / overlapped schedules (beyond Fig. 14) ----------------


@register_strategy("ps_phased")
def _ps_phased(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """PS as two dependent phases: all pushes complete before any pull.

    The barrier semantics of a synchronous PS round — the server cannot
    serve updated weights until every push has landed — versus the ``ps``
    strategy's optimistic fully-concurrent flow set.
    """
    workers = list(ctx.workers)
    kw = dict(ctx.flow_kw)
    return CollectiveSchedule.serial(
        "ps_phased",
        (
            ("push", parameter_server_flows(
                workers[0], workers[1:], grad_bytes, direction="push", **kw)),
            ("pull", parameter_server_flows(
                workers[0], workers[1:], grad_bytes, direction="pull", **kw)),
        ),
    )


def _rs_ag_phases(ctx: StrategyContext, grad_bytes: int) -> Tuple[Tuple[Flow, ...], Tuple[Flow, ...]]:
    workers = list(ctx.workers)
    rs = tuple(reduce_scatter_flows(workers, grad_bytes, **ctx.flow_kw))
    ag = tuple(all_gather_flows(workers, grad_bytes, **ctx.flow_kw))
    return rs, ag


@register_strategy("rs_then_ag")
def _rs_then_ag(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Unpipelined ring: the all-gather waits for the full reduce-scatter."""
    rs, ag = _rs_ag_phases(ctx, grad_bytes)
    return CollectiveSchedule.serial("rs_then_ag", (("rs", rs), ("ag", ag)))


@register_strategy("rs_ag_overlap")
def _rs_ag_overlap(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Pipelined ring: reduce-scatter and all-gather traffic in flight together.

    The fluid-granularity model of NCCL's chunked ring pipeline: per-chunk
    the all-gather step chases the reduce-scatter step around the ring, so
    at any instant both phases' traffic (on disjoint QP connection groups —
    see :func:`repro.core.flows.all_gather_flows`) contends for the same
    links.  On shared bottlenecks this lands strictly between
    ``max(RS, AG)`` (they do contend) and serial RS -> AG (imbalanced
    per-link byte loads no longer stack, and only one terminal propagation
    delay is paid) — the ``bench_schedule.py`` gate.
    """
    rs, ag = _rs_ag_phases(ctx, grad_bytes)
    return CollectiveSchedule(
        "rs_ag_overlap", (Phase("rs", rs), Phase("ag", ag))
    )


@register_strategy("alltoall")
def _alltoall(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Flat MoE all-to-all among every worker (arXiv 2407.12819's stressor)."""
    return CollectiveSchedule.single(
        "alltoall", all_to_all_flows(list(ctx.workers), grad_bytes, **ctx.flow_kw)
    )


@register_strategy("hier_alltoall")
def _hier_alltoall(ctx: StrategyContext, grad_bytes: int, **_) -> CollectiveSchedule:
    """Two-phase MoE all-to-all: intra-DC dispatch, leader-only WAN combine."""
    pods = [list(p) for p in ctx.pod_workers]
    kw = dict(ctx.flow_kw)
    return CollectiveSchedule.serial(
        "hier_alltoall",
        (
            ("dispatch", hierarchical_all_to_all_flows(
                pods, grad_bytes, phase="dispatch", **kw)),
            ("combine", hierarchical_all_to_all_flows(
                pods, grad_bytes, phase="combine", **kw)),
        ),
    )
