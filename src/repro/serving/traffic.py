"""Open-loop geo-serving request generation.

Each DC hosts a pinned user population (the data-sovereignty assumption:
users are regional, their traffic originates where they live).  Arrivals
per emulated step are Poisson with a rate modulated by a sinusoidal
diurnal curve whose peak *rotates* across DCs — DC 1 peaks first, the
last DC peaks ``(num_dcs-1)/num_dcs`` of a period later — so at any
instant some region is near peak while another idles, the load shape
that makes geo-failover worth having.  Per-request context lengths are
heavy-tailed (lognormal or Pareto), matching measured LLM-serving token
distributions: most requests are short, the p99 is many multiples of the
mean, and it is exactly those tail requests whose KV handoff bytes hurt
on a degraded WAN.

The whole trace is a pure function of ``(spec, num_dcs, num_steps)`` via
one ``numpy`` generator seeded from ``spec.seed`` — sweep workers and
JSON round-trips reproduce it byte-for-byte.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

from repro.scenario.spec import ServingSpec

__all__ = [
    "Request",
    "diurnal_factor",
    "generate_trace",
    "resolve_populations",
]


@dataclass(frozen=True)
class Request:
    """One inference request: ``user`` in ``home_dc`` wants ``tokens`` of
    context served at ``step``.  ``rid`` is globally unique within a
    trace and seeds the request's QPN."""

    rid: int
    step: int
    home_dc: int
    user: int
    tokens: int


def resolve_populations(spec: ServingSpec, num_dcs: int) -> Tuple[int, ...]:
    """Per-DC user counts: explicit ``users_per_dc`` or ``users`` split
    near-evenly (first DCs absorb the remainder, like ``split_bytes``)."""
    if spec.users_per_dc:
        if len(spec.users_per_dc) != num_dcs:
            raise ValueError(
                f"users_per_dc has {len(spec.users_per_dc)} entries for "
                f"{num_dcs} DCs"
            )
        return spec.users_per_dc
    from repro.core.flows import split_bytes

    return tuple(split_bytes(spec.users, num_dcs))


def diurnal_factor(spec: ServingSpec, step: int, dc: int, num_dcs: int) -> float:
    """Arrival-rate multiplier in ``[1-A, 1+A]``; DC phases are spread a
    full period apart across the fleet (time zones)."""
    phase = (dc - 1) / max(num_dcs, 1)
    return 1.0 + spec.diurnal_amplitude * math.sin(
        2.0 * math.pi * (step / spec.diurnal_period_steps + phase)
    )


def generate_trace(
    spec: ServingSpec, num_dcs: int, num_steps: int
) -> Tuple[Tuple[Request, ...], ...]:
    """The full deterministic trace: ``trace[step]`` is that step's
    requests, ordered by (DC, draw order)."""
    import numpy as np

    populations = resolve_populations(spec, num_dcs)
    rng = np.random.default_rng(spec.seed)
    # lognormal mu chosen so E[tokens] == mean_tokens for the given sigma
    mu = math.log(spec.mean_tokens) - spec.tail_sigma**2 / 2.0
    # Pareto scale xm with E = xm * alpha / (alpha - 1)
    xm = spec.mean_tokens * (spec.tail_alpha - 1.0) / spec.tail_alpha

    trace: List[Tuple[Request, ...]] = []
    rid = 0
    for step in range(num_steps):
        step_requests: List[Request] = []
        for dc in range(1, num_dcs + 1):
            pop = populations[dc - 1]
            rate = pop * spec.requests_per_user_step
            if rate <= 0.0:
                continue
            n = int(rng.poisson(rate * diurnal_factor(spec, step, dc, num_dcs)))
            for _ in range(n):
                user = int(rng.integers(0, pop))
                if spec.tail == "lognormal":
                    raw = float(rng.lognormal(mu, spec.tail_sigma))
                else:
                    raw = xm * (1.0 + float(rng.pareto(spec.tail_alpha)))
                tokens = max(1, int(round(raw)))
                step_requests.append(
                    Request(rid=rid, step=step, home_dc=dc, user=user, tokens=tokens)
                )
                rid += 1
        trace.append(tuple(step_requests))
    return tuple(trace)
