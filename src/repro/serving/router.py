"""Session/KV-cache affinity routing with cross-DC failover.

Sessions are sticky: a user's KV cache lives in one DC, and re-homing it
costs ``session_tokens * kv_bytes_per_token`` over the WAN — the router
only pays that when it must.  A deterministic per-user hash steadily
serves ``remote_fraction`` of each DC's users cross-DC (capacity
spillover; the traffic class a WAN brownout actually hurts), and
failover re-homes a session when its serving DC dies or its home<->serving
pair goes bad — as reported by the scenario's SLA probes when a
:class:`~repro.scenario.spec.DegradationPolicy` is active, else straight
from ``Netem``'s degraded-pair set.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Mapping, Optional, Tuple

from repro.scenario.spec import ServingSpec

__all__ = ["FabricHealth", "Route", "SessionRouter"]

_INF = float("inf")


@dataclass(frozen=True)
class FabricHealth:
    """One step's routing view of the fabric: which DCs are alive, which
    DC pairs are degraded/tripped, and leader RTTs (``inf`` = partitioned)."""

    alive: FrozenSet[int]
    bad_pairs: FrozenSet[Tuple[int, int]]
    rtt_ms: Mapping[Tuple[int, int], float]

    def dc_ok(self, dc: int) -> bool:
        return dc in self.alive

    def pair_ok(self, a: int, b: int) -> bool:
        if a == b:
            return True
        pair = (a, b) if a < b else (b, a)
        return pair not in self.bad_pairs and self.rtt(a, b) != _INF

    def rtt(self, a: int, b: int) -> float:
        if a == b:
            return 0.0
        pair = (a, b) if a < b else (b, a)
        return float(self.rtt_ms.get(pair, _INF))

    def reachable(self, a: int, b: int) -> bool:
        return a == b or self.rtt(a, b) != _INF


@dataclass(frozen=True)
class Route:
    """Where one request is served.  ``migrated`` marks a session re-home
    this step; ``kv_source`` is the DC the session's KV is pulled from
    (None: fresh placement, or the cache died with its DC / behind a
    partition and must be recomputed — bytes saved, latency SLO lost)."""

    serving_dc: int
    migrated: bool = False
    kv_source: Optional[int] = None


@dataclass
class SessionRouter:
    spec: ServingSpec
    num_dcs: int
    #: (home_dc, user) -> DC currently holding the session's KV
    _serving: Dict[Tuple[int, int], int] = field(default_factory=dict)

    def _wants_remote(self, home: int, user: int) -> bool:
        """Deterministic per-user coin for the steady cross-DC class."""
        if self.spec.remote_fraction <= 0.0 or self.num_dcs < 2:
            return False
        h = ((user + 1) * 2654435761 + home * 97) & 0xFFFFFFFF
        return h / 2**32 < self.spec.remote_fraction

    def _preferred_remote(self, home: int, health: FabricHealth) -> Optional[int]:
        """Lowest-RTT healthy remote DC with a healthy pair to home."""
        best: Optional[int] = None
        best_rtt = _INF
        for dc in range(1, self.num_dcs + 1):
            if dc == home or not health.dc_ok(dc) or not health.pair_ok(home, dc):
                continue
            rtt = health.rtt(home, dc)
            if rtt < best_rtt:
                best, best_rtt = dc, rtt
        return best

    def _target(self, home: int, user: int, health: FabricHealth) -> Optional[int]:
        """Where this session *should* live right now."""
        if health.dc_ok(home):
            if self._wants_remote(home, user):
                remote = self._preferred_remote(home, health)
                if remote is not None:
                    return remote
            return home
        # home DC is down: nearest alive DC takes the user
        best: Optional[int] = None
        best_rtt = _INF
        for dc in sorted(health.alive):
            rtt = health.rtt(home, dc)
            if rtt < best_rtt:
                best, best_rtt = dc, rtt
        return best

    def rehome_all(self, health: FabricHealth):
        """The step-boundary failover sweep: re-home *every* tracked
        session whose placement is unhealthy (a live session suffers a
        brownout whether or not it issues a request this step).

        Returns ``[(home, user, old_dc, Route)]`` in sorted session order
        (deterministic).  Sessions with nowhere to go are dropped from
        the table (their users re-place on next contact)."""
        if not self.spec.failover:
            return []
        moves = []
        for key in sorted(self._serving):
            home, user = key
            cur = self._serving[key]
            unhealthy = not health.dc_ok(cur) or (
                health.dc_ok(home) and cur != home and not health.pair_ok(home, cur)
            )
            if not unhealthy:
                continue
            new = self._target(home, user, health)
            if new is None:
                del self._serving[key]
                continue
            if new == cur:
                continue
            kv_source = (
                cur if health.dc_ok(cur) and health.reachable(cur, new) else None
            )
            self._serving[key] = new
            moves.append(
                (home, user, cur,
                 Route(serving_dc=new, migrated=True, kv_source=kv_source))
            )
        return moves

    def route(self, home: int, user: int, health: FabricHealth) -> Optional[Route]:
        """Resolve one request; mutates session state.  None = dropped
        (no alive DC can take it)."""
        key = (home, user)
        cur = self._serving.get(key)
        if cur is None:
            target = self._target(home, user, health)
            if target is None:
                return None
            self._serving[key] = target
            return Route(serving_dc=target)

        unhealthy = not health.dc_ok(cur) or (
            health.dc_ok(home) and cur != home and not health.pair_ok(home, cur)
        )
        if not unhealthy or not self.spec.failover:
            return Route(serving_dc=cur)

        new = self._target(home, user, health)
        if new is None:
            del self._serving[key]
            return None
        if new == cur:
            return Route(serving_dc=cur)
        kv_source = cur if health.dc_ok(cur) and health.reachable(cur, new) else None
        self._serving[key] = new
        return Route(serving_dc=new, migrated=True, kv_source=kv_source)
