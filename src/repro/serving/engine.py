"""The serving engine: turns a step's request trace into concrete fabric
flows and reads per-request latencies back out of the congestion
simulator's per-flow timeline.

Co-scheduling is structural, not additive: the engine emits its flows as
extra dependency-free :class:`~repro.core.schedule.Phase`\\ s appended to
the step's training schedule, so :func:`~repro.core.congestion.
simulate_schedule` runs training collectives and serving transfers as
concurrent flow classes through the *same* weighted max-min allocator.
A training AllReduce burst steals spine-WAN capacity from in-flight
request handoffs (inflating serving p99), and heavy serving load slows
the AllReduce — both directions fall out of the allocator, nothing is
hand-priced.

What a request's flow models: the prefill -> decode-host KV handoff
(``tokens * kv_bytes_per_token`` bytes) from the home DC's ingress
leader to the user's pinned decode host — intra-DC for home-served
sessions, spine-WAN for the ``remote_fraction`` class and for failed-over
sessions.  Migration flows (``session_tokens * kv_bytes_per_token``
leader-to-leader) ride a second phase.  Latency per request is the
simulator's ``completion - start`` for its flow; requests with no wire
cost (single-host DCs) count as 0 ms.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.flows import Flow, open_loop_flows
from repro.core.schedule import Phase
from repro.scenario.spec import ServingSpec
from repro.serving.router import FabricHealth, Route, SessionRouter
from repro.serving.traffic import Request, generate_trace

__all__ = [
    "MIGRATION_PHASE",
    "SERVING_BASE_QPN",
    "SERVING_PHASE",
    "ServingEngine",
    "ServingPlan",
    "ServingStepStats",
]

#: Phase names the engine appends to each step's schedule.
SERVING_PHASE = "serving_rq"
MIGRATION_PHASE = "serving_kv"
#: QPN plane for serving flows, disjoint from the collectives' 0x11.
SERVING_BASE_QPN = 0x5E0000
#: flow_id offset separating migration QPNs from request QPNs.
_MIGRATION_FLOW_BASE = 1_000_000


@dataclass(frozen=True)
class ServingStepStats:
    """One step's serving rollup, the serving-side sibling of the
    runner's per-step :class:`~repro.core.geo.SyncCost` record."""

    step: int
    requests: int
    dropped: int
    tokens: int
    remote_requests: int
    migrated_sessions: int
    migration_bytes: int
    slo_misses: int
    p50_ms: float
    p99_ms: float
    latencies_ms: Tuple[float, ...] = ()

    @property
    def slo_miss_frac(self) -> float:
        if self.requests == 0:
            return 0.0
        return self.slo_misses / self.requests

    def to_dict(self) -> Dict[str, object]:
        return {
            "step": self.step,
            "requests": self.requests,
            "dropped": self.dropped,
            "tokens": self.tokens,
            "remote_requests": self.remote_requests,
            "migrated_sessions": self.migrated_sessions,
            "migration_bytes": self.migration_bytes,
            "slo_misses": self.slo_misses,
            "slo_miss_frac": self.slo_miss_frac,
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
        }


@dataclass(frozen=True)
class ServingPlan:
    """One step's serving flows, pre-simulation.  ``placements`` holds
    ``(request, route, has_flow)`` in emission order — exactly the order
    the request flows occupy the :data:`SERVING_PHASE` slice of the
    report's per-flow arrays."""

    step: int
    phases: Tuple[Phase, ...]
    placements: Tuple[Tuple[Request, Route, bool], ...]
    dropped: int
    remote_requests: int
    migrated_sessions: int
    migration_bytes: int


@dataclass
class ServingEngine:
    """Per-scenario serving state: the precomputed trace, the sticky
    session router, and the accumulated per-step stats."""

    spec: ServingSpec
    num_dcs: int
    num_steps: int
    port_scheme: str = "qp_aware"
    trace: Tuple[Tuple[Request, ...], ...] = field(init=False)
    router: SessionRouter = field(init=False)
    kv_bytes_per_token: int = field(init=False)
    session_kv_bytes: int = field(init=False)
    stats: List[ServingStepStats] = field(init=False, default_factory=list)
    _mig_seq: int = field(init=False, default=0)

    def __post_init__(self):
        self.trace = generate_trace(self.spec, self.num_dcs, self.num_steps)
        self.router = SessionRouter(self.spec, self.num_dcs)
        self.kv_bytes_per_token = self.spec.resolve_kv_bytes_per_token()
        self.session_kv_bytes = self.kv_bytes_per_token * self.spec.session_tokens

    def plan_step(self, step: int, geo, health: FabricHealth) -> ServingPlan:
        """Route this step's requests and synthesize their flows.

        A failover sweep runs first: every tracked session sitting on a
        now-unhealthy placement is re-homed (and pays its migration
        bytes) before this step's requests route."""
        leaders = geo.pod_leaders()
        rq_flows: List[Flow] = []
        mig_flows: List[Flow] = []
        placements: List[Tuple[Request, Route, bool]] = []
        dropped = remote = migrated = 0
        migration_bytes = 0

        for _home, _user, _old, route in self.router.rehome_all(health):
            migrated += 1
            if route.kv_source is not None and self.session_kv_bytes > 0:
                migration_bytes += self.session_kv_bytes
                self._mig_seq += 1
                mig_flows += open_loop_flows(
                    leaders[route.kv_source - 1],
                    leaders[route.serving_dc - 1],
                    _MIGRATION_FLOW_BASE + self._mig_seq,
                    self.session_kv_bytes,
                    scheme=self.port_scheme,
                    base_qpn=SERVING_BASE_QPN,
                )

        for req in self.trace[step]:
            route = self.router.route(req.home_dc, req.user, health)
            if route is None:
                dropped += 1
                continue
            serving_dc = route.serving_dc
            # ingress: traffic enters where the user is — unless their
            # whole DC is down, in which case they reconnect at the
            # failover DC directly.
            ingress_dc = req.home_dc if health.dc_ok(req.home_dc) else serving_dc
            if serving_dc != req.home_dc:
                remote += 1
            if route.migrated:
                migrated += 1
                if route.kv_source is not None and self.session_kv_bytes > 0:
                    migration_bytes += self.session_kv_bytes
                    self._mig_seq += 1
                    mig_flows += open_loop_flows(
                        leaders[route.kv_source - 1],
                        leaders[serving_dc - 1],
                        _MIGRATION_FLOW_BASE + self._mig_seq,
                        self.session_kv_bytes,
                        scheme=self.port_scheme,
                        base_qpn=SERVING_BASE_QPN,
                    )

            ingress = leaders[ingress_dc - 1]
            hosts = geo.workers(serving_dc)
            nbytes = req.tokens * self.kv_bytes_per_token
            if ingress_dc == serving_dc:
                # home-served: ingress leader -> the user's decode host
                if len(hosts) > 1 and nbytes > 0:
                    dst = hosts[1 + req.user % (len(hosts) - 1)]
                    rq_flows += open_loop_flows(
                        ingress, dst, req.rid, nbytes,
                        scheme=self.port_scheme, base_qpn=SERVING_BASE_QPN,
                    )
                    placements.append((req, route, True))
                else:
                    placements.append((req, route, False))
            else:
                # cross-DC: the KV handoff rides the spine WAN
                dst = hosts[req.user % len(hosts)]
                if nbytes > 0:
                    rq_flows += open_loop_flows(
                        ingress, dst, req.rid, nbytes,
                        scheme=self.port_scheme, base_qpn=SERVING_BASE_QPN,
                    )
                    placements.append((req, route, True))
                else:
                    placements.append((req, route, False))

        phases: List[Phase] = []
        if rq_flows:
            phases.append(Phase(SERVING_PHASE, flows=tuple(rq_flows)))
        if mig_flows:
            phases.append(Phase(MIGRATION_PHASE, flows=tuple(mig_flows)))
        return ServingPlan(
            step=step,
            phases=tuple(phases),
            placements=tuple(placements),
            dropped=dropped,
            remote_requests=remote,
            migrated_sessions=migrated,
            migration_bytes=migration_bytes,
        )

    def finish_step(self, plan: ServingPlan, report=None) -> ServingStepStats:
        """Read per-request latencies out of the simulated report and
        roll up this step's stats."""
        import numpy as np

        latencies: List[float] = []
        if report is not None and any(p.name == SERVING_PHASE for p in plan.phases):
            timing = report.phase(SERVING_PHASE)
            # one flow per placed request with has_flow, in emission order
            idx = timing.flow_lo
            for _req, _route, has_flow in plan.placements:
                if has_flow:
                    lat = (
                        float(report.completion_s[idx])
                        - float(report.flow_start_s[idx])
                    ) * 1e3
                    latencies.append(lat)
                    idx += 1
                else:
                    latencies.append(0.0)
        else:
            latencies = [0.0] * len(plan.placements)

        requests = len(plan.placements) + plan.dropped
        tokens = sum(req.tokens for req, _r, _h in plan.placements)
        arr = np.asarray(latencies, dtype=float)
        p50 = float(np.percentile(arr, 50)) if len(arr) else 0.0
        p99 = float(np.percentile(arr, 99)) if len(arr) else 0.0
        slo_misses = int((arr > self.spec.slo_ms).sum()) + plan.dropped
        stats = ServingStepStats(
            step=plan.step,
            requests=requests,
            dropped=plan.dropped,
            tokens=tokens,
            remote_requests=plan.remote_requests,
            migrated_sessions=plan.migrated_sessions,
            migration_bytes=plan.migration_bytes,
            slo_misses=slo_misses,
            p50_ms=p50,
            p99_ms=p99,
            latencies_ms=tuple(latencies),
        )
        self.stats.append(stats)
        return stats
