"""Materialize a trace :class:`~repro.serving.traffic.Request` as a real
model input batch.

The simulator prices a request by its KV bytes; this module is the
execution-side counterpart — the same frontend-aware batch construction
the serving CLI uses (:mod:`repro.launch.batches`), keyed off the
request's trace identity so a given request always materializes the same
prompt.  ``examples/serve_geo.py`` uses it to run a traced request
through a real prefill.
"""

from __future__ import annotations

from typing import Dict

from repro.serving.traffic import Request

__all__ = ["request_batch"]


def request_batch(cfg, request: Request, *, key=None) -> Dict[str, object]:
    """A batch-of-one prefill input for ``request``, deterministic in
    ``request.rid`` unless an explicit ``key`` is passed."""
    import jax

    from repro.launch.batches import synthetic_prompt_batch

    if key is None:
        key = jax.random.PRNGKey(request.rid)
    prompt_len = max(request.tokens, 1)
    if cfg.frontend == "patch":
        # the patch frontend needs room for its prefix tokens
        prompt_len += cfg.num_prefix_tokens
    return synthetic_prompt_batch(cfg, key, 1, prompt_len)
