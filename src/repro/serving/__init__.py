"""Geo-serving subsystem: millions-of-users inference traffic priced on
the same fabric as training (the north-star "serves heavy traffic from
millions of users" workload).

Pieces:

* :mod:`repro.serving.traffic` — seeded open-loop request generation:
  per-DC user populations, rotating diurnal curves, heavy-tailed token
  counts; deterministic traces.
* :mod:`repro.serving.router` — session/KV-cache affinity with
  SLA-probe-driven cross-DC failover; migrations carry a concrete WAN
  byte cost.
* :mod:`repro.serving.engine` — flows + phases for each step, appended
  to the training schedule so :func:`~repro.core.congestion.
  simulate_schedule` co-schedules both through one max-min allocator;
  per-request latency read back from the per-flow timeline.
* :mod:`repro.serving.requests` — trace request -> real model batch
  (shared frontend logic with ``repro.launch.serve``).

Declared via :class:`~repro.scenario.spec.ServingSpec` on a
:class:`~repro.scenario.spec.Scenario`; scenarios without one keep the
runner's historical costing path byte-for-byte.
"""

from repro.serving.engine import (
    MIGRATION_PHASE,
    SERVING_BASE_QPN,
    SERVING_PHASE,
    ServingEngine,
    ServingPlan,
    ServingStepStats,
)
from repro.serving.requests import request_batch
from repro.serving.router import FabricHealth, Route, SessionRouter
from repro.serving.traffic import (
    Request,
    diurnal_factor,
    generate_trace,
    resolve_populations,
)

__all__ = [
    "FabricHealth",
    "MIGRATION_PHASE",
    "Request",
    "Route",
    "SERVING_BASE_QPN",
    "SERVING_PHASE",
    "ServingEngine",
    "ServingPlan",
    "ServingStepStats",
    "SessionRouter",
    "diurnal_factor",
    "generate_trace",
    "request_batch",
    "resolve_populations",
]
