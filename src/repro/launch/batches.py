"""Frontend-aware synthetic batch construction, shared by the serving CLI
(:mod:`repro.launch.serve`) and the geo-serving request model
(:mod:`repro.serving.requests`).

Each model frontend takes a different prompt pytree — ``frame`` wants
embeddings, ``patch`` wants a token/patch split, plain LMs want tokens —
and both call sites need bit-identical RNG usage, so the branching lives
here exactly once.
"""

from __future__ import annotations

from typing import Dict

__all__ = ["decode_step_input", "synthetic_prompt_batch"]


def synthetic_prompt_batch(cfg, key, batch: int, prompt_len: int) -> Dict[str, object]:
    """A synthetic prefill batch matching ``cfg.frontend``'s input pytree."""
    import jax

    if cfg.frontend == "frame":
        return {
            "frame_embeds": jax.random.normal(
                key, (batch, prompt_len, cfg.frontend_dim)
            )
        }
    if cfg.frontend == "patch":
        p = cfg.num_prefix_tokens
        if prompt_len <= p:
            raise ValueError(
                f"patch frontend needs prompt_len > {p} prefix tokens, "
                f"got {prompt_len}"
            )
        return {
            "tokens": jax.random.randint(
                key, (batch, prompt_len - p), 0, cfg.vocab_size
            ),
            "patch_embeds": jax.random.normal(key, (batch, p, cfg.frontend_dim)),
        }
    return {
        "tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    }


def decode_step_input(cfg, key, tokens, batch: int, i: int):
    """The per-step decode input: frame frontends feed fresh embeddings
    (folded-in RNG per step), token frontends feed back the argmax."""
    import jax

    if cfg.frontend == "frame":
        return jax.random.normal(
            jax.random.fold_in(key, i), (batch, 1, cfg.frontend_dim)
        )
    return tokens
