"""Assigned input-shape sets and allocation-free input specs.

Four LM shapes (seq_len x global_batch):

    train_4k     4,096 x 256   training        -> lowers train_step
    prefill_32k  32,768 x 32   inference       -> lowers prefill_step
    decode_32k   32,768 x 128  decode          -> lowers serve_step
    long_500k    524,288 x 1   long-ctx decode -> lowers serve_step
                               (sub-quadratic archs only)

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input — weak-type-correct, shardable, zero allocation — which
is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Eligibility per the assignment.

    ``long_500k`` requires sub-quadratic attention: pure full-attention
    archs are skipped (noted in DESIGN.md §Arch-applicability).
    """
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.subquadratic:
        return False, f"{cfg.name}: full attention is quadratic at 500k ctx"
    return True, ""


def _token_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill input pytree as ShapeDtypeStructs."""
    i32 = jnp.int32
    f32 = jnp.dtype(cfg.dtype)
    if cfg.frontend == "frame":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
    if cfg.frontend == "patch":
        p = cfg.num_prefix_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq - p), i32),
            "patch_embeds": jax.ShapeDtypeStruct((batch, p, cfg.frontend_dim), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, object]:
    """Specs for the step function selected by the shape's ``kind``.

    train/prefill -> {"batch": ...}
    decode        -> {"tokens_t", "position"} (the cache is built separately
                     via ``decode_cache_specs`` so it can be donated).
    """
    spec = SHAPES[shape]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"shape {shape} unsupported: {why}")
    if spec.kind in ("train", "prefill"):
        return {"batch": _token_specs(cfg, spec.global_batch, spec.seq_len)}
    # decode: one new token against a seq_len-deep cache
    if cfg.frontend == "frame":
        tok = jax.ShapeDtypeStruct(
            (spec.global_batch, 1, cfg.frontend_dim), jnp.dtype(cfg.dtype)
        )
    else:
        tok = jax.ShapeDtypeStruct((spec.global_batch,), jnp.int32)
    return {
        "tokens_t": tok,
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_cache_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStructs of the decode cache for ``shape`` via eval_shape."""
    from repro.models.transformer import init_decode_cache

    spec = SHAPES[shape]
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, spec.global_batch, spec.seq_len)
    )


def params_specs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter pytree via eval_shape."""
    from repro.models.transformer import init_params

    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
