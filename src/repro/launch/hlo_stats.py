"""HLO post-processing: collective byte accounting from compiled modules.

``compiled.cost_analysis()`` reports per-device FLOPs and bytes but NOT
collective traffic, so we parse the optimized HLO text: every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction contributes its result-shape bytes.

Cross-pod classification: on the (2, 16, 16) production mesh, device ids
0..255 are pod 0 and 256..511 pod 1 (the pod axis varies slowest), so a
replica group containing ids from both halves is WAN traffic.  Both the
explicit ``{{0,256},...}`` and iota-v2 ``[g,n]<=[512]`` group encodings
are handled (iota conservatively: classified cross-pod when the group
size exceeds the per-pod device count or the iota covers the full mesh
with a permutation mixing the leading dim).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[128,1024]{1,0} all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[^\]]*\][^\s\)]*)(?:,\s*[a-z0-9]+\[[^\]]*\][^\s\)]*)*)\s*\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

#: XLA elides long group lists ("{{0,256},{1,257},...}"); dots allowed.
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,{}. ]*)\}\}")
#: collective-permute uses point-to-point pairs, not replica groups.  A
#: 2-pod psum is lowered by XLA as permute+add, so these carry the
#: cross-pod gradient traffic on the 2x16x16 mesh.
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{([0-9,{}. ]*)\}\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    #: per-op-kind total result bytes (one device's view)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    #: bytes on collectives whose replica groups span pods (WAN)
    cross_pod_bytes: int = 0
    #: bytes on collectives we could not classify
    unclassified_bytes: int = 0
    count: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _groups_cross_pod(line: str, pod_size: int) -> Optional[bool]:
    m = _PERMUTE_PAIRS_RE.search(line)
    if m:
        for pair in m.group(1).split("},{"):
            ids = [
                int(x)
                for x in pair.replace("{", "").replace("}", "").split(",")
                if x.strip().isdigit()
            ]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
        return False
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [
                int(x)
                for x in grp.replace("{", "").replace("}", "").split(",")
                if x.strip().isdigit()
            ]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
        return False
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        iota_dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in iota_dims:
            total *= d
        n_pods = max(total // pod_size, 1)
        if len(iota_dims) == 1 and not m.group(4):
            # contiguous iota: group g covers [g*group_size, (g+1)*size)
            if group_size > pod_size:
                return True
            return pod_size % group_size != 0
        # N-d (possibly transposed) iota: group members are the trailing
        # dims of the permuted device array whose product covers
        # group_size; the group crosses pods iff the pod dim (original
        # dim 0, by mesh construction) is among those varying dims.
        perm = (
            [int(x) for x in m.group(4).split(",")]
            if m.group(4)
            else list(range(len(iota_dims)))
        )
        permuted = [iota_dims[p] for p in perm]
        prod, varying = 1, []
        for pos in range(len(permuted) - 1, -1, -1):
            if prod >= group_size:
                break
            prod *= permuted[pos]
            varying.append(perm[pos])
        if iota_dims[0] == n_pods and n_pods > 1:
            return 0 in varying
        return None
    return None


_OPNAME_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def parse_collectives(hlo_text: str, *, pod_size: int = 0) -> CollectiveStats:
    """Scan optimized HLO for collective ops; bytes are one device's view.

    The result may be a TUPLE shape (XLA's all-reduce combiner merges many
    psums into one tuple all-reduce, with /*index=N*/ comments inline), so
    bytes are summed over every shape token LEFT of the op name — i.e. the
    result only, never the operands.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped.startswith("%") or "= " not in stripped:
            continue
        m = _OPNAME_RE.search(stripped)
        if not m:
            continue
        opname, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # counted at -start
        nbytes = shape_bytes(stripped[: m.start()])
        stats.bytes_by_kind[opname] = stats.bytes_by_kind.get(opname, 0) + nbytes
        stats.count += 1
        if pod_size:
            crosses = _groups_cross_pod(stripped, pod_size)
            if crosses is None:
                stats.unclassified_bytes += nbytes
            elif crosses:
                stats.cross_pod_bytes += nbytes
    return stats


def scan_trip_counts(hlo_text: str) -> List[int]:
    """Best-effort trip counts of while loops (scan bodies) in the module."""
    # XLA annotates known trip counts:  while(...), ... trip_count=12
    return [int(x) for x in re.findall(r"trip_count[=:]\s*(\d+)", hlo_text)]
