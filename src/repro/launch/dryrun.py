import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, on BOTH production meshes
(16x16 single-pod and 2x16x16 multi-pod):

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                      .lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves the cell fits per-device HBM
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

plus two UNROLLED cost probes (L = pattern, 2*pattern layers at full
width/shape) whose difference yields exact per-layer-group FLOPs/bytes/
collective-bytes — necessary because ``cost_analysis`` counts a
``lax.scan`` body once (measured; see DESIGN.md §6).

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark and EXPERIMENTS.md read from there.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed import (
    batch_shardings,
    cache_shardings,
    make_train_step,
    params_shardings,
)
from repro.distributed.act_sharding import activation_sharding
from repro.launch.hlo_stats import parse_collectives, scan_trip_counts
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (
    SHAPES,
    decode_cache_specs,
    input_specs,
    params_specs,
    shape_supported,
)
from repro.models import decode_step, prefill
from repro.optim import AdamWConfig

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# v5e-class chip constants (roofline; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


def _lower_train(cfg, mesh, batch_specs):
    """Lower the full train step (the deliverable-(e) artifact)."""
    p_shapes = params_specs(cfg)
    step, sh = make_train_step(
        cfg, mesh,
        opt_cfg=AdamWConfig(),
        strategy="hier" if "pod" in mesh.axis_names else "allreduce",
        params_shapes=p_shapes,
        batch_shapes=batch_specs["batch"],
        donate=False,
    )
    from repro.distributed.steps import init_train_state

    state_shapes = jax.eval_shape(
        lambda p: init_train_state(
            p, AdamWConfig(), strategy="hier" if "pod" in mesh.axis_names else "allreduce"
        ),
        p_shapes,
    )
    return step.lower(p_shapes, state_shapes, batch_specs["batch"])


def _lower_prefill(cfg, mesh, batch_specs):
    p_shapes = params_specs(cfg)
    p_shard = params_shardings(p_shapes, mesh)
    b_shard = batch_shardings(batch_specs["batch"], mesh)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    seq_axes = "model" if "model" in mesh.axis_names else None

    def fn(params, batch):
        with activation_sharding(batch_axes, seq_axes):
            return prefill(params, batch, cfg)

    cache_shapes = jax.eval_shape(fn, p_shapes, batch_specs["batch"])[1]
    c_shard = cache_shardings(cache_shapes, mesh)
    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard), out_shardings=(None, c_shard))
    return jitted.lower(p_shapes, batch_specs["batch"])


def _lower_decode(cfg, mesh, shape_name: str):
    p_shapes = params_specs(cfg)
    cache_shapes = decode_cache_specs(cfg, shape_name)
    tok = input_specs(cfg, shape_name)["tokens_t"]
    p_shard = params_shardings(p_shapes, mesh)
    c_shard = cache_shardings(cache_shapes, mesh)
    b_shard = batch_shardings({"t": tok}, mesh)["t"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def fn(params, tokens_t, cache, position):
        with activation_sharding(batch_axes):
            return decode_step(params, tokens_t, cache, cfg, position)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, b_shard, c_shard, None),
        out_shardings=(None, c_shard),
    )
    return jitted.lower(
        p_shapes, tok, cache_shapes, jax.ShapeDtypeStruct((), jnp.int32)
    )


def lower_cell(cfg, mesh, shape_name: str):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return _lower_train(cfg, mesh, input_specs(cfg, shape_name))
    if kind == "prefill":
        return _lower_prefill(cfg, mesh, input_specs(cfg, shape_name))
    return _lower_decode(cfg, mesh, shape_name)


def analyse(lowered, mesh) -> dict:
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    pod_size = 1
    for name, size in mesh.shape.items():
        if name != "pod":
            pod_size *= size
    colls = parse_collectives(hlo, pod_size=pod_size if "pod" in mesh.axis_names else 0)
    return {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": {
            "by_kind": colls.bytes_by_kind,
            "total_bytes": colls.total_bytes,
            "cross_pod_bytes": colls.cross_pod_bytes,
            "unclassified_bytes": colls.unclassified_bytes,
            "count": colls.count,
        },
        "scan_trip_counts": scan_trip_counts(hlo),
        "hlo_size_chars": len(hlo),
    }


def probe_costs(cfg, mesh, shape_name: str) -> dict:
    """Unrolled L=|pattern| and L=2|pattern| probes -> per-group costs."""
    plen = len(cfg.pattern)
    probes = {}
    for mult in (1, 2):
        pcfg = dataclasses.replace(
            cfg, num_layers=mult * plen, scan_layers=False, remat="none"
        )
        lowered = lower_cell(pcfg, mesh, shape_name)
        probes[mult] = analyse(lowered, mesh)
    g_flops = probes[2]["flops_per_device"] - probes[1]["flops_per_device"]
    g_bytes = probes[2]["bytes_per_device"] - probes[1]["bytes_per_device"]
    g_coll = (
        probes[2]["collectives"]["total_bytes"]
        - probes[1]["collectives"]["total_bytes"]
    )
    n_groups_total = cfg.num_layers / plen  # fractional remainder ok
    base_flops = probes[1]["flops_per_device"] - g_flops
    base_bytes = probes[1]["bytes_per_device"] - g_bytes
    base_coll = probes[1]["collectives"]["total_bytes"] - g_coll
    return {
        "per_group": {"flops": g_flops, "bytes": g_bytes, "collective_bytes": g_coll},
        "base": {"flops": base_flops, "bytes": base_bytes, "collective_bytes": base_coll},
        "estimated_total": {
            "flops": base_flops + g_flops * n_groups_total,
            "bytes": base_bytes + g_bytes * n_groups_total,
            "collective_bytes": base_coll + g_coll * n_groups_total,
        },
        "probe1": probes[1],
        "probe2": probes[2],
    }


def attn_scan_correction(cfg, shape_name: str, chips: int) -> dict:
    """FLOP/byte correction for chunked (scanned) attention.

    ``cost_analysis`` counts the q-block scan body once per layer, i.e.
    1/n_blocks of the true attention work.  The missing part is exact
    arithmetic: per block, the two attention matmuls cost
    ``4 * B * block * kv_span * H * hd`` forward FLOPs (masked elements
    included — the dense-block HLO really computes them), and the block
    re-reads ``kv_span`` keys+values from HBM.  Train probes run with
    remat="none", so the backward multiplier is 3x (fwd + 2 bwd matmuls).
    Returns per-device corrections to ADD to the probe-estimated totals.
    """
    from repro.models.config import ATTN, LOCAL

    spec = SHAPES[shape_name]
    if spec.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0, "n_blocks": 1}
    s, b = spec.seq_len, spec.global_batch
    block = cfg.attn_block
    chunked = cfg.attn_impl in ("auto", "chunked") and s >= 2 * block and s % block == 0
    if not chunked:
        return {"flops": 0.0, "bytes": 0.0, "n_blocks": 1}
    nb = s // block
    mult = 3.0 if spec.kind == "train" else 1.0
    h, hd, kvh = cfg.num_heads, cfg.head_dim, cfg.num_kv_heads
    kinds = list(cfg.pattern) * cfg.num_groups + list(cfg.remainder)
    miss_flops = 0.0
    miss_bytes = 0.0
    for kind in kinds:
        if kind == ATTN:
            window = cfg.window
        elif kind == LOCAL:
            window = cfg.local_window
        else:
            continue
        kv_span = s if window is None else min(window + block, s)
        per_block_flops = 4.0 * b * block * kv_span * h * hd
        per_block_bytes = 2.0 * b * kv_span * kvh * hd * 2  # k+v reads, bf16
        miss_flops += (nb - 1) * per_block_flops * mult
        miss_bytes += (nb - 1) * per_block_bytes * mult
    return {
        "flops": miss_flops / chips,
        "bytes": miss_bytes / chips,
        "n_blocks": nb,
    }


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode: D = batch."""
    spec = SHAPES[shape_name]
    n = cfg.active_param_count()
    if spec.kind == "train":
        tokens = spec.seq_len * spec.global_batch
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        tokens = spec.seq_len * spec.global_batch
        return 2.0 * n * tokens  # forward only
    return 2.0 * n * spec.global_batch  # one token per sequence


def run_cell(arch: str, shape_name: str, mesh_name: str, *, probes: bool, out_dir: Path) -> dict:
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape_name)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record
    mesh = _mesh_for(mesh_name)
    chips = 1
    for s in mesh.shape.values():
        chips *= s
    t0 = time.time()
    with mesh:
        lowered = lower_cell(cfg, mesh, shape_name)
        t_lower = time.time() - t0
        t0 = time.time()
        record["main"] = analyse(lowered, mesh)
        t_compile = time.time() - t0
        if probes:
            t0 = time.time()
            record["probes"] = probe_costs(cfg, mesh, shape_name)
            record["probes"]["seconds"] = time.time() - t0
    record["status"] = "ok"
    record["chips"] = chips
    record["lower_seconds"] = t_lower
    record["compile_seconds"] = t_compile
    record["model_flops_total"] = model_flops(cfg, shape_name)
    # roofline terms (single-pod only, per instructions)
    if mesh_name == "single" and "probes" in record:
        est = record["probes"]["estimated_total"]
        corr = attn_scan_correction(cfg, shape_name, chips)
        record["attn_scan_correction"] = corr
        flops = est["flops"] + corr["flops"]
        nbytes = est["bytes"] + corr["bytes"]
        record["roofline"] = {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": nbytes / HBM_BW,
            "collective_s": est["collective_bytes"] / ICI_BW,
            "model_flops_ratio": record["model_flops_total"] / chips / max(flops, 1.0),
        }
        terms = {k: record["roofline"][f"{k}_s"] for k in ("compute", "memory", "collective")}
        record["roofline"]["bottleneck"] = max(terms, key=terms.get)
    return record


def _run_one(arch: str, shape_name: str, mesh_name: str, probes: bool, out_dir: Path) -> dict:
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    t0 = time.time()
    try:
        rec = run_cell(arch, shape_name, mesh_name, probes=probes, out_dir=out_dir)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "error", "error": str(e)[:2000],
            "traceback": traceback.format_exc()[-4000:],
        }
    rec["wall_seconds"] = time.time() - t0
    path.write_text(json.dumps(rec, indent=2))
    return rec


def _print_cell(rec: dict, wall: float) -> None:
    status = rec.get("status", "error")
    extra = ""
    if status == "ok":
        mem = rec["main"]["memory"]["peak_estimate_bytes"] / 2**30
        extra = f"peak={mem:.2f}GiB colls={rec['main']['collectives']['count']}"
        if "roofline" in rec:
            r = rec["roofline"]
            extra += (
                f" compute={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms"
                f" coll={r['collective_s']*1e3:.1f}ms bottleneck={r['bottleneck']}"
            )
    print(
        f"[{status}] {rec['arch']} {rec['shape']} {rec['mesh']} ({wall:.0f}s) {extra}",
        flush=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ASSIGNED_ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument(
        "--in-process", action="store_true",
        help="run cells in this process (default: one subprocess per cell, "
        "so an XLA C++ CHECK abort cannot kill the whole sweep)",
    )
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ASSIGNED_ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    single_cell = len(archs) == 1 and len(shapes) == 1 and len(meshes) == 1

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    try:
                        if json.loads(path.read_text()).get("status") in ("ok", "skipped"):
                            print(f"[skip] {path.name}")
                            continue
                    except Exception:  # noqa: BLE001
                        pass
                probes = not args.no_probes and mesh_name == "single"
                t0 = time.time()
                if single_cell or args.in_process:
                    rec = _run_one(arch, shape_name, mesh_name, probes, out_dir)
                else:
                    # isolate each cell: XLA partitioner CHECK failures abort
                    # the process; a subprocess confines the blast radius.
                    import subprocess, sys

                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape_name, "--mesh", mesh_name,
                        "--out", str(out_dir),
                    ]
                    if args.no_probes:
                        cmd.append("--no-probes")
                    proc = subprocess.run(cmd, capture_output=True, text=True)
                    if path.exists():
                        rec = json.loads(path.read_text())
                    else:
                        rec = {
                            "arch": arch, "shape": shape_name, "mesh": mesh_name,
                            "status": "error",
                            "error": f"worker died rc={proc.returncode}",
                            "stderr_tail": proc.stderr[-3000:],
                        }
                        path.write_text(json.dumps(rec, indent=2))
                if rec.get("status") == "error":
                    failures.append(path.name)
                _print_cell(rec, time.time() - t0)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
