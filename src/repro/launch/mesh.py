"""Production mesh construction.

``pod`` is the data-center axis: collectives crossing it ride the WAN/DCI
modeled by :mod:`repro.core` — exactly the traffic class the paper's fabric
carries.  ``data`` is intra-pod data parallelism (+ FSDP sharding), and
``model`` is tensor/expert parallelism.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count locks on first backend initialization).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types; older releases have neither
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions (axis_types only where supported)."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (used by tests and the elastic re-mesh path)."""
    return _make_mesh(shape, axes)


def make_host_mesh(
    *, pods: int = 1, data: Optional[int] = None, model: int = 1
) -> Mesh:
    """Best-effort mesh over however many (possibly fake) devices exist.

    Used by smoke/integration tests that run under
    ``--xla_force_host_platform_device_count=N``.
    """
    n = len(jax.devices())
    if data is None:
        data = n // (pods * model)
    assert pods * data * model == n, (pods, data, model, n)
    if pods > 1:
        return make_mesh((pods, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_pods(mesh: Mesh) -> int:
    return mesh.shape.get("pod", 1)


def chips_per_pod(mesh: Mesh) -> int:
    total = 1
    for size in mesh.shape.values():
        total *= size
    return total // num_pods(mesh)
