"""Serving launcher: batched prefill + decode over the geo mesh.

``python -m repro.launch.serve --arch <id> --prompt-len 64 --gen 32``

Runs a smoke-scale model end to end: batched synthetic prompts through
``prefill`` then greedy ``decode_step`` tokens, reporting per-phase
timing and (for multi-pod meshes) the WAN placement sanity (serving is
pod-local: no cross-pod collectives should appear — verified).
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="distilgpt2-82m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.launch.batches import decode_step_input, synthetic_prompt_batch
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    batch = synthetic_prompt_batch(cfg, key, args.batch, args.prompt_len)

    t0 = time.time()
    prefill_jit = jax.jit(lambda pr, b: prefill(pr, b, cfg, max_len=max_len))
    logits, cache = prefill_jit(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")

    decode_jit = jax.jit(
        lambda pr, tok, c, pos: decode_step(pr, tok, c, cfg, pos)
    )
    tokens = jnp.argmax(logits, axis=-1)
    generated = [tokens]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.int32(args.prompt_len + i)
        step_in = decode_step_input(cfg, key, tokens, args.batch, i)
        logits, cache = decode_jit(params, step_in, cache, pos)
        tokens = jnp.argmax(logits, axis=-1)
        generated.append(tokens)
    tokens.block_until_ready()
    t_decode = time.time() - t0
    toks_per_s = args.batch * args.gen / t_decode
    print(f"decode: {args.gen} steps in {t_decode:.3f}s ({toks_per_s:.1f} tok/s)")
    out = jnp.stack(generated, axis=1)
    print(f"sample[0]: {out[0].tolist()}")


if __name__ == "__main__":
    main()
