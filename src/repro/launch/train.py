"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs the GeoTrainer end to end on the selected architecture (full or
smoke-scale), mesh, and WAN sync strategy.  On this CPU container the
default is the smoke-scale config with a host mesh; on a real TPU fleet
the same flags drive the production meshes.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="distilgpt2-82m")
    ap.add_argument("--shape", default=None, help="named shape (train_4k) or custom")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--strategy", default="hier",
                    choices=["allreduce", "ps", "hier", "hier_int8", "local_sgd"])
    ap.add_argument("--num-channels", type=int, default=4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (paper-scale) config instead of smoke")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"],
                    help="host = whatever devices exist; single/multi = production")
    ap.add_argument("--pods", type=int, default=1, help="pod axis for host mesh")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=None)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-json", default=None)
    args = ap.parse_args()

    # late imports: mesh choice may require the 512-device flag first
    if args.mesh in ("single", "multi"):
        import os

        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.core.geo import GeoFabric
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.shapes import SHAPES
    from repro.runtime import GeoTrainer, TrainerConfig

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    if args.shape is not None:
        spec = SHAPES[args.shape]
        args.seq_len, args.global_batch = spec.seq_len, spec.global_batch

    if args.mesh == "host":
        n = len(jax.devices())
        mesh = make_host_mesh(pods=args.pods, model=1) if n > 1 else make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    npods = mesh.shape.get("pod", 1)
    geo = GeoFabric(num_pods=max(npods, 2), workers_per_pod=2, seed=args.seed)

    trainer = GeoTrainer(
        cfg, mesh,
        trainer_cfg=TrainerConfig(
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            steps=args.steps,
            strategy=args.strategy,
            num_channels=args.num_channels,
            checkpoint_every=args.checkpoint_every,
            seed=args.seed,
        ),
        checkpoint_dir=args.checkpoint_dir,
        geo=geo,
    )
    result = trainer.run(inject_failure_at=args.inject_failure_at)
    if result["final_loss"] is None:
        print(
            f"\nnothing to do: checkpoint at {args.checkpoint_dir} already "
            f"covers {args.steps} steps (use --steps N or a fresh dir)"
        )
        return
    print(
        f"\nfinal loss {result['final_loss']:.4f} | "
        f"sync efficiency {result['sync_efficiency']:.2f} | "
        f"last checkpoint step {result['last_checkpoint']}"
    )
    if result["recovery_drills"]:
        for drill in result["recovery_drills"]:
            p = drill["plan"]
            print(
                f"recovery drill @step {drill['step']}: dead={drill['dead']} "
                f"downtime={p['detection_s'] + p['restore_s'] + p['remesh_s']:.2f}s "
                f"lost_steps={p['lost_steps']}"
            )
    if args.out_json:
        Path(args.out_json).write_text(json.dumps(result["metrics"], indent=1))


if __name__ == "__main__":
    main()
