"""Named scenario library: the paper's §5 studies as one-line specs.

Every named entry is a factory returning a ready-to-run
:class:`~repro.scenario.spec.Scenario`; factories take keyword overrides
so sweeps are spec edits, not new scripts:

* ``fig14_allreduce`` / ``fig14_ps`` — the Fig. 14 AllReduce-vs-PS
  geo-training study (DistilGPT2 gradient volumes, contended WAN);
* ``compute_overlap`` — the compute/communication overlap sweep (one
  fraction per scenario) under the event-driven congestion model;
* ``rs_then_ag`` / ``rs_ag_overlap`` — serial vs pipelined ring schedules
  on shared WAN bottlenecks (the schedule-overlap gate's pair);
* ``bfd_flap_storm`` — the 8-DC BFD-cadence flap storm (§5.3 at scale):
  deterministic fail/restore script over the scaled topology, recovery
  timelines + EVPN resync stats in the result;
* ``multi_tenant_churn`` — tenant attach/detach churn on the paper's
  Fig. 1 fabric plus a leaf-isolation episode, surfacing
  :class:`~repro.core.evpn.EvpnResyncStats` (§5.4 beyond Table 1);
* ``ecmp_collision`` — the §5.2 collision study costed end-to-end: same
  workload under ``baseline`` vs ``qp_aware`` port allocation with the
  ECMP-weighted congestion model.

The shared topology/workload constants the benchmarks used to copy-paste
(`SCALED8`, the storm event scripts, the Fig. 14 gradient volumes) live
here so ``benchmarks/bench_*.py`` and ``examples/`` are thin wrappers.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.core.fabric import Fabric, FabricConfig
from repro.core.geo import SyncOptions
from repro.scenario.spec import (
    DegradationPolicy,
    Scenario,
    ScenarioEvent,
    ServingSpec,
    TopologySpec,
    WorkloadSpec,
)

__all__ = [
    "AR_GRAD_BYTES",
    "CALIBRATED_COMPUTE_S",
    "DISTILGPT2_KV_BYTES_PER_TOKEN",
    "PS_GRAD_BYTES",
    "SCALED8",
    "STORM_GRAD_BYTES",
    "evpn_storm_events",
    "get_scenario",
    "register_scenario",
    "scenario_names",
    "storm_events",
]

#: DistilGPT2 fp32 gradient volume (paper: ~312 MB with DDP).
AR_GRAD_BYTES = 312_000_000
#: PS per-batch volume (paper: ~459 MB: fp32 grads + momentum-carrying pulls).
PS_GRAD_BYTES = 459_000_000
#: Per-batch gradient-computation floor calibrated to Fig. 14 (see
#: ``benchmarks/bench_training.py`` for the derivation).
CALIBRATED_COMPUTE_S = 2.2

#: 8-DC scaled fabric for the flap storm: 32 spines, 32 leaves, 64 hosts,
#: 28 DC pairs x 16 spine-pair WAN links = 448 WAN links.
SCALED8 = FabricConfig(
    num_dcs=8,
    spines_per_dc=4,
    leaves_per_dc=4,
    hosts_per_leaf=tuple(tuple(2 for _ in range(4)) for _ in range(8)),
)

STORM_GRAD_BYTES = 16_000_001


def storm_events(fabric: Fabric) -> List[Tuple[str, Tuple[str, str]]]:
    """Deterministic BFD-cadence flap schedule: isolated WAN flaps spread
    over the DC pairs, one correlated burst (3 of d1s1's 4 links toward
    DC2), and a leaf-spine flap; a few links stay down at the end."""
    wan = sorted(tuple(sorted(l)) for l in fabric.wan_links)
    events: List[Tuple[str, Tuple[str, str]]] = []
    for k in range(8):
        link = wan[(k * 53) % len(wan)]
        events.append(("fail", link))
        events.append(("restore", link))
    burst = [l for l in wan if l[0] == "d1s1" and l[1].startswith("d2s")]
    for link in burst[:3]:
        events.append(("fail", link))
    for link in burst[:2]:
        events.append(("restore", link))
    events.append(("fail", ("d3l2", "d3s1")))
    return events


def evpn_storm_events(fabric: Fabric) -> List[Tuple[str, Tuple[str, str]]]:
    """The data-plane storm plus a leaf-isolation episode: d5l1 loses all
    four uplinks one BFD flap at a time (only the fourth partitions the
    BGP session graph), then gets them back — the only event class whose
    EVPN blast radius is non-empty."""
    events = list(storm_events(fabric))
    uplinks = [("d5l1", f"d5s{j}") for j in range(1, 5)]
    events += [("fail", link) for link in uplinks]
    events += [("restore", link) for link in uplinks]
    return events


# -- registry -----------------------------------------------------------------

ScenarioFactory = Callable[..., Scenario]

_LIBRARY: Dict[str, ScenarioFactory] = {}


def register_scenario(
    name: str, factory: Optional[ScenarioFactory] = None, *, overwrite: bool = False
):
    """Register a scenario factory under ``name`` (usable as a decorator).

    Factories are called as ``factory(**overrides)`` and must return a
    :class:`Scenario`.  Re-registering raises unless ``overwrite=True``.
    """

    def _register(f: ScenarioFactory) -> ScenarioFactory:
        if not overwrite and name in _LIBRARY:
            raise ValueError(f"scenario {name!r} already registered")
        _LIBRARY[name] = f
        return f

    return _register if factory is None else _register(factory)


def get_scenario(name: str, **overrides) -> Scenario:
    """Build the named scenario, forwarding keyword overrides."""
    try:
        factory = _LIBRARY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {scenario_names()}"
        ) from None
    return factory(**overrides)


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(_LIBRARY))


# -- the paper's §5 studies ---------------------------------------------------


def _fig14_scenario(name: str, strategy: str, grad_bytes: int, **kw) -> Scenario:
    opts = kw.pop("options", SyncOptions(jitter=False, congestion=True))
    return Scenario(
        name=name,
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=14),
        workload=WorkloadSpec(strategy=strategy, grad_bytes=grad_bytes, **kw),
        options=opts,
        description=(
            "Fig. 14 geo-training study: DistilGPT2 gradients over the "
            "emulated 800 Mbit/s / 22 ms WAN, contended congestion model."
        ),
    )


@register_scenario("fig14_allreduce")
def fig14_allreduce(**kw) -> Scenario:
    return _fig14_scenario("fig14_allreduce", "allreduce", AR_GRAD_BYTES, **kw)


@register_scenario("fig14_ps")
def fig14_ps(**kw) -> Scenario:
    return _fig14_scenario("fig14_ps", "ps", PS_GRAD_BYTES, **kw)


@register_scenario("compute_overlap")
def compute_overlap(overlap_fraction: float = 0.5, **kw) -> Scenario:
    """One point of the compute/communication overlap sweep (ROADMAP):
    flat AllReduce grafted with the calibrated compute phase, costed by
    the event-driven simulator."""
    return Scenario(
        name=f"compute_overlap_f{int(overlap_fraction * 100):02d}",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=14),
        workload=WorkloadSpec(
            strategy="allreduce",
            grad_bytes=AR_GRAD_BYTES,
            compute_seconds=kw.pop("compute_seconds", CALIBRATED_COMPUTE_S),
            overlap_fraction=overlap_fraction,
        ),
        options=kw.pop("options", SyncOptions(jitter=False, congestion=True)),
        description=(
            "Compute/communication overlap as DAG structure: communication "
            "may start once the non-overlappable head of backprop is done."
        ),
    )


def _ring_schedule_scenario(name: str, strategy: str, **kw) -> Scenario:
    return Scenario(
        name=name,
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=3),
        workload=WorkloadSpec(
            strategy=strategy, grad_bytes=kw.pop("grad_bytes", AR_GRAD_BYTES)
        ),
        options=kw.pop("options", SyncOptions(jitter=False, congestion=True)),
        description=(
            "Ring reduce-scatter/all-gather on shared WAN bottlenecks: "
            "pipelined overlap lands strictly between max(RS, AG) and "
            "serial RS -> AG."
        ),
    )


@register_scenario("rs_then_ag")
def rs_then_ag(**kw) -> Scenario:
    return _ring_schedule_scenario("rs_then_ag", "rs_then_ag", **kw)


@register_scenario("rs_ag_overlap")
def rs_ag_overlap(**kw) -> Scenario:
    return _ring_schedule_scenario("rs_ag_overlap", "rs_ag_overlap", **kw)


@register_scenario("bfd_flap_storm")
def bfd_flap_storm(mechanism: str = "bfd", **kw) -> Scenario:
    """The §5.3 storm as a scenario: the deterministic flap script over
    the 8-DC scaled topology, one BFD event per step, with a hierarchical
    leader sync riding through it.  ``ScenarioResult.recoveries`` /
    ``evpn_resyncs`` carry the per-flap rollups."""
    events = tuple(
        ScenarioEvent(
            kind="fail_link" if action == "fail" else "restore_link",
            at_step=i,
            link=link,
            mechanism=mechanism,
        )
        for i, (action, link) in enumerate(storm_events(Fabric(SCALED8)))
    )
    return Scenario(
        name="bfd_flap_storm",
        topology=TopologySpec(fabric=SCALED8, num_channels=4, seed=5),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", STORM_GRAD_BYTES),
            steps=len(events),
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=events,
        description=(
            "8-DC BFD-cadence flap storm: isolated WAN flaps, a correlated "
            "burst, and a leaf-spine flap, with leader sync costed every "
            "step of the storm."
        ),
    )


@register_scenario("multi_tenant_churn")
def multi_tenant_churn(**kw) -> Scenario:
    """Tenant attach/detach churn on the paper's Fig. 1 fabric plus a
    leaf-isolation episode (d1l3 loses both uplinks, then recovers).

    The workload is the hierarchical leader sync (leaders d1h1/d2h1 stay
    attached throughout), so every churn step re-costs sync under the
    current control-plane state; detach/attach churn exercises Type-2
    withdrawal/re-advertisement, and the isolation episode is the one
    event class with a non-empty EVPN resync blast radius."""
    churn_hosts = ("d1h2", "d2h2", "d1h4", "d2h3")
    events: List[ScenarioEvent] = []
    step = 1
    for host in churn_hosts:  # detach/re-attach each host, one per step
        events.append(
            ScenarioEvent(kind="tenant_detach", at_step=step, tenant="training", host=host)
        )
        events.append(
            ScenarioEvent(kind="tenant_attach", at_step=step + 1, tenant="training", host=host)
        )
        step += 2
    # leaf-isolation episode: d1l3 (hosts d1h5) loses both uplinks
    for j, action in ((1, "fail_link"), (2, "fail_link"), (1, "restore_link"), (2, "restore_link")):
        events.append(
            ScenarioEvent(kind=action, at_step=step, link=("d1l3", f"d1s{j}"))
        )
        step += 1
    return Scenario(
        name="multi_tenant_churn",
        topology=TopologySpec(fabric=FabricConfig(), num_channels=4, seed=1),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", 64_000_000),
            steps=step + 1,
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=tuple(events),
        description=(
            "Multi-tenant churn (§5.4 beyond Table 1): per-step tenant "
            "detach/attach plus a leaf-isolation flap sequence; "
            "EvpnResyncStats rollups surface the control-plane blast radius."
        ),
    )


@register_scenario("wan_brownout")
def wan_brownout(
    policy: Optional[DegradationPolicy] = DegradationPolicy(
        degraded_sync_every=8, int8_wan=True
    ),
    bandwidth_fraction: float = 0.25,
    **kw,
) -> Scenario:
    """Gray-failure brownout: one DC pair silently loses 4x bandwidth
    mid-run (no link goes down — BFD stays UP throughout), then recovers.

    With the default :class:`~repro.scenario.spec.DegradationPolicy` the
    SLA probes trip after two breaching steps and the runner gracefully
    degrades (sync every 8 steps, int8 WAN compression) until the probes
    recover; ``policy=None`` rides the brownout at full cost — the
    ``bench_resilience.py`` brownout gate prices the difference."""
    events = (
        ScenarioEvent(
            kind="degrade_pair",
            at_step=4,
            pair=(1, 2),
            bandwidth_fraction=bandwidth_fraction,
        ),
        ScenarioEvent(kind="restore_degradation", at_step=12, pair=(1, 2)),
    )
    return Scenario(
        name="wan_brownout",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=7),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", AR_GRAD_BYTES),
            steps=16,
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=events,
        policy=policy,
        description=(
            "WAN brownout on pair (1,2): bandwidth quietly drops to a "
            "fraction while BFD sessions stay UP; SLA probes trip with "
            "hysteresis and the degradation policy falls back gracefully."
        ),
    )


#: distilgpt2-82m decode-cache bytes per context token
#: (= ``model_kv_bytes("distilgpt2-82m")``, pinned so control-plane-only
#: runs never import jax).
DISTILGPT2_KV_BYTES_PER_TOKEN = 18_432


@register_scenario("serving_under_flap")
def serving_under_flap(
    policy: Optional[DegradationPolicy] = DegradationPolicy(),
    serving: Optional[ServingSpec] = None,
    **kw,
) -> Scenario:
    """Geo-serving through a WAN brownout + BFD flap: 400k users across
    two DCs, half of DC-crossing sessions steadily served remote, while a
    hierarchical leader sync trains underneath on the same spine WAN.

    The event arc: pair (1,2) browns out at step 4 (bandwidth to 20%,
    +30 ms), a spine WAN link BFD-flaps at step 5/6, and the brownout
    lifts at step 10.  With the default detection-only policy the SLA
    probes trip after the second breaching observation, the session
    router's failover sweep re-homes every remote session (paying
    leader-to-leader KV migration bytes), serving p99 collapses back
    under the SLO, and once the probes recover the remote class resumes —
    goodput-under-flap, priced end to end.  ``bench_serving.py`` gates
    the whole arc."""
    if serving is None:
        serving = ServingSpec(
            users=400_000,
            requests_per_user_step=2e-5,
            remote_fraction=0.5,
            mean_tokens=128,
            session_tokens=1024,
            kv_bytes_per_token=DISTILGPT2_KV_BYTES_PER_TOKEN,
            slo_ms=400.0,
            seed=23,
        )
    events = (
        ScenarioEvent(
            kind="degrade_pair",
            at_step=4,
            pair=(1, 2),
            bandwidth_fraction=0.2,
            extra_delay_ms=30.0,
        ),
        ScenarioEvent(kind="fail_link", at_step=5, link=("d1s1", "d2s1")),
        ScenarioEvent(kind="restore_link", at_step=6, link=("d1s1", "d2s1")),
        ScenarioEvent(kind="restore_degradation", at_step=10, pair=(1, 2)),
    )
    return Scenario(
        name="serving_under_flap",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=19),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", AR_GRAD_BYTES),
            steps=14,
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=events,
        policy=policy,
        serving=serving,
        description=(
            "Inference co-load through a gray-failure arc: brownout + BFD "
            "flap trip the SLA probes, the affinity router fails user "
            "sessions over (KV migration priced on the WAN), p99 recovers."
        ),
    )


@register_scenario("srlg_fiber_cut")
def srlg_fiber_cut(**kw) -> Scenario:
    """SRLG fiber cut on a 4-DC fabric: the DC pairs (1,2) and (3,4)
    share one conduit (``subsea-1``), so a single backhoe fails every WAN
    link of both pairs *atomically* — one shared BFD detection window, one
    withdrawal/best-path/FIB pipeline, per-link incremental reroute + EVPN
    resync in deterministic order.  Leader-ring traffic between the cut
    pairs transits the surviving DCs until the fiber is respliced.  The
    resulting routing state is pinned byte-for-byte equal to sequential
    per-link failure by the ``bench_resilience.py`` SRLG gate."""
    events = (
        ScenarioEvent(kind="fiber_cut", at_step=2, srlg="subsea-1"),
        ScenarioEvent(kind="fiber_restore", at_step=5, srlg="subsea-1"),
    )
    return Scenario(
        name="srlg_fiber_cut",
        topology=TopologySpec(
            num_pods=4,
            workers_per_pod=2,
            num_channels=4,
            seed=9,
            srlgs=(("subsea-1", ((1, 2), (3, 4))),),
        ),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", 64_000_000),
            steps=8,
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=events,
        policy=kw.pop("policy", None),
        description=(
            "Shared-risk-link-group cut: pairs (1,2) and (3,4) fail "
            "together in one detection window; sync reroutes through the "
            "surviving DCs until fiber_restore."
        ),
    )


@register_scenario("pod_loss_recovery")
def pod_loss_recovery(
    policy: Optional[DegradationPolicy] = DegradationPolicy(),
    **kw,
) -> Scenario:
    """Whole-pod loss priced end to end: pod 2 stops heartbeating at step
    6, the HeartbeatMonitor declares it dead ~3 intervals later, and the
    runner prices the recovery — roll back to the last checkpoint *before*
    the death, restore over the WAN, re-mesh onto the survivor — into the
    step timeline (``StepRecord.downtime_seconds``) and the
    :class:`~repro.scenario.runner.PodRecovery` record.  Subsequent steps
    cost the survivor-only schedule (single-DC: WAN sync disabled)."""
    events = (ScenarioEvent(kind="pod_fail", at_step=6, pod=2),)
    return Scenario(
        name="pod_loss_recovery",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=11),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=kw.pop("grad_bytes", AR_GRAD_BYTES),
            steps=12,
        ),
        options=kw.pop("options", SyncOptions(jitter=False)),
        events=events,
        policy=policy,
        description=(
            "Pod-loss economics: lost work = steps since the last "
            "pre-failure checkpoint, plus detection + checkpoint restore "
            "+ elastic remesh downtime."
        ),
    )


@register_scenario("ecmp_collision")
def ecmp_collision(port_scheme: str = "baseline", **kw) -> Scenario:
    """The §5.2 collision study costed end-to-end: the same ring AllReduce
    under ``baseline`` vs ``qp_aware`` source-port allocation, with the
    ECMP-weighted congestion model turning recorded hash-slot collisions
    into completion-time inflation.  At the default 4 channels (the
    paper's sensitive regime) Algorithm 1 must cost visibly less."""
    return Scenario(
        name=f"ecmp_collision_{port_scheme}",
        topology=TopologySpec(
            num_pods=2,
            workers_per_pod=2,
            num_channels=kw.pop("num_channels", 4),
            port_scheme=port_scheme,
            seed=2,
        ),
        workload=WorkloadSpec(
            strategy="allreduce", grad_bytes=kw.pop("grad_bytes", 64_000_000)
        ),
        options=kw.pop(
            "options",
            SyncOptions(jitter=False, congestion=True, ecmp_weighted=True),
        ),
        description=(
            "ECMP hash-collision study: identical workload, two port "
            "allocators; weighted max-min prices the collisions each "
            "scheme leaves."
        ),
    )
