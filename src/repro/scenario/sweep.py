"""Sweep/campaign engine: one base :class:`Scenario`, many variants, one table.

PR 5 made one study = one spec; this module makes *thousands* of studies =
one campaign (the ROADMAP's scenario-fleets item):

* :func:`apply_overrides` — expand dotted-field overrides
  (``{"workload.overlap_fraction": 0.5, "topology.wan_pairs": {...}}``)
  into a new :class:`Scenario`, replacing through the nested frozen
  dataclasses in one pass per level so co-dependent fields (``num_pods`` +
  ``wan_pairs``) validate together;
* :class:`Sweep` — a base scenario plus a list of override dicts;
  :meth:`Sweep.run` executes every variant (``run_scenario`` is
  embarrassingly parallel, so ``workers > 1`` fans out over a process
  pool) and joins the per-variant ``metrics()`` into a
  :class:`SweepResult` table.  Every variant is fully determined by its
  serialized spec — all randomness inside a run flows through the spec's
  seed — so the joined table is identical for any worker count;
* :func:`random_campaign` — Monte Carlo campaign generation: sampled
  topologies, per-DC-pair RTT/bandwidth draws (the asymmetric-WAN axis),
  WAN flap scripts and straggler mixes, all drawn from one seeded
  ``numpy`` Generator, returned as a plain :class:`Sweep` — a
  reproducible, serializable campaign artifact;
* :func:`fiber_latency_campaign` — the headline study: per-pair RTT x
  overlap fraction, reproducing the Papavasileiou-style
  overlap-benefit-vs-RTT curve ("Modeling the Impact of Fiber Latency on
  Compute-Communication Overlap", PAPERS.md) as one spec, gated in
  ``benchmarks/bench_sweeps.py``.

``SweepResult.to_dict()`` is the campaign's joined result table —
``benchmarks/compare.py`` reads its ``variants`` list exactly like a
suite's ``rows``, so campaign conclusions are regression-gated like
everything else.
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.wan import NetemProfile
from repro.scenario.runner import run_scenario
from repro.scenario.spec import (
    Scenario,
    ScenarioEvent,
    ServingSpec,
    SyncOptions,
    TopologySpec,
    WorkloadSpec,
)

__all__ = [
    "Sweep",
    "SweepResult",
    "SweepRow",
    "apply_overrides",
    "fiber_latency_campaign",
    "random_campaign",
    "run_sweep",
]

OverrideMap = Mapping[str, object]


@dataclass(frozen=True)
class _Leaf:
    """Marks an override *value* in the nested update tree (a value may
    itself be a dict — ``topology.wan_pairs`` — without being a subtree)."""

    value: object


def apply_overrides(scenario: Scenario, overrides: OverrideMap) -> Scenario:
    """Return ``scenario`` with dotted-field ``overrides`` applied.

    Paths name nested dataclass fields (``"workload.overlap_fraction"``,
    ``"topology.wan.delay_ms"``, ``"options.congestion"``, ``"events"``,
    ``"name"``).  Sibling overrides of one dataclass are applied in a
    single ``dataclasses.replace`` call, so ``topology.num_pods`` and
    ``topology.wan_pairs`` set together validate against each other, not
    against the base spec.
    """
    tree: Dict[str, object] = {}
    for path, value in overrides.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            nxt = node.setdefault(p, {})
            if isinstance(nxt, _Leaf):
                raise ValueError(f"override path {path!r} descends into leaf {p!r}")
            node = nxt
        if isinstance(node.get(parts[-1]), dict):
            raise ValueError(f"override path {path!r} conflicts with a deeper path")
        node[parts[-1]] = _Leaf(value)
    return _apply_tree(scenario, tree, "")


def _apply_tree(obj, tree: Dict[str, object], prefix: str):
    updates = {}
    for key, sub in tree.items():
        path = f"{prefix}{key}"
        if isinstance(sub, _Leaf):
            updates[key] = sub.value
        else:
            if not hasattr(obj, key):
                raise ValueError(f"no field {path!r} on {type(obj).__name__}")
            child = getattr(obj, key)
            if not dataclasses.is_dataclass(child):
                raise ValueError(
                    f"override path descends into non-spec field {path!r}"
                )
            updates[key] = _apply_tree(child, sub, f"{path}.")
    try:
        return dataclasses.replace(obj, **updates)
    except TypeError as e:
        raise ValueError(
            f"bad override field(s) {sorted(updates)} for "
            f"{type(obj).__name__}: {e}"
        ) from None


def _jsonify(value):
    """JSON-able record of an override value (specs, profiles, tuple keys)."""
    if isinstance(value, (NetemProfile, ScenarioEvent)):
        return dataclasses.asdict(value)
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return dataclasses.asdict(value)
    if isinstance(value, Mapping):
        return [[_jsonify(k), _jsonify(v)] for k, v in value.items()]
    if isinstance(value, (list, tuple)):
        return [_jsonify(v) for v in value]
    return value


@dataclass(frozen=True)
class SweepRow:
    """One variant of the joined table: its name, what changed vs the base,
    and its deterministic ``ScenarioResult.metrics()``."""

    name: str
    overrides: Dict[str, object]
    metrics: Dict[str, float]

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "overrides": {k: _jsonify(v) for k, v in self.overrides.items()},
            "metrics": dict(self.metrics),
        }


@dataclass
class SweepResult:
    """The campaign's joined result table.

    ``to_dict()`` is the gateable artifact: ``benchmarks/compare.py``
    reads the ``variants`` list exactly like a suite's ``rows`` (one
    BenchRow-shaped entry per variant).
    """

    name: str
    base: Scenario
    rows: List[SweepRow]
    seed: Optional[int] = None  # set for random campaigns

    def metric(self, key: str) -> List[float]:
        """One metric as a per-variant column (missing entries -> nan)."""
        return [float(r.metrics.get(key, float("nan"))) for r in self.rows]

    def row(self, name: str) -> SweepRow:
        for r in self.rows:
            if r.name == name:
                return r
        raise KeyError(f"no variant {name!r} in sweep {self.name!r}")

    def to_dict(self) -> Dict[str, object]:
        return {
            "campaign": self.name,
            "base": self.base.to_dict(),
            "seed": self.seed,
            "variants": [r.to_dict() for r in self.rows],
        }


@dataclass(frozen=True)
class Sweep:
    """A base scenario and the override dicts that expand it into variants.

    ``overrides[i]`` may carry a ``"name"`` key; otherwise variant ``i``
    is named ``{base.name}#{i:03d}``.  The expansion is pure spec algebra
    (no fabric is built), so a Sweep is cheap to construct, serialize and
    inspect before committing to a run.
    """

    base: Scenario
    overrides: Tuple[OverrideMap, ...]
    name: str = ""
    seed: Optional[int] = None  # provenance for random campaigns

    def __post_init__(self):
        object.__setattr__(self, "overrides", tuple(self.overrides))
        if not self.name:
            object.__setattr__(self, "name", f"{self.base.name}_sweep")

    def variant_name(self, i: int) -> str:
        name = self.overrides[i].get("name")
        return str(name) if name else f"{self.base.name}#{i:03d}"

    def variants(self) -> List[Scenario]:
        """Expand every override dict into a concrete :class:`Scenario`."""
        out = []
        for i, ov in enumerate(self.overrides):
            ov = dict(ov)
            ov.setdefault("name", self.variant_name(i))
            out.append(apply_overrides(self.base, ov))
        return out

    def run(self, *, workers: int = 0) -> SweepResult:
        return run_sweep(self, workers=workers)


def _run_variant_payload(payload: Dict[str, object]) -> Dict[str, float]:
    """Process-pool work item: spec dict in, joined-table metrics out.

    Module-level (picklable) and fed the *serialized* spec, so parallel
    workers execute byte-identical inputs to the serial path.
    """
    return run_scenario(Scenario.from_dict(payload)).metrics()


def run_sweep(sweep: Sweep, *, workers: int = 0) -> SweepResult:
    """Execute every variant and join the per-variant metrics.

    ``workers > 1`` fans the variants out over a process pool
    (``run_scenario`` is embarrassingly parallel); results are joined in
    variant order and each variant's randomness is seeded by its own spec,
    so the table is identical for any worker count — pinned by
    ``tests/test_sweep.py`` and the ``bench_sweeps`` parallel-identity
    gate.
    """
    variants = sweep.variants()
    payloads = [v.to_dict() for v in variants]
    if workers > 1:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            metrics = list(pool.map(_run_variant_payload, payloads))
    else:
        metrics = [_run_variant_payload(p) for p in payloads]
    rows = [
        SweepRow(
            name=v.name,
            overrides={k: v2 for k, v2 in ov.items() if k != "name"},
            metrics=m,
        )
        for v, ov, m in zip(variants, sweep.overrides, metrics)
    ]
    return SweepResult(name=sweep.name, base=sweep.base, rows=rows, seed=sweep.seed)


# -- the headline fiber-latency campaign --------------------------------------


def fiber_latency_campaign(
    rtt_ms: Sequence[float] = (2.0, 10.0, 30.0, 60.0),
    overlap_fractions: Sequence[float] = (0.0, 0.75),
    *,
    grad_bytes: int = 48_000_000,
    compute_seconds: float = 0.35,
    bandwidth_gbps: float = 0.8,
) -> Sweep:
    """Per-pair RTT x overlap fraction: the Papavasileiou-style study.

    Every variant pins the 2-DC pair's WAN profile to one sampled one-way
    ``delay_ms`` (= RTT/2 per netem interface pair, jitter-free) through
    ``topology.wan_pairs`` and sweeps the overlappable fraction of the
    compute window.  The overlap *benefit* — the fraction of the
    no-overlap step time that overlap recovers — decays as per-pair RTT
    grows past the compute window: propagation is exposed no matter when
    communication starts.  ``benchmarks/bench_sweeps.py`` gates exactly
    that monotone decay.
    """
    base = Scenario(
        name="fiber_latency",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=11),
        workload=WorkloadSpec(
            strategy="allreduce",
            grad_bytes=grad_bytes,
            compute_seconds=compute_seconds,
            steps=1,
        ),
        options=SyncOptions(jitter=False),
        description=(
            "Fiber-latency campaign: overlap benefit vs per-DC-pair RTT "
            "(asymmetric-WAN axis), one spec per (rtt, overlap) point."
        ),
    )
    overrides = []
    for rtt in rtt_ms:
        profile = NetemProfile(
            delay_ms=rtt / 2.0, jitter_ms=0.0, bandwidth_gbps=bandwidth_gbps
        )
        for frac in overlap_fractions:
            overrides.append(
                {
                    "name": f"rtt{rtt:g}ms_f{int(frac * 100):02d}",
                    "topology.wan_pairs": {(1, 2): profile},
                    "workload.overlap_fraction": frac,
                }
            )
    return Sweep(base=base, overrides=tuple(overrides), name="fiber_latency_campaign")


def overlap_benefit_curve(result: SweepResult) -> List[Tuple[float, float]]:
    """Join a :func:`fiber_latency_campaign` result into the
    overlap-benefit-vs-RTT curve: ``(rtt_ms, benefit_frac)`` per swept
    RTT, where ``benefit_frac`` is the largest fraction of the no-overlap
    step time any swept overlap fraction recovers."""
    by_rtt: Dict[float, Dict[str, float]] = {}
    for row in result.rows:
        rtt_part, frac_part = row.name.rsplit("_f", 1)
        rtt = float(rtt_part[len("rtt"):-len("ms")])
        by_rtt.setdefault(rtt, {})[frac_part] = row.metrics["mean_step_seconds"]
    curve = []
    for rtt in sorted(by_rtt):
        steps = by_rtt[rtt]
        t0 = steps.pop("00")
        best = min(steps.values(), default=t0)
        curve.append((rtt, (t0 - best) / t0 if t0 > 0 else 0.0))
    return curve


# -- Monte Carlo campaign generation ------------------------------------------

def _campaign_base() -> Scenario:
    """Default base for :func:`random_campaign`: a 2-step contended
    geo-training workload every sampled axis perturbs."""
    return Scenario(
        name="campaign",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=0),
        workload=WorkloadSpec(
            strategy="allreduce",
            grad_bytes=24_000_000,
            compute_seconds=1.0,
            overlap_fraction=0.5,
            steps=2,
        ),
        options=SyncOptions(jitter=False, congestion=True),
        description="Monte Carlo campaign over asymmetric WANs.",
    )


def random_campaign(
    seed: int,
    *,
    variants: int = 8,
    base: Optional[Scenario] = None,
    num_pods_choices: Sequence[int] = (2, 3),
    rtt_ms_range: Tuple[float, float] = (4.0, 60.0),
    bandwidth_gbps_range: Tuple[float, float] = (0.4, 2.0),
    flap_probability: float = 0.5,
    straggler_probability: float = 0.5,
    degrade_probability: float = 0.0,
    storm_probability: float = 0.0,
    serving_probability: float = 0.0,
) -> Sweep:
    """Sample a reproducible Monte Carlo campaign as a :class:`Sweep`.

    Every variant draws, from one ``numpy`` Generator seeded with
    ``seed`` (so the campaign — specs *and* results — is a deterministic
    artifact of the seed alone):

    * a topology (``num_pods`` from ``num_pods_choices``);
    * a full per-DC-pair asymmetric WAN: one RTT and bandwidth draw per
      inter-DC fiber bundle (``topology.wan_pairs``);
    * an overlap fraction and per-variant spec seed;
    * optionally a WAN flap script (fail + BFD recovery + restore of one
      sampled spine-pair link) and a straggler mix (sampled slowdown over
      a sampled step span);
    * with ``degrade_probability > 0``, a gray-failure brownout: one
      sampled DC pair quietly loses a sampled bandwidth fraction and
      gains latency (``degrade_pair`` — BFD never fires), restored one
      step later;
    * with ``storm_probability > 0``, a multi-pair flap storm: one
      sampled spine dies whole (``fail_switch`` — every incident link,
      WAN links to *all* peer DCs included, fails atomically through one
      shared detection window), then comes back;
    * with ``serving_probability > 0``, a geo-serving co-load: a sampled
      :class:`~repro.scenario.spec.ServingSpec` (population, per-user
      request rate, remote fraction, per-token KV bytes, its own seed)
      rides the training fabric, adding ``serving_*`` metrics to the row.

    Probability-gated axes draw nothing when their probability is 0, so
    campaigns generated before an axis existed replay byte-identically.
    """
    rng = np.random.default_rng(seed)
    base = base if base is not None else _campaign_base()
    overrides: List[Dict[str, object]] = []
    for i in range(variants):
        num_pods = int(rng.choice(np.asarray(num_pods_choices)))
        wan_pairs = {}
        for a in range(1, num_pods + 1):
            for b in range(a + 1, num_pods + 1):
                rtt = float(rng.uniform(*rtt_ms_range))
                bw = float(rng.uniform(*bandwidth_gbps_range))
                wan_pairs[(a, b)] = NetemProfile(
                    delay_ms=rtt / 2.0, jitter_ms=0.0, bandwidth_gbps=bw
                )
        events: List[ScenarioEvent] = []
        if float(rng.uniform()) < flap_probability:
            a = int(rng.integers(1, num_pods))  # a < b always exists
            b = int(rng.integers(a + 1, num_pods + 1))
            link = (f"d{a}s{int(rng.integers(1, 3))}", f"d{b}s{int(rng.integers(1, 3))}")
            at = int(rng.integers(0, base.workload.steps))
            events.append(ScenarioEvent(kind="fail_link", at_step=at, link=link))
            events.append(ScenarioEvent(kind="restore_link", at_step=at + 1, link=link))
        if float(rng.uniform()) < straggler_probability:
            events.append(
                ScenarioEvent(
                    kind="straggler",
                    at_step=int(rng.integers(0, base.workload.steps)),
                    slowdown=float(rng.uniform(1.5, 4.0)),
                    duration_steps=int(rng.integers(1, base.workload.steps + 1)),
                )
            )
        if degrade_probability > 0 and float(rng.uniform()) < degrade_probability:
            pairs = sorted(wan_pairs)
            pair = pairs[int(rng.integers(0, len(pairs)))]
            at = int(rng.integers(0, base.workload.steps))
            events.append(
                ScenarioEvent(
                    kind="degrade_pair",
                    at_step=at,
                    pair=pair,
                    bandwidth_fraction=float(rng.uniform(0.2, 0.8)),
                    extra_delay_ms=float(rng.uniform(0.0, 10.0)),
                )
            )
            events.append(
                ScenarioEvent(kind="restore_degradation", at_step=at + 1, pair=pair)
            )
        if storm_probability > 0 and float(rng.uniform()) < storm_probability:
            node = f"d{int(rng.integers(1, num_pods + 1))}s{int(rng.integers(1, 3))}"
            at = int(rng.integers(0, base.workload.steps))
            events.append(ScenarioEvent(kind="fail_switch", at_step=at, node=node))
            events.append(
                ScenarioEvent(kind="restore_switch", at_step=at + 1, node=node)
            )
        serving: Optional[ServingSpec] = None
        if serving_probability > 0 and float(rng.uniform()) < serving_probability:
            serving = ServingSpec(
                users=int(rng.integers(50_000, 500_001)),
                requests_per_user_step=float(rng.uniform(2e-6, 2e-5)),
                remote_fraction=float(rng.uniform(0.0, 0.5)),
                kv_bytes_per_token=int(rng.integers(8_192, 65_537)),
                mean_tokens=128,
                session_tokens=1024,
                seed=int(rng.integers(0, 2**31 - 1)),
            )
        overrides.append(
            {
                "name": f"mc{i:03d}_p{num_pods}",
                "topology.num_pods": num_pods,
                "topology.wan_pairs": wan_pairs,
                "topology.seed": int(rng.integers(0, 2**31 - 1)),
                "workload.overlap_fraction": float(rng.choice([0.0, 0.25, 0.5, 0.75, 1.0])),
                "events": tuple(events),
                **({"serving": serving} if serving is not None else {}),
            }
        )
    return Sweep(
        base=base,
        overrides=tuple(overrides),
        name=f"random_campaign_s{seed}",
        seed=seed,
    )
