"""Declarative experiment specs: one value object from topology to metrics.

The paper's contribution is a *reproducible emulation framework* (§4-§5),
but ad-hoc studies fragment fast: every benchmark and example used to
hand-roll its own ``FabricConfig`` + netem + workload + failure-script
builder.  This module makes the whole experiment a single declarative
:class:`Scenario`:

* :class:`TopologySpec` — the emulated deployment: pod/worker counts (or a
  raw :class:`~repro.core.fabric.FabricConfig` override for scaled
  studies), WAN/LAN :class:`~repro.core.wan.NetemProfile`\\ s, QP channel
  count and port scheme, RNG seed;
* :class:`WorkloadSpec` — what trains: a registered strategy name (or a
  :class:`~repro.core.schedule.CollectiveSchedule` built directly),
  gradient bytes (or a ``repro.configs`` model name to derive them from),
  per-step compute and the compute/communication overlap fraction, and how
  many steps to emulate;
* :class:`~repro.core.geo.SyncOptions` — the costing knobs
  (``sync_every`` / ``int8_ratio`` / ``jitter`` / ``congestion`` /
  ``ecmp_weighted``), consolidated from ``GeoFabric.sync_cost``'s historic
  keyword sprawl;
* :class:`ScenarioEvent` — timed control-plane/data-plane events: link
  flaps (BFD- or BGP-detected), tenant attach/detach churn, straggler
  injection.

A :class:`Scenario` round-trips through JSON
(``Scenario.from_dict(s.to_dict()) == s``) so studies are serializable,
diffable artifacts; :func:`repro.scenario.runner.run_scenario` executes one
and returns a :class:`~repro.scenario.runner.ScenarioResult`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

from repro.core.fabric import FabricConfig
from repro.core.geo import GeoFabric, SyncOptions
from repro.core.schedule import CollectiveSchedule
from repro.core.wan import NetemProfile, PAPER_LAN, PAPER_WAN, normalize_wan_pairs

__all__ = [
    "DegradationPolicy",
    "Scenario",
    "ScenarioEvent",
    "ServingSpec",
    "SyncOptions",
    "TopologySpec",
    "WorkloadSpec",
    "model_grad_bytes",
    "model_kv_bytes",
]


def _reject_unknown_keys(cls, d: Dict[str, object]) -> None:
    """Clear error for unknown keys in a spec dict (sweep-override typos
    used to die as a bare ``TypeError`` from ``cls(**d)``)."""
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - fields)
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} key(s) {unknown}; valid: {sorted(fields)}"
        )


def _profile_dict(p: NetemProfile) -> Dict[str, float]:
    return dataclasses.asdict(p)


def _fabric_dict(c: FabricConfig) -> Dict[str, object]:
    d = dataclasses.asdict(c)
    d["hosts_per_leaf"] = [list(t) for t in c.hosts_per_leaf]
    return d


def _fabric_from_dict(d: Dict[str, object]) -> FabricConfig:
    d = dict(d)
    d["hosts_per_leaf"] = tuple(tuple(t) for t in d["hosts_per_leaf"])
    return FabricConfig(**d)


@dataclass(frozen=True)
class TopologySpec:
    """The emulated deployment a scenario runs on.

    ``num_pods``/``workers_per_pod`` build the standard
    :class:`~repro.core.geo.GeoFabric` shape; ``fabric`` overrides it with
    a raw :class:`~repro.core.fabric.FabricConfig` (the 8-DC storm and the
    paper's asymmetric Fig. 1 topology need exact host layouts).
    ``wan_pairs`` assigns one :class:`NetemProfile` per inter-DC fiber
    bundle — ``{(1, 3): NetemProfile(delay_ms=28.0, ...)}`` — resolved by
    :meth:`Netem.profile <repro.core.wan.Netem.profile>` ahead of the
    ``wan`` class default (a dict or pre-normalized entry tuple is
    accepted; it is canonicalized so spec equality and the JSON round-trip
    hold).  ``default_tenant=False`` skips the all-hosts training tenant
    so tenancy scenarios can lay out their own VNIs via events.

    ``srlgs`` declares *shared-risk link groups*: named sets of DC pairs
    whose WAN links ride the same physical conduit (the sovereignty-driven
    shared-fiber reality), so one ``fiber_cut`` event fails them together —
    ``{"coastal": [(1, 2), (1, 3)]}`` (a dict or the canonicalized entry
    tuple; pairs are normalized ``(lo, hi)`` and validated against
    ``num_dcs`` exactly like ``wan_pairs`` keys).
    """

    num_pods: int = 2
    workers_per_pod: int = 2
    wan: NetemProfile = PAPER_WAN
    lan: NetemProfile = PAPER_LAN
    num_channels: int = 4
    port_scheme: str = "qp_aware"
    seed: int = 0
    fabric: Optional[FabricConfig] = None
    default_tenant: bool = True
    wan_pairs: Tuple[Tuple[Tuple[int, int], NetemProfile], ...] = ()
    srlgs: Tuple[Tuple[str, Tuple[Tuple[int, int], ...]], ...] = ()

    def __post_init__(self):
        normalized = normalize_wan_pairs(dict(self.wan_pairs or ()), self.num_dcs)
        object.__setattr__(
            self, "wan_pairs", tuple(sorted(normalized.items()))
        )
        object.__setattr__(self, "srlgs", self._normalize_srlgs(self.srlgs))

    def _normalize_srlgs(
        self, srlgs
    ) -> Tuple[Tuple[str, Tuple[Tuple[int, int], ...]], ...]:
        canon = []
        for name, pairs in sorted(dict(srlgs or ()).items()):
            if not name or not isinstance(name, str):
                raise ValueError(
                    f"SRLG name must be a non-empty string, got {name!r}"
                )
            norm = set()
            for key in pairs:
                i, j = int(key[0]), int(key[1])
                if i == j:
                    raise ValueError(f"SRLG {name!r} entry {key!r} is not a DC pair")
                lo, hi = (i, j) if i < j else (j, i)
                if lo < 1 or hi > self.num_dcs:
                    raise ValueError(
                        f"SRLG {name!r} pair {key!r} outside DCs 1..{self.num_dcs}"
                    )
                norm.add((lo, hi))
            if not norm:
                raise ValueError(f"SRLG {name!r} has no member pairs")
            canon.append((name, tuple(sorted(norm))))
        return tuple(canon)

    def srlg_pairs(self, name: str) -> Tuple[Tuple[int, int], ...]:
        """Member DC pairs of the named shared-risk group."""
        for group, pairs in self.srlgs:
            if group == name:
                return pairs
        known = tuple(g for g, _ in self.srlgs)
        raise ValueError(f"unknown SRLG {name!r}; declared: {known}")

    @property
    def num_dcs(self) -> int:
        return self.fabric.num_dcs if self.fabric is not None else self.num_pods

    def build(self) -> GeoFabric:
        """Materialize the emulated deployment."""
        return GeoFabric(
            self.num_pods,
            self.workers_per_pod,
            wan=self.wan,
            lan=self.lan,
            wan_pairs=dict(self.wan_pairs) or None,
            num_channels=self.num_channels,
            port_scheme=self.port_scheme,
            seed=self.seed,
            config=self.fabric,
            default_tenant="training" if self.default_tenant else None,
        )

    def to_dict(self) -> Dict[str, object]:
        return {
            "num_pods": self.num_pods,
            "workers_per_pod": self.workers_per_pod,
            "wan": _profile_dict(self.wan),
            "lan": _profile_dict(self.lan),
            "num_channels": self.num_channels,
            "port_scheme": self.port_scheme,
            "seed": self.seed,
            "fabric": None if self.fabric is None else _fabric_dict(self.fabric),
            "default_tenant": self.default_tenant,
            "wan_pairs": [
                [list(pair), _profile_dict(p)] for pair, p in self.wan_pairs
            ],
            "srlgs": [
                [name, [list(p) for p in pairs]] for name, pairs in self.srlgs
            ],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "TopologySpec":
        d = dict(d)
        _reject_unknown_keys(cls, d)
        d["wan"] = NetemProfile(**d["wan"])
        d["lan"] = NetemProfile(**d["lan"])
        if d.get("fabric") is not None:
            d["fabric"] = _fabric_from_dict(d["fabric"])
        d["wan_pairs"] = tuple(
            (tuple(pair), NetemProfile(**p)) for pair, p in d.get("wan_pairs", ())
        )
        d["srlgs"] = tuple(
            (name, tuple(tuple(p) for p in pairs))
            for name, pairs in d.get("srlgs", ())
        )
        return cls(**d)


@dataclass(frozen=True)
class WorkloadSpec:
    """What the scenario trains/synchronizes, and for how many steps.

    ``strategy`` is a registered schedule-strategy name, a
    :class:`CollectiveSchedule` built directly (not JSON-serializable),
    or ``None`` for control-plane-only scenarios (tenancy matrices, pure
    flap storms).  Gradient volume comes from ``grad_bytes`` or is
    derived from a ``repro.configs`` model name (fp32 parameter bytes via
    ``jax.eval_shape`` — exact, allocation-free).  ``compute_seconds`` > 0
    turns each step into :meth:`~repro.core.geo.GeoFabric.step_time` with
    ``overlap_fraction`` of compute overlappable; 0 costs pure sync.
    """

    strategy: Union[str, CollectiveSchedule, None] = "allreduce"
    grad_bytes: int = 0
    model: Optional[str] = None
    compute_seconds: float = 0.0
    overlap_fraction: float = 0.0
    steps: int = 1

    def __post_init__(self):
        if self.steps < 0:
            raise ValueError("steps must be >= 0")
        if not 0.0 <= self.overlap_fraction <= 1.0:
            raise ValueError("overlap_fraction must be in [0, 1]")
        if self.compute_seconds < 0:
            raise ValueError("compute_seconds must be >= 0")
        if self.grad_bytes < 0:
            raise ValueError("grad_bytes must be >= 0")

    def resolve_grad_bytes(self) -> int:
        """Gradient bytes: explicit, or fp32 parameter bytes of ``model``."""
        if self.grad_bytes > 0:
            return self.grad_bytes
        if self.model is not None:
            return model_grad_bytes(self.model)
        if isinstance(self.strategy, CollectiveSchedule):
            return 0  # a schedule carries its own flow byte counts
        if self.strategy is None:
            return 0
        raise ValueError(
            f"workload {self.strategy!r} needs grad_bytes > 0 or a model name"
        )

    def to_dict(self) -> Dict[str, object]:
        if isinstance(self.strategy, CollectiveSchedule):
            raise TypeError(
                f"schedule-valued workloads are not JSON-serializable "
                f"(schedule {self.strategy.name!r}); use a registered "
                "strategy name"
            )
        return {
            "strategy": self.strategy,
            "grad_bytes": self.grad_bytes,
            "model": self.model,
            "compute_seconds": self.compute_seconds,
            "overlap_fraction": self.overlap_fraction,
            "steps": self.steps,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "WorkloadSpec":
        _reject_unknown_keys(cls, d)
        return cls(**d)


_MODEL_GRAD_BYTES: Dict[str, int] = {}


def model_grad_bytes(model: str) -> int:
    """fp32 gradient volume of a ``repro.configs`` model (cached)."""
    cached = _MODEL_GRAD_BYTES.get(model)
    if cached is None:
        import jax
        import numpy as np

        from repro.configs import get_config
        from repro.launch.shapes import params_specs

        specs = params_specs(get_config(model))
        cached = int(
            sum(int(np.prod(s.shape)) * 4 for s in jax.tree.leaves(specs))
        )
        _MODEL_GRAD_BYTES[model] = cached
    return cached


_MODEL_KV_BYTES: Dict[str, int] = {}


def model_kv_bytes(model: str, tokens: int = 1) -> int:
    """Decode-cache (KV / recurrent-state) bytes a served session holds
    per context token, times ``tokens`` (cached per model).

    Mirrors :func:`model_grad_bytes`: exact and allocation-free via
    ``jax.eval_shape`` over the model's ``decode_32k`` cache layout,
    amortized to per-token bytes.  Recurrent/RWKV layers hold O(1) state
    independent of context length, so their per-token share is tiny —
    exactly the serving-cost asymmetry sub-quadratic archs buy.
    """
    if tokens < 0:
        raise ValueError("tokens must be >= 0")
    per_token = _MODEL_KV_BYTES.get(model)
    if per_token is None:
        import jax
        import numpy as np

        from repro.configs import get_config
        from repro.launch.shapes import SHAPES, decode_cache_specs

        spec = SHAPES["decode_32k"]
        cache = decode_cache_specs(get_config(model), "decode_32k")
        total = sum(
            int(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree.leaves(cache)
        )
        per_token = max(total // (spec.global_batch * spec.seq_len), 1)
        _MODEL_KV_BYTES[model] = per_token
    return per_token * int(tokens)


@dataclass(frozen=True)
class ServingSpec:
    """Geo-serving co-load: open-loop inference traffic on the training fabric.

    **Request generation** (``repro.serving.traffic``): each DC hosts a
    pinned user population (``users_per_dc``, or ``users`` split evenly —
    the data-sovereignty assumption), producing Poisson arrivals per step
    at ``requests_per_user_step``, modulated by a sinusoidal diurnal curve
    whose peak rotates across DCs (time zones), with heavy-tailed
    (lognormal/Pareto) per-request token counts.  The whole trace is a
    pure function of this spec and ``seed``, so serving results are
    byte-identical across sweep worker counts.

    **KV sizing**: each request moves ``tokens * kv_bytes_per_token``
    bytes (the prefill -> decode-host cache handoff) and a migrated
    session moves ``session_tokens * kv_bytes_per_token``.  Per-token
    bytes come explicitly or from a ``repro.configs`` model name via
    :func:`model_kv_bytes` (the ``grad_bytes``/``model`` duality of
    :class:`WorkloadSpec`).

    **Affinity + failover** (``repro.serving.router``): sessions are
    sticky to their home DC; ``remote_fraction`` of users are steadily
    served cross-DC (the traffic class WAN brownouts actually hurt).  With
    ``failover=True`` the router re-homes a session when its serving pair
    trips an :class:`~repro.core.slaprobe.SlaProbe` (or, without probes,
    when a ``degrade_pair`` lands or the pair partitions), paying the
    session's KV migration bytes over the WAN.

    Requests whose modeled latency exceeds ``slo_ms`` are SLO misses;
    goodput is reported as ``serving_slo_miss_frac`` (lower is better).
    """

    users: int = 1_000_000
    users_per_dc: Tuple[int, ...] = ()
    requests_per_user_step: float = 8e-6
    diurnal_amplitude: float = 0.5
    diurnal_period_steps: int = 24
    tail: str = "lognormal"
    tail_sigma: float = 0.8
    tail_alpha: float = 2.5
    mean_tokens: int = 256
    session_tokens: int = 2048
    model: Optional[str] = None
    kv_bytes_per_token: int = 0
    remote_fraction: float = 0.0
    slo_ms: float = 250.0
    failover: bool = True
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "users_per_dc", tuple(int(u) for u in self.users_per_dc)
        )
        if self.users < 0 or any(u < 0 for u in self.users_per_dc):
            raise ValueError("user populations must be >= 0")
        if self.requests_per_user_step < 0:
            raise ValueError("requests_per_user_step must be >= 0")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1]")
        if self.diurnal_period_steps < 1:
            raise ValueError("diurnal_period_steps must be >= 1")
        if self.tail not in ("lognormal", "pareto"):
            raise ValueError(
                f"tail must be 'lognormal' or 'pareto', got {self.tail!r}"
            )
        if self.tail_sigma <= 0:
            raise ValueError("tail_sigma must be > 0")
        if self.tail_alpha <= 1.0:
            raise ValueError("tail_alpha must be > 1 (finite mean)")
        if self.mean_tokens < 1:
            raise ValueError("mean_tokens must be >= 1")
        if self.session_tokens < 0:
            raise ValueError("session_tokens must be >= 0")
        if not 0.0 <= self.remote_fraction <= 1.0:
            raise ValueError("remote_fraction must be in [0, 1]")
        if self.slo_ms <= 0:
            raise ValueError("slo_ms must be > 0")
        if self.kv_bytes_per_token < 0:
            raise ValueError("kv_bytes_per_token must be >= 0")

    def resolve_kv_bytes_per_token(self) -> int:
        """Per-token KV bytes: explicit, or derived from ``model``."""
        if self.kv_bytes_per_token > 0:
            return self.kv_bytes_per_token
        if self.model is not None:
            return model_kv_bytes(self.model)
        raise ValueError(
            "ServingSpec needs kv_bytes_per_token > 0 or a model name"
        )

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["users_per_dc"] = list(self.users_per_dc)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ServingSpec":
        d = dict(d)
        _reject_unknown_keys(cls, d)
        d["users_per_dc"] = tuple(d.get("users_per_dc", ()))
        return cls(**d)


#: The event kinds :func:`repro.scenario.runner.run_scenario` executes.
EVENT_KINDS = (
    "fail_link",            # BFD/BGP-detected link failure -> RecoveryTimeline
    "restore_link",         # link comes back -> incremental reroute + EVPN resync
    "tenant_attach",        # attach host to tenant (created on first use)
    "tenant_detach",        # withdraw the host's Type-2 routes fabric-wide
    "straggler",            # multiply compute_seconds for duration_steps steps
    "degrade_link",         # gray failure: brownout one link's NetemProfile
    "degrade_pair",         # gray failure: brownout one DC pair's fiber bundle
    "restore_degradation",  # lift a degrade_link/degrade_pair exactly
    "fail_switch",          # atomic multi-link failure of a spine/leaf switch
    "restore_switch",       # bring the switch's failed links back
    "fiber_cut",            # SRLG cut: fail every member pair's WAN links atomically
    "fiber_restore",        # bring the SRLG's links back
    "pod_fail",             # pod stops heartbeating -> detect/restore/remesh chain
)


@dataclass(frozen=True)
class ScenarioEvent:
    """One timed event; fields beyond ``kind``/``at_step`` are per-kind.

    ``fail_link``/``restore_link`` need ``link`` (and ``mechanism`` for
    failures: ``"bfd"`` | ``"bgp"``); ``tenant_attach`` needs ``tenant``,
    ``host`` and — when the tenant does not exist yet — ``vni``;
    ``tenant_detach`` needs ``tenant`` + ``host``; ``straggler`` needs
    ``slowdown`` (compute multiplier) and ``duration_steps``.

    Gray-failure kinds: ``degrade_link`` needs ``link``, ``degrade_pair``
    needs ``pair`` — both take ``bandwidth_fraction`` (brownout),
    ``extra_delay_ms`` (latency inflation) and ``extra_loss`` (loss
    spike), applied through the :meth:`Netem.profile
    <repro.core.wan.Netem.profile>` resolver mid-run;
    ``restore_degradation`` needs exactly one of ``link``/``pair``.
    ``fail_switch``/``restore_switch`` need ``node`` (a spine/leaf name);
    ``fiber_cut``/``fiber_restore`` need ``srlg`` (declared in
    ``TopologySpec.srlgs``); ``pod_fail`` needs ``pod`` (1-based DC
    index).
    """

    kind: str
    at_step: int = 0
    link: Optional[Tuple[str, str]] = None
    mechanism: str = "bfd"
    tenant: Optional[str] = None
    vni: Optional[int] = None
    host: Optional[str] = None
    slowdown: float = 1.0
    duration_steps: int = 1
    pair: Optional[Tuple[int, int]] = None
    bandwidth_fraction: float = 1.0
    extra_delay_ms: float = 0.0
    extra_loss: float = 0.0
    node: Optional[str] = None
    srlg: Optional[str] = None
    pod: Optional[int] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; one of {EVENT_KINDS}")
        if self.at_step < 0:
            raise ValueError("at_step must be >= 0")
        if self.link is not None:
            object.__setattr__(self, "link", tuple(self.link))
        if self.pair is not None:
            i, j = int(self.pair[0]), int(self.pair[1])
            if i == j:
                raise ValueError(f"event pair {self.pair!r} is not a DC pair")
            object.__setattr__(self, "pair", (i, j) if i < j else (j, i))
        if self.kind in ("fail_link", "restore_link", "degrade_link") and self.link is None:
            raise ValueError(f"{self.kind} event needs a link")
        if self.kind == "degrade_pair" and self.pair is None:
            raise ValueError("degrade_pair event needs a pair")
        if self.kind == "restore_degradation" and (self.link is None) == (self.pair is None):
            raise ValueError(
                "restore_degradation event needs exactly one of link/pair"
            )
        if self.kind in ("degrade_link", "degrade_pair"):
            if not 0.0 < self.bandwidth_fraction <= 1.0:
                raise ValueError("bandwidth_fraction must be in (0, 1]")
            if self.extra_delay_ms < 0.0:
                raise ValueError("extra_delay_ms must be >= 0")
            if not 0.0 <= self.extra_loss < 1.0:
                raise ValueError("extra_loss must be in [0, 1)")
        if self.kind in ("fail_switch", "restore_switch") and self.node is None:
            raise ValueError(f"{self.kind} event needs a node")
        if self.kind in ("fiber_cut", "fiber_restore") and self.srlg is None:
            raise ValueError(f"{self.kind} event needs an srlg name")
        if self.kind == "pod_fail" and (self.pod is None or self.pod < 1):
            raise ValueError("pod_fail event needs a pod index >= 1")
        if self.kind in ("tenant_attach", "tenant_detach") and (
            self.tenant is None or self.host is None
        ):
            raise ValueError(f"{self.kind} event needs tenant and host")
        if self.kind == "straggler":
            if self.slowdown < 1.0:
                raise ValueError("straggler slowdown must be >= 1.0")
            if self.duration_steps < 1:
                raise ValueError("straggler duration_steps must be >= 1")

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["link"] = None if self.link is None else list(self.link)
        d["pair"] = None if self.pair is None else list(self.pair)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ScenarioEvent":
        d = dict(d)
        _reject_unknown_keys(cls, d)
        if d.get("link") is not None:
            d["link"] = tuple(d["link"])
        if d.get("pair") is not None:
            d["pair"] = tuple(d["pair"])
        return cls(**d)


@dataclass(frozen=True)
class DegradationPolicy:
    """How a scenario detects gray failures and gracefully degrades.

    **Detection** (the :class:`~repro.core.slaprobe.SlaProbeBank` knobs):
    per-DC-pair probes calibrate against the healthy baseline and trip
    when the observed WAN rate falls below ``rate_floor_frac`` of it or
    the leader RTT exceeds ``rtt_ceiling_frac`` times it, for
    ``trip_after`` consecutive steps; ``recover_after`` clean steps
    recover (hysteresis both ways).

    **Adaptation** while any probe is DEGRADED (applied from the *next*
    step — detect, then react): switch to ``fallback_strategy`` (any
    :func:`repro.core.schedule.register_strategy` name, e.g. ``hier`` to
    concentrate WAN traffic on leaders), raise the sync period to
    ``degraded_sync_every``, and/or engage int8 WAN compression
    (``int8_wan`` — gradient bytes scaled by the options' ``int8_ratio``,
    the :mod:`repro.distributed.compression` wire format).

    **Pod-loss recovery pricing** (the HeartbeatMonitor -> checkpoint ->
    remesh chain): heartbeat cadence/multiplier, the periodic checkpoint
    cadence that bounds lost work, and restore/remesh cost constants fed
    to :func:`repro.runtime.failure.plan_recovery`.
    """

    rate_floor_frac: float = 0.5
    rtt_ceiling_frac: float = 2.0
    trip_after: int = 2
    recover_after: int = 2
    fallback_strategy: Optional[str] = None
    degraded_sync_every: Optional[int] = None
    int8_wan: bool = False
    heartbeat_interval_ms: float = 100.0
    heartbeat_detect_mult: int = 3
    checkpoint_every: int = 4
    restore_bandwidth_gbps: float = 10.0
    remesh_s: float = 30.0

    def __post_init__(self):
        if not 0.0 <= self.rate_floor_frac <= 1.0:
            raise ValueError("rate_floor_frac must be in [0, 1]")
        if self.rtt_ceiling_frac < 1.0:
            raise ValueError("rtt_ceiling_frac must be >= 1")
        if self.trip_after < 1 or self.recover_after < 1:
            raise ValueError("trip_after/recover_after must be >= 1")
        if self.degraded_sync_every is not None and self.degraded_sync_every < 1:
            raise ValueError("degraded_sync_every must be >= 1")
        if self.heartbeat_interval_ms <= 0:
            raise ValueError("heartbeat_interval_ms must be > 0")
        if self.heartbeat_detect_mult < 1:
            raise ValueError("heartbeat_detect_mult must be >= 1")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.restore_bandwidth_gbps <= 0:
            raise ValueError("restore_bandwidth_gbps must be > 0")
        if self.remesh_s < 0:
            raise ValueError("remesh_s must be >= 0")

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "DegradationPolicy":
        _reject_unknown_keys(cls, d)
        return cls(**d)


@dataclass(frozen=True)
class Scenario:
    """One complete, declarative experiment: topology + workload + options
    + events.  ``run_scenario(scenario)`` executes it; ``to_dict`` /
    ``from_dict`` round-trip through JSON losslessly (identity is pinned
    in ``tests/test_scenario.py``)."""

    name: str
    topology: TopologySpec = field(default_factory=TopologySpec)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    options: SyncOptions = field(default_factory=SyncOptions)
    events: Tuple[ScenarioEvent, ...] = ()
    description: str = ""
    #: gray-failure detection + graceful degradation; None (the default)
    #: keeps the runner's historical behavior byte-for-byte
    policy: Optional[DegradationPolicy] = None
    #: geo-serving co-load on the same fabric; None (the default) keeps
    #: the runner's costing path byte-for-byte
    serving: Optional[ServingSpec] = None

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if not self.name:
            raise ValueError("scenario name must be non-empty")

    @property
    def num_steps(self) -> int:
        """Steps the runner emulates: the workload's, extended to cover
        every event."""
        last_event = max((e.at_step for e in self.events), default=-1)
        return max(self.workload.steps, last_event + 1)

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "topology": self.topology.to_dict(),
            "workload": self.workload.to_dict(),
            "options": self.options.to_dict(),
            "events": [e.to_dict() for e in self.events],
            "description": self.description,
            "policy": None if self.policy is None else self.policy.to_dict(),
            "serving": None if self.serving is None else self.serving.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "Scenario":
        _reject_unknown_keys(cls, d)
        policy = d.get("policy")
        serving = d.get("serving")
        return cls(
            name=d["name"],
            topology=TopologySpec.from_dict(d["topology"]),
            workload=WorkloadSpec.from_dict(d["workload"]),
            options=SyncOptions.from_dict(d["options"]),
            events=tuple(ScenarioEvent.from_dict(e) for e in d["events"]),
            description=d.get("description", ""),
            policy=None if policy is None else DegradationPolicy.from_dict(policy),
            serving=None if serving is None else ServingSpec.from_dict(serving),
        )
