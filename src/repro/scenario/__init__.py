"""Declarative Scenario/Experiment API: one spec from topology to metrics.

The experiment surface of the reproduction, consolidated (ISSUE 5): a
:class:`Scenario` declares the emulated topology, the training workload,
the costing options (:class:`~repro.core.geo.SyncOptions`) and a timed
event script (link flaps, tenant churn, stragglers);
:func:`run_scenario` executes it into a :class:`ScenarioResult` with a
per-step timeline and ``SyncCost`` / ``RecoveryTimeline`` /
``EvpnResyncStats`` rollups, JSON-serializable and gate-able by
``benchmarks/compare.py``.  The named library (:mod:`.library`) ships the
paper's §5 studies, so a new study is a spec edit::

    from repro.scenario import get_scenario, run_scenario

    result = run_scenario(get_scenario("fig14_allreduce"))
    print(result.sync.wan_seconds, result.metrics())
"""

from repro.core.geo import SyncOptions
from repro.scenario.library import (
    get_scenario,
    register_scenario,
    scenario_names,
)
from repro.scenario.runner import ScenarioResult, StepRecord, run_scenario
from repro.scenario.spec import (
    EVENT_KINDS,
    Scenario,
    ScenarioEvent,
    TopologySpec,
    WorkloadSpec,
    model_grad_bytes,
)

__all__ = [
    "EVENT_KINDS",
    "Scenario",
    "ScenarioEvent",
    "ScenarioResult",
    "StepRecord",
    "SyncOptions",
    "TopologySpec",
    "WorkloadSpec",
    "get_scenario",
    "model_grad_bytes",
    "register_scenario",
    "run_scenario",
    "scenario_names",
]
