"""Declarative Scenario/Experiment API: one spec from topology to metrics.

The experiment surface of the reproduction, consolidated (ISSUE 5): a
:class:`Scenario` declares the emulated topology, the training workload,
the costing options (:class:`~repro.core.geo.SyncOptions`) and a timed
event script (link flaps, tenant churn, stragglers);
:func:`run_scenario` executes it into a :class:`ScenarioResult` with a
per-step timeline and ``SyncCost`` / ``RecoveryTimeline`` /
``EvpnResyncStats`` rollups, JSON-serializable and gate-able by
``benchmarks/compare.py``.  The named library (:mod:`.library`) ships the
paper's §5 studies, so a new study is a spec edit::

    from repro.scenario import get_scenario, run_scenario

    result = run_scenario(get_scenario("fig14_allreduce"))
    print(result.sync.wan_seconds, result.metrics())

The sweep/campaign engine (:mod:`.sweep`, ISSUE 6) scales one spec to a
fleet: a :class:`Sweep` expands dotted-field overrides into variants and
joins their metrics into one gateable table (optionally over a process
pool), and :func:`random_campaign` samples reproducible Monte Carlo
campaigns over asymmetric per-DC-pair WANs::

    from repro.scenario import fiber_latency_campaign

    table = fiber_latency_campaign().run(workers=4)
"""

from repro.core.geo import SyncOptions
from repro.scenario.library import (
    get_scenario,
    register_scenario,
    scenario_names,
)
from repro.scenario.runner import (
    PodRecovery,
    ScenarioResult,
    StepRecord,
    run_scenario,
)
from repro.scenario.spec import (
    EVENT_KINDS,
    DegradationPolicy,
    Scenario,
    ScenarioEvent,
    ServingSpec,
    TopologySpec,
    WorkloadSpec,
    model_grad_bytes,
    model_kv_bytes,
)
from repro.scenario.sweep import (
    Sweep,
    SweepResult,
    SweepRow,
    apply_overrides,
    fiber_latency_campaign,
    random_campaign,
    run_sweep,
)

__all__ = [
    "EVENT_KINDS",
    "DegradationPolicy",
    "PodRecovery",
    "Scenario",
    "ScenarioEvent",
    "ScenarioResult",
    "ServingSpec",
    "StepRecord",
    "Sweep",
    "SweepResult",
    "SweepRow",
    "SyncOptions",
    "TopologySpec",
    "WorkloadSpec",
    "apply_overrides",
    "fiber_latency_campaign",
    "get_scenario",
    "model_grad_bytes",
    "model_kv_bytes",
    "random_campaign",
    "register_scenario",
    "run_scenario",
    "run_sweep",
    "scenario_names",
]
