"""Execute a declarative :class:`~repro.scenario.spec.Scenario`.

``run_scenario`` builds the emulated deployment from the spec, replays the
event script step by step (link flaps through the BFD/BGP failure
detector — which drives the fabric's incremental re-convergence and the
EVPN incremental resync — tenant churn through the tenancy manager,
straggler injection into the compute term), costs every training step with
the spec's :class:`~repro.core.geo.SyncOptions`, and returns a
:class:`ScenarioResult`:

* a per-step timeline (modeled seconds, WAN sync seconds, straggler
  factor, the events that fired);
* rollups of the three observability records the substrate already emits —
  :class:`~repro.core.geo.SyncCost` (a deterministic jitter-free
  representative), :class:`~repro.core.bfd.RecoveryTimeline` per failure,
  :class:`~repro.core.evpn.EvpnResyncStats` per control-plane resync;
* ``metrics()`` — the flat deterministic observables the CI baseline gate
  (``benchmarks/compare.py``) consumes — and ``to_dict()`` — the full
  JSON-serializable record.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.bfd import RecoveryTimeline
from repro.core.evpn import EvpnResyncStats
from repro.core.fabric import RerouteStats, UnreachableError
from repro.core.geo import GeoFabric, SyncCost
from repro.core.schedule import build_schedule, with_compute_overlap
from repro.core.slaprobe import ProbeState, ProbeTransition, SlaProbeBank
from repro.scenario.spec import DegradationPolicy, Scenario, ScenarioEvent

__all__ = [
    "PodRecovery",
    "ScenarioResult",
    "StepRecord",
    "apply_event",
    "run_scenario",
]


@dataclass(frozen=True)
class StepRecord:
    """One emulated training step of a scenario."""

    step: int
    seconds: float  # modeled wall time of the step (compute + exposed sync)
    sync_seconds: float  # the step's WAN sync term (amortized)
    compute_seconds: float  # compute term after straggler scaling
    straggler_factor: float
    events: Tuple[str, ...] = ()  # kinds of the events that fired this step
    strategy: str = ""  # schedule actually costed (policy may have switched it)
    degraded: bool = False  # was a DegradationPolicy adaptation active?
    downtime_seconds: float = 0.0  # pod-loss detect/restore/remesh paid this step

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["events"] = list(self.events)
        return d


@dataclass(frozen=True)
class PodRecovery:
    """One priced pod-loss episode: heartbeat detection -> checkpoint
    restore -> elastic remesh, as the runner executed it."""

    pod: int
    failed_at_step: int
    detected_at_step: int
    plan: object  # repro.runtime.failure.RecoveryPlan
    mesh: object  # repro.runtime.elastic.MeshPlan

    def to_dict(self) -> Dict[str, object]:
        return {
            "pod": self.pod,
            "failed_at_step": self.failed_at_step,
            "detected_at_step": self.detected_at_step,
            "detection_s": float(self.plan.detection_s),
            "restore_s": float(self.plan.restore_s),
            "remesh_s": float(self.plan.remesh_s),
            "lost_steps": int(self.plan.lost_steps),
            "lost_work_s": float(self.plan.lost_work_s),
            "total_downtime_s": float(self.plan.total_downtime_s),
            "total_cost_s": float(self.plan.total_cost_s),
            "mesh": self.mesh.to_dict(),
        }


def _sync_cost_dict(c: SyncCost) -> Dict[str, object]:
    return {
        "strategy": c.strategy,
        "wan_seconds": float(c.wan_seconds),
        "amortized_seconds": float(c.amortized_seconds),
        "wan_bytes": int(c.wan_bytes),
        "sync_every": int(c.sync_every),
        "bottleneck_link": None if c.bottleneck_link is None else list(c.bottleneck_link),
        "bottleneck_bytes": int(c.bottleneck_bytes),
        "bottleneck_utilization": float(c.bottleneck_utilization),
        "load_factor": float(c.load.load_factor),
        "phases": [
            {
                "name": p.name,
                "start_s": float(p.start_s),
                "end_s": float(p.end_s),
                "wan_bytes": int(p.wan_bytes),
            }
            for p in c.phases
        ],
    }


def _recovery_dict(t: RecoveryTimeline) -> Dict[str, object]:
    return {
        "mechanism": t.mechanism,
        "recovery_ms": float(t.recovery_ms),
        "detect_ms": float(t.detected_at_ms - t.failure_at_ms),
    }


def _resync_dict(s: EvpnResyncStats) -> Dict[str, object]:
    return {
        "link": list(s.link),
        "action": s.action,
        "patched": s.patched,
        "rebuilt": s.rebuilt,
        "retained": s.retained,
        "vtep_touched_frac": float(s.vtep_touched_frac),
    }


@dataclass
class ScenarioResult:
    """Everything one scenario run produced.

    ``geo`` is the live emulated deployment (post-events) so thin bench
    wrappers can keep probing it; it is deliberately absent from
    ``to_dict()``.
    """

    scenario: Scenario
    steps: List[StepRecord]
    sync: Optional[SyncCost]  # jitter-free representative sync cost
    recoveries: List[RecoveryTimeline] = field(default_factory=list)
    reroutes: List[RerouteStats] = field(default_factory=list)
    evpn_resyncs: List[EvpnResyncStats] = field(default_factory=list)
    geo: Optional[GeoFabric] = None
    probe_transitions: List[ProbeTransition] = field(default_factory=list)
    pod_recoveries: List[PodRecovery] = field(default_factory=list)
    #: (at_step, pod) per pod_fail event, recorded by apply_event so the
    #: trainer's replay sees them too; the runner's heartbeat loop prices them
    pod_failures: List[Tuple[int, int]] = field(default_factory=list)
    #: per-step ServingStepStats when the scenario carries a ServingSpec
    serving_steps: List = field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return float(sum(s.seconds for s in self.steps))

    @property
    def mean_step_seconds(self) -> float:
        return self.total_seconds / len(self.steps) if self.steps else 0.0

    @property
    def evpn_mean_touched_frac(self) -> float:
        if not self.evpn_resyncs:
            return 0.0
        return float(
            sum(s.vtep_touched_frac for s in self.evpn_resyncs)
            / len(self.evpn_resyncs)
        )

    def metrics(self) -> Dict[str, float]:
        """Deterministic gated observables for ``benchmarks/compare.py``.

        Only seeded model outputs belong here (the compare-gate contract
        of ``benchmarks/common.py``); wall-clock never does.  Keys follow
        the direction-by-suffix convention (``*_seconds``/``*_frac`` lower
        is better, etc.).
        """
        out: Dict[str, float] = {}
        if self.steps:
            out["total_step_seconds"] = self.total_seconds
            out["mean_step_seconds"] = self.mean_step_seconds
        if self.sync is not None:
            out["sync_wan_seconds"] = float(self.sync.wan_seconds)
            out["sync_wan_bytes"] = float(self.sync.wan_bytes)
        if self.recoveries:
            out["mean_recovery_ms"] = float(
                sum(t.recovery_ms for t in self.recoveries) / len(self.recoveries)
            )
        if self.evpn_resyncs:
            out["evpn_mean_touched_frac"] = self.evpn_mean_touched_frac
        if self.probe_transitions:
            out["probe_trip_count"] = float(
                sum(1 for t in self.probe_transitions if t.state == ProbeState.DEGRADED)
            )
            trips = [
                t.at_ms for t in self.probe_transitions
                if t.state == ProbeState.DEGRADED
            ]
            if trips:
                out["probe_first_trip_ms"] = float(min(trips))
        if self.pod_recoveries:
            out["pod_lost_work_seconds"] = float(
                sum(r.plan.lost_work_s for r in self.pod_recoveries)
            )
            out["pod_downtime_seconds"] = float(
                sum(r.plan.total_downtime_s for r in self.pod_recoveries)
            )
            out["pod_total_cost_seconds"] = float(
                sum(r.plan.total_cost_s for r in self.pod_recoveries)
            )
        if self.serving_steps:
            import numpy as np

            requests = sum(s.requests for s in self.serving_steps)
            out["serving_requests"] = float(requests)
            lat = [l for s in self.serving_steps for l in s.latencies_ms]
            if lat:
                out["serving_p50_ms"] = float(np.percentile(lat, 50))
                out["serving_p99_ms"] = float(np.percentile(lat, 99))
            misses = sum(s.slo_misses for s in self.serving_steps)
            out["serving_slo_miss_frac"] = (
                float(misses) / requests if requests else 0.0
            )
            out["serving_migrated_sessions"] = float(
                sum(s.migrated_sessions for s in self.serving_steps)
            )
            out["serving_migration_bytes"] = float(
                sum(s.migration_bytes for s in self.serving_steps)
            )
        return out

    def to_dict(self) -> Dict[str, object]:
        return {
            "scenario": self.scenario.to_dict(),
            "steps": [s.to_dict() for s in self.steps],
            "sync": None if self.sync is None else _sync_cost_dict(self.sync),
            "recoveries": [_recovery_dict(t) for t in self.recoveries],
            "evpn_resyncs": [_resync_dict(s) for s in self.evpn_resyncs],
            "probe_transitions": [t.to_dict() for t in self.probe_transitions],
            "pod_recoveries": [r.to_dict() for r in self.pod_recoveries],
            "serving_steps": [s.to_dict() for s in self.serving_steps],
            "metrics": self.metrics(),
            "total_seconds": self.total_seconds,
        }


def _switch_links(geo: GeoFabric, node: str, *, down: bool) -> List[Tuple[str, str]]:
    """Links incident to ``node``, filtered by current state, sorted."""
    links = [
        tuple(sorted(l))
        for l in geo.fabric.all_links()
        if node in l and geo.fabric.link_up(*l) != down
    ]
    if not links and not any(node in l for l in geo.fabric.all_links()):
        raise ValueError(f"no links incident to node {node!r}")
    return sorted(links)


def _srlg_links(
    geo: GeoFabric, pairs: Tuple[Tuple[int, int], ...], *, down: bool
) -> List[Tuple[str, str]]:
    """WAN links of the SRLG's member DC pairs, filtered by state, sorted."""
    members = set(pairs)
    return sorted(
        tuple(sorted(l))
        for l in geo.fabric.wan_links
        if geo.fabric.wan_pair(*l) in members and geo.fabric.link_up(*l) != down
    )


def _apply_group_failure(
    geo: GeoFabric,
    result: ScenarioResult,
    links: List[Tuple[str, str]],
    *,
    mechanism: str,
    label: str,
) -> None:
    timeline, reroutes, resyncs = geo.detector.fail_group(
        links, mechanism=mechanism, label=label
    )
    result.recoveries.append(timeline)
    result.reroutes.extend(reroutes)
    result.evpn_resyncs.extend(resyncs)


def apply_event(
    event: ScenarioEvent,
    geo: GeoFabric,
    result: ScenarioResult,
    straggler: Dict[int, float],
) -> None:
    """Apply one :class:`ScenarioEvent` to a live deployment.

    Rollups (recovery timelines, reroute stats, EVPN resyncs) accumulate
    on ``result``; straggler multipliers accumulate per step index in
    ``straggler``.  Shared by :func:`run_scenario` and the scenario-driven
    :class:`repro.runtime.trainer.GeoTrainer`, so both replay an event
    script with identical semantics.
    """
    if event.kind == "fail_link":
        timeline = geo.detector.fail_and_recover(
            tuple(event.link), mechanism=event.mechanism
        )
        result.recoveries.append(timeline)
        if timeline.reroute is not None:
            result.reroutes.append(timeline.reroute)
        if timeline.evpn_resync is not None:
            result.evpn_resyncs.append(timeline.evpn_resync)
    elif event.kind == "restore_link":
        stats = geo.detector.restore(tuple(event.link))
        result.reroutes.append(stats)
        if geo.evpn.last_resync is not None:
            result.evpn_resyncs.append(geo.evpn.last_resync)
    elif event.kind == "tenant_attach":
        if event.tenant not in geo.tenancy.tenants:
            if event.vni is None:
                raise ValueError(
                    f"tenant_attach for new tenant {event.tenant!r} needs a vni"
                )
            geo.tenancy.create_tenant(event.tenant, vni=event.vni)
        geo.tenancy.attach(event.tenant, event.host)
    elif event.kind == "tenant_detach":
        geo.tenancy.detach(event.tenant, event.host)
    elif event.kind == "straggler":
        for s in range(event.at_step, event.at_step + event.duration_steps):
            straggler[s] = straggler.get(s, 1.0) * event.slowdown
    elif event.kind == "degrade_link":
        geo.netem.degrade_link(
            *event.link,
            bandwidth_fraction=event.bandwidth_fraction,
            extra_delay_ms=event.extra_delay_ms,
            extra_loss=event.extra_loss,
        )
    elif event.kind == "degrade_pair":
        geo.netem.degrade_pair(
            *event.pair,
            bandwidth_fraction=event.bandwidth_fraction,
            extra_delay_ms=event.extra_delay_ms,
            extra_loss=event.extra_loss,
        )
    elif event.kind == "restore_degradation":
        if event.link is not None:
            geo.netem.restore_link_profile(*event.link)
        else:
            geo.netem.restore_pair(*event.pair)
    elif event.kind == "fail_switch":
        links = _switch_links(geo, event.node, down=False)
        if links:
            _apply_group_failure(
                geo, result, links,
                mechanism=event.mechanism,
                label=f"switch {event.node} down",
            )
    elif event.kind == "restore_switch":
        down = _switch_links(geo, event.node, down=True)
        result.reroutes.extend(geo.detector.restore_group(down))
    elif event.kind == "fiber_cut":
        pairs = result.scenario.topology.srlg_pairs(event.srlg)
        links = _srlg_links(geo, pairs, down=False)
        if links:
            _apply_group_failure(
                geo, result, links,
                mechanism=event.mechanism,
                label=f"SRLG {event.srlg} cut ({len(pairs)} DC pairs)",
            )
    elif event.kind == "fiber_restore":
        pairs = result.scenario.topology.srlg_pairs(event.srlg)
        down = _srlg_links(geo, pairs, down=True)
        result.reroutes.extend(geo.detector.restore_group(down))
    elif event.kind == "pod_fail":
        if event.pod > geo.num_pods:
            raise ValueError(
                f"pod_fail pod {event.pod} outside pods 1..{geo.num_pods}"
            )
        result.pod_failures.append((event.at_step, int(event.pod)))
    else:  # pragma: no cover - spec validation rejects unknown kinds
        raise ValueError(f"unknown event kind {event.kind!r}")


def _wan_window_s(phases, fallback_s: float) -> float:
    """Span of the schedule's WAN-carrying phases (the comm observation
    window an SLA probe rates bytes against) — excludes a grafted compute
    head, so overlapped and pure-sync steps measure consistently."""
    spans = [(p.start_s, p.end_s) for p in phases if p.wan_bytes > 0]
    if not spans:
        return float(fallback_s)
    return max(e for _, e in spans) - min(s for s, _ in spans)


def _pair_rates(
    geo: GeoFabric, phases, fallback_s: float
) -> Dict[Tuple[int, int], float]:
    """Observed per-DC-pair WAN rate (Gbit/s) of the last costed schedule,
    from the fabric's routed byte counters and the comm window."""
    window = _wan_window_s(phases, fallback_s)
    if window <= 0.0:
        return {}
    pair_bytes: Dict[Tuple[int, int], int] = {}
    for (u, v), b in geo.fabric.link_bytes.items():
        if b and geo.fabric.is_wan_link(u, v):
            pair = geo.fabric.wan_pair(u, v)
            pair_bytes[pair] = pair_bytes.get(pair, 0) + b
    return {p: b * 8.0 / (window * 1e9) for p, b in pair_bytes.items()}


def _pair_rtt_ms(geo: GeoFabric, pair: Tuple[int, int]) -> float:
    """Jitter-free leader RTT of a DC pair; inf when partitioned."""
    leaders = geo.pod_leaders()
    try:
        return geo.netem.base_rtt_ms(leaders[pair[0] - 1], leaders[pair[1] - 1])
    except UnreachableError:
        return math.inf


def _fabric_health(geo: GeoFabric, probes: Optional[SlaProbeBank], dead_pods):
    """The serving router's per-step view of the fabric.

    A pair is bad when it is partitioned, when its SLA probe is tripped
    (scenarios with a :class:`DegradationPolicy` — detection with
    hysteresis, the realistic signal), or — probe-less — when ``Netem``
    currently degrades it (ground truth, reaction without detection lag).
    """
    from repro.serving.router import FabricHealth

    alive = frozenset(
        p for p in range(1, geo.num_pods + 1) if p not in dead_pods
    )
    rtt: Dict[Tuple[int, int], float] = {}
    bad: set = set()
    for a in range(1, geo.num_pods + 1):
        for b in range(a + 1, geo.num_pods + 1):
            r = _pair_rtt_ms(geo, (a, b))
            rtt[(a, b)] = r
            if r == math.inf:
                bad.add((a, b))
    if probes is not None:
        bad.update(probes.tripped())
    else:
        bad.update(geo.netem.degraded_pairs)
    return FabricHealth(alive=alive, bad_pairs=frozenset(bad), rtt_ms=rtt)


def _serving_step(
    engine,
    geo: GeoFabric,
    step: int,
    *,
    training_active: bool,
    strategy,
    grad_bytes: int,
    compute: float,
    policy: Optional[DegradationPolicy],
    options,
    overlap_fraction: float,
    degraded: bool,
    dead_pods,
    probes: Optional[SlaProbeBank],
    baseline_rates: Dict[Tuple[int, int], float],
):
    """Cost one step with serving co-load: route the step's requests,
    append their flows as dependency-free phases to the (possibly
    policy-adapted) training schedule, and run both through
    :func:`~repro.core.congestion.simulate_schedule` — one max-min
    allocation prices the contention in both directions.

    Always the event-driven simulator (serving latency needs the per-flow
    timeline), always jitter-free (determinism is the serving contract).
    Returns ``(seconds, sync_seconds, strategy_name)`` for the training
    record, or ``None`` on serving-only steps.
    """
    from repro.core.congestion import simulate_schedule
    from repro.core.schedule import CollectiveSchedule

    health = _fabric_health(geo, probes, dead_pods)
    plan = engine.plan_step(step, geo, health)

    training_phases: Tuple = ()
    sync_every = 1
    strategy_name = ""
    eff_opts = options
    name = "serving"
    if training_active:
        eff_strategy, eff_grad = strategy, grad_bytes
        if degraded and policy is not None:
            if policy.fallback_strategy is not None and isinstance(strategy, str):
                eff_strategy = policy.fallback_strategy
            if policy.degraded_sync_every is not None:
                eff_opts = dataclasses.replace(
                    eff_opts, sync_every=policy.degraded_sync_every
                )
            if policy.int8_wan:
                eff_grad = max(int(grad_bytes * eff_opts.int8_ratio), 1)
        if isinstance(eff_strategy, str):
            schedule = build_schedule(
                eff_strategy,
                geo.strategy_context(tuple(sorted(dead_pods))),
                eff_grad,
                sync_every=eff_opts.sync_every,
                int8_ratio=eff_opts.int8_ratio,
            )
        else:
            schedule = eff_strategy
        strategy_name = schedule.name
        if compute > 0:
            schedule = with_compute_overlap(schedule, compute, overlap_fraction)
        training_phases = schedule.phases
        sync_every = max(schedule.sync_every, 1)
        name = f"{schedule.name}+serving"

    all_phases = tuple(training_phases) + plan.phases
    report = None
    if all_phases:
        combined = CollectiveSchedule(name, all_phases, sync_every=sync_every)
        report = simulate_schedule(
            geo.fabric,
            geo.netem,
            combined,
            check_reachability=geo.tenancy.reachable,
            ecmp_weighted=eff_opts.ecmp_weighted,
        )
    engine.finish_step(plan, report)

    if probes is not None and report is not None:
        rates = _pair_rates(geo, report.phase_timings, report.seconds)
        probe_now_ms = step * 1000.0  # one emulated second per step
        for pair in probes.pairs:
            probes.observe(
                pair,
                probe_now_ms,
                rate_gbps=rates.get(pair, baseline_rates.get(pair, 0.0)),
                rtt_ms=_pair_rtt_ms(geo, pair),
            )
    if not training_active:
        return None
    train_names = {p.name for p in training_phases}
    train_end = 0.0
    if report is not None:
        train_end = max(
            (p.end_s for p in report.phase_timings if p.name in train_names),
            default=0.0,
        )
    if compute > 0:
        exposed = max(train_end - compute, 0.0)
        sync_seconds = exposed / sync_every
        seconds = compute + sync_seconds
    else:
        sync_seconds = train_end / sync_every
        seconds = sync_seconds
    return seconds, sync_seconds, strategy_name


def run_scenario(
    scenario: Scenario, *, geo: Optional[GeoFabric] = None
) -> ScenarioResult:
    """Execute ``scenario`` and return its :class:`ScenarioResult`.

    ``geo`` overrides the topology build (reuse a warm fabric across a
    sweep — the spec's topology must describe it).  Steps run in order;
    each step first fires its events, then costs the training step under
    the (possibly changed) fabric state.  With ``compute_seconds > 0`` the
    step is :meth:`GeoFabric.step_time` (compute overlap as DAG
    structure, straggler factor applied to the compute term); otherwise
    it is the amortized sync cost alone.  The representative ``sync``
    rollup is costed jitter-free *before* any event fires, so it is a
    deterministic healthy-fabric baseline regardless of the event script.

    With a :class:`~repro.scenario.spec.DegradationPolicy` on the spec,
    the runner additionally closes the gray-failure loop: per-DC-pair
    :class:`~repro.core.slaprobe.SlaProbe`\\ s calibrate against the
    healthy representative, observe each step's achieved WAN rate and
    leader RTT, and — once tripped — the policy's graceful degradation
    (strategy fallback / raised sync period / int8 WAN compression)
    applies from the next step until the probes recover.  ``pod_fail``
    events drive the HeartbeatMonitor -> checkpoint-restore ->
    ``plan_remesh`` chain: detection is priced into the step timeline
    (``StepRecord.downtime_seconds``) and subsequent steps cost the
    surviving-pod schedule; per-episode :class:`PodRecovery` records land
    in the result.

    With a :class:`~repro.scenario.spec.ServingSpec` on the spec, every
    step additionally runs the geo-serving co-load: the
    :class:`~repro.serving.engine.ServingEngine` routes that step's
    deterministic request trace (sticky sessions, probe/degradation-driven
    failover) and its flows join the training schedule inside one
    event-driven max-min simulation — per-step
    :class:`~repro.serving.engine.ServingStepStats` land on
    ``result.serving_steps``.  Serving steps are always costed by the
    event-driven simulator and jitter-free; scenarios without a
    ``ServingSpec`` keep the historical costing path bit-for-bit.
    """
    geo = geo if geo is not None else scenario.topology.build()
    workload = scenario.workload
    grad_bytes = workload.resolve_grad_bytes()
    strategy = workload.strategy
    policy = scenario.policy
    result = ScenarioResult(scenario=scenario, steps=[], sync=None, geo=geo)

    # serving co-load: lazy import so scenarios without a ServingSpec never
    # pay for (or depend on) the serving subsystem
    engine = None
    if scenario.serving is not None:
        from repro.serving.engine import ServingEngine

        engine = ServingEngine(
            scenario.serving,
            num_dcs=geo.num_pods,
            num_steps=scenario.num_steps,
            port_scheme=geo.port_scheme,
        )

    baseline_rates: Dict[Tuple[int, int], float] = {}
    if strategy is not None:
        result.sync = geo.sync_cost(
            strategy,
            grad_bytes,
            options=dataclasses.replace(scenario.options, jitter=False),
        )
        if policy is not None:
            baseline_rates = _pair_rates(
                geo, result.sync.phases, result.sync.wan_seconds
            )

    # gray-failure probes: one per WAN DC pair, calibrated on the healthy
    # representative (pairs the schedule never touches calibrate at rate 0,
    # which disables their rate floor but keeps the RTT ceiling live)
    probes: Optional[SlaProbeBank] = None
    if policy is not None and strategy is not None and geo.num_pods > 1:
        probes = SlaProbeBank(
            rate_floor_frac=policy.rate_floor_frac,
            rtt_ceiling_frac=policy.rtt_ceiling_frac,
            trip_after=policy.trip_after,
            recover_after=policy.recover_after,
        )
        for a in range(1, geo.num_pods + 1):
            for b in range(a + 1, geo.num_pods + 1):
                probes.calibrate(
                    (a, b),
                    rate_gbps=baseline_rates.get((a, b), 0.0),
                    rtt_ms=_pair_rtt_ms(geo, (a, b)),
                )
        result.probe_transitions = probes.transitions

    # pod-loss chain: a real HeartbeatMonitor on a step-indexed simulated
    # clock (one heartbeat interval per step), priced via plan_recovery +
    # the elastic coordinator's remesh plan.  Lazy import: repro.runtime
    # pulls in jax, which control-plane-only sweeps must not pay for.
    pricing = policy if policy is not None else DegradationPolicy()
    monitor = coordinator = None
    pod_names: List[str] = []
    if any(e.kind == "pod_fail" for e in scenario.events):
        from repro.runtime.elastic import ElasticCoordinator
        from repro.runtime.failure import HeartbeatMonitor

        pod_names = [f"pod{i}" for i in range(1, geo.num_pods + 1)]
        monitor = HeartbeatMonitor(
            pod_names,
            interval_ms=pricing.heartbeat_interval_ms,
            detect_mult=pricing.heartbeat_detect_mult,
            start_ms=0.0,
        )
        coordinator = ElasticCoordinator(
            pod_names, data=len(geo.workers(pod=1)), model=1
        )
    step_time_ref = workload.compute_seconds + (
        result.sync.amortized_seconds if result.sync is not None else 0.0
    )

    by_step: Dict[int, List[ScenarioEvent]] = {}
    for e in scenario.events:
        by_step.setdefault(e.at_step, []).append(e)
    straggler: Dict[int, float] = {}
    silenced: Dict[int, int] = {}  # pod -> step its heartbeats stopped
    dead_pods: set = set()

    # while no event has touched the fabric and the options are already
    # jitter-free, every pure-sync step costs exactly the representative
    # rollup — skip the duplicate congestion solve
    fabric_pristine = True
    reusable = result.sync is not None and not scenario.options.jitter

    for step in range(scenario.num_steps):
        fired = by_step.get(step, ())
        for event in fired:
            apply_event(event, geo, result, straggler)
            fabric_pristine = fabric_pristine and event.kind == "straggler"
            if event.kind == "pod_fail":
                silenced.setdefault(int(event.pod), step)
        downtime_s = 0.0
        if monitor is not None:
            now_ms = step * pricing.heartbeat_interval_ms
            for idx, name in enumerate(pod_names, 1):
                if idx not in silenced:
                    monitor.heartbeat(name, now_ms)
            for name in monitor.poll(now_ms):
                idx = int(name[len("pod"):])
                dead_pods.add(idx)
                from repro.runtime.failure import plan_recovery

                mesh = coordinator.on_pod_lost(name, step)
                # rollback anchor: the last checkpoint *before* the pod
                # died — nothing taken after the death is globally valid
                failed_at = silenced.get(idx, step)
                plan = plan_recovery(
                    step=step,
                    last_checkpoint_step=(failed_at // pricing.checkpoint_every)
                    * pricing.checkpoint_every,
                    step_time_s=step_time_ref,
                    detect_time_ms=monitor.detect_time_ms(),
                    checkpoint_bytes=float(grad_bytes),
                    restore_bandwidth_gbps=pricing.restore_bandwidth_gbps,
                    remesh_s=pricing.remesh_s,
                )
                result.pod_recoveries.append(
                    PodRecovery(
                        pod=idx,
                        failed_at_step=failed_at,
                        detected_at_step=step,
                        plan=plan,
                        mesh=mesh,
                    )
                )
                downtime_s += plan.total_downtime_s
        training_active = strategy is not None and step < workload.steps
        if engine is None and not training_active:
            continue  # event-only tail (or control-plane-only scenario)
        factor = straggler.get(step, 1.0)
        compute = workload.compute_seconds * factor if training_active else 0.0
        degraded = probes is not None and probes.any_degraded
        if engine is not None:
            served = _serving_step(
                engine,
                geo,
                step,
                training_active=training_active,
                strategy=strategy,
                grad_bytes=grad_bytes,
                compute=compute,
                policy=policy,
                options=scenario.options,
                overlap_fraction=workload.overlap_fraction,
                degraded=degraded,
                dead_pods=dead_pods,
                probes=probes,
                baseline_rates=baseline_rates,
            )
            if served is None:
                continue  # serving-only step: stats on result.serving_steps
            seconds, sync_seconds, strategy_name = served
        elif policy is None and not dead_pods:
            # the historical costing path, untouched (bit-identical
            # timelines for every pre-existing scenario)
            strategy_name = (
                strategy if isinstance(strategy, str) else strategy.name
            )
            if workload.compute_seconds > 0:
                seconds = geo.step_time(
                    strategy,
                    grad_bytes,
                    compute,
                    overlap_fraction=workload.overlap_fraction,
                    options=scenario.options,
                )
                sync_seconds = max(seconds - compute, 0.0)
            else:
                cost = (
                    result.sync
                    if reusable and fabric_pristine
                    else geo.sync_cost(strategy, grad_bytes, options=scenario.options)
                )
                sync_seconds = cost.amortized_seconds
                seconds = sync_seconds
        else:
            # resilience path: cost the (possibly adapted) schedule over
            # the surviving pods, then feed the probes what it observed
            eff_strategy, eff_grad, eff_opts = strategy, grad_bytes, scenario.options
            if degraded and policy is not None:
                if policy.fallback_strategy is not None and isinstance(strategy, str):
                    eff_strategy = policy.fallback_strategy
                if policy.degraded_sync_every is not None:
                    eff_opts = dataclasses.replace(
                        eff_opts, sync_every=policy.degraded_sync_every
                    )
                if policy.int8_wan:
                    eff_grad = max(int(grad_bytes * eff_opts.int8_ratio), 1)
            if isinstance(eff_strategy, str):
                schedule = build_schedule(
                    eff_strategy,
                    geo.strategy_context(tuple(sorted(dead_pods))),
                    eff_grad,
                    sync_every=eff_opts.sync_every,
                    int8_ratio=eff_opts.int8_ratio,
                )
            else:
                schedule = eff_strategy
            strategy_name = schedule.name
            if workload.compute_seconds > 0:
                overlapped = with_compute_overlap(
                    schedule, compute, workload.overlap_fraction
                )
                cost = geo.sync_cost(overlapped, options=eff_opts)
                exposed = max(cost.wan_seconds - compute, 0.0)
                sync_seconds = exposed / cost.sync_every
                seconds = compute + sync_seconds
            else:
                cost = geo.sync_cost(schedule, options=eff_opts)
                sync_seconds = cost.amortized_seconds
                seconds = sync_seconds
            if probes is not None:
                rates = _pair_rates(geo, cost.phases, cost.wan_seconds)
                probe_now_ms = step * 1000.0  # one emulated second per step
                for pair in probes.pairs:
                    probes.observe(
                        pair,
                        probe_now_ms,
                        rate_gbps=rates.get(pair, baseline_rates.get(pair, 0.0)),
                        rtt_ms=_pair_rtt_ms(geo, pair),
                    )
        result.steps.append(
            StepRecord(
                step=step,
                seconds=float(seconds) + float(downtime_s),
                sync_seconds=float(sync_seconds),
                compute_seconds=float(compute),
                straggler_factor=float(factor),
                events=tuple(e.kind for e in fired),
                strategy=strategy_name,
                degraded=bool(degraded),
                downtime_seconds=float(downtime_s),
            )
        )
    if engine is not None:
        result.serving_steps = list(engine.stats)
    return result
