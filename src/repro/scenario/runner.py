"""Execute a declarative :class:`~repro.scenario.spec.Scenario`.

``run_scenario`` builds the emulated deployment from the spec, replays the
event script step by step (link flaps through the BFD/BGP failure
detector — which drives the fabric's incremental re-convergence and the
EVPN incremental resync — tenant churn through the tenancy manager,
straggler injection into the compute term), costs every training step with
the spec's :class:`~repro.core.geo.SyncOptions`, and returns a
:class:`ScenarioResult`:

* a per-step timeline (modeled seconds, WAN sync seconds, straggler
  factor, the events that fired);
* rollups of the three observability records the substrate already emits —
  :class:`~repro.core.geo.SyncCost` (a deterministic jitter-free
  representative), :class:`~repro.core.bfd.RecoveryTimeline` per failure,
  :class:`~repro.core.evpn.EvpnResyncStats` per control-plane resync;
* ``metrics()`` — the flat deterministic observables the CI baseline gate
  (``benchmarks/compare.py``) consumes — and ``to_dict()`` — the full
  JSON-serializable record.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.bfd import RecoveryTimeline
from repro.core.evpn import EvpnResyncStats
from repro.core.fabric import RerouteStats
from repro.core.geo import GeoFabric, SyncCost
from repro.scenario.spec import Scenario, ScenarioEvent

__all__ = ["ScenarioResult", "StepRecord", "apply_event", "run_scenario"]


@dataclass(frozen=True)
class StepRecord:
    """One emulated training step of a scenario."""

    step: int
    seconds: float  # modeled wall time of the step (compute + exposed sync)
    sync_seconds: float  # the step's WAN sync term (amortized)
    compute_seconds: float  # compute term after straggler scaling
    straggler_factor: float
    events: Tuple[str, ...] = ()  # kinds of the events that fired this step

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["events"] = list(self.events)
        return d


def _sync_cost_dict(c: SyncCost) -> Dict[str, object]:
    return {
        "strategy": c.strategy,
        "wan_seconds": float(c.wan_seconds),
        "amortized_seconds": float(c.amortized_seconds),
        "wan_bytes": int(c.wan_bytes),
        "sync_every": int(c.sync_every),
        "bottleneck_link": None if c.bottleneck_link is None else list(c.bottleneck_link),
        "bottleneck_bytes": int(c.bottleneck_bytes),
        "bottleneck_utilization": float(c.bottleneck_utilization),
        "load_factor": float(c.load.load_factor),
        "phases": [
            {
                "name": p.name,
                "start_s": float(p.start_s),
                "end_s": float(p.end_s),
                "wan_bytes": int(p.wan_bytes),
            }
            for p in c.phases
        ],
    }


def _recovery_dict(t: RecoveryTimeline) -> Dict[str, object]:
    return {
        "mechanism": t.mechanism,
        "recovery_ms": float(t.recovery_ms),
        "detect_ms": float(t.detected_at_ms - t.failure_at_ms),
    }


def _resync_dict(s: EvpnResyncStats) -> Dict[str, object]:
    return {
        "link": list(s.link),
        "action": s.action,
        "patched": s.patched,
        "rebuilt": s.rebuilt,
        "retained": s.retained,
        "vtep_touched_frac": float(s.vtep_touched_frac),
    }


@dataclass
class ScenarioResult:
    """Everything one scenario run produced.

    ``geo`` is the live emulated deployment (post-events) so thin bench
    wrappers can keep probing it; it is deliberately absent from
    ``to_dict()``.
    """

    scenario: Scenario
    steps: List[StepRecord]
    sync: Optional[SyncCost]  # jitter-free representative sync cost
    recoveries: List[RecoveryTimeline] = field(default_factory=list)
    reroutes: List[RerouteStats] = field(default_factory=list)
    evpn_resyncs: List[EvpnResyncStats] = field(default_factory=list)
    geo: Optional[GeoFabric] = None

    @property
    def total_seconds(self) -> float:
        return float(sum(s.seconds for s in self.steps))

    @property
    def mean_step_seconds(self) -> float:
        return self.total_seconds / len(self.steps) if self.steps else 0.0

    @property
    def evpn_mean_touched_frac(self) -> float:
        if not self.evpn_resyncs:
            return 0.0
        return float(
            sum(s.vtep_touched_frac for s in self.evpn_resyncs)
            / len(self.evpn_resyncs)
        )

    def metrics(self) -> Dict[str, float]:
        """Deterministic gated observables for ``benchmarks/compare.py``.

        Only seeded model outputs belong here (the compare-gate contract
        of ``benchmarks/common.py``); wall-clock never does.  Keys follow
        the direction-by-suffix convention (``*_seconds``/``*_frac`` lower
        is better, etc.).
        """
        out: Dict[str, float] = {}
        if self.steps:
            out["total_step_seconds"] = self.total_seconds
            out["mean_step_seconds"] = self.mean_step_seconds
        if self.sync is not None:
            out["sync_wan_seconds"] = float(self.sync.wan_seconds)
            out["sync_wan_bytes"] = float(self.sync.wan_bytes)
        if self.recoveries:
            out["mean_recovery_ms"] = float(
                sum(t.recovery_ms for t in self.recoveries) / len(self.recoveries)
            )
        if self.evpn_resyncs:
            out["evpn_mean_touched_frac"] = self.evpn_mean_touched_frac
        return out

    def to_dict(self) -> Dict[str, object]:
        return {
            "scenario": self.scenario.to_dict(),
            "steps": [s.to_dict() for s in self.steps],
            "sync": None if self.sync is None else _sync_cost_dict(self.sync),
            "recoveries": [_recovery_dict(t) for t in self.recoveries],
            "evpn_resyncs": [_resync_dict(s) for s in self.evpn_resyncs],
            "metrics": self.metrics(),
            "total_seconds": self.total_seconds,
        }


def apply_event(
    event: ScenarioEvent,
    geo: GeoFabric,
    result: ScenarioResult,
    straggler: Dict[int, float],
) -> None:
    """Apply one :class:`ScenarioEvent` to a live deployment.

    Rollups (recovery timelines, reroute stats, EVPN resyncs) accumulate
    on ``result``; straggler multipliers accumulate per step index in
    ``straggler``.  Shared by :func:`run_scenario` and the scenario-driven
    :class:`repro.runtime.trainer.GeoTrainer`, so both replay an event
    script with identical semantics.
    """
    if event.kind == "fail_link":
        timeline = geo.detector.fail_and_recover(
            tuple(event.link), mechanism=event.mechanism
        )
        result.recoveries.append(timeline)
        if timeline.reroute is not None:
            result.reroutes.append(timeline.reroute)
        if timeline.evpn_resync is not None:
            result.evpn_resyncs.append(timeline.evpn_resync)
    elif event.kind == "restore_link":
        stats = geo.detector.restore(tuple(event.link))
        result.reroutes.append(stats)
        if geo.evpn.last_resync is not None:
            result.evpn_resyncs.append(geo.evpn.last_resync)
    elif event.kind == "tenant_attach":
        if event.tenant not in geo.tenancy.tenants:
            if event.vni is None:
                raise ValueError(
                    f"tenant_attach for new tenant {event.tenant!r} needs a vni"
                )
            geo.tenancy.create_tenant(event.tenant, vni=event.vni)
        geo.tenancy.attach(event.tenant, event.host)
    elif event.kind == "tenant_detach":
        geo.tenancy.detach(event.tenant, event.host)
    elif event.kind == "straggler":
        for s in range(event.at_step, event.at_step + event.duration_steps):
            straggler[s] = straggler.get(s, 1.0) * event.slowdown
    else:  # pragma: no cover - spec validation rejects unknown kinds
        raise ValueError(f"unknown event kind {event.kind!r}")


def run_scenario(
    scenario: Scenario, *, geo: Optional[GeoFabric] = None
) -> ScenarioResult:
    """Execute ``scenario`` and return its :class:`ScenarioResult`.

    ``geo`` overrides the topology build (reuse a warm fabric across a
    sweep — the spec's topology must describe it).  Steps run in order;
    each step first fires its events, then costs the training step under
    the (possibly changed) fabric state.  With ``compute_seconds > 0`` the
    step is :meth:`GeoFabric.step_time` (compute overlap as DAG
    structure, straggler factor applied to the compute term); otherwise
    it is the amortized sync cost alone.  The representative ``sync``
    rollup is costed jitter-free *before* any event fires, so it is a
    deterministic healthy-fabric baseline regardless of the event script.
    """
    geo = geo if geo is not None else scenario.topology.build()
    workload = scenario.workload
    grad_bytes = workload.resolve_grad_bytes()
    strategy = workload.strategy
    result = ScenarioResult(scenario=scenario, steps=[], sync=None, geo=geo)

    if strategy is not None:
        result.sync = geo.sync_cost(
            strategy,
            grad_bytes,
            options=dataclasses.replace(scenario.options, jitter=False),
        )

    by_step: Dict[int, List[ScenarioEvent]] = {}
    for e in scenario.events:
        by_step.setdefault(e.at_step, []).append(e)
    straggler: Dict[int, float] = {}

    # while no event has touched the fabric and the options are already
    # jitter-free, every pure-sync step costs exactly the representative
    # rollup — skip the duplicate congestion solve
    fabric_pristine = True
    reusable = result.sync is not None and not scenario.options.jitter

    for step in range(scenario.num_steps):
        fired = by_step.get(step, ())
        for event in fired:
            apply_event(event, geo, result, straggler)
            fabric_pristine = fabric_pristine and event.kind == "straggler"
        if strategy is None or step >= workload.steps:
            continue  # event-only tail (or control-plane-only scenario)
        factor = straggler.get(step, 1.0)
        compute = workload.compute_seconds * factor
        if workload.compute_seconds > 0:
            seconds = geo.step_time(
                strategy,
                grad_bytes,
                compute,
                overlap_fraction=workload.overlap_fraction,
                options=scenario.options,
            )
            sync_seconds = max(seconds - compute, 0.0)
        else:
            cost = (
                result.sync
                if reusable and fabric_pristine
                else geo.sync_cost(strategy, grad_bytes, options=scenario.options)
            )
            sync_seconds = cost.amortized_seconds
            seconds = sync_seconds
        result.steps.append(
            StepRecord(
                step=step,
                seconds=float(seconds),
                sync_seconds=float(sync_seconds),
                compute_seconds=float(compute),
                straggler_factor=float(factor),
                events=tuple(e.kind for e in fired),
            )
        )
    return result
