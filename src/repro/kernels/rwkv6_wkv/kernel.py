"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The sub-quadratic engine behind the `long_500k` shapes: per (batch, head)
the recurrence carries an [N, N] state (N = 64 -> 16 KB f32, comfortably
VMEM-resident) while streaming T timesteps through in chunks.

Grid: (B, H, T/chunk) with the time dimension sequential ("arbitrary") —
the state lives in VMEM scratch across chunk steps, so HBM traffic is
exactly one read of (r, k, v, w) and one write of the output: the kernel
is HBM-bandwidth-bound by construction, which is the roofline-optimal
shape for this memory-bound recurrence (arithmetic intensity ~N/2).

Inside a chunk the timestep loop is a ``fori_loop`` of rank-1 updates:
    out_t  = r_t . (S + u * k_t v_t^T)
    S     <- diag(w_t) S + k_t v_t^T
The (N, 1) x (1, N) outer products and (1, N) x (N, N) row-vector matmuls
map onto the MXU as skinny matmuls.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _wkv_kernel(
    r_ref,  # [1, chunk, 1, N]
    k_ref,
    v_ref,
    w_ref,
    u_ref,  # [1, N]
    s0_ref,  # [1, 1, N, N]
    o_ref,  # [1, chunk, 1, N]
    sout_ref,  # [1, 1, N, N]
    state_scr,  # [N, N] f32 VMEM scratch
    *,
    chunk: int,
    num_chunks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # [N]

    def step(t, state):
        r_t = r_ref[0, t, 0].astype(jnp.float32)  # [N]
        k_t = k_ref[0, t, 0].astype(jnp.float32)
        v_t = v_ref[0, t, 0].astype(jnp.float32)
        w_t = w_ref[0, t, 0].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]  # [N, N]
        boosted = state + u[:, None] * kv
        out = jax.lax.dot_general(
            r_t[None, :], boosted, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[0]
        o_ref[0, t, 0] = out.astype(o_ref.dtype)
        return state * w_t[:, None] + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ti == num_chunks - 1)
    def _final():
        sout_ref[0, 0] = state.astype(sout_ref.dtype)


def wkv6_fwd(
    r: jnp.ndarray,  # [B, T, H, N]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # [H, N]
    state0: jnp.ndarray,  # [B, H, N, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, t, h, n = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nchunks = t // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nchunks)
    seq_spec = pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ti: (b_, ti, h_, 0))
    out, sout = pl.pallas_call(
        kernel,
        grid=(b, h, nchunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, n), lambda b_, h_, ti: (h_, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, ti: (b_, h_, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, ti: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, state0)
    return out, sout
