"""Jit-ready wrapper for the WKV6 Pallas kernel."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import wkv6_fwd
from .ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def wkv6(
    r, k, v, w, u, state0=None, *,
    chunk: int = 128,
    interpret: bool = True,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6 recurrence. r/k/v/w: [B, T, H, N]; u: [H, N].

    Returns (out [B, T, H, N] f32, final state [B, H, N, N] f32).
    """
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    if not use_kernel or t % min(chunk, t) != 0:
        return wkv6_ref(r, k, v, w, u, state0)
    return wkv6_fwd(r, k, v, w, u, state0, chunk=chunk, interpret=interpret)
