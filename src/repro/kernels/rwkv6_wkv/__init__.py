from .kernel import wkv6_fwd
from .ops import wkv6
from .ref import wkv6_ref

__all__ = ["wkv6", "wkv6_fwd", "wkv6_ref"]
