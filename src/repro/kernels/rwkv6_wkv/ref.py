"""Pure-jnp oracle for the RWKV6 WKV kernel: scan over time.

Identical math to ``repro.models.rwkv6.wkv6_scan`` (kept standalone so the
kernel test does not depend on the model stack).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jnp.ndarray,  # [B, T, H, N]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # decay in (0, 1)
    u: jnp.ndarray,  # [H, N] bonus
    state0: jnp.ndarray,  # [B, H, N, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    def step(state, inputs):
        r_t, k_t, v_t, w_t = inputs  # [B, H, N]
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B, H, N, N]
        out = jnp.einsum("bhi,bhij->bhj", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., :, None] + kv
        return state, out

    xs = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w)
    )
    final, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), final
