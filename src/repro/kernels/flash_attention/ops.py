"""Jit-ready wrapper for the flash-attention Pallas kernel.

Accepts the model's native [B, S, H, hd] layout, transposes to the
kernel's heads-first tiling layout, picks MXU-aligned block sizes, and
falls back to the jnp reference for shapes the kernel cannot tile (tiny
smoke shapes, non-divisible sequence lengths).

On this CPU container the kernel runs with ``interpret=True`` (Pallas
executes the kernel body in Python) — the TPU target is the compiled
Mosaic path with identical semantics.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import flash_attention_ref


def _pick_block(s: int, preferred: int) -> Optional[int]:
    for b in (preferred, 512, 256, 128):
        if b <= s and s % b == 0:
            return b
    return None


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Sk, KVH, hd]
    v: jnp.ndarray,  # [B, Sk, KVH, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    qt = jnp.swapaxes(q, 1, 2)  # [B, H, Sq, hd]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    bq = _pick_block(qt.shape[2], block_q)
    bk = _pick_block(kt.shape[2], block_k)
    if bq is None or bk is None:
        out = flash_attention_ref(
            qt, kt, vt, causal=causal, window=window, logit_softcap=logit_softcap
        )
    else:
        out = flash_attention_fwd(
            qt, kt, vt,
            causal=causal, window=window, logit_softcap=logit_softcap,
            block_q=bq, block_k=bk, interpret=interpret,
        )
    return jnp.swapaxes(out, 1, 2)
