"""Flash attention as a Pallas TPU kernel.

Online-softmax blocked attention (Dao et al., adapted to the TPU memory
hierarchy): the grid walks (batch, q-head, q-block) in parallel and the
k-block dimension sequentially ("arbitrary"), carrying the running max
``m``, normalizer ``l``, and accumulator in VMEM scratch.  Block shapes
are MXU-aligned (q/k blocks multiples of 128 lanes, head_dim untiled) and
sized so the working set — one q tile, one k tile, one v tile, and the
f32 accumulator — stays a few MB of VMEM.

Causality and sliding windows are handled two ways:
* whole out-of-range k-blocks are skipped with ``pl.when`` (no MXU work),
* partially masked blocks apply the positional mask to the logits.

GQA: q-head h reads kv-head ``h * KVH // H`` via the k/v index_maps.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, 1, bq, hd]
    k_ref,  # [1, 1, bk, hd]
    v_ref,  # [1, 1, bk, hd]
    o_ref,  # [1, 1, bq, hd]
    m_scr,  # [bq, 1] f32
    l_scr,  # [bq, 1] f32
    acc_scr,  # [bq, hd] f32
    *,
    causal: bool,
    window: Optional[int],
    logit_softcap: Optional[float],
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    sm_scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level reachability: any (q, k) pair in range?
    in_range = True
    if causal:
        in_range = jnp.logical_and(in_range, k_start <= q_start + block_q - 1)
    if window is not None:
        in_range = jnp.logical_and(
            in_range, k_start + block_k - 1 > q_start - window
        )

    @pl.when(in_range)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [bq, bk]
        if logit_softcap is not None:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_new = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KVH, Sk, hd]
    v: jnp.ndarray,  # [B, KVH, Sk, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    group = h // kvh

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        sm_scale=hd ** -0.5,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
