"""Pure-jnp oracle for the flash-attention kernel.

Numerically the plain softmax-attention definition — the kernel must match
this to tolerance across the shape/dtype sweep in tests/test_kernels.py.
Layout: heads-first [B, H, S, hd] (the kernel's native tiling layout).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KVH, Sk, hd]
    v: jnp.ndarray,  # [B, KVH, Sk, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    kvh = k.shape[1]
    groups = h // kvh
    qg = q.reshape(b, kvh, groups, sq, hd).astype(jnp.float32)
    scale = hd ** -0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, sq, hd).astype(q.dtype)
