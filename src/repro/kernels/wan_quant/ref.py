"""Pure-jnp oracle for the WAN int8 quantization kernel.

Matches ``repro.distributed.compression.int8_compress`` exactly: per-row
blocks of 256 lanes, absmax scale, symmetric round-to-nearest int8.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 256


def wan_quant_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [rows, lanes] (lanes % 256 == 0) -> (int8 [rows, lanes],
    scales f32 [rows, lanes/256])."""
    rows, lanes = x.shape
    assert lanes % BLOCK == 0
    blocks = x.astype(jnp.float32).reshape(rows, lanes // BLOCK, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(rows, lanes), scale[..., 0]


def wan_dequant_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    rows, lanes = q.shape
    blocks = q.reshape(rows, lanes // BLOCK, BLOCK).astype(jnp.float32)
    return (blocks * scales[..., None]).reshape(rows, lanes)
