"""Per-block int8 absmax quantization as a Pallas TPU kernel.

The compute hot-spot introduced by the paper's setting: gradients must be
compressed *at line rate* before the inter-data-center hop (hier_int8
sync), i.e. the quantizer must stream the full gradient through the VPU
faster than the WAN drains it.  The kernel tiles [rows, lanes] into
(row_tile x 256-lane) VMEM blocks — 256 lanes is both the wire-format
block (one f32 scale per 256 int8 payload) and a multiple of the VPU lane
width, so absmax reduction and scaling vectorize with no cross-lane
shuffles.  Quantize and dequantize are separate kernels (they run on
opposite sides of the WAN).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BLOCK = 256  # lanes per scale block (wire format)
ROW_TILE = 256  # rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # [rt, BLOCK]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # [rt, 1]
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...]


def wan_quant(
    x: jnp.ndarray, *, row_tile: int = ROW_TILE, interpret: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [rows, lanes] -> (int8 [rows, lanes], scales f32 [rows, lanes/256])."""
    rows, lanes = x.shape
    assert lanes % BLOCK == 0, lanes
    rt = min(row_tile, rows)
    assert rows % rt == 0, (rows, rt)
    nblocks = lanes // BLOCK
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rows // rt, nblocks),
        in_specs=[pl.BlockSpec((rt, BLOCK), lambda r, c: (r, c))],
        out_specs=[
            pl.BlockSpec((rt, BLOCK), lambda r, c: (r, c)),
            pl.BlockSpec((rt, 1), lambda r, c: (r, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int8),
            jax.ShapeDtypeStruct((rows, nblocks), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x)
    return q, s


def wan_dequant(
    q: jnp.ndarray, scales: jnp.ndarray, *, row_tile: int = ROW_TILE,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, lanes = q.shape
    rt = min(row_tile, rows)
    assert rows % rt == 0 and lanes % BLOCK == 0
    nblocks = lanes // BLOCK
    return pl.pallas_call(
        _dequant_kernel,
        grid=(rows // rt, nblocks),
        in_specs=[
            pl.BlockSpec((rt, BLOCK), lambda r, c: (r, c)),
            pl.BlockSpec((rt, 1), lambda r, c: (r, c)),
        ],
        out_specs=pl.BlockSpec((rt, BLOCK), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(q, scales)
