from .kernel import BLOCK, wan_dequant, wan_quant
from .ops import dequantize, quantize
from .ref import wan_dequant_ref, wan_quant_ref

__all__ = ["BLOCK", "dequantize", "quantize", "wan_dequant", "wan_dequant_ref", "wan_quant", "wan_quant_ref"]
