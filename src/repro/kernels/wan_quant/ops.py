"""Jit-ready wrappers for the WAN quantization kernels.

Handles arbitrary pytree-leaf shapes: pads the trailing dim to a 256
multiple, flattens leading dims to rows, and dispatches to the Pallas
kernel (interpret mode on CPU).  The round-trip composes with the error-
feedback machinery in ``repro.distributed.compression``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import BLOCK, wan_dequant, wan_quant


def _to_rows(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...], int]:
    orig_shape = tuple(x.shape)
    if x.ndim == 0:
        x = x.reshape(1, 1)
    elif x.ndim == 1:
        x = x.reshape(1, -1)
    else:
        x = x.reshape(-1, x.shape[-1])
    last = x.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, orig_shape, last


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jnp.ndarray, *, interpret: bool = True):
    """Any-shape leaf -> (int8 rows, scales, static (shape, last)) bundle."""
    rows, orig_shape, last = _to_rows(x.astype(jnp.float32))
    rt = 1
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows.shape[0] % cand == 0:
            rt = cand
            break
    q, s = wan_quant(rows, row_tile=rt, interpret=interpret)
    return q, s


@functools.partial(jax.jit, static_argnames=("orig_shape", "interpret"))
def dequantize(q, s, *, orig_shape: Tuple[int, ...], interpret: bool = True):
    rt = 1
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if q.shape[0] % cand == 0:
            rt = cand
            break
    full = wan_dequant(q, s, row_tile=rt, interpret=interpret)
    last = orig_shape[-1] if orig_shape else 1
    if full.ndim and orig_shape:
        full = full[:, :last] if full.shape[-1] != last else full
        return full.reshape(orig_shape)
    return full.reshape(orig_shape)
