"""Distribution layer: sharding rules, WAN sync strategies, step builders."""

from .compression import (
    Int8Compressed,
    apply_error_feedback,
    compressed_bytes,
    init_error_feedback,
    int8_compress,
    int8_decompress,
    residual,
    topk_densify,
    topk_sparsify,
)
from .sharding import (
    batch_pspecs,
    batch_shardings,
    cache_pspecs,
    cache_shardings,
    params_pspecs,
    params_shardings,
)
from .steps import (
    TrainState,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_pspecs,
)
from .sync import STRATEGIES, sync_allreduce, sync_hier, sync_hier_int8, wan_bytes_per_step

__all__ = [
    "Int8Compressed",
    "STRATEGIES",
    "TrainState",
    "apply_error_feedback",
    "batch_pspecs",
    "batch_shardings",
    "cache_pspecs",
    "cache_shardings",
    "compressed_bytes",
    "init_error_feedback",
    "init_train_state",
    "int8_compress",
    "int8_decompress",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
    "params_pspecs",
    "params_shardings",
    "residual",
    "state_pspecs",
    "sync_allreduce",
    "sync_hier",
    "sync_hier_int8",
    "topk_densify",
    "topk_sparsify",
    "wan_bytes_per_step",
]
