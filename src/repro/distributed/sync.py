"""Cross-pod (inter-data-center) gradient synchronization strategies.

The mesh hierarchy mirrors the paper's deployment: ``data``/``model`` axes
live on intra-DC ICI; the ``pod`` axis is the WAN.  Inside the jitted step,
intra-pod reduction is GSPMD-automatic (reduce-scatter over ``data``
because parameters are FSDP-sharded), so whatever crosses the ``pod`` axis
here is exactly the WAN traffic the ScaleAcross fabric carries — each
strategy below corresponds to one row of the Fig. 14 / §Perf study:

* ``allreduce``  — flat psum over ``pod`` (the paper's M2 / DDP setting);
* ``ps``         — parameter-server emulation (paper's M1): gradients
                   gather to pod 0, the update happens there, parameters
                   broadcast back (2x full-volume WAN, server hot-spot);
* ``hier``       — hierarchical: identical bytes to ``allreduce`` per
                   device but chunked into ``num_channels`` independent
                   collectives = the QP/channel striping of §3.3 (each
                   chunk rides its own WAN flow; the fabric model assigns
                   ports via Algorithm 1);
* ``hier_int8``  — ``hier`` with int8+error-feedback compression on the
                   WAN hop only;
* ``local_sgd``  — no per-step WAN traffic; every H steps the runtime
                   triggers a DiLoCo-style outer step (see
                   ``repro.optim.diloco``).

All functions assume they run inside ``shard_map`` with the ``pod`` axis
manual (see ``repro.distributed.steps``).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .compression import (
    apply_error_feedback,
    int8_compress,
    int8_decompress,
    residual,
)

STRATEGIES = ("allreduce", "ps", "hier", "hier_int8", "local_sgd")

#: pre-0.6 jax: the old SPMD partitioner CHECK-fails on all-gather of
#: auto-axis-sharded operands beneath a manual "pod" sub-mesh.
_LEGACY_SHARD_MAP = not hasattr(jax, "shard_map")


def all_gather_compat(x, axis: str, *, axis_index=None):
    """``jax.lax.all_gather`` with a legacy-safe lowering.

    On old jax the gather is expressed as a one-hot psum (exact for the
    int8/f32 payloads used on the WAN hop: int8 values round-trip through
    f32 losslessly), which the old partitioner handles fine.
    ``axis_index`` lets callers under a partial-manual mesh supply the
    position explicitly (``jax.lax.axis_index`` lowers to a PartitionId
    instruction the old partitioner rejects there).
    """
    if not _LEGACY_SHARD_MAP:
        return jax.lax.all_gather(x, axis)
    n = jax.lax.psum(1, axis)  # folds to the static axis size
    idx = jax.lax.axis_index(axis) if axis_index is None else axis_index
    mask = jax.lax.broadcasted_iota(jnp.int32, (n,) + (1,) * x.ndim, 0) == idx
    xf = x.astype(jnp.float32)
    out = jax.lax.psum(jnp.where(mask, xf[None], 0.0), axis)
    return out.astype(x.dtype)


def _chunk_bounds(dim0: int, num_channels: int):
    """Static slice bounds splitting dim 0 into <= num_channels parts."""
    base, rem = divmod(dim0, num_channels)
    bounds, start = [], 0
    for i in range(num_channels):
        size = base + (1 if i < rem else 0)
        if size == 0:
            break
        bounds.append((start, size))
        start += size
    return bounds


def _f32(grads):
    """Upcast before the WAN hop.

    Two reasons: (1) fp32 summation across pods is numerically safer than
    bf16 (and matches the paper's DDP fp32 gradient volumes); (2) XLA's
    SPMD partitioner CHECK-fails on bf16 all-reduces of 2-axis-sharded
    operands beneath a manual "pod" sub-mesh — the convert breaks the
    pattern (same family as the gather issue in act_sharding.py).
    """
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def sync_allreduce(grads, *, axis: str = "pod"):
    """Flat cross-pod mean (paper M2)."""
    n = jax.lax.psum(1, axis)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, _f32(grads))


def sync_hier(grads, *, axis: str = "pod", num_channels: int = 4):
    """Channel-striped cross-pod mean: large leaves split along their
    leading (layer-stack) dim into ``num_channels`` independent psums —
    the JAX-native analogue of NCCL multi-QP striping (§3.3): distinct
    flows on the WAN that the queue-pair-aware allocator spreads over
    distinct ECMP paths.  The leading stack dim is replicated in our
    sharding rules, so the split never forces a GSPMD reshard (a flat
    ``reshape(-1)`` would all-gather every leaf — measured +14 GiB/device
    on phi-3-vision).
    """
    n = jax.lax.psum(1, axis)

    def one(g):
        if g.ndim == 0 or g.shape[0] < 2:
            return jax.lax.psum(g, axis) / n
        parts = [
            jax.lax.psum(jax.lax.slice_in_dim(g, s, s + size, axis=0), axis)
            for s, size in _chunk_bounds(g.shape[0], num_channels)
        ]
        return jnp.concatenate(parts, axis=0) / n

    return jax.tree.map(one, _f32(grads))


def sync_hier_int8(grads, ef, *, axis: str = "pod", num_channels: int = 4, axis_index=None):
    """int8 + error feedback on the WAN hop.

    Pattern: g' = g + ef; q = quant(g'); all-gather(q) over pod; dequant &
    mean locally; new ef = g' - dequant(q_local).  Only int8 payloads (+
    fp32 block scales, ~1.6%) cross the WAN.
    Returns (synced grads, new error feedback).
    """
    n = jax.lax.psum(1, axis)
    boosted = apply_error_feedback(grads, ef)

    def one(g):
        c = int8_compress(g)
        vals = all_gather_compat(c.values, axis, axis_index=axis_index)  # (npods, ..., L) int8
        scls = all_gather_compat(c.scales, axis, axis_index=axis_index)  # (npods, ..., L/B) f32
        nblocks = c.scales.shape[-1]
        blocks = vals.reshape(*vals.shape[:-1], nblocks, -1).astype(jnp.float32)
        deq = (blocks * scls[..., None]).reshape(vals.shape).sum(0)
        mean = deq[..., : c.orig_last].reshape(c.orig_shape) / n
        local_deq = int8_decompress(c)
        return mean, local_deq

    flat, treedef = jax.tree.flatten(boosted)
    synced, transmitted = [], []
    for g in flat:
        m, t = one(g)
        synced.append(m)
        transmitted.append(t)
    synced = jax.tree.unflatten(treedef, synced)
    transmitted = jax.tree.unflatten(treedef, transmitted)
    new_ef = residual(boosted, transmitted)
    return synced, new_ef


def sync_ps(grads, params, apply_update: Callable, *, axis: str = "pod", axis_index=None):
    """Parameter-server emulation (paper M1).

    Workers push gradients to the server (pod 0), the server applies the
    update, workers pull fresh parameters.  Expressed with collectives:
    all-gather(grads) [push], masked update on pod 0, psum-broadcast of the
    updated params [pull].  WAN volume = grads + params per step, matching
    the paper's observation that PS moves ~1.5x the bytes of AllReduce
    (459 MB vs 312 MB per batch) and concentrates them on one site.

    ``apply_update(grads) -> new_params-like pytree`` runs only on pod 0's
    values (identical computation everywhere; non-0 pods discard).
    Returns the broadcast updated params.
    """
    idx = jax.lax.axis_index(axis) if axis_index is None else axis_index
    # push: server receives every pod's gradients
    gathered = jax.tree.map(
        lambda g: all_gather_compat(g, axis, axis_index=idx), grads
    )
    g_mean = jax.tree.map(lambda g: g.mean(0), gathered)
    updated = apply_update(g_mean)
    # pull: only the server's copy survives the broadcast
    is_server = (idx == 0).astype(jnp.float32)

    def bcast(u):
        return jax.lax.psum(u * is_server.astype(u.dtype), axis)

    return jax.tree.map(bcast, updated)


def sync_local(grads):
    """local_sgd: no WAN traffic in the inner step."""
    return grads


def wan_bytes_per_step(params_size_bytes: int, strategy: str, *, npods: int = 2) -> float:
    """Analytic WAN byte volume per pod per step (for the §Perf table)."""
    if strategy == "allreduce":
        return 2 * (npods - 1) / npods * params_size_bytes
    if strategy == "ps":
        return 2.0 * params_size_bytes  # push grads + pull params
    if strategy == "hier":
        return 2 * (npods - 1) / npods * params_size_bytes
    if strategy == "hier_int8":
        return (npods - 1) * (params_size_bytes / 4 * 1.016)  # int8 + scales
    if strategy == "local_sgd":
        return 0.0
    raise ValueError(strategy)
