"""Trace-time activation-sharding hook (batch DP + sequence parallelism).

Two jobs:

1. **Gather-safety** — XLA's SPMD partitioner (CPU pipeline) CHECK-fails
   when a gather's indices arrive pre-sharded over ``data`` beneath a
   manual ``pod`` sub-mesh.  The robust pattern: feed the batch sharded
   over ``pod`` only and constrain the *embedding output* onto ``data`` —
   GSPMD propagates batch sharding everywhere without partitioning the
   token gather's indices.

2. **Sequence parallelism** — between blocks, activations are additionally
   sharded over ``model`` on the sequence dim, so the ``lax.scan``-carried
   residuals (what remat saves per layer) occupy 1/TP of the memory.
   GSPMD inserts the all-gather before attention/matmuls and the
   reduce-scatter after — the standard SP schedule, visible in the
   dry-run's collective table.

The step builders enter :func:`activation_sharding` around tracing; the
model calls :func:`shard_activations` at the embedding and at every block
boundary.  Outside any context the hook is a no-op, so single-device
tests are unaffected.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axes = Optional[Union[str, Tuple[str, ...]]]

_SPEC: contextvars.ContextVar = contextvars.ContextVar("repro_act_axes", default=None)


def _axis_size(name: Axes) -> int:
    """Size of a mesh axis in the ambient (context) mesh, 1 if unknown."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or name is None:
            return 1
        return int(mesh.shape.get(name, 1))
    except Exception:  # noqa: BLE001 — no ambient mesh
        return 1


@contextlib.contextmanager
def activation_sharding(batch_axes: Axes, seq_axes: Axes = None):
    """Declare mesh axes for the activation batch dim and (optionally) the
    sequence dim of [B, S, D] activations."""
    token = _SPEC.set((batch_axes, seq_axes))
    try:
        yield
    finally:
        _SPEC.reset(token)


def shard_activations(x):
    """Constrain activations to the active (batch, seq) axes (no-op outside)."""
    spec = _SPEC.get()
    if spec is None:
        return x
    batch_axes, seq_axes = spec
    if x.ndim >= 3 and seq_axes is not None and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(
            x, P(batch_axes, seq_axes, *([None] * (x.ndim - 2)))
        )
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, *([None] * (x.ndim - 1)))
    )


def shard_heads(x):
    """Constrain a [B, T, H, ...] tensor to (batch, None, tensor-axis, ...).

    Used by recurrences (WKV) whose chunked time axis must stay unsharded:
    re-laying the heads onto the model axis replaces a per-chunk
    all-gather of the full sequence with one cheap all-to-all.

    No-op when the head count doesn't divide the tensor axis — GSPMD would
    pad (e.g. yi-34b's 56 heads on a 16-way axis pad to 64) and the padded
    reshards measurably thrash (+11 s collective, §Perf yi iteration 1).
    """
    spec = _SPEC.get()
    if spec is None or x.ndim < 3:
        return x
    batch_axes, seq_axes = spec
    if seq_axes is None:
        return x
    if x.shape[2] % max(_axis_size(seq_axes), 1) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, None, seq_axes, *([None] * (x.ndim - 3)))
    )


def replicate_seq(x):
    """Constrain [B, S, ...] to batch-only sharding (seq gathered).

    Used for k/v ahead of the KV-block attention scan: gathering the
    (small) kv heads across the sequence beats all-gathering full-width
    activations by d_model / (2 * kv_heads * head_dim).
    """
    spec = _SPEC.get()
    if spec is None or x.ndim < 2:
        return x
    batch_axes, _ = spec
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, *([None] * (x.ndim - 1)))
    )
