"""Distributed step builders: train / prefill / decode over any mesh.

Training composes three layers, mirroring the paper's deployment stack:

1. **intra-pod** — GSPMD-automatic: FSDP reduce-scatter over ``data``,
   tensor-parallel collectives over ``model`` (fast ICI);
2. **cross-pod** — explicit, inside a partial-manual ``shard_map`` over the
   ``pod`` axis: this is the WAN, where the ScaleAcross sync strategies
   (allreduce / ps / hier / hier_int8 / local_sgd) apply;
3. **optimizer** — AdamW on the (sharded) pytrees, plus the DiLoCo outer
   step for ``local_sgd``.

Builders return jitted callables plus the sharding trees used, so the
launcher, the dry-run, and the checkpointing layer all agree on placement.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import decode_step as model_decode_step
from repro.models import loss_fn, prefill
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.optim.diloco import DilocoConfig, DilocoState, init_diloco, outer_step

from .act_sharding import activation_sharding
from .compression import init_error_feedback
from .sharding import (
    batch_pspecs,
    batch_shardings,
    cache_shardings,
    params_pspecs,
    params_shardings,
)
from .sync import (
    STRATEGIES,
    _LEGACY_SHARD_MAP,  # single source of truth for the legacy-jax shims
    all_gather_compat,
    sync_allreduce,
    sync_hier,
    sync_hier_int8,
)

if not _LEGACY_SHARD_MAP:  # jax >= 0.6: shard_map in the top-level namespace
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
        """Adapt the new keyword surface onto jax.experimental.shard_map.

        ``axis_names`` lists the *manual* axes; the old API instead takes
        ``auto`` = the complement.  ``check_vma`` was called ``check_rep``.
        """
        manual = frozenset(mesh.axis_names if axis_names is None else axis_names)
        auto = frozenset(mesh.axis_names) - manual
        return _experimental_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, auto=auto,
        )


class TrainState(NamedTuple):
    adam: AdamWState
    ef: Any  # error-feedback pytree ( () when unused )
    diloco: Any  # DilocoState        ( () when unused )


def init_train_state(
    params, opt_cfg: AdamWConfig, *, strategy: str = "hier"
) -> TrainState:
    return TrainState(
        adam=init_adamw(params),
        ef=init_error_feedback(params) if strategy == "hier_int8" else (),
        diloco=init_diloco(params) if strategy == "local_sgd" else (),
    )


def state_pspecs(params_shapes, mesh: Mesh, *, strategy: str = "hier"):
    """PartitionSpecs for a TrainState matching the params' placement."""
    pspec = params_pspecs(params_shapes, mesh)
    return TrainState(
        adam=AdamWState(step=P(), m=pspec, v=pspec),
        ef=pspec if strategy == "hier_int8" else (),
        diloco=DilocoState(anchor=pspec, momentum=pspec) if strategy == "local_sgd" else (),
    )


def _tree_shardings(pspec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    opt_cfg: Optional[AdamWConfig] = None,
    strategy: str = "hier",
    num_channels: int = 4,
    diloco_cfg: Optional[DilocoConfig] = None,
    params_shapes=None,
    batch_shapes=None,
    donate: bool = True,
):
    """Build the jitted train step for (cfg, mesh, strategy).

    Returns (step_fn, shardings) where
      step_fn(params, state, batch) -> (params, state, metrics)
      shardings = {"params": ..., "state": ..., "batch": ...}
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy {strategy!r} not in {STRATEGIES}")
    opt_cfg = opt_cfg or AdamWConfig()
    diloco_cfg = diloco_cfg or DilocoConfig()
    multi_pod = "pod" in mesh.axis_names

    def inner(params, state: TrainState, batch, pod_idx=None):
        # ``pod_idx`` is a length-1 slice of arange(npods) sharded over the
        # manual "pod" axis — position info without jax.lax.axis_index,
        # whose PartitionId lowering old partitioners reject here.
        idx = pod_idx[0] if pod_idx is not None else None
        # batch enters sharded over "pod" only (manual); constrain the
        # embedding output onto "data" so GSPMD spreads activations without
        # partitioning the token-gather indices (XLA CPU partitioner bug —
        # see distributed/act_sharding.py).
        act_axes = "data" if multi_pod else (
            "data" if "data" in mesh.axis_names else None
        )
        seq_axes = "model" if "model" in mesh.axis_names else None
        if multi_pod and _LEGACY_SHARD_MAP:
            # pre-0.6 SPMD partitioners CHECK-fail on sharding constraints
            # naming auto axes inside a partial-manual region; the
            # constraints are perf hints, so dropping them is numerically
            # identical (activations stay GSPMD-propagated).
            act_axes = seq_axes = None
        with activation_sharding(act_axes, seq_axes):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg), has_aux=True
            )(params)
        new_ef = state.ef
        if multi_pod:
            npods = jax.lax.psum(1, "pod")
            metrics = {k: jax.lax.psum(v, "pod") / npods for k, v in metrics.items()}
            loss = jax.lax.psum(loss, "pod") / npods
            if strategy == "allreduce":
                grads = sync_allreduce(grads)
            elif strategy == "hier":
                grads = sync_hier(grads, num_channels=num_channels)
            elif strategy == "hier_int8":
                grads, new_ef = sync_hier_int8(grads, state.ef, axis_index=idx)
            elif strategy in ("ps", "local_sgd"):
                pass  # ps: handled after the optimizer; local_sgd: no WAN here

        new_params, new_adam, opt_metrics = adamw_update(
            opt_cfg, grads, state.adam, params
        )
        new_diloco = state.diloco

        if multi_pod and strategy == "ps":
            # pull phase of the parameter server: pod 0 is authoritative,
            # everyone receives its parameters (full WAN broadcast).  The
            # push phase is the all_gather of gradients below.
            gathered = jax.tree.map(
                lambda g: all_gather_compat(
                    g.astype(jnp.float32), "pod", axis_index=idx
                ),
                grads,
            )
            g_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), gathered)
            new_params, new_adam, opt_metrics = adamw_update(
                opt_cfg, g_mean, state.adam, params
            )
            server_idx = jax.lax.axis_index("pod") if idx is None else idx
            is_server = (server_idx == 0).astype(jnp.float32)
            new_params = jax.tree.map(
                lambda u: jax.lax.psum(u * is_server.astype(u.dtype), "pod"), new_params
            )

        if multi_pod and strategy == "local_sgd":
            def do_outer(operands):
                p, d = operands
                return outer_step(diloco_cfg, p, d)

            new_params, new_diloco = jax.lax.cond(
                new_adam.step % diloco_cfg.sync_every == 0,
                do_outer,
                lambda operands: operands,
                (new_params, new_diloco),
            )

        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics.update(opt_metrics)
        return new_params, TrainState(new_adam, new_ef, new_diloco), metrics

    # -- shardings -----------------------------------------------------------
    if params_shapes is None or batch_shapes is None:
        raise ValueError("params_shapes and batch_shapes are required")
    p_pspec = params_pspecs(params_shapes, mesh)
    b_pspec = batch_pspecs(batch_shapes, mesh)
    s_pspec = state_pspecs(params_shapes, mesh, strategy=strategy)
    if multi_pod:
        # jit-level batch placement is pod-only (the manual axis); "data"
        # spreading happens via the activation constraint inside.
        def _pod_only(spec: P) -> P:
            lead = spec[0] if len(spec) else None
            axes = lead if isinstance(lead, tuple) else (lead,)
            rest = [None] * max(len(spec) - 1, 0)
            return P("pod" if "pod" in axes else None, *rest)

        b_pspec = jax.tree.map(_pod_only, b_pspec, is_leaf=lambda x: isinstance(x, P))
    p_shard = _tree_shardings(p_pspec, mesh)
    b_shard = _tree_shardings(b_pspec, mesh)
    s_shard = _tree_shardings(s_pspec, mesh)

    if multi_pod:
        # pod axis is manual; everything else stays GSPMD-auto.
        def pod_batch_spec(spec: P) -> P:
            lead = spec[0] if len(spec) else None
            axes = lead if isinstance(lead, tuple) else (lead,)
            return P("pod" if "pod" in axes else None)

        in_specs = (
            jax.tree.map(lambda s: P(), p_pspec, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: P(), s_pspec, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(pod_batch_spec, b_pspec, is_leaf=lambda x: isinstance(x, P)),
            P("pod"),
        )
        out_specs = (
            jax.tree.map(lambda s: P(), p_pspec, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: P(), s_pspec, is_leaf=lambda x: isinstance(x, P)),
            P(),
        )
        sharded = _shard_map(
            inner,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names={"pod"},
            check_vma=False,
        )
        npods = int(mesh.shape["pod"])

        def fn(params, state, batch):
            return sharded(params, state, batch, jnp.arange(npods, dtype=jnp.int32))
    else:
        fn = inner

    jit_kwargs: Dict[str, Any] = dict(
        in_shardings=(p_shard, s_shard, b_shard),
        out_shardings=(p_shard, s_shard, None),
    )
    if donate:
        jit_kwargs["donate_argnums"] = (0, 1)
    step_fn = jax.jit(fn, **jit_kwargs)
    shardings = {"params": p_shard, "state": s_shard, "batch": b_shard}
    return step_fn, shardings


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, *, params_shapes, batch_shapes):
    """Inference prefill: logits for the last position + KV caches."""
    p_shard = params_shardings(params_shapes, mesh)
    b_shard = batch_shardings(batch_shapes, mesh)

    def fn(params, batch):
        return prefill(params, batch, cfg)

    cache_shapes = jax.eval_shape(fn, params_shapes, batch_shapes)[1]
    c_shard = cache_shardings(cache_shapes, mesh)
    step_fn = jax.jit(
        fn,
        in_shardings=(p_shard, b_shard),
        out_shardings=(None, c_shard),
    )
    return step_fn, {"params": p_shard, "batch": b_shard, "cache": c_shard}


def make_decode_step(
    cfg: ModelConfig, mesh: Mesh, *, params_shapes, cache_shapes, token_shapes
):
    """One-token serve step against a seq_len-deep cache (decode shapes)."""
    p_shard = params_shardings(params_shapes, mesh)
    c_shard = cache_shardings(cache_shapes, mesh)
    t_shard = batch_shardings(token_shapes, mesh)

    def fn(params, tokens_t, cache, position):
        return model_decode_step(params, tokens_t, cache, cfg, position)

    step_fn = jax.jit(
        fn,
        in_shardings=(p_shard, t_shard, c_shard, None),
        out_shardings=(None, c_shard),
        donate_argnums=(2,),
    )
    return step_fn, {"params": p_shard, "cache": c_shard, "tokens": t_shard}
