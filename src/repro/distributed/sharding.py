"""Sharding rules: parameter/batch/cache PartitionSpecs per mesh.

Policy (training *and* serving — 2-D weight sharding):

* projections' input-ish dim -> ``data`` (FSDP), output-ish dim -> ``model``
  (tensor parallelism); experts -> ``model`` (expert parallelism) with the
  expert FFN width additionally FSDP-sharded over ``data``;
* parameters are REPLICATED across ``pod`` — each data center holds a full
  replica, the geo-DP setting of the paper; only gradient synchronization
  crosses the WAN (see ``repro.distributed.sync``);
* batch dims shard over ``("pod", "data")``; KV caches shard batch over
  ``data`` and kv-heads over ``model``;
* a dim is sharded only when the mesh axis divides it — otherwise the rule
  falls back to replication for that dim (keeps odd vocabularies and tiny
  smoke configs compiling).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# rules keyed by parameter leaf name -> spec over the TRAILING dims.
# "F" = fsdp/data axis, "T" = tensor/model axis, "E" = expert/model axis,
# None = replicated.  Leading (stack) dims are padded with None.
_TRAILING_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings.  NOTE: the embedding table must not shard its non-vocab
    # dim over "data": XLA's SPMD partitioner (CPU pipeline) hits a CHECK
    # failure partitioning the token gather under a manual "pod" sub-mesh
    # when the gather operand is partially replicated over "data"
    # (PartitionGatherTrivialSlicedOperandDimensions -> ReplicatePartial).
    # Vocab-over-model is also the TP-friendly layout for the LM head.
    "embed": ("T", None),  # (V, D)
    "unembed": ("F", "T"),  # (D, V)
    # frontend_proj's output dim must ALSO avoid "data": its sharding
    # propagates through the prefix-concat onto the token-gather output,
    # retriggering the same partitioner CHECK.
    "frontend_proj": (None, "T"),  # (frontend_dim, D)
    # attention
    "wq": ("F", "T"),
    "wk": ("F", "T"),
    "wv": ("F", "T"),
    "wo": ("T", "F"),
    "bq": ("T",),
    "bk": ("T",),
    "bv": ("T",),
    "bo": (None,),
    # dense ffn
    "w_gate": ("F", "T"),
    "w_up": ("F", "T"),
    "w_down": ("T", "F"),
    "b_up": ("T",),
    "b_down": (None,),
    # rwkv time-mix / channel-mix
    "wr": ("F", "T"),
    "wg": ("F", "T"),
    "cm_k": ("F", "T"),
    "cm_v": ("T", "F"),
    "cm_r": ("F", "T"),
    "decay_a": ("F", None),
    "decay_b": (None, "F"),
    # rg-lru
    "w_in_x": ("F", "T"),
    "w_in_g": ("F", "T"),
    "w_gate_a": ("F", "T"),
    "w_gate_x": ("F", "T"),
    "w_out": ("T", "F"),
    "conv_w": (None, "T"),
    "conv_b": ("T",),
    # moe
    "router": ("F", None),
}

# MoE expert weights carry an extra leading E dim -> expert parallelism.
_MOE_TENSORS = {"w_gate", "w_up", "w_down"}
_MOE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("E", None, "F"),  # (E, D, F)
    "w_up": ("E", None, "F"),
    "w_down": ("E", "F", None),  # (E, F, D)
}


def _axis(mesh: Mesh, tag: Optional[str]) -> Optional[str]:
    if tag is None:
        return None
    name = {"F": "data", "T": "model", "E": "model"}[tag]
    return name if name in mesh.axis_names else None


def _spec_for(path: Tuple, leaf, mesh: Mesh) -> P:
    names = [getattr(k, "key", getattr(k, "name", getattr(k, "idx", None))) for k in path]
    leaf_name = names[-1] if names else None
    in_moe = any(n == "ffn" for n in names) and leaf_name in _MOE_TENSORS and leaf.ndim >= 3
    rank = len(leaf.shape)
    if in_moe and rank >= 3:
        trailing = _MOE_RULES[leaf_name]
        e_dim = rank - 3  # (..., E, D/F, F/D)
        if "model" in mesh.axis_names and leaf.shape[e_dim] % mesh.shape["model"] != 0:
            # few-expert MoE (e.g. Mixtral's 8 experts on a 16-way model
            # axis): EP doesn't divide, so shard the FFN width over BOTH
            # model and data jointly — otherwise 100+ GB of experts
            # replicate per model shard.
            f_axes = ("model", "data")
            ok = all(a in mesh.axis_names for a in f_axes)
            if ok:
                spec: list = [None] * rank
                width = 1
                for a in f_axes:
                    width *= mesh.shape[a]
                if leaf_name in ("w_gate", "w_up"):
                    f_dim = rank - 1  # (E, D, F)
                else:
                    f_dim = rank - 2  # (E, F, D)
                if leaf.shape[f_dim] % width == 0:
                    spec[f_dim] = f_axes
                    return P(*spec)
    else:
        trailing = _TRAILING_RULES.get(leaf_name)
    if trailing is None or rank < len(trailing):
        return P()
    spec: list = [None] * rank
    used = set()
    for i, tag in enumerate(trailing):
        dim = rank - len(trailing) + i
        axis = _axis(mesh, tag)
        if axis is None or axis in used:
            continue
        if leaf.shape[dim] % mesh.shape[axis] == 0 and leaf.shape[dim] > 0:
            spec[dim] = axis
            used.add(axis)
    return P(*spec)


def params_pspecs(params_shapes, mesh: Mesh):
    """PartitionSpec pytree for a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh), params_shapes
    )


def params_shardings(params_shapes, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), params_pspecs(params_shapes, mesh)
    )


# -- batch / cache ---------------------------------------------------------------


def _batch_axes(mesh: Mesh, size: int) -> P:
    """Shard a batch dim over ("pod","data") as divisibility allows."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    combo: Tuple[str, ...] = ()
    prod = 1
    for a in axes:
        if size % (prod * mesh.shape[a]) == 0:
            combo = combo + (a,)
            prod *= mesh.shape[a]
    return combo if combo else None


def batch_pspecs(batch_shapes, mesh: Mesh):
    """Shard every batch input over its leading (batch) dim."""

    def spec(leaf):
        b = _batch_axes(mesh, leaf.shape[0]) if leaf.ndim >= 1 else None
        return P(b, *([None] * max(leaf.ndim - 1, 0)))

    return jax.tree.map(spec, batch_shapes)


def batch_shardings(batch_shapes, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspecs(batch_shapes, mesh))


def cache_pspecs(cache_shapes, mesh: Mesh):
    """KV/recurrent cache sharding.

    Layout per leaf (after the optional leading group-stack dim):
      k/v:  [B, S, KVH, hd]  -> batch over data, kv-heads over model
      pos:  [S]              -> replicated
      wkv:  [B, H, N, N]     -> batch over data, heads over model
      conv/h/shift: [B, ...] -> batch over data
    """
    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        leaf_name = names[-1]
        stacked = any(n == "groups" for n in names)
        lead = (None,) if stacked else ()
        shape = leaf.shape[1:] if stacked else leaf.shape
        def div(dim_size, axis):
            return axis in mesh.axis_names and dim_size % mesh.shape[axis] == 0

        if leaf_name in ("k", "v") and len(shape) == 4:
            b, s, kvh, hd = shape
            # prefer kv-head TP; fall back to head_dim TP when kv_heads
            # don't divide (GQA with few kv heads on a wide model axis) —
            # without this, e.g. yi-34b decode_32k replicates a 1 TB cache.
            if div(kvh, "model"):
                kv_spec, hd_spec = "model", None
            elif div(hd, "model"):
                kv_spec, hd_spec = None, "model"
            else:
                kv_spec, hd_spec = None, None
            return P(*lead,
                     "data" if div(b, "data") else None,
                     None, kv_spec, hd_spec)
        if leaf_name == "wkv" and len(shape) == 4:
            b, h, n, _ = shape
            return P(*lead,
                     "data" if div(b, "data") else None,
                     "model" if div(h, "model") else None,
                     None, None)
        if leaf_name in ("h", "conv", "shift_att", "shift_ffn") and len(shape) >= 2:
            b = shape[0]
            d_last = shape[-1]
            mid = [None] * (len(shape) - 2)
            return P(*lead,
                     "data" if div(b, "data") else None,
                     *mid,
                     "model" if div(d_last, "model") else None)
        return P(*lead, *([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), cache_pspecs(cache_shapes, mesh))
