"""Gradient compression for the WAN hop (beyond-paper optimization).

Only cross-pod (inter-data-center) traffic is compressed: intra-pod ICI
collectives stay full precision.  Two compressors:

* :func:`int8_compress` / :func:`int8_decompress` — per-block absmax int8,
  blocks of 256 lanes along the LAST axis (leading dims untouched, so a
  GSPMD-sharded gradient never needs resharding to be compressed);
  4x byte reduction on fp32.  The Pallas kernel
  (``repro.kernels.wan_quant``) implements the same transform for the TPU
  hot path; this jnp version is its oracle and the CPU/dry-run path.

* :func:`topk_sparsify` — magnitude top-k with index+value transport.

:class:`ErrorFeedback` helpers accumulate the quantization residual per
pod and re-inject it the next step (Seide et al.; standard for convergent
compressed all-reduce).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Int8Compressed(NamedTuple):
    values: jnp.ndarray  # int8  [..., L_pad]
    scales: jnp.ndarray  # f32   [..., L_pad / BLOCK]
    orig_last: int  # unpadded last-dim size
    orig_shape: Tuple[int, ...]


def _as_2plus_d(x):
    """View with >=1 trailing lane dim (scalars/1-d promoted)."""
    if x.ndim == 0:
        return x.reshape(1)
    return x


def int8_compress(x: jnp.ndarray) -> Int8Compressed:
    orig_shape = tuple(x.shape)
    x2 = _as_2plus_d(x.astype(jnp.float32))
    last = x2.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x2 = jnp.pad(x2, [(0, 0)] * (x2.ndim - 1) + [(0, pad)])
    nblocks = x2.shape[-1] // BLOCK
    blocks = x2.reshape(*x2.shape[:-1], nblocks, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Int8Compressed(
        values=q.reshape(*x2.shape[:-1], nblocks * BLOCK),
        scales=scale[..., 0],
        orig_last=last,
        orig_shape=orig_shape,
    )


def int8_decompress(c: Int8Compressed) -> jnp.ndarray:
    lead = c.values.shape[:-1]
    nblocks = c.values.shape[-1] // BLOCK
    blocks = c.values.reshape(*lead, nblocks, BLOCK).astype(jnp.float32)
    full = (blocks * c.scales[..., None]).reshape(*lead, nblocks * BLOCK)
    return full[..., : c.orig_last].reshape(c.orig_shape)


def compressed_bytes(c: Int8Compressed) -> int:
    return int(c.values.size + c.scales.size * 4)


def topk_sparsify(x: jnp.ndarray, k_fraction: float = 0.01):
    """Magnitude top-k: returns (values, flat indices, shape)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * k_fraction))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx, tuple(x.shape)


def topk_densify(vals, idx, shape):
    size = 1
    for s in shape:
        size *= s
    flat = jnp.zeros((size,), vals.dtype)
    return flat.at[idx].set(vals).reshape(shape)


# -- error feedback ----------------------------------------------------------------


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def apply_error_feedback(grads, ef):
    """g' = g + residual (per leaf)."""
    return jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)


def residual(original, transmitted):
    """New residual = what compression lost this step."""
    return jax.tree.map(
        lambda o, t: o.astype(jnp.float32) - t.astype(jnp.float32), original, transmitted
    )
