"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Follows arXiv:2404.05892.  Per head of dimension ``N``:

    wkv_t   = sum_{i<=t} diag(prod_{j=i+1..t} w_j) k_i v_i^T   (+ bonus u k_t v_t^T)
    out_t   = r_t . (wkv state)

with the decay ``w_t = exp(-exp(w0 + lora(x_t)))`` data-dependent (the
Finch innovation over RWKV5's static decay).  Token-shift interpolations
use the RWKV6 "ddlerp" (data-dependent linear interpolation).

Two execution paths:

* :func:`wkv6_scan` — ``lax.scan`` over time (reference; O(T) state),
* a chunked Pallas kernel (``repro.kernels.rwkv6_wkv``) for the TPU target.

Decode is O(1): carry ``(wkv_state, shift_att, shift_ffn)`` per layer.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]

LORA_RANK = 64


def init_rwkv_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    f = cfg.d_ff
    n = cfg.rwkv_head_dim
    h = d // n
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    return {
        # time-mix projections
        "wr": dense_init(ks[0], (d, d), dtype=pdt),
        "wk": dense_init(ks[1], (d, d), dtype=pdt),
        "wv": dense_init(ks[2], (d, d), dtype=pdt),
        "wg": dense_init(ks[3], (d, d), dtype=pdt),
        "wo": dense_init(ks[4], (d, d), dtype=pdt),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x W_a) W_b))
        "decay_w0": jnp.full((h, n), -6.0, jnp.float32)
        + jnp.linspace(0.0, 2.0, n, dtype=jnp.float32)[None, :],
        "decay_a": dense_init(ks[5], (d, LORA_RANK), dtype=jnp.float32),
        "decay_b": dense_init(ks[6], (LORA_RANK, d), in_axis_size=LORA_RANK, dtype=jnp.float32),
        # per-head bonus u ("first token" boost)
        "bonus": jnp.zeros((h, n), jnp.float32),
        # token-shift mixing coefficients (static part of ddlerp)
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_g": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        # group-norm over heads at the output
        "gn_scale": jnp.ones((d,), jnp.float32),
        # channel-mix
        "cm_mix": jnp.full((d,), 0.5, jnp.float32),
        "cm_k": dense_init(ks[7], (d, f), dtype=pdt),
        "cm_v": dense_init(ks[8], (f, d), in_axis_size=f, dtype=pdt),
        "cm_r": dense_init(ks[9], (d, d), dtype=pdt),
    }


def _token_shift(x, shift_state):
    """Shift sequence right by one; position 0 takes ``shift_state``.

    x: [B, T, D]; shift_state: [B, D] (last token of the previous segment).
    Returns (shifted x, new shift_state = x[:, -1]).
    """
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def wkv6_scan(r, k, v, w, u):
    """Reference WKV6 recurrence via lax.scan over time.

    r, k, v: [B, T, H, N]; w: [B, T, H, N] (decay in (0,1)); u: [H, N].
    Returns out [B, T, H, N] and final state [B, H, N, N].

    State S has shape [B, H, N, N] with S[b,h,i,j] accumulating k_i * v_j.
    """
    b, t, h, n = r.shape
    init = jnp.zeros((b, h, n, n), jnp.float32)

    def step(state, inputs):
        r_t, k_t, v_t, w_t = inputs  # each [B, H, N]
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B, H, N, N]
        # bonus: current token contributes with boost u before decay folds in
        out = jnp.einsum("bhi,bhij->bhj", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., :, None] + kv
        return state, out

    xs = (
        jnp.moveaxis(r.astype(jnp.float32), 1, 0),
        jnp.moveaxis(k.astype(jnp.float32), 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(w.astype(jnp.float32), 1, 0),
    )
    final, outs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(outs, 0, 1), final  # [B, T, H, N], [B, H, N, N]


def _group_norm(x, scale, h, n, eps=1e-5):
    """Per-head layer norm over the head dim (RWKV's group_norm)."""
    b, t, d = x.shape
    xh = x.reshape(b, t, h, n).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    normed = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (normed.reshape(b, t, d) * scale).astype(x.dtype)


def time_mix(
    params: Params,
    x,  # [B, T, D]
    cfg: ModelConfig,
    *,
    shift_state,  # [B, D]
    wkv_state,  # [B, H, N, N]
):
    """RWKV6 attention replacement.  Returns (y, new_shift, new_wkv)."""
    b, t, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    dt = cfg.compute_dtype

    from repro.distributed.act_sharding import shard_heads

    prev, new_shift = _token_shift(x, shift_state)

    def lerp(mix):
        return x + (prev - x) * mix.astype(x.dtype)

    # heads (not seq) ride the model axis through the recurrence: the
    # chunked WKV reshapes the time dim, which must stay unsharded.
    r = shard_heads((lerp(params["mix_r"]) @ params["wr"].astype(dt)).reshape(b, t, h, n))
    k = shard_heads((lerp(params["mix_k"]) @ params["wk"].astype(dt)).reshape(b, t, h, n))
    v = shard_heads((lerp(params["mix_v"]) @ params["wv"].astype(dt)).reshape(b, t, h, n))
    g = jax.nn.silu(lerp(params["mix_g"]) @ params["wg"].astype(dt))

    # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(x a) b))
    xw = lerp(params["mix_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ params["decay_a"]) @ params["decay_b"]  # [B, T, D]
    log_neg = params["decay_w0"].reshape(1, 1, h, n) + dd.reshape(b, t, h, n)
    w = shard_heads(jnp.exp(-jnp.exp(log_neg)))  # in (0, 1)

    # recurrence (seeded with the carried state)
    out, new_wkv = _wkv_with_initial_state(r, k, v, w, params["bonus"], wkv_state)
    out = _group_norm(out.reshape(b, t, d).astype(dt), params["gn_scale"], h, n)
    y = (out * g) @ params["wo"].astype(dt)
    return y, new_shift, new_wkv


WKV_CHUNK = 256


def _wkv_with_initial_state(r, k, v, w, u, state0, *, chunk: int = WKV_CHUNK):
    """WKV recurrence, chunked+checkpointed over time.

    A naive T-step scan saves the per-step (B, H, N, N) key-value outer
    products for the backward pass — 206 GiB/device at train_4k scale
    (measured in the dry-run).  Processing the time axis in checkpointed
    chunks keeps only the chunk-boundary states (T/chunk of them) and
    recomputes inside each chunk during backward — the same schedule the
    Pallas kernel (kernels/rwkv6_wkv) uses on TPU, where the state lives
    in VMEM scratch across chunk steps.
    """
    b, t, h, n = r.shape

    def step(state, inputs):
        r_t, k_t, v_t, w_t = inputs
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., :, None] + kv
        return state, out

    def run_scan(state, rs, ks, vs, ws):
        xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (rs, ks, vs, ws))
        final, outs = jax.lax.scan(step, state, xs)
        return final, jnp.moveaxis(outs, 0, 1)

    state0 = state0.astype(jnp.float32)
    if t <= 2 * chunk or t % chunk != 0:
        final, outs = run_scan(state0, r, k, v, w)
        return outs, final

    nc = t // chunk

    def reshape(a):
        return jnp.moveaxis(
            a.reshape(b, nc, chunk, h, n), 1, 0
        )  # [nc, B, chunk, H, N]

    @jax.checkpoint
    def chunk_body(state, inputs):
        rs, ks, vs, ws = inputs
        final, outs = run_scan(state, rs, ks, vs, ws)
        return final, outs

    final, outs = jax.lax.scan(
        chunk_body, state0, (reshape(r), reshape(k), reshape(v), reshape(w))
    )
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, n)
    return outs, final


def channel_mix(params: Params, x, cfg: ModelConfig, *, shift_state):
    """RWKV6 FFN: squared-relu with token-shift and receptance gate."""
    dt = cfg.compute_dtype
    prev, new_shift = _token_shift(x, shift_state)
    mix = params["cm_mix"].astype(x.dtype)
    xk = x + (prev - x) * mix
    xr = x + (prev - x) * mix
    k = jnp.square(jax.nn.relu(xk @ params["cm_k"].astype(dt)))
    kv = k @ params["cm_v"].astype(dt)
    r = jax.nn.sigmoid(xr @ params["cm_r"].astype(dt))
    return r * kv, new_shift


def init_rwkv_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
        "shift_att": jnp.zeros((batch, d), cfg.compute_dtype),
        "shift_ffn": jnp.zeros((batch, d), cfg.compute_dtype),
    }
