"""Model stack: unified decoder covering all assigned architectures."""

from .config import ATTN, LOCAL, RECURRENT, RWKV, ModelConfig, MoEConfig
from .transformer import (
    IGNORE_LABEL,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "ATTN",
    "LOCAL",
    "RECURRENT",
    "RWKV",
    "IGNORE_LABEL",
    "ModelConfig",
    "MoEConfig",
    "decode_step",
    "forward",
    "init_decode_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
