"""Grouped-query attention with full/sliding-window masks and KV caching.

The jnp implementation here is the numerical reference and the path XLA
compiles in dry-runs; on TPU the inner product is replaced by the Pallas
flash-attention kernel (``repro.kernels.flash_attention``) when
``use_flash=True`` — both paths are tested against each other.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init, softcap

Params = Dict[str, jnp.ndarray]


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    q_out = cfg.num_heads * hd
    kv_out = cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d, q_out), dtype=jnp.dtype(cfg.param_dtype)),
        "wk": dense_init(ks[1], (d, kv_out), dtype=jnp.dtype(cfg.param_dtype)),
        "wv": dense_init(ks[2], (d, kv_out), dtype=jnp.dtype(cfg.param_dtype)),
        "wo": dense_init(ks[3], (q_out, d), in_axis_size=q_out, dtype=jnp.dtype(cfg.param_dtype)),
    }
    if cfg.use_bias_attn:
        params["bq"] = jnp.zeros((q_out,), jnp.dtype(cfg.param_dtype))
        params["bk"] = jnp.zeros((kv_out,), jnp.dtype(cfg.param_dtype))
        params["bv"] = jnp.zeros((kv_out,), jnp.dtype(cfg.param_dtype))
        params["bo"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return params


def _project_qkv(params: Params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    dt = cfg.compute_dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.use_bias_attn:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _out_proj(params: Params, attn_out, cfg: ModelConfig):
    B, S = attn_out.shape[:2]
    dt = cfg.compute_dtype
    y = attn_out.reshape(B, S, cfg.num_heads * cfg.head_dim) @ params["wo"].astype(dt)
    if cfg.use_bias_attn:
        y = y + params["bo"].astype(dt)
    return y


def _sdpa_dense(
    q, k, v, *, q_positions, k_positions, window, logit_softcap
) -> jnp.ndarray:
    """Fully materialized masked attention with GQA head grouping."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    groups = H // KVH
    qg = q.reshape(B, Sq, KVH, groups, hd)
    scale = hd ** -0.5
    # bf16 operands, f32 accumulation (MXU-native): upcasting q/k to f32
    # materializes f32 copies that XLA then all-gathers at double width
    # under tensor parallelism (§Perf arctic iteration 4).
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits * scale
    logits = softcap(logits, logit_softcap)
    mask = k_positions[None, :] <= q_positions[:, None]  # causal
    mask &= k_positions[None, :] >= 0  # empty cache slots
    if window is not None:
        mask &= k_positions[None, :] > q_positions[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(
    q, k, v, *, q_positions, k_positions, window, logit_softcap, block
) -> jnp.ndarray:
    """Query-block-sequential attention: flash-style O(block*Sk) memory.

    Scans over query blocks; each block attends to the full key range (or,
    for windowed attention, a ``window + block`` slice around the block —
    both the memory footprint and the FLOPs of sliding-window attention
    then scale with the window, not the sequence).

    NOTE for the dry-run roofline: ``cost_analysis`` counts the scan body
    once, so per-layer attention FLOPs must be corrected analytically
    (launch/dryrun.py::attn_flops).
    """
    B, Sq, H, hd = q.shape
    assert Sq % block == 0, (Sq, block)
    nb = Sq // block
    qb = jnp.moveaxis(q.reshape(B, nb, block, H, hd), 1, 0)  # [nb, B, blk, H, hd]
    pb = q_positions.reshape(nb, block)
    starts = jnp.arange(nb) * block

    kv_span = None if window is None else window + block

    def body(_, inp):
        qi, pi, start = inp
        if kv_span is None or kv_span >= k.shape[1]:
            ki, vi, kpi = k, v, k_positions
        else:
            # keys for queries [start, start+block) live in
            # [start - window + 1, start + block); clamp to array bounds —
            # the positional mask squelches any overhang.
            s = jnp.clip(start - (kv_span - block), 0, k.shape[1] - kv_span)
            ki = jax.lax.dynamic_slice_in_dim(k, s, kv_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, s, kv_span, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(k_positions, s, kv_span, axis=0)
        out = _sdpa_dense(
            qi, ki, vi,
            q_positions=pi, k_positions=kpi,
            window=window, logit_softcap=logit_softcap,
        )
        return None, out

    # checkpoint the block: without it, scan's backward saves each block's
    # softmax probs — re-materializing the full (Sq, Sk) matrix the chunking
    # exists to avoid.  Recompute-in-backward is the flash-attention deal.
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qb, pb, starts))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def _sdpa_chunked_kv(
    q, k, v, *, q_positions, k_positions, window, logit_softcap, block
) -> jnp.ndarray:
    """KV-block-sequential flash attention (online softmax in pure jnp).

    Scans over KEY blocks carrying running (max, normalizer, accumulator);
    queries are never reshaped or re-laid out, so a sequence-sharded q
    flows straight through under SP — only k/v (2*kv_heads*head_dim wide
    vs d_model for activations) need the sequence gather.  This is the
    same schedule as the Pallas flash kernel, expressed to XLA.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    assert Sk % block == 0, (Sk, block)
    nb = Sk // block
    KVH = k.shape[2]
    groups = H // KVH
    qg = q.reshape(B, Sq, KVH, groups, hd)
    scale = hd ** -0.5
    kb = jnp.moveaxis(k.reshape(B, nb, block, KVH, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, KVH, hd), 1, 0)
    pb = k_positions.reshape(nb, block)

    @jax.checkpoint
    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ki, vi, kpi = inp
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, ki, preferred_element_type=jnp.float32
        ) * scale
        logits = softcap(logits, logit_softcap)
        mask = kpi[None, :] <= q_positions[:, None]
        mask &= kpi[None, :] >= 0
        if window is not None:
            mask &= kpi[None, :] > q_positions[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, KVH, groups, Sq), -1e30, jnp.float32),
        jnp.zeros((B, KVH, groups, Sq), jnp.float32),
        jnp.zeros((B, KVH, groups, Sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1)  # [B, Sq, KVH, groups, hd]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def sdpa(
    q,  # [B, Sq, H, hd]
    k,  # [B, Sk, KVH, hd]
    v,  # [B, Sk, KVH, hd]
    *,
    q_positions,  # [Sq] absolute positions of queries
    k_positions,  # [Sk] absolute positions of keys (-1 = empty cache slot)
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    impl: str = "auto",
    block: int = 512,
) -> jnp.ndarray:
    """Masked scaled-dot-product attention with GQA head grouping.

    Causality and windowing are expressed purely through positions, so the
    same code serves training (q_positions == k_positions == arange) and
    decode (one query against a rolling cache with slot positions).
    """
    Sq, Sk = q.shape[1], k.shape[1]
    if impl == "chunked_kv" or (
        impl == "auto" and Sq >= 2 * block and Sq % block == 0 and Sk % block == 0
    ):
        return _sdpa_chunked_kv(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, logit_softcap=logit_softcap, block=block,
        )
    if impl == "chunked" or (impl == "auto" and Sq >= 2 * block and Sq % block == 0):
        return _sdpa_chunked(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, logit_softcap=logit_softcap, block=block,
        )
    return _sdpa_dense(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        window=window, logit_softcap=logit_softcap,
    )


def attention_forward(
    params: Params,
    x,  # [B, S, D]
    cfg: ModelConfig,
    *,
    window: Optional[int],
    positions=None,  # [S] absolute positions, defaults to arange
    return_cache: bool = False,
    cache_len: Optional[int] = None,  # total decode capacity (>= S)
):
    """Training / prefill attention; optionally returns the KV cache."""
    from repro.distributed.act_sharding import replicate_seq

    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(params, x, cfg)
    # sequence-parallel: q stays seq-sharded through the KV-block scan;
    # only k/v (2*kv_heads*head_dim wide, vs d_model for activations) are
    # gathered across the sequence — d_model/(2*kv*hd) ~ 7x fewer bytes
    # than all-gathering activations (§Perf yi-34b iteration 2).
    k, v = replicate_seq(k), replicate_seq(v)
    q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    out = sdpa(
        q, k, v,
        q_positions=positions, k_positions=positions,
        window=window, logit_softcap=cfg.attn_logit_softcap,
        impl=cfg.attn_impl, block=cfg.attn_block,
    )
    y = _out_proj(params, out, cfg)
    if not return_cache:
        return y, None
    cache = make_cache_from_prefill(
        k, v, positions, window=window, max_len=cache_len or S
    )
    return y, cache


# -- KV cache ------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, window: Optional[int]):
    """Empty rolling cache.  ``size = min(window, max_len)`` slots."""
    size = max_len if window is None else min(window, max_len)
    dt = cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dt),
        "pos": jnp.full((size,), -1, jnp.int32),  # absolute position per slot
    }


def make_cache_from_prefill(k, v, positions, *, window: Optional[int], max_len: int):
    """Cache holding the (windowed tail of the) prefill keys/values.

    The cache is sized for ``max_len`` total positions and laid out so that
    absolute position ``p`` occupies slot ``p % size`` — the invariant
    :func:`attention_decode` relies on when it writes new tokens.
    """
    n = k.shape[1]
    size = max_len if window is None else min(window, max_len)
    positions = positions.astype(jnp.int32)
    if n > size:  # keep only the windowed tail
        k, v, positions = k[:, -size:], v[:, -size:], positions[-size:]
        n = size
    if n < size:  # pad to capacity; empty slots flagged with pos = -1
        pad = size - n
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, (0, pad), constant_values=-1)
    # roll so that entry holding absolute position p sits at slot p % size
    first = positions[0]
    shift = jnp.where(first > 0, first % size, 0)
    k = jnp.roll(k, shift, axis=1)
    v = jnp.roll(v, shift, axis=1)
    positions = jnp.roll(positions, shift, axis=0)
    return {"k": k, "v": v, "pos": positions}


def attention_decode(
    params: Params,
    x_t,  # [B, 1, D]
    cache,
    cfg: ModelConfig,
    position,  # scalar int32: absolute position of the new token
    *,
    window: Optional[int],
):
    """One decode step against (and updating) a rolling KV cache."""
    q, k_new, v_new = _project_qkv(params, x_t, cfg)
    pos_arr = jnp.full((1,), position, dtype=jnp.int32)
    q = apply_rope(q, pos_arr, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k_new = apply_rope(k_new, pos_arr, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    size = cache["k"].shape[1]
    slot = position % size  # rolling for windows; affine for full caches
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_arr, slot, axis=0
    )
    out = sdpa(
        q, k, v,
        q_positions=pos_arr, k_positions=pos,
        window=window, logit_softcap=cfg.attn_logit_softcap,
    )
    y = _out_proj(params, out, cfg)
    return y, {"k": k, "v": v, "pos": pos}
