"""Shared building blocks: norms, rotary embeddings, initializers."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


# -- initializers -------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (1/sqrt(fan_in))."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# -- norms ---------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with f32 *reduction* but compute-dtype elementwise math.

    Upcasting the whole activation to f32 (the naive formulation) makes
    XLA materialize — and, under sequence parallelism, ALL-GATHER — f32
    copies of every (B, S, D) tensor, doubling collective and HBM traffic
    (measured on arctic-480b, §Perf iteration 3).  Only the variance
    reduction needs f32; the scaling multiply stays in bf16.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = x * inv
    if scale is not None:
        out = out * (1.0 + scale).astype(x.dtype)
    return out


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = (x - mean.astype(x.dtype)) * inv
    if scale is not None:
        out = out * scale.astype(x.dtype)
    if bias is not None:
        out = out + bias.astype(x.dtype)
    return out


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm: no learnable scale or bias."""
    return layer_norm(x, None, None, eps=eps)


def init_norm(key, cfg: ModelConfig):
    if cfg.norm == "nonparametric_ln":
        return {}
    if cfg.norm == "layernorm":
        return {
            "scale": jnp.ones((cfg.d_model,), jnp.float32),
            "bias": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    # rmsnorm: stored as (scale - 1) so zeros-init is identity
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def apply_norm(params, x, cfg: ModelConfig):
    if cfg.norm == "nonparametric_ln":
        return nonparametric_ln(x)
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    return rms_norm(x, params["scale"])


# -- rotary position embeddings --------------------------------------------------


def rope_frequencies(head_dim: int, fraction: float, theta: float):
    """Inverse frequencies for the rotated sub-dimension."""
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, *, theta: float = 10_000.0, fraction: float = 1.0):
    """Rotary embedding over the leading ``fraction`` of the head dim.

    ``fraction=1.0`` is standard (llama/starcoder); ``fraction=0.5`` is the
    ChatGLM "2d" convention where only half of each head rotates and the
    other half carries position-free content.

    x: [..., seq, heads, head_dim]; positions: [..., seq]
    """
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    angles = positions[..., None].astype(jnp.float32) * inv  # [..., seq, rot/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# -- activations -----------------------------------------------------------------


def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise ValueError(f"not a simple activation: {name}")


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
