"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(x_t W_a)                    # recurrence gate
    i_t = sigmoid(x_t W_x)                    # input gate
    a_t = a^(c * r_t)        with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

embedded in Griffin's recurrent block: linear in-proj to a 2-branch
(GeGLU-style gate + temporal conv1d(4) + RG-LRU) and linear out-proj.
Decode is O(1): state = (lru hidden [B, Dr], conv tail [B, 3, Dr]).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]

CONV_WIDTH = 4
LRU_C = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dr = cfg.d_rnn
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigmoid(Lambda)^c spans ~(0.9, 0.999)
    lam = jnp.log(jnp.linspace(0.9, 0.999, dr) ** (1.0 / LRU_C))
    lam = lam - jnp.log1p(-jnp.exp(lam))  # logit
    return {
        "w_in_x": dense_init(ks[0], (d, dr), dtype=pdt),  # recurrent branch
        "w_in_g": dense_init(ks[1], (d, dr), dtype=pdt),  # gate branch
        "conv_w": (jax.random.normal(ks[2], (CONV_WIDTH, dr)) * 0.1).astype(pdt),
        "conv_b": jnp.zeros((dr,), pdt),
        "lru_lambda": lam.astype(jnp.float32),
        "w_gate_a": dense_init(ks[3], (dr, dr), dtype=pdt),
        "w_gate_x": dense_init(ks[4], (dr, dr), dtype=pdt),
        "w_out": dense_init(ks[5], (dr, d), in_axis_size=dr, dtype=pdt),
    }


def _causal_conv1d(x, w, b, *, tail):
    """Depthwise causal conv, width CONV_WIDTH.

    x: [B, T, Dr]; tail: [B, CONV_WIDTH-1, Dr] from the previous segment.
    Returns (y [B, T, Dr], new_tail).
    """
    b_, t, dr = x.shape
    padded = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # [B, T+3, Dr]
    y = jnp.zeros_like(x)
    for i in range(CONV_WIDTH):
        y = y + padded[:, i : i + t, :] * w[i][None, None, :].astype(x.dtype)
    y = y + b[None, None, :].astype(x.dtype)
    new_tail = padded[:, t:, :]
    return y, new_tail


def rg_lru(x, r_gate, i_gate, lam, *, h0):
    """The RG-LRU recurrence via associative scan.

    x, r_gate, i_gate: [B, T, Dr]; h0: [B, Dr].
    Returns (h [B, T, Dr], h_last [B, Dr]).

    Uses the linear-recurrence composition (a1, b1) o (a2, b2) =
    (a1*a2, b1*a2 + b2) under jax.lax.associative_scan (log-depth on TPU).
    """
    log_a_base = jax.nn.log_sigmoid(lam)[None, None, :]  # [1,1,Dr]
    log_a = LRU_C * r_gate.astype(jnp.float32) * log_a_base
    a = jnp.exp(log_a)
    gated_x = (i_gate * x).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_seq = beta * gated_x

    # fold the initial state into the first element
    b_seq = b_seq.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_cum, h = jax.lax.associative_scan(combine, (a, b_seq), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_block(
    params: Params,
    x,  # [B, T, D]
    cfg: ModelConfig,
    *,
    state,  # {"h": [B, Dr], "conv": [B, 3, Dr]}
):
    """Griffin recurrent block. Returns (y, new_state)."""
    dt = cfg.compute_dtype
    branch_x = x @ params["w_in_x"].astype(dt)
    branch_g = jax.nn.gelu(x @ params["w_in_g"].astype(dt))
    conv_out, new_tail = _causal_conv1d(
        branch_x, params["conv_w"], params["conv_b"], tail=state["conv"]
    )
    r_gate = jax.nn.sigmoid(conv_out @ params["w_gate_a"].astype(dt))
    i_gate = jax.nn.sigmoid(conv_out @ params["w_gate_x"].astype(dt))
    h, h_last = rg_lru(conv_out, r_gate, i_gate, params["lru_lambda"], h0=state["h"])
    y = (h * branch_g) @ params["w_out"].astype(dt)
    return y, {"h": h_last, "conv": new_tail}


def init_rglru_state(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, cfg.d_rnn), cfg.compute_dtype),
    }
