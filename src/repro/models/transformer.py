"""Unified decoder stack covering all ten assigned architectures.

The layer stack is ``cfg.pattern`` repeated ``cfg.num_groups`` times (+ a
small unrolled remainder), executed with ``lax.scan`` over the groups so
compile time stays flat in depth.  Each *slot* of the pattern owns its own
stacked parameters, so heterogeneous patterns like RecurrentGemma's
(recurrent, recurrent, local_attn) scan cleanly.

Entry points (all pure functions over a params pytree):

* :func:`init_params`
* :func:`forward`      — training/prefill forward -> (logits, aux)
* :func:`prefill`      — forward + per-layer KV/recurrent caches
* :func:`decode_step`  — one token through the cache pytree
* :func:`loss_fn`      — next-token CE (+ router aux, z-loss)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_decode, attention_forward, init_attention, init_cache
from .config import ATTN, LOCAL, RECURRENT, RWKV, ModelConfig
from .ffn import dense_ffn, init_dense_ffn, init_moe, moe_ffn
from .layers import apply_norm, dense_init, embed_init, init_norm, softcap
from .rglru import init_rglru_block, init_rglru_state, rglru_block
from .rwkv6 import (
    channel_mix,
    init_rwkv_block,
    init_rwkv_state,
    time_mix,
)

Params = Dict[str, Any]
IGNORE_LABEL = -100


# -- per-kind layer init ---------------------------------------------------------


def _init_layer(key, kind: str, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: Params = {"norm1": init_norm(k3, cfg), "norm2": init_norm(k4, cfg)}
    if kind in (ATTN, LOCAL):
        params["attn"] = init_attention(k1, cfg)
        if cfg.moe is not None and kind == ATTN:
            params["ffn"] = init_moe(k2, cfg)
        else:
            params["ffn"] = init_dense_ffn(k2, cfg)
    elif kind == RECURRENT:
        params["rec"] = init_rglru_block(k1, cfg)
        params["ffn"] = init_dense_ffn(k2, cfg)
    elif kind == RWKV:
        params["rwkv"] = init_rwkv_block(k1, cfg)
    else:
        raise ValueError(kind)
    return params


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    # Embedding tables stay fp32 even under bf16 params: standard for
    # quality, and the fp32->bf16 convert between table and token gather is
    # load-bearing — without it the gather's operand is the sharded
    # parameter itself, which XLA's SPMD partitioner CHECK-fails on under
    # a manual "pod" sub-mesh (see distributed/act_sharding.py).
    params: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype=jnp.float32),
        "final_norm": init_norm(keys[1], cfg),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            keys[2], (cfg.d_model, cfg.vocab_size), dtype=jnp.float32
        )
    if cfg.frontend in ("patch", "frame"):
        params["frontend_proj"] = dense_init(
            keys[3], (cfg.frontend_dim, cfg.d_model), dtype=jnp.float32
        )
    # scanned groups: one stacked tree per pattern slot
    if cfg.num_groups > 0:
        slots = {}
        slot_keys = jax.random.split(keys[4], len(cfg.pattern))
        for s, kind in enumerate(cfg.pattern):
            gkeys = jax.random.split(slot_keys[s], cfg.num_groups)
            slots[f"slot{s}"] = jax.vmap(lambda k, kind=kind: _init_layer(k, kind, cfg))(gkeys)
        params["groups"] = slots
    # unrolled remainder layers
    if cfg.remainder:
        rkeys = jax.random.split(keys[5], len(cfg.remainder))
        params["remainder"] = [
            _init_layer(rkeys[i], kind, cfg) for i, kind in enumerate(cfg.remainder)
        ]
    return params


# -- blocks ----------------------------------------------------------------------


def _layer_window(kind: str, cfg: ModelConfig) -> Optional[int]:
    if kind == LOCAL:
        return cfg.local_window
    if kind == ATTN:
        return cfg.window
    return None


def _block_train(params: Params, x, kind: str, cfg: ModelConfig, positions):
    """One layer (training/prefill, no cache). Returns (x, aux, cache)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, LOCAL):
        h = apply_norm(params["norm1"], x, cfg)
        attn_out, _ = attention_forward(
            params["attn"], h, cfg, window=_layer_window(kind, cfg), positions=positions
        )
        x = x + attn_out
        h = apply_norm(params["norm2"], x, cfg)
        if cfg.moe is not None and kind == ATTN:
            ffn_out, aux = moe_ffn(params["ffn"], h, cfg)
        else:
            ffn_out = dense_ffn(params["ffn"], h, cfg)
        x = x + ffn_out
    elif kind == RECURRENT:
        b = x.shape[0]
        h = apply_norm(params["norm1"], x, cfg)
        rec_out, _ = rglru_block(params["rec"], h, cfg, state=init_rglru_state(cfg, b))
        x = x + rec_out
        h = apply_norm(params["norm2"], x, cfg)
        x = x + dense_ffn(params["ffn"], h, cfg)
    elif kind == RWKV:
        b = x.shape[0]
        st = init_rwkv_state(cfg, b)
        h = apply_norm(params["norm1"], x, cfg)
        tm_out, _, _ = time_mix(
            params["rwkv"], h, cfg, shift_state=st["shift_att"], wkv_state=st["wkv"]
        )
        x = x + tm_out
        h = apply_norm(params["norm2"], x, cfg)
        cm_out, _ = channel_mix(params["rwkv"], h, cfg, shift_state=st["shift_ffn"])
        x = x + cm_out
    else:
        raise ValueError(kind)
    return x, aux


def _block_prefill(params: Params, x, kind: str, cfg: ModelConfig, positions, max_len: int):
    """One layer, returning its decode cache."""
    if kind in (ATTN, LOCAL):
        h = apply_norm(params["norm1"], x, cfg)
        attn_out, cache = attention_forward(
            params["attn"], h, cfg,
            window=_layer_window(kind, cfg), positions=positions,
            return_cache=True, cache_len=max_len,
        )
        x = x + attn_out
        h = apply_norm(params["norm2"], x, cfg)
        if cfg.moe is not None and kind == ATTN:
            ffn_out, _ = moe_ffn(params["ffn"], h, cfg)
        else:
            ffn_out = dense_ffn(params["ffn"], h, cfg)
        x = x + ffn_out
        return x, cache
    if kind == RECURRENT:
        b = x.shape[0]
        h = apply_norm(params["norm1"], x, cfg)
        rec_out, state = rglru_block(params["rec"], h, cfg, state=init_rglru_state(cfg, b))
        x = x + rec_out
        h = apply_norm(params["norm2"], x, cfg)
        x = x + dense_ffn(params["ffn"], h, cfg)
        return x, state
    if kind == RWKV:
        b = x.shape[0]
        st = init_rwkv_state(cfg, b)
        h = apply_norm(params["norm1"], x, cfg)
        tm_out, shift_att, wkv = time_mix(
            params["rwkv"], h, cfg, shift_state=st["shift_att"], wkv_state=st["wkv"]
        )
        x = x + tm_out
        h = apply_norm(params["norm2"], x, cfg)
        cm_out, shift_ffn = channel_mix(params["rwkv"], h, cfg, shift_state=st["shift_ffn"])
        x = x + cm_out
        return x, {"wkv": wkv, "shift_att": shift_att, "shift_ffn": shift_ffn}
    raise ValueError(kind)


def _block_decode(params: Params, x_t, cache, kind: str, cfg: ModelConfig, position):
    """One layer, one token. Returns (x_t, new_cache)."""
    if kind in (ATTN, LOCAL):
        h = apply_norm(params["norm1"], x_t, cfg)
        attn_out, cache = attention_decode(
            params["attn"], h, cache, cfg, position, window=_layer_window(kind, cfg)
        )
        x_t = x_t + attn_out
        h = apply_norm(params["norm2"], x_t, cfg)
        if cfg.moe is not None and kind == ATTN:
            ffn_out, _ = moe_ffn(params["ffn"], h, cfg)
        else:
            ffn_out = dense_ffn(params["ffn"], h, cfg)
        return x_t + ffn_out, cache
    if kind == RECURRENT:
        h = apply_norm(params["norm1"], x_t, cfg)
        rec_out, state = rglru_block(params["rec"], h, cfg, state=cache)
        x_t = x_t + rec_out
        h = apply_norm(params["norm2"], x_t, cfg)
        return x_t + dense_ffn(params["ffn"], h, cfg), state
    if kind == RWKV:
        h = apply_norm(params["norm1"], x_t, cfg)
        tm_out, shift_att, wkv = time_mix(
            params["rwkv"], h, cfg, shift_state=cache["shift_att"], wkv_state=cache["wkv"]
        )
        x_t = x_t + tm_out
        h = apply_norm(params["norm2"], x_t, cfg)
        cm_out, shift_ffn = channel_mix(
            params["rwkv"], h, cfg, shift_state=cache["shift_ffn"]
        )
        x_t = x_t + cm_out
        return x_t, {"wkv": wkv, "shift_att": shift_att, "shift_ffn": shift_ffn}
    raise ValueError(kind)


# -- embedding / frontends -------------------------------------------------------


def embed_inputs(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Token + stub-frontend embedding -> (x [B, S, D], positions [S])."""
    from repro.distributed.act_sharding import shard_activations

    dt = cfg.compute_dtype
    if cfg.frontend == "frame":
        x = batch["frame_embeds"].astype(dt) @ params["frontend_proj"].astype(dt)
    else:
        x = params["embed"].astype(dt)[batch["tokens"]]
        if cfg.frontend == "patch":
            patches = batch["patch_embeds"].astype(dt) @ params["frontend_proj"].astype(dt)
            x = jnp.concatenate([patches, x], axis=1)
    x = shard_activations(x)  # batch dim -> ("pod",)"data" per active context
    positions = jnp.arange(x.shape[1])
    return x, positions


def unembed(params: Params, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    h = apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(dt).T
    else:
        logits = h @ params["unembed"].astype(dt)
    return softcap(logits, cfg.logits_softcap)


# -- full-stack passes -----------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return fn


def forward(params: Params, batch, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward pass -> (logits [B, S, V], moe_aux scalar)."""
    x, positions = embed_inputs(params, batch, cfg)

    from repro.distributed.act_sharding import shard_activations

    def group_body(carry, slot_params):
        x, aux = carry
        for s, kind in enumerate(cfg.pattern):
            x, a = _block_train(slot_params[f"slot{s}"], x, kind, cfg, positions)
            aux = aux + a
        # sequence-parallel boundary: the scan carry (= remat residual)
        # lives sharded over (batch, seq) between blocks.
        return (shard_activations(x), aux), None

    aux = jnp.zeros((), jnp.float32)
    if cfg.num_groups > 0:
        body = _maybe_remat(group_body, cfg)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])
        else:  # unrolled: used by the dry-run cost probes
            for i in range(cfg.num_groups):
                slot_i = jax.tree.map(lambda a, i=i: a[i], params["groups"])
                (x, aux), _ = body((x, aux), slot_i)
    for i, kind in enumerate(cfg.remainder):
        x, a = _block_train(params["remainder"][i], x, kind, cfg, positions)
        aux = aux + a
    logits = unembed(params, x, cfg)
    return logits, aux


def loss_fn(params: Params, batch, cfg: ModelConfig):
    """Next-token cross-entropy with label masking and aux losses."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    # standard causal shift: logits[t] predicts labels[t]
    logits = logits[:, : labels.shape[1], :]
    mask = (labels != IGNORE_LABEL).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    token_ll = jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(token_ll * mask).sum() / denom
    total = ce
    if cfg.z_loss:
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        zl = cfg.z_loss * jnp.mean(jnp.square(logz) * mask)
        total = total + zl
    if cfg.moe is not None:
        total = total + cfg.moe.router_aux_coef * aux
    metrics = {"ce": ce, "aux": aux, "tokens": denom}
    return total, metrics


def prefill(params: Params, batch, cfg: ModelConfig, *, max_len: Optional[int] = None):
    """Forward + caches. Returns (last-position logits [B, V], cache pytree)."""
    x, positions = embed_inputs(params, batch, cfg)
    max_len = max_len or x.shape[1]

    from repro.distributed.act_sharding import shard_activations

    def group_body(x, slot_params):
        caches = {}
        for s, kind in enumerate(cfg.pattern):
            x, cache = _block_prefill(
                slot_params[f"slot{s}"], x, kind, cfg, positions, max_len
            )
            caches[f"slot{s}"] = cache
        return shard_activations(x), caches

    cache: Params = {}
    if cfg.num_groups > 0:
        if cfg.scan_layers:
            x, cache["groups"] = jax.lax.scan(group_body, x, params["groups"])
        else:
            caches = []
            for i in range(cfg.num_groups):
                slot_i = jax.tree.map(lambda a, i=i: a[i], params["groups"])
                x, c = group_body(x, slot_i)
                caches.append(c)
            cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    rem = []
    for i, kind in enumerate(cfg.remainder):
        x, c = _block_prefill(params["remainder"][i], x, kind, cfg, positions, max_len)
        rem.append(c)
    if rem:
        cache["remainder"] = rem
    logits = unembed(params, x[:, -1:, :], cfg)[:, 0, :]
    return logits, cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Zero-filled cache pytree matching :func:`prefill`'s output."""

    def one(kind: str):
        if kind in (ATTN, LOCAL):
            return init_cache(cfg, batch, max_len, window=_layer_window(kind, cfg))
        if kind == RECURRENT:
            return init_rglru_state(cfg, batch)
        return init_rwkv_state(cfg, batch)

    cache: Params = {}
    if cfg.num_groups > 0:
        cache["groups"] = {
            f"slot{s}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_groups,) + a.shape), one(kind)
            )
            for s, kind in enumerate(cfg.pattern)
        }
    if cfg.remainder:
        cache["remainder"] = [one(kind) for kind in cfg.remainder]
    return cache


def decode_step(params: Params, tokens_t, cache, cfg: ModelConfig, position):
    """One decode step.

    tokens_t: [B] token ids (or [B, 1, frontend_dim] embeddings for the
    "frame" stub); position: scalar absolute position.
    Returns (logits [B, V], new cache).
    """
    from repro.distributed.act_sharding import shard_activations

    dt = cfg.compute_dtype
    if cfg.frontend == "frame":
        x_t = tokens_t.astype(dt) @ params["frontend_proj"].astype(dt)
    else:
        x_t = params["embed"].astype(dt)[tokens_t][:, None, :]
    x_t = shard_activations(x_t)

    def group_body(x_t, xs):
        slot_params, slot_cache = xs
        new_caches = {}
        for s, kind in enumerate(cfg.pattern):
            x_t, nc = _block_decode(
                slot_params[f"slot{s}"], x_t, slot_cache[f"slot{s}"], kind, cfg, position
            )
            new_caches[f"slot{s}"] = nc
        return x_t, new_caches

    new_cache: Params = {}
    if cfg.num_groups > 0:
        if cfg.scan_layers:
            x_t, new_cache["groups"] = jax.lax.scan(
                group_body, x_t, (params["groups"], cache["groups"])
            )
        else:
            caches = []
            for i in range(cfg.num_groups):
                take_i = lambda a, i=i: jax.tree.map(lambda v: v[i], a)
                x_t, c = group_body(x_t, (take_i(params["groups"]), take_i(cache["groups"])))
                caches.append(c)
            new_cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    if cfg.remainder:
        rem = []
        for i, kind in enumerate(cfg.remainder):
            x_t, nc = _block_decode(
                params["remainder"][i], x_t, cache["remainder"][i], kind, cfg, position
            )
            rem.append(nc)
        new_cache["remainder"] = rem
    logits = unembed(params, x_t, cfg)[:, 0, :]
    return logits, new_cache
