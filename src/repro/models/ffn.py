"""Feed-forward blocks: dense (GELU / SwiGLU / GeGLU) and mixture-of-experts.

Two MoE dispatch implementations, selectable per config:

* ``einsum`` — GShard-style grouped dispatch/combine one-hot einsums.
  Tokens are processed in groups of ``group_size`` so the dispatch tensor is
  ``(G, Tg, E, C)`` with per-group capacity ``C = ceil(cf * k * Tg / E)``;
  sharding the group axis over the batch mesh axes and the expert axis over
  the model axis lets GSPMD derive the canonical all-to-all schedule.
  Dispatch-einsum FLOPs are real but small (~2*T*E*C*D vs 6*T*k*D*F expert
  FLOPs); the roofline table reports the ratio.

* ``gather`` — argsort/gather based dispatch that avoids the one-hot
  matmuls entirely (true-FLOPs path, used in the §Perf hillclimb).

Arctic's "dense residual" (a small dense FFN in parallel with the MoE) is
supported via ``MoEConfig.parallel_dense``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import activation_fn, dense_init

Params = Dict[str, jnp.ndarray]

MOE_GROUP_SIZE = 512  # tokens per dispatch group (GShard "G" dimension)


# -- dense FFN -----------------------------------------------------------------


def init_dense_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        params = {
            "w_gate": dense_init(ks[0], (d, f), dtype=pdt),
            "w_up": dense_init(ks[1], (d, f), dtype=pdt),
            "w_down": dense_init(ks[2], (f, d), in_axis_size=f, dtype=pdt),
        }
    else:
        params = {
            "w_up": dense_init(ks[0], (d, f), dtype=pdt),
            "w_down": dense_init(ks[1], (f, d), in_axis_size=f, dtype=pdt),
        }
    if cfg.use_bias_mlp:
        params["b_up"] = jnp.zeros((f,), pdt)
        params["b_down"] = jnp.zeros((d,), pdt)
    return params


def dense_ffn(params: Params, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.activation in ("swiglu", "geglu"):
        inner = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        gate = inner(x @ params["w_gate"].astype(dt))
        up = x @ params["w_up"].astype(dt)
        if cfg.use_bias_mlp:
            up = up + params["b_up"].astype(dt)
        h = gate * up
    else:
        h = x @ params["w_up"].astype(dt)
        if cfg.use_bias_mlp:
            h = h + params["b_up"].astype(dt)
        h = activation_fn(cfg.activation)(h)
    y = h @ params["w_down"].astype(dt)
    if cfg.use_bias_mlp:
        y = y + params["b_down"].astype(dt)
    return y


# -- mixture of experts ----------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    moe = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, moe.num_experts
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    glu = cfg.activation in ("swiglu", "geglu")
    params: Params = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_up": dense_init(ks[1], (e, d, f), in_axis_size=d, dtype=pdt),
        "w_down": dense_init(ks[2], (e, f, d), in_axis_size=f, dtype=pdt),
    }
    if glu:
        params["w_gate"] = dense_init(ks[3], (e, d, f), in_axis_size=d, dtype=pdt)
    if moe.parallel_dense:
        params["dense"] = init_dense_ffn(ks[4], cfg)
    return params


def _router_probs(params: Params, x_flat, moe: MoEConfig):
    """Router softmax in fp32 + top-k selection with renormalized gates.

    Indices come from a stop-gradient top_k; gate values are recovered by
    one-hot contraction against the differentiable softmax.  This keeps
    gradients flowing to the router while avoiding top_k's scatter-based
    backward, which XLA's SPMD partitioner cannot handle beneath a manual
    "pod" sub-mesh (same CHECK failure as sharded gathers).
    """
    logits = x_flat.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    _, expert_idx = jax.lax.top_k(
        jax.lax.stop_gradient(probs), moe.num_experts_per_tok
    )
    gate_cols = [
        jnp.sum(probs * jax.nn.one_hot(expert_idx[:, j], probs.shape[-1]), axis=-1)
        for j in range(moe.num_experts_per_tok)
    ]
    gate_vals = jnp.stack(gate_cols, axis=-1)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def _aux_loss(probs, expert_idx, moe: MoEConfig):
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    e = moe.num_experts
    counts = jnp.zeros((e,), jnp.float32)
    for j in range(moe.num_experts_per_tok):
        counts = counts + jnp.sum(
            jax.nn.one_hot(expert_idx[:, j], e, dtype=jnp.float32), axis=0
        )
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p = probs.mean(axis=0)
    return e * jnp.sum(f * p)


def _capacity(tg: int, moe: MoEConfig) -> int:
    c = math.ceil(moe.capacity_factor * moe.num_experts_per_tok * tg / moe.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _expert_ffn(params: Params, xs, cfg: ModelConfig):
    """xs: (..., E, C, D) -> (..., E, C, D) through per-expert weights."""
    dt = cfg.compute_dtype
    glu = cfg.activation in ("swiglu", "geglu")
    if glu:
        inner = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        gate = inner(jnp.einsum("...ecd,edf->...ecf", xs, params["w_gate"].astype(dt)))
        up = jnp.einsum("...ecd,edf->...ecf", xs, params["w_up"].astype(dt))
        h = gate * up
    else:
        h = activation_fn(cfg.activation)(
            jnp.einsum("...ecd,edf->...ecf", xs, params["w_up"].astype(dt))
        )
    return jnp.einsum("...ecf,efd->...ecd", h, params["w_down"].astype(dt))


def _moe_constraint(x, spec_axes):
    """Best-effort sharding constraint on MoE intermediates.

    GSPMD's default schedule for the grouped dispatch einsums all-gathers
    the (g, e, c, d) dispatched activations across the data axis (~18
    GB/device/layer on arctic-480b, measured); pinning groups->data and
    experts->model keeps every einsum local and lets only the weight-grad
    all-reduces cross the fabric.
    """
    from repro.distributed.act_sharding import _SPEC

    spec = _SPEC.get()
    if spec is None:
        return x
    batch_axes, seq_axes = spec
    names = {"G": batch_axes, "E": seq_axes, None: None}
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*(names[a] for a in spec_axes))
    )


def _moe_einsum(params: Params, x_flat, cfg: ModelConfig):
    """GShard grouped dispatch/combine."""
    moe = cfg.moe
    t, d = x_flat.shape
    tg = min(MOE_GROUP_SIZE, t)
    assert t % tg == 0, f"token count {t} not divisible by group size {tg}"
    g = t // tg
    c = _capacity(tg, moe)
    e = moe.num_experts

    probs, gates, expert_idx = _router_probs(params, x_flat, moe)
    aux = _aux_loss(probs, expert_idx, moe)

    # per-group capacity assignment.  dispatch/combine are built directly
    # in the compute dtype: the f32 versions were the largest tensors the
    # backward pass saved and re-gathered (§Perf arctic iteration 2).
    dt = cfg.compute_dtype
    idx_g = expert_idx.reshape(g, tg, moe.num_experts_per_tok)
    gate_g = gates.reshape(g, tg, moe.num_experts_per_tok).astype(dt)
    dispatch = jnp.zeros((g, tg, e, c), dt)
    combine = jnp.zeros((g, tg, e, c), dt)
    counts = jnp.zeros((g, e), jnp.int32)
    for j in range(moe.num_experts_per_tok):
        onehot = jax.nn.one_hot(idx_g[:, :, j], e, dtype=jnp.int32)  # (g, tg, e)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]  # (g, tg, e)
        counts = counts + onehot.sum(axis=1)
        # one-hot contraction instead of take_along_axis: gathers with
        # sharded operands CHECK-fail in XLA's partitioner under a manual
        # pod sub-mesh (see distributed/act_sharding.py)
        pos_of_token = jnp.sum(pos * onehot, axis=-1)  # (g, tg)
        keep = pos_of_token < c
        slot_onehot = jax.nn.one_hot(pos_of_token, c, dtype=dt)  # (g, tg, c)
        contrib = (
            onehot.astype(dt)[..., None]
            * slot_onehot[:, :, None, :]
            * keep[..., None, None].astype(dt)
        )
        dispatch = dispatch + contrib
        combine = combine + contrib * gate_g[:, :, j][..., None, None]

    x_g = _moe_constraint(x_flat.reshape(g, tg, d), ("G", None, None))
    dispatch = _moe_constraint(dispatch, ("G", None, "E", None))
    combine = _moe_constraint(combine, ("G", None, "E", None))
    xs = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), x_g)  # (g, e, c, d)
    xs = _moe_constraint(xs, ("G", "E", None, None))
    ys = _moe_constraint(_expert_ffn(params, xs, cfg), ("G", "E", None, None))
    y_g = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ys)
    y_g = _moe_constraint(y_g, ("G", None, None))
    return y_g.reshape(t, d), aux


def _moe_gather(params: Params, x_flat, cfg: ModelConfig):
    """Sort/gather dispatch: no one-hot matmuls (true-FLOPs path)."""
    moe = cfg.moe
    t, d = x_flat.shape
    e = moe.num_experts
    k = moe.num_experts_per_tok
    c = _capacity(t, moe)

    probs, gates, expert_idx = _router_probs(params, x_flat, moe)
    aux = _aux_loss(probs, expert_idx, moe)

    flat_expert = expert_idx.reshape(-1)  # (t*k,)
    flat_gate = gates.reshape(-1).astype(jnp.float32)
    flat_token = jnp.repeat(jnp.arange(t), k)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (t*k, e)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_of = jnp.take_along_axis(pos, flat_expert[:, None], axis=-1)[:, 0]
    keep = pos_of < c
    slot = jnp.where(keep, flat_expert * c + pos_of, e * c)  # overflow -> dropped

    # token index per (expert, capacity) slot; e*c slot table (+1 spill row)
    token_of_slot = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(flat_token, mode="drop")
    gate_of_slot = jnp.zeros((e * c + 1,), jnp.float32).at[slot].set(flat_gate, mode="drop")
    filled = jnp.zeros((e * c + 1,), jnp.bool_).at[slot].set(keep, mode="drop")

    xs = jnp.take(x_flat, token_of_slot[: e * c], axis=0)  # (e*c, d)
    xs = xs * filled[: e * c, None].astype(xs.dtype)
    ys = _expert_ffn(params, xs.reshape(1, e, c, d), cfg)[0]  # (e, c, d)
    weighted = ys.reshape(e * c, d) * gate_of_slot[: e * c, None].astype(ys.dtype)
    out = jax.ops.segment_sum(weighted, token_of_slot[: e * c], num_segments=t)
    return out.astype(x_flat.dtype), aux


def moe_ffn(params: Params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE feed-forward over x: [B, S, D] -> ([B, S, D], aux_loss)."""
    assert cfg.moe is not None
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    if cfg.moe.impl == "einsum":
        y, aux = _moe_einsum(params, x_flat, cfg)
    elif cfg.moe.impl == "gather":
        y, aux = _moe_gather(params, x_flat, cfg)
    else:
        raise ValueError(f"unknown moe impl {cfg.moe.impl!r}")
    y = y.reshape(b, s, d)
    if cfg.moe.parallel_dense:
        y = y + dense_ffn(params["dense"], x, cfg)
    return y, aux
