"""Model configuration for every assigned architecture family.

One :class:`ModelConfig` describes any of the ten architectures: dense GQA
transformers, sliding-window/local-attention variants, MoE (with optional
parallel dense residual, as in Arctic), RWKV6 (attention-free), and the
RG-LRU/local-attention hybrid (RecurrentGemma).  The layer stack is given
as a repeating ``pattern`` of layer kinds plus an optional remainder, which
keeps ``lax.scan``-over-layers applicable to heterogeneous stacks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# Layer kinds appearing in ``pattern``.
ATTN = "attn"  # (self-)attention block (full / windowed per config)
LOCAL = "local_attn"  # short-window local attention (RecurrentGemma)
RECURRENT = "recurrent"  # RG-LRU recurrent block
RWKV = "rwkv"  # RWKV6 time-mix + channel-mix block
LAYER_KINDS = (ATTN, LOCAL, RECURRENT, RWKV)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    num_experts_per_tok: int = 2
    capacity_factor: float = 1.25
    # Arctic: a small dense FFN runs in parallel with the MoE ("dense residual")
    parallel_dense: bool = False
    # router implementation: "einsum" (GShard dispatch/combine einsums, robust
    # GSPMD sharding) or "gather" (sort/gather based, true-FLOPs path)
    impl: str = "einsum"
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # layer stack: ``pattern`` repeats; remainder layers appended at the end.
    # dense default: ("attn",) * 1 repeated num_layers times.
    pattern: Tuple[str, ...] = (ATTN,)

    # attention
    window: Optional[int] = None  # sliding window for ATTN (None = full)
    local_window: int = 2048  # window for LOCAL layers
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # chatglm applies rotary to half the head dim
    attn_logit_softcap: Optional[float] = None
    # attention execution: "naive" materializes (Sq, Sk) logits; "chunked"
    # processes query blocks sequentially (flash-style memory, O(block*Sk));
    # "auto" chunks when Sq >= 2*attn_block.  On TPU the Pallas flash kernel
    # replaces both (kernels/flash_attention).
    attn_impl: str = "auto"
    attn_block: int = 512

    # norms / activations
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln (OLMo)
    activation: str = "swiglu"  # swiglu | geglu | gelu | relu_sq
    parallel_block: bool = False  # attn+ffn in parallel (not used by defaults)
    tie_embeddings: bool = False
    use_bias_attn: bool = False  # starcoder2 / chatglm3 qkv bias
    use_bias_mlp: bool = False  # starcoder2

    # MoE
    moe: Optional[MoEConfig] = None

    # RWKV6 / RG-LRU
    rwkv_head_dim: int = 64
    d_rnn: Optional[int] = None  # RG-LRU recurrence width (defaults d_model)
    lru_block_width: Optional[int] = None

    # stub modality frontends (backbone-only per assignment):
    #   "none"  — token ids in, standard LM
    #   "patch" — precomputed patch embeddings prepended to token embeddings
    #   "frame" — precomputed frame embeddings in, projected to d_model
    frontend: str = "none"
    frontend_dim: int = 1024  # incoming embedding width for patch/frame stubs
    num_prefix_tokens: int = 256  # patch count for the vlm stub

    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    logits_softcap: Optional[float] = None
    z_loss: float = 1e-4

    # training-time behaviour
    remat: str = "none"  # none | full | dots  — activation checkpoint policy
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.d_rnn is None:
            object.__setattr__(self, "d_rnn", self.d_model)
        for kind in self.pattern:
            if kind not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {kind!r}")
        if self.num_heads % self.num_kv_heads != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # -- stack helpers -------------------------------------------------------

    @property
    def num_groups(self) -> int:
        """Number of full pattern repetitions."""
        return self.num_layers // len(self.pattern)

    @property
    def remainder(self) -> Tuple[str, ...]:
        """Layer kinds left over after the repeating groups."""
        r = self.num_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def attn_free(self) -> bool:
        return all(k in (RWKV, RECURRENT) for k in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: every layer's state is o(seq_len)."""
        return all(
            k in (RWKV, RECURRENT, LOCAL) or (k == ATTN and self.window is not None)
            for k in self.pattern
        )

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    # -- size accounting ------------------------------------------------------

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stacked layers + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # unembedding
        total += d  # final norm (rmsnorm scale); ok to count even if nonparam
        kinds = list(self.pattern) * self.num_groups + list(self.remainder)
        for kind in kinds:
            total += self._layer_params(kind)
        if self.frontend in ("patch", "frame"):
            total += self.frontend_dim * d  # stub projection
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        ffn = self._ffn_expert_params()
        inactive = (self.moe.num_experts - self.moe.num_experts_per_tok) * ffn
        n_moe_layers = sum(
            1 for k in (list(self.pattern) * self.num_groups + list(self.remainder)) if k == ATTN
        )
        return full - inactive * n_moe_layers

    def _ffn_expert_params(self) -> int:
        d, f = self.d_model, self.d_ff
        return 3 * d * f if self.activation in ("swiglu", "geglu") else 2 * d * f

    def _layer_params(self, kind: str) -> int:
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        norms = 2 * d if self.norm != "nonparametric_ln" else 0
        if kind in (ATTN, LOCAL):
            attn = d * q + 2 * d * kv + q * d
            if self.moe is not None and kind == ATTN:
                ffn = self.moe.num_experts * self._ffn_expert_params()
                ffn += d * self.moe.num_experts  # router
                if self.moe.parallel_dense:
                    ffn += self._ffn_expert_params()
            else:
                ffn = self._ffn_expert_params()
            return attn + ffn + norms
        if kind == RECURRENT:
            dr = self.d_rnn
            # RG-LRU block: in/out proj + conv1d(4) + gates a/x + ffn
            block = 2 * d * dr + 4 * dr + 2 * dr * dr // 8 + dr  # low-rank-ish gates
            return block + self._ffn_expert_params() + norms
        if kind == RWKV:
            # time-mix: r,k,v,g,o projections + decay/lora + channel-mix
            tm = 5 * d * d + 2 * d * 64 + 64 * d  # lora for data-dependent decay
            cm = 2 * d * int(f) if self.activation == "relu_sq" else 2 * d * f
            return tm + cm + norms
        raise ValueError(kind)
