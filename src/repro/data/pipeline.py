"""Deterministic synthetic LM data pipeline (WikiText-2 stand-in).

The paper fine-tunes DistilGPT2 on WikiText-2; this container has no
dataset downloads, so we generate a *learnable* synthetic corpus: a
hidden-state Markov source over a Zipf-distributed vocabulary.  The
source has real mutual information between consecutive tokens, so the
training loss decreases exactly as a real corpus' would (tests assert
this), while every batch is a pure function of (seed, step, host) —
bit-identical resume after checkpoint restore, no data files.

Sharding: each host draws only its slice of the global batch
(``host_index / num_hosts``), matching multi-host JAX data loading.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_states: int = 64  # hidden Markov states
    zipf_a: float = 1.2
    frontend: str = "none"  # none | patch | frame (mirrors ModelConfig)
    frontend_dim: int = 32
    num_prefix_tokens: int = 4


class SyntheticCorpus:
    """Hidden-Markov token source with Zipfian emission."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, s = cfg.vocab_size, cfg.num_states
        # transition matrix: sparse-ish, row-stochastic
        trans = rng.dirichlet(np.full(s, 0.1), size=s)
        self.trans_cum = np.cumsum(trans, axis=1)
        # per-state emission: a Zipf ranking permuted per state
        ranks = np.arange(1, v + 1, dtype=np.float64)
        base = 1.0 / ranks ** cfg.zipf_a
        emissions = np.stack(
            [base[rng.permutation(v)] for _ in range(s)], axis=0
        )
        emissions /= emissions.sum(axis=1, keepdims=True)
        self.emit_cum = np.cumsum(emissions, axis=1)

    def sample(self, rng: np.random.Generator, batch: int, length: int) -> np.ndarray:
        s = rng.integers(0, self.cfg.num_states, size=batch)
        out = np.empty((batch, length), np.int32)
        for t in range(length):
            u = rng.random(batch)
            rows = self.emit_cum[s]
            out[:, t] = (rows < u[:, None]).sum(axis=1)
            u2 = rng.random(batch)
            s = (self.trans_cum[s] < u2[:, None]).sum(axis=1)
        np.clip(out, 0, self.cfg.vocab_size - 1, out=out)
        return out


class ShardedLoader:
    """Deterministic per-host batch iterator with O(1) seek (resume)."""

    def __init__(
        self,
        cfg: DataConfig,
        *,
        host_index: int = 0,
        num_hosts: int = 1,
        start_step: int = 0,
    ):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.step = start_step
        self.corpus = SyntheticCorpus(cfg)

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_index)
        )

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng_for(self.step)
        local = cfg.global_batch // self.num_hosts
        batch: Dict[str, np.ndarray] = {}
        if cfg.frontend == "frame":
            tokens = self.corpus.sample(rng, local, cfg.seq_len)
            batch["frame_embeds"] = rng.standard_normal(
                (local, cfg.seq_len, cfg.frontend_dim), dtype=np.float32
            )
            batch["labels"] = tokens
        elif cfg.frontend == "patch":
            p = cfg.num_prefix_tokens
            tokens = self.corpus.sample(rng, local, cfg.seq_len - p)
            batch["tokens"] = tokens
            batch["patch_embeds"] = rng.standard_normal(
                (local, p, cfg.frontend_dim), dtype=np.float32
            )
            labels = np.full((local, cfg.seq_len), -100, np.int32)
            labels[:, p:] = tokens
            batch["labels"] = labels
        else:
            tokens = self.corpus.sample(rng, local, cfg.seq_len + 1)
            batch["tokens"] = tokens[:, :-1]
            batch["labels"] = tokens[:, 1:]
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def loader_for_model(model_cfg, *, seq_len: int, global_batch: int, seed: int = 0,
                     host_index: int = 0, num_hosts: int = 1, start_step: int = 0):
    """Build a loader matching a ModelConfig's frontend contract."""
    cfg = DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        frontend=model_cfg.frontend,
        frontend_dim=model_cfg.frontend_dim,
        num_prefix_tokens=model_cfg.num_prefix_tokens,
    )
    return ShardedLoader(
        cfg, host_index=host_index, num_hosts=num_hosts, start_step=start_step
    )
