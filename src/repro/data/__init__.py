from .pipeline import DataConfig, ShardedLoader, SyntheticCorpus, loader_for_model

__all__ = ["DataConfig", "ShardedLoader", "SyntheticCorpus", "loader_for_model"]
