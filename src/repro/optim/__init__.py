"""Optimizers: native AdamW + DiLoCo outer optimizer for local_sgd."""

from .adamw import AdamWConfig, AdamWState, adamw_update, clip_by_global_norm, global_norm, init_adamw, schedule_lr
from .diloco import DilocoConfig, DilocoState, init_diloco, outer_step

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_update", "clip_by_global_norm",
    "global_norm", "init_adamw", "schedule_lr",
    "DilocoConfig", "DilocoState", "init_diloco", "outer_step",
]
