"""AdamW implemented natively on pytrees (no optax dependency).

Supports: global-norm clipping, decoupled weight decay, fp32 moments over
bf16 params, linear-warmup + cosine schedules, and donation-friendly
update signatures for the distributed step builders.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | constant


class AdamWState(NamedTuple):
    step: jnp.ndarray  # i32 scalar
    m: Any  # fp32 pytree
    v: Any  # fp32 pytree


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule_lr(cfg: AdamWConfig, step) -> jnp.ndarray:
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = jnp.clip(
            (step_f - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    cfg: AdamWConfig, grads, state: AdamWState, params
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics: Dict[str, jnp.ndarray] = {}
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm is not None:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = norm
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    metrics["lr"] = lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
