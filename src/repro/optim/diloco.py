"""DiLoCo-style outer optimization for the ``local_sgd`` sync strategy.

Each pod (data center) runs H inner AdamW steps with NO WAN traffic; every
H steps the pods exchange parameter deltas once and apply an outer
Nesterov-momentum step.  This is the communication-frequency reduction the
paper's related-work section points to (federated/communication-efficient
training) made first-class: WAN bytes drop by ~H/(compression) while the
outer momentum keeps replicas converging.

All functions assume a manual ``pod`` axis (inside shard_map).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class DilocoConfig(NamedTuple):
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    sync_every: int = 8  # H


class DilocoState(NamedTuple):
    anchor: Any  # fp32 params at last outer sync (replicated across pods)
    momentum: Any  # fp32 outer Nesterov momentum


def init_diloco(params) -> DilocoState:
    f32 = lambda p: p.astype(jnp.float32)
    return DilocoState(
        anchor=jax.tree.map(f32, params),
        momentum=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def outer_step(
    cfg: DilocoConfig, params, state: DilocoState, *, axis: str = "pod"
) -> Tuple[Any, DilocoState]:
    """Cross-pod outer Nesterov step on parameter deltas.

    delta   = anchor - params          (per pod; what inner steps learned)
    d_mean  = psum(delta) / npods      (the ONLY WAN transfer)
    mom     = beta * mom + d_mean
    params' = anchor - outer_lr * (beta * mom + d_mean)   (Nesterov)
    anchor' = params'
    """
    n = jax.lax.psum(1, axis)

    def one(anchor, p, mom):
        delta = anchor - p.astype(jnp.float32)
        d_mean = jax.lax.psum(delta, axis) / n
        new_mom = cfg.outer_momentum * mom + d_mean
        step = cfg.outer_momentum * new_mom + d_mean  # Nesterov look-ahead
        new_p = anchor - cfg.outer_lr * step
        return new_p.astype(p.dtype), new_p, new_mom

    flat_a, treedef = jax.tree.flatten(state.anchor)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state.momentum)
    out = [one(a, p, m) for a, p, m in zip(flat_a, flat_p, flat_m)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_anchor = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_mom = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, DilocoState(anchor=new_anchor, momentum=new_mom)
