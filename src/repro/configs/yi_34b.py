"""yi-34b [dense] — llama-architecture GQA, the largest dense arch.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 [arXiv:2403.04652]
"""

from repro.models.config import ModelConfig

ARCH_ID = "yi-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=5_000_000.0,
        param_dtype="bfloat16",  # halves FSDP weight-gather bytes (§Perf yi iter 3)
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=3,
        d_model=56,
        num_heads=7,
        num_kv_heads=1,
        d_ff=160,
        vocab_size=256,
        activation="swiglu",
        norm="rmsnorm",
        dtype="float32",
    )
