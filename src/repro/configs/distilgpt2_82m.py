"""distilgpt2-82m — the paper's own workload (§5.5, Fig. 14).

6L d_model=768 12H d_ff=3072 vocab=50257, ~82M parameters.  Both the
AllReduce (M2) and Parameter-Server (M1) geo-training experiments
fine-tune this model; per-batch gradient volume ~312 MB (DDP fp32 grads)
matches the paper's measurement.

(The original uses learned positional embeddings; we use RoPE — the
parameter count and communication volume, which is what the paper
measures, are preserved.)
"""

from repro.models.config import ModelConfig

ARCH_ID = "distilgpt2-82m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=6,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=50257,
        activation="gelu",
        norm="layernorm",
        tie_embeddings=True,
        use_bias_attn=True,
        use_bias_mlp=True,
        remat="none",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=256,
        activation="gelu",
        norm="layernorm",
        tie_embeddings=True,
        use_bias_attn=True,
        use_bias_mlp=True,
        dtype="float32",
    )
