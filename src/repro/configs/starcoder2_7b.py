"""starcoder2-7b [dense] — GQA kv=4, RoPE, GELU MLP with biases.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 [arXiv:2402.19173]
"""

from repro.models.config import ModelConfig

ARCH_ID = "starcoder2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        activation="gelu",
        norm="layernorm",
        use_bias_attn=True,
        use_bias_mlp=True,
        rope_theta=100_000.0,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=72,
        num_heads=6,
        num_kv_heads=2,
        d_ff=288,
        vocab_size=256,
        activation="gelu",
        norm="layernorm",
        use_bias_attn=True,
        use_bias_mlp=True,
        dtype="float32",
    )
