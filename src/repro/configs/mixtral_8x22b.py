"""mixtral-8x22b [moe] — 8-expert top-2 MoE with sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2
[arXiv:2401.04088]

SWA (window 4096) makes this arch `long_500k`-eligible: the decode KV
cache is bounded by the window regardless of context length.
"""

from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        activation="swiglu",
        norm="rmsnorm",
        window=4096,  # sliding-window attention
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, capacity_factor=1.25),
        param_dtype="bfloat16",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        activation="swiglu",
        norm="rmsnorm",
        window=8,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, capacity_factor=2.0),
        dtype="float32",
    )
