"""arctic-480b [moe] — 128-expert top-2 MoE with parallel dense residual.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base]

Snowflake Arctic's "dense-MoE hybrid": every layer runs a small dense FFN
*in parallel* with the 128-expert MoE (``MoEConfig.parallel_dense``).
"""

from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "arctic-480b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        activation="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=128,
            num_experts_per_tok=2,
            capacity_factor=1.25,
            parallel_dense=True,
            impl="einsum",
        ),
        param_dtype="bfloat16",  # 480B params: bf16 + fp32 master offline
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        activation="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=8,
            num_experts_per_tok=2,
            capacity_factor=2.0,
            parallel_dense=True,
        ),
        dtype="float32",
    )
