"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284]

Backbone only per the assignment: the EnCodec frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (one fused
embedding per audio frame across the four codebooks).
"""

from repro.models.config import ModelConfig

ARCH_ID = "musicgen-large"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        activation="gelu",
        norm="layernorm",
        frontend="frame",
        frontend_dim=512,  # EnCodec latent width
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        activation="gelu",
        norm="layernorm",
        frontend="frame",
        frontend_dim=32,
        dtype="float32",
    )
