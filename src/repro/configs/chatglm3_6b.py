"""chatglm3-6b [dense] — 2d RoPE (half-dim rotary), GQA kv=2, QKV bias.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 [arXiv:2406.12793]
"""

from repro.models.config import ModelConfig

ARCH_ID = "chatglm3-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        activation="swiglu",
        norm="rmsnorm",
        rope_fraction=0.5,  # GLM "2d" rotary: only half of each head rotates
        use_bias_attn=True,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        activation="swiglu",
        norm="rmsnorm",
        rope_fraction=0.5,
        use_bias_attn=True,
        dtype="float32",
    )
