"""rwkv6-7b [ssm] — "Finch": attention-free, data-dependent decay.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 [arXiv:2404.05892]

O(1) decode state (per layer: WKV [H, N, N] + two token-shift vectors),
so `long_500k` runs natively. The flash-attention kernel is inapplicable
(no attention); the WKV Pallas kernel is the hot-spot instead.
"""

from repro.models.config import ModelConfig

ARCH_ID = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,  # informational: d_model / rwkv_head_dim
        num_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        pattern=("rwkv",),
        rwkv_head_dim=64,
        activation="relu_sq",  # RWKV channel-mix uses squared ReLU
        norm="layernorm",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=224,
        vocab_size=256,
        pattern=("rwkv",),
        rwkv_head_dim=16,
        activation="relu_sq",
        norm="layernorm",
        dtype="float32",
    )
