"""Architecture registry: the ten assigned archs + the paper's own model.

Every architecture is selectable via ``--arch <id>`` in the launchers.
``EXPECTED_PARAMS`` records the published total parameter counts used by
``tests/test_configs.py`` to validate the configs (via ``jax.eval_shape``
over ``init_params`` — exact, allocation-free).
"""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

_MODULES = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "starcoder2-7b": "starcoder2_7b",
    "chatglm3-6b": "chatglm3_6b",
    "olmo-1b": "olmo_1b",
    "yi-34b": "yi_34b",
    "arctic-480b": "arctic_480b",
    "mixtral-8x22b": "mixtral_8x22b",
    "rwkv6-7b": "rwkv6_7b",
    "musicgen-large": "musicgen_large",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "distilgpt2-82m": "distilgpt2_82m",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(k for k in _MODULES if k != "distilgpt2-82m")
ALL_ARCHS: Tuple[str, ...] = tuple(_MODULES)

#: Published total parameter counts (backbone scope for vlm/audio).
EXPECTED_PARAMS: Dict[str, float] = {
    "phi-3-vision-4.2b": 3.8e9,  # 4.2B minus the (stubbed) CLIP tower
    "starcoder2-7b": 7.2e9,
    "chatglm3-6b": 6.2e9,
    "olmo-1b": 1.2e9,
    "yi-34b": 34.4e9,
    "arctic-480b": 482e9,
    "mixtral-8x22b": 141e9,
    "rwkv6-7b": 7.6e9,
    "musicgen-large": 2.4e9,  # 3.3B total minus the (stubbed) T5 text encoder
    "recurrentgemma-9b": 9.4e9,
    "distilgpt2-82m": 82e6,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke_config()
