"""olmo-1b [dense] — non-parametric LayerNorm, tied embeddings.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304 [arXiv:2402.00838]
"""

from repro.models.config import ModelConfig

ARCH_ID = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        activation="swiglu",
        norm="nonparametric_ln",  # OLMo's distinguishing choice
        tie_embeddings=True,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        activation="swiglu",
        norm="nonparametric_ln",
        tie_embeddings=True,
        dtype="float32",
    )
