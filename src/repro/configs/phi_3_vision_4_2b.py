"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP patch stub.

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]

The modality frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed CLIP-L/14 patch embeddings (width 1024) which the
backbone projects and prepends to the text tokens.
"""

from repro.models.config import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        frontend="patch",
        frontend_dim=1024,
        num_prefix_tokens=256,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        activation="swiglu",
        norm="rmsnorm",
        frontend="patch",
        frontend_dim=32,
        num_prefix_tokens=4,
        dtype="float32",
    )
