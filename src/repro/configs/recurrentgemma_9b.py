"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427]

Griffin block pattern: (recurrent, recurrent, local_attn) repeated; the
38-layer stack is 12 groups + a 2-layer recurrent remainder.  Local
attention window 2048 and O(1) recurrent state make `long_500k` eligible.
"""

from repro.models.config import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,  # MQA in the local-attention layers
        d_ff=12288,
        vocab_size=256_000,
        pattern=("recurrent", "recurrent", "local_attn"),
        local_window=2048,
        d_rnn=4096,
        activation="geglu",
        norm="rmsnorm",
        logits_softcap=30.0,
        tie_embeddings=True,  # gemma family ties in/out embeddings
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=5,  # one full group + (recurrent, recurrent) remainder
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        pattern=("recurrent", "recurrent", "local_attn"),
        local_window=8,
        d_rnn=64,
        activation="geglu",
        norm="rmsnorm",
        logits_softcap=30.0,
        dtype="float32",
    )
