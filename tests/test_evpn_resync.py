"""Incremental EVPN resync tests (ISSUE 4 tentpole, control-plane half).

The contract mirrors ``test_failover_incremental.py`` one layer up: after
*any* flap sequence in which every flap is synced through
``resync_incremental(RerouteStats)``, the control-plane session state
(per-speaker RIBs + derived MAC/IP/flood tables) must be byte-identical to
a control plane that ran a full ``resync()`` after every event — while the
common non-partitioning flap retains every speaker untouched.
"""

import copy

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bfd import FailureDetector
from repro.core.evpn import EvpnControlPlane, EvpnResyncStats
from repro.core.fabric import Fabric, FabricConfig

#: 3-DC fabric with enough leaves for a real blast-radius contrast.
MID = FabricConfig(
    num_dcs=3,
    spines_per_dc=2,
    leaves_per_dc=3,
    hosts_per_leaf=((2, 1, 1), (1, 2, 1), (1, 1, 2)),
)


def _stack(config=None):
    fabric = Fabric(config)
    evpn = EvpnControlPlane(fabric)
    for host in sorted(fabric.hosts):
        evpn.learn_host(host, 100)
    return fabric, evpn


def _state(evpn):
    # deep copies, so same-instance before/after comparisons see real
    # snapshots rather than aliases of the live (mutable) tables
    return (
        {name: frozenset(sp.rib) for name, sp in evpn.speakers.items()},
        copy.deepcopy(evpn.mac_table),
        copy.deepcopy(evpn.ip_table),
        copy.deepcopy(evpn.flood_list),
    )


def _apply(fabric, evpn, action, link, *, full):
    stats = (
        fabric.fail_link(*link) if action == "fail" else fabric.restore_link(*link)
    )
    if full:
        evpn.resync()
        return None
    return evpn.resync_incremental(stats)


class TestNonPartitioningFlaps:
    def test_wan_flap_retains_everything(self):
        """A single WAN-link flap never partitions the full-bipartite
        session graph: zero RIB edits, zero table rebuilds."""
        fabric, evpn = _stack()
        wan = sorted(fabric.wan_links[0])
        before = _state(evpn)
        stats = _apply(fabric, evpn, "fail", (wan[0], wan[1]), full=False)
        assert isinstance(stats, EvpnResyncStats)
        assert stats.touched == 0
        assert stats.retained == len(evpn.speakers)
        assert stats.origins_recomputed == 0
        assert stats.vtep_touched_frac == 0.0
        assert _state(evpn) == before
        stats = _apply(fabric, evpn, "restore", (wan[0], wan[1]), full=False)
        assert stats.touched == 0

    def test_host_link_flap_is_noop(self):
        """Host attachments carry no BGP session: nothing to diff."""
        fabric, evpn = _stack()
        leaf = fabric.hosts["d1h1"].leaf
        stats = _apply(fabric, evpn, "fail", ("d1h1", leaf), full=False)
        assert stats.touched == 0
        assert stats.retained == len(evpn.speakers)
        fabric.restore_link("d1h1", leaf)

    def test_leaf_spine_flap_with_redundancy_retains(self):
        fabric, evpn = _stack()
        stats = _apply(fabric, evpn, "fail", ("d1l1", "d1s1"), full=False)
        assert stats.touched == 0  # d1l1 still peers via d1s2


class TestPartitioningFlaps:
    def test_leaf_isolation_withdraws_and_restores(self):
        fabric, evpn = _stack()
        # only the LAST uplink failure partitions; earlier ones retain
        s1 = _apply(fabric, evpn, "fail", ("d1l1", "d1s1"), full=False)
        assert s1.touched == 0
        s2 = _apply(fabric, evpn, "fail", ("d1l1", "d1s2"), full=False)
        assert s2.touched > 0
        assert s2.origins_recomputed > 0
        assert not evpn.reachable("d2h1", "d1h1")
        # reconnect: routes re-flood to exactly the re-joined speakers
        s3 = _apply(fabric, evpn, "restore", ("d1l1", "d1s1"), full=False)
        assert s3.touched > 0
        assert evpn.reachable("d2h1", "d1h1")
        fabric.restore_link("d1l1", "d1s2")

    def test_stats_partition_speaker_counts(self):
        fabric, evpn = _stack()
        fabric.fail_link("d1l1", "d1s1")
        stats = _apply(fabric, evpn, "fail", ("d1l1", "d1s2"), full=False)
        assert stats.patched + stats.rebuilt + stats.retained == len(
            evpn.speakers
        )
        assert stats.total_vteps == len(fabric.leaves)
        # rebuilt counts leaf VTEPs, patched counts spine RIB edits
        assert stats.rebuilt <= len(fabric.leaves)


class TestFullResyncEquivalence:
    def _twins(self, config=None):
        return _stack(config), _stack(config)

    def test_isolation_episode_matches_full_resync(self):
        (f_inc, e_inc), (f_full, e_full) = self._twins(MID)
        uplinks = [("d2l2", "d2s1"), ("d2l2", "d2s2")]
        seq = [("fail", link) for link in uplinks] + [
            ("restore", link) for link in uplinks
        ]
        for action, link in seq:
            _apply(f_inc, e_inc, action, link, full=False)
            _apply(f_full, e_full, action, link, full=True)
            assert _state(e_inc) == _state(e_full)

    def test_advertisement_during_outage_matches_full_resync(self):
        """Routes advertised while a leaf is isolated flood partially;
        the incremental restore must extend them exactly like a full
        resync would."""
        (f_inc, e_inc), (f_full, e_full) = self._twins()
        for f, e in ((f_inc, e_inc), (f_full, e_full)):
            _apply(f, e, "fail", ("d2l1", "d2s1"), full=e is e_full)
            _apply(f, e, "fail", ("d2l1", "d2s2"), full=e is e_full)
            # new tenant appears mid-outage
            e.learn_host("d1h2", 200)
            e.learn_host("d2h1", 200)  # d2h1 sits on isolated d2l1
            _apply(f, e, "restore", ("d2l1", "d2s1"), full=e is e_full)
            _apply(f, e, "restore", ("d2l1", "d2s2"), full=e is e_full)
        assert _state(e_inc) == _state(e_full)
        assert e_inc.reachable("d1h2", "d2h1")

    @settings(max_examples=15, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.booleans(),  # True = fail, False = restore
                st.integers(min_value=0, max_value=17),
            ),
            min_size=1,
            max_size=12,
        )
    )
    def test_random_flap_sequences_match_full_resync(self, seq):
        """Property: any session-link flap sequence leaves incremental and
        full-resync control planes in byte-identical state."""
        (f_inc, e_inc), (f_full, e_full) = self._twins(MID)
        links = sorted(tuple(sorted(l)) for l in f_inc.wan_links)
        # mix in leaf-spine session links (indices past the WAN list)
        links += [("d1l1", "d1s1"), ("d1l1", "d1s2"), ("d2l2", "d2s1"),
                  ("d2l2", "d2s2"), ("d3l3", "d3s1")]
        for is_fail, idx in seq:
            link = links[idx % len(links)]
            action = "fail" if is_fail else "restore"
            _apply(f_inc, e_inc, action, link, full=False)
            _apply(f_full, e_full, action, link, full=True)
        assert _state(e_inc) == _state(e_full)


class TestDetectorIntegration:
    def test_fail_and_recover_carries_resync_stats(self):
        fabric, evpn = _stack()
        det = FailureDetector(fabric, evpn)
        wan = sorted(fabric.wan_links[0])
        tl = det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        assert tl.evpn_resync is not None
        assert tl.evpn_resync.action == "fail"
        assert tl.evpn_resync.touched == 0
        assert any("EVPN resynced incrementally" in msg for _, msg in tl.events)
        det.restore((wan[0], wan[1]))
        assert evpn.last_resync is not None
        assert evpn.last_resync.action == "restore"

    def test_recovery_timing_unchanged(self):
        """Swapping full resync for incremental must not move the Fig. 9
        recovery timeline."""
        fabric, evpn = _stack()
        det = FailureDetector(fabric, evpn)
        wan = sorted(fabric.wan_links[0])
        tl = det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        assert 90.0 < tl.recovery_ms < 130.0

    def test_withdraw_leaf_not_resurrected(self):
        fabric, evpn = _stack()
        evpn.withdraw_leaf("d1l1")
        assert not evpn.reachable("d2h1", "d1h1")
        # neither a full nor an incremental resync may bring them back
        evpn.resync()
        assert not evpn.reachable("d2h1", "d1h1")
        det = FailureDetector(fabric, evpn)
        wan = sorted(fabric.wan_links[0])
        det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        det.restore((wan[0], wan[1]))
        assert not evpn.reachable("d2h1", "d1h1")
