"""EVPN control plane + VNI multi-tenancy tests (paper §3.2, §4.2, §5.4)."""

import pytest

from repro.core.evpn import EvpnControlPlane, RouteType2, RouteType3
from repro.core.fabric import Fabric, UnreachableError
from repro.core.tenancy import TenancyManager


@pytest.fixture()
def stack():
    fabric = Fabric()
    evpn = EvpnControlPlane(fabric)
    tenancy = TenancyManager(fabric, evpn)
    return fabric, evpn, tenancy


class TestEvpnControlPlane:
    def test_type3_vtep_discovery(self, stack):
        fabric, evpn, _ = stack
        route = evpn.configure_vni("d1l1", 100)
        assert isinstance(route, RouteType3)
        assert route.vtep_ip == fabric.vtep_ip("d1l1")
        # remote leaf with the same VNI imports the flood-list entry
        evpn.configure_vni("d2l1", 100)
        assert fabric.vtep_ip("d1l1") in evpn.flood_list["d2l1"][100]
        assert fabric.vtep_ip("d2l1") in evpn.flood_list["d1l1"][100]

    def test_type2_macip_propagation(self, stack):
        """Fig. 5 sequence: host ARP -> Type-2 -> cross-DC reachability."""
        fabric, evpn, _ = stack
        evpn.configure_vni("d1l1", 100)
        evpn.configure_vni("d2l1", 100)
        route = evpn.learn_host("d1h1", 100)
        assert isinstance(route, RouteType2)
        assert route.mac == fabric.hosts["d1h1"].mac
        d2l1_entry = evpn.ip_table["d2l1"].get((100, fabric.hosts["d1h1"].ip))
        assert d2l1_entry == fabric.vtep_ip("d1l1")

    def test_rt_import_policy(self, stack):
        """A leaf without the VNI configured must not import its routes."""
        fabric, evpn, _ = stack
        evpn.configure_vni("d1l1", 100)
        evpn.learn_host("d1h1", 100)
        # d2l1 never configured VNI 100 -> no entry
        assert (100, fabric.hosts["d1h1"].ip) not in evpn.ip_table["d2l1"]

    def test_route_counts(self, stack):
        fabric, evpn, _ = stack
        evpn.configure_vni("d1l1", 100)
        evpn.configure_vni("d2l1", 100)
        evpn.learn_host("d1h1", 100)
        counts = evpn.speakers["d2s1"].rib
        assert any(isinstance(r, RouteType2) for r in counts)
        assert evpn.route_count("d2s1")["type2"] == 1
        assert evpn.route_count("d2s1")["type3"] == 2

    def test_reachability_requires_route(self, stack):
        fabric, evpn, _ = stack
        evpn.learn_host("d1h1", 100)
        assert not evpn.reachable("d1h1", "d2h1")  # d2h1 not attached yet
        evpn.learn_host("d2h1", 100)
        assert evpn.reachable("d1h1", "d2h1")
        assert evpn.reachable("d2h1", "d1h1")

    def test_withdraw_leaf(self, stack):
        fabric, evpn, _ = stack
        evpn.learn_host("d1h1", 100)
        evpn.learn_host("d2h1", 100)
        assert evpn.reachable("d2h1", "d1h1")
        evpn.withdraw_leaf("d1l1")
        assert not evpn.reachable("d2h1", "d1h1")


class TestMultiTenancy:
    def test_table1_matrix(self, stack):
        """Reproduces Table 1: intra-VNI reachable, inter-VNI unreachable."""
        fabric, evpn, tenancy = stack
        tenancy.create_tenant("job-a", vni=100)
        tenancy.create_tenant("job-b", vni=200)
        tenancy.create_tenant("job-c", vni=300)
        # paper's host assignment
        for host in ("d1h1", "d1h2", "d2h1"):
            tenancy.attach("job-a", host)
        for host in ("d1h3", "d1h5", "d2h4"):
            tenancy.attach("job-b", host)
        tenancy.attach("job-c", "d1h4")

        assert tenancy.ping("d1h1", "d2h1")  # VNI 100 -> VNI 100 (21.4 ms row)
        assert tenancy.ping("d1h3", "d1h5")  # VNI 200 -> VNI 200 (0.07 ms row)
        assert not tenancy.ping("d1h2", "d1h3")  # VNI 100 -> 200: unreachable
        assert not tenancy.ping("d1h4", "d2h4")  # VNI 300 -> 200: unreachable
        tenancy.verify_isolation()

    def test_duplicate_vni_rejected(self, stack):
        _, _, tenancy = stack
        tenancy.create_tenant("a", vni=100)
        with pytest.raises(ValueError):
            tenancy.create_tenant("b", vni=100)

    def test_vni_24bit_range(self, stack):
        """§3.1: 16M VNIs vs 4096 VLANs."""
        _, _, tenancy = stack
        tenancy.create_tenant("big", vni=(1 << 24) - 1)  # fine: 24-bit
        with pytest.raises(ValueError):
            tenancy.create_tenant("too-big", vni=1 << 24)

    def test_double_attach_conflict(self, stack):
        _, _, tenancy = stack
        tenancy.create_tenant("a", vni=100)
        tenancy.create_tenant("b", vni=200)
        tenancy.attach("a", "d1h1")
        with pytest.raises(ValueError):
            tenancy.attach("b", "d1h1")

    def test_unreachable_send_raises(self, stack):
        fabric, _, tenancy = stack
        tenancy.create_tenant("a", vni=100)
        tenancy.create_tenant("b", vni=200)
        tenancy.attach("a", "d1h1")
        tenancy.attach("b", "d2h1")
        with pytest.raises(UnreachableError):
            fabric.send("d1h1", "d2h1", 100, src_port=49192,
                        check_reachability=tenancy.reachable)
