"""Unit + property tests for source-port allocation (paper Algorithm 1)."""

import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.ports import (
    ALIASING_STRIDE,
    ALIASING_STRIDE_STRONG,
    MAX_PORT,
    NUM_PORT_OFFSETS,
    ROCE_V2_BASE_PORT,
    QueuePair,
    allocate_ports,
    hash_32,
    make_queue_pairs,
    qp_aware_port,
    rxe_baseline_port,
)


class TestHash32:
    def test_matches_kernel_reference(self):
        # hash_32(val, bits) = (val * GOLDEN_RATIO_32) >> (32 - bits), u32
        assert hash_32(0, 14) == 0
        assert hash_32(1, 14) == (0x61C88647 >> 18)
        assert hash_32(2**32 - 1, 14) < 2**14

    @given(st.integers(min_value=0, max_value=2**32 - 1), st.integers(min_value=1, max_value=31))
    def test_range(self, val, bits):
        assert 0 <= hash_32(val, bits) < 2**bits


class TestBaseline:
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_port_in_roce_range(self, qpn):
        port = rxe_baseline_port(qpn)
        assert ROCE_V2_BASE_PORT <= port <= MAX_PORT

    def test_aliasing_stride(self):
        """The production pathology (§3.3): correlated QP numbers receive
        identical source ports under stock rdma-rxe hashing."""
        for stride in (ALIASING_STRIDE, ALIASING_STRIDE_STRONG):
            qps = make_queue_pairs(8, base_number=12345, stride=stride)
            ports = [rxe_baseline_port(q.number) for q in qps]
            assert len(set(ports)) < len(ports), (
                f"stride {stride} should alias baseline ports, got {ports}"
            )

    def test_strong_alias_is_total(self):
        qps = make_queue_pairs(8, base_number=777, stride=ALIASING_STRIDE_STRONG)
        ports = [rxe_baseline_port(q.number) for q in qps]
        assert len(set(ports)) == 1


class TestQpAware:
    @given(
        st.integers(min_value=0, max_value=2**20),
        st.integers(min_value=0, max_value=2**32 - 1),
        st.sampled_from([1, 2, 4, 8, 16]),
    )
    def test_port_in_range(self, index, number, k):
        port = qp_aware_port(QueuePair(index, number), k=k)
        assert ROCE_V2_BASE_PORT <= port <= MAX_PORT

    @given(
        st.integers(min_value=0, max_value=2**20),
        st.integers(min_value=0, max_value=2**32 - 1),
        st.sampled_from([2, 4, 8]),
    )
    def test_bin_assignment(self, index, number, k):
        """Algorithm 1 line 6: the bin is determined by index mod k."""
        w_b = NUM_PORT_OFFSETS // k
        port = qp_aware_port(QueuePair(index, number), k=k)
        offset = port - ROCE_V2_BASE_PORT
        assert offset // w_b == index % k

    @given(st.integers(min_value=0, max_value=2**28), st.sampled_from([2, 4, 8]))
    def test_bins_nonoverlapping(self, number, k):
        """QPs with distinct index mod k can never share a port, even with a
        fully degenerate hash (the structural-separation guarantee)."""
        w_b = NUM_PORT_OFFSETS // k
        ports = [qp_aware_port(QueuePair(i, number), k=k) for i in range(k)]
        bins = [(p - ROCE_V2_BASE_PORT) // w_b for p in ports]
        assert sorted(bins) == list(range(k))
        assert len(set(ports)) == k

    def test_aliased_qps_get_distinct_ports(self):
        """The fix, end to end: under the aliasing stride the baseline gives
        one port for all 4 QPs, Algorithm 1 gives 4 distinct ports."""
        qps = make_queue_pairs(4, base_number=99, stride=ALIASING_STRIDE_STRONG)
        assert len(set(allocate_ports(qps, scheme="baseline"))) == 1
        assert len(set(allocate_ports(qps, scheme="qp_aware"))) == 4

    def test_hash_preserved_within_bin(self):
        """Algorithm 1 line 7: within the bin, the offset is o_r mod W_b."""
        qp = QueuePair(index=2, number=0xDEADBEEF)
        o_r = hash_32(qp.number, 14)
        port = qp_aware_port(qp, k=4)
        assert port == ROCE_V2_BASE_PORT + 2 * 4096 + (o_r % 4096)

    def test_paper_constants(self):
        # Algorithm 1 lines 1-3
        assert ROCE_V2_BASE_PORT == 49192
        assert NUM_PORT_OFFSETS == 16384
        assert NUM_PORT_OFFSETS // 4 == 4096

    def test_bad_scheme_rejected(self):
        with pytest.raises(ValueError):
            allocate_ports(make_queue_pairs(2), scheme="nonsense")

    def test_bad_k_rejected(self):
        with pytest.raises(ValueError):
            qp_aware_port(QueuePair(0, 1), k=0)
