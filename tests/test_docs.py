"""Docs tree + link-checker tests (ISSUE 9 satellites).

The CI docs job runs ``tools/check_links.py`` over the README and
``docs/``; these tests pin the same contract in tier-1 (the docs exist,
are linked from the README, and contain no dead intra-repo links) and
unit-test the checker's slug/anchor logic so a checker regression cannot
silently let dead links through.
"""

import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_links  # noqa: E402


class TestDocsTree:
    def test_docs_exist(self):
        assert (REPO / "docs" / "ARCHITECTURE.md").is_file()
        assert (REPO / "docs" / "PERFORMANCE.md").is_file()

    def test_readme_links_both_docs(self):
        readme = (REPO / "README.md").read_text()
        assert "docs/ARCHITECTURE.md" in readme
        assert "docs/PERFORMANCE.md" in readme

    def test_no_dead_links_in_readme_and_docs(self):
        """Exactly what the CI docs job runs."""
        proc = subprocess.run(
            [sys.executable, "tools/check_links.py", "README.md", "docs"],
            cwd=REPO,
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr


class TestSlugLogic:
    @pytest.mark.parametrize(
        "heading,slug",
        [
            ("Performance", "performance"),
            ("The byte-identity-gate convention", "the-byte-identity-gate-convention"),
            ("Reading and refreshing bench baselines", "reading-and-refreshing-bench-baselines"),
            ("`compare.py` metric-suffix direction rules", "comparepy-metric-suffix-direction-rules"),
            ("Allocator complexity, before and after", "allocator-complexity-before-and-after"),
        ],
    )
    def test_github_slug(self, heading, slug):
        assert check_links.github_slug(heading) == slug

    def test_duplicate_headings_get_suffixes(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("# A\n\n## Same\n\n## Same\n")
        assert check_links.heading_anchors(md) == {"a", "same", "same-1"}

    def test_fenced_code_is_ignored(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("# Real\n\n```\n# not a heading\n[x](nope.md)\n```\n")
        assert check_links.heading_anchors(md) == {"real"}
        assert list(check_links.iter_links(md)) == []


class TestChecker:
    def test_dead_path_reported(self, tmp_path):
        md = tmp_path / "a.md"
        md.write_text("[x](missing.md)\n")
        errors = check_links.check_file(md, tmp_path)
        assert len(errors) == 1 and "no such file" in errors[0]

    def test_dead_anchor_reported(self, tmp_path):
        (tmp_path / "b.md").write_text("# Only Heading\n")
        md = tmp_path / "a.md"
        md.write_text("[x](b.md#wrong-anchor)\n")
        errors = check_links.check_file(md, tmp_path)
        assert len(errors) == 1 and "wrong-anchor" in errors[0]

    def test_good_links_pass(self, tmp_path):
        (tmp_path / "b.md").write_text("# Target Heading\n")
        md = tmp_path / "a.md"
        md.write_text(
            "[ok](b.md)\n[ok2](b.md#target-heading)\n"
            "[self](#local)\n\n# Local\n"
            "[ext](https://example.com/404)\n"
        )
        assert check_links.check_file(md, tmp_path) == []

    def test_escaping_repo_root_reported(self, tmp_path):
        sub = tmp_path / "docs"
        sub.mkdir()
        md = sub / "a.md"
        md.write_text("[x](../../etc/passwd)\n")
        errors = check_links.check_file(md, sub)
        assert len(errors) == 1 and "escapes" in errors[0]
