"""Batched routing engine equivalence + collective flow-library tests.

Covers ISSUE 1's tentpole guarantees:

* ``route_flows_batched`` produces byte-identical ``link_bytes`` to the
  sequential per-flow walk on the seed Fig. 1 topology for every
  collective pattern (and under link failure, odd ports, VNI isolation);
* the four new generators (reduce-scatter, all-gather, all-to-all,
  pipeline P2P) emit the right flow counts, conserve bytes exactly, and
  cross the WAN where the pattern says they must;
* ``split_bytes`` never drops remainder bytes (the old ring
  double-truncation bug).
"""

import pytest

from repro.core.fabric import Fabric, FabricConfig, UnreachableError
from repro.core.flows import (
    Flow,
    all_gather_flows,
    all_to_all_flows,
    hierarchical_flows,
    parameter_server_flows,
    pipeline_p2p_flows,
    reduce_scatter_flows,
    ring_allreduce_flows,
    route_flows,
    route_flows_batched,
    split_bytes,
)
from repro.core.ports import QueuePair


@pytest.fixture()
def fabric():
    return Fabric()  # the paper's Fig. 1 seed topology


def _patterns(hosts):
    """Every collective pattern over the seed fabric's 9 hosts."""
    return {
        "ring": ring_allreduce_flows(hosts, 10_000_003),
        "ps": parameter_server_flows(hosts[0], hosts[1:], 5_000_001),
        "reduce_scatter": reduce_scatter_flows(hosts, 7_777_777),
        "all_gather": all_gather_flows(hosts, 7_777_777),
        "all_to_all": all_to_all_flows(hosts, 9_999_999),
        "pipeline_p2p": pipeline_p2p_flows(
            [hosts[0:3], hosts[3:6], hosts[6:9]], 1_234_567, num_microbatches=3
        ),
        "hierarchical": hierarchical_flows([hosts[0], hosts[5]], 2_000_001),
    }


class TestBatchedEquivalence:
    @pytest.mark.parametrize(
        "pattern",
        [
            "ring", "ps", "reduce_scatter", "all_gather",
            "all_to_all", "pipeline_p2p", "hierarchical",
        ],
    )
    def test_byte_identical_per_pattern(self, fabric, pattern):
        flows = _patterns(list(fabric.hosts))[pattern]
        assert flows, pattern
        seq = route_flows(fabric, flows)
        bat = route_flows_batched(fabric, flows)
        assert seq == bat

    def test_byte_identical_both_schemes(self, fabric):
        hosts = list(fabric.hosts)
        for scheme in ("baseline", "qp_aware"):
            flows = ring_allreduce_flows(hosts, 4_000_001, scheme=scheme)
            assert route_flows(fabric, flows) == route_flows_batched(fabric, flows)

    def test_byte_identical_under_link_failure(self, fabric):
        flows = ring_allreduce_flows(list(fabric.hosts), 8_000_000)
        fabric.fail_link("d1l1", "d1s1")
        try:
            assert route_flows(fabric, flows) == route_flows_batched(fabric, flows)
        finally:
            fabric.restore_link("d1l1", "d1s1")
        # table invalidation: results must change back after restore
        assert route_flows(fabric, flows) == route_flows_batched(fabric, flows)

    def test_byte_identical_odd_ports(self, fabric):
        """Source ports outside the 5-digit range take the scalar fallback."""
        qp = QueuePair(0, 1)
        flows = [
            Flow("d1h1", "d2h2", 1000, qp, port)
            for port in (1, 7, 99, 9_999, 10_000, 99_999, 100_000, 54_321)
        ]
        assert route_flows(fabric, flows) == route_flows_batched(fabric, flows)

    def test_byte_identical_zero_byte_flows(self, fabric):
        """send() records zero-valued counter entries for every traversed
        link; the batched engine must emit the same keys (split_bytes
        yields zero-byte channels whenever total_bytes < num_channels)."""
        flows = all_to_all_flows(list(fabric.hosts), 2, num_channels=4)
        assert any(f.nbytes == 0 for f in flows)
        seq = route_flows(fabric, flows)
        bat = route_flows_batched(fabric, flows)
        assert seq == bat
        assert set(seq) == set(bat)  # including zero-valued keys

    def test_same_leaf_flows(self, fabric):
        qp = QueuePair(0, 1)
        flows = [Flow("d1h1", "d1h2", 500, qp, 50_000)] * 3
        assert route_flows(fabric, flows) == route_flows_batched(fabric, flows)

    def test_scaled_topology(self):
        big = Fabric(FabricConfig(
            num_dcs=4, spines_per_dc=4, leaves_per_dc=8,
            hosts_per_leaf=tuple(tuple(2 for _ in range(8)) for _ in range(4)),
        ))
        flows = all_to_all_flows(list(big.hosts)[::4], 3_000_007)
        assert route_flows(big, flows) == route_flows_batched(big, flows)

    def test_reachability_check_raises(self, fabric):
        flows = [Flow("d1h1", "d2h2", 100, QueuePair(0, 1), 50_000)]
        with pytest.raises(UnreachableError):
            route_flows_batched(fabric, flows, check_reachability=lambda s, d: False)

    def test_no_route_raises(self, fabric):
        flows = [Flow("d1h1", "d2h2", 100, QueuePair(0, 1), 50_000)]
        fabric.fail_link("d1l1", "d1s1")
        fabric.fail_link("d1l1", "d1s2")
        try:
            with pytest.raises(RuntimeError, match="no route"):
                route_flows_batched(fabric, flows)
        finally:
            fabric.restore_link("d1l1", "d1s1")
            fabric.restore_link("d1l1", "d1s2")

    def test_counters_accumulate_across_batches(self, fabric):
        """Fabric.route_flows_batched adds to existing counters (like send)."""
        flows = [Flow("d1h1", "d2h2", 1000, QueuePair(0, 1), 50_000)]
        fabric.reset_counters()
        first = fabric.route_flows_batched(flows)
        fabric.route_flows_batched(flows)
        for link, b in first.items():
            assert fabric.link_bytes[link] == 2 * b


class TestSplitBytes:
    def test_exact_conservation(self):
        for total in (0, 1, 999, 1333, 10_000_003):
            for parts in (1, 2, 3, 4, 7, 16):
                chunks = split_bytes(total, parts)
                assert sum(chunks) == total
                assert len(chunks) == parts
                assert max(chunks) - min(chunks) <= 1

    def test_rejects_bad_parts(self):
        with pytest.raises(ValueError):
            split_bytes(100, 0)


class TestRingRemainder:
    def test_no_silent_truncation(self):
        """The old path dropped up to num_channels-1 bytes per worker:
        B=1000, n=3 -> per-worker 1333; 4 channels of 333 lost 1 byte."""
        flows = ring_allreduce_flows(["d1h1", "d1h2", "d1h3"], 1000, num_channels=4)
        per_worker = (2 * 2 * 1000) // 3  # 1333
        by_src = {}
        for f in flows:
            by_src[f.src] = by_src.get(f.src, 0) + f.nbytes
        assert all(v == per_worker for v in by_src.values()), by_src

    def test_flow_count(self):
        flows = ring_allreduce_flows([f"d1h{i}" for i in range(1, 6)][:4], 100, num_channels=4)
        assert len(flows) == 4 * 4  # n workers x channels


class TestNewGenerators:
    WORKERS = ["d1h1", "d1h2", "d1h4", "d2h1", "d2h3"]  # spans both DCs

    def _wan_flow_bytes(self, fabric, flows):
        """Bytes of flows whose endpoints live in different DCs."""
        return sum(
            f.nbytes for f in flows
            if fabric.hosts[f.src].dc != fabric.hosts[f.dst].dc
        )

    def test_reduce_scatter_counts_and_bytes(self):
        n, ch, B = len(self.WORKERS), 4, 9_999_991
        flows = reduce_scatter_flows(self.WORKERS, B, num_channels=ch)
        assert len(flows) == n * ch
        per_worker = ((n - 1) * B) // n
        for w in self.WORKERS:
            assert sum(f.nbytes for f in flows if f.src == w) == per_worker

    def test_all_gather_counts_and_bytes(self):
        n, ch, B = len(self.WORKERS), 4, 9_999_991
        flows = all_gather_flows(self.WORKERS, B, num_channels=ch)
        assert len(flows) == n * ch
        per_worker = ((n - 1) * B) // n
        for w in self.WORKERS:
            assert sum(f.nbytes for f in flows if f.src == w) == per_worker

    def test_all_gather_distinct_qps_from_reduce_scatter(self):
        rs = reduce_scatter_flows(self.WORKERS, 1_000_000)
        ag = all_gather_flows(self.WORKERS, 1_000_000)
        assert {f.qp.number for f in rs}.isdisjoint({f.qp.number for f in ag})

    def test_all_gather_qps_disjoint_at_scale(self):
        """The offset must clear the whole RS span, not a fixed 0x10000
        (at 502+ workers pair_id*131 overruns a constant offset)."""
        workers = [f"w{i}" for i in range(600)]
        rs = reduce_scatter_flows(workers, 1_000_000)
        ag = all_gather_flows(workers, 1_000_000)
        assert {f.qp.number for f in rs}.isdisjoint({f.qp.number for f in ag})

    def test_all_to_all_counts_and_bytes(self):
        n, ch, B = len(self.WORKERS), 4, 10_000_001
        flows = all_to_all_flows(self.WORKERS, B, num_channels=ch)
        assert len(flows) == n * (n - 1) * ch
        shards = split_bytes(B, n)
        for i, w in enumerate(self.WORKERS):
            sent = sum(f.nbytes for f in flows if f.src == w)
            assert sent == B - shards[i]  # everything but the self-shard

    def test_all_to_all_wan_crossings(self, fabric):
        flows = all_to_all_flows(self.WORKERS, 10_000_001)
        dc = {w: fabric.hosts[w].dc for w in self.WORKERS}
        expected_pairs = sum(
            1 for s in self.WORKERS for d in self.WORKERS
            if s != d and dc[s] != dc[d]
        )
        crossing = {(f.src, f.dst) for f in flows
                    if dc[f.src] != dc[f.dst]}
        assert len(crossing) == expected_pairs
        # routed WAN bytes == bytes of the DC-crossing flows
        route_flows_batched(fabric, flows)
        wan_bytes = sum(
            b for (u, v), b in fabric.link_bytes.items() if fabric.is_wan_link(u, v)
        )
        assert wan_bytes == self._wan_flow_bytes(fabric, flows)

    def test_pipeline_p2p_counts_and_bytes(self):
        stages = [["d1h1", "d1h2"], ["d1h4", "d1h5"], ["d2h1", "d2h2"]]
        act, mb, ch = 999_999, 4, 4
        flows = pipeline_p2p_flows(stages, act, num_microbatches=mb, num_channels=ch)
        assert len(flows) == 2 * 2 * ch  # 2 boundaries x width 2 x channels
        per_rank = act * mb
        total = sum(f.nbytes for f in flows)
        assert total == 2 * 2 * per_rank

    def test_pipeline_p2p_uneven_stages(self):
        flows = pipeline_p2p_flows([["d1h1", "d1h2", "d1h3"], ["d2h1"]], 1_000)
        # width 3: every rank of the wide stage sends to the narrow stage
        assert {f.src for f in flows} == {"d1h1", "d1h2", "d1h3"}
        assert {f.dst for f in flows} == {"d2h1"}

    def test_pipeline_p2p_wan_crossings(self, fabric):
        stages = [["d1h1", "d1h2"], ["d2h1", "d2h2"]]
        flows = pipeline_p2p_flows(stages, 1_000_000)
        assert all(fabric.hosts[f.src].dc != fabric.hosts[f.dst].dc for f in flows)
        route_flows_batched(fabric, flows)
        wan_bytes = sum(
            b for (u, v), b in fabric.link_bytes.items() if fabric.is_wan_link(u, v)
        )
        assert wan_bytes == sum(f.nbytes for f in flows)

    def test_pipeline_p2p_rejects_empty_stage(self):
        with pytest.raises(ValueError):
            pipeline_p2p_flows([["d1h1"], []], 100)

    def test_ps_byte_conservation(self):
        B, ch = 5_000_003, 4
        flows = parameter_server_flows("d2h1", self.WORKERS[:3], B, num_channels=ch)
        assert len(flows) == 3 * 2 * ch
        for w in self.WORKERS[:3]:
            assert sum(f.nbytes for f in flows if f.src == w) == B  # push
            assert sum(f.nbytes for f in flows if f.dst == w) == B  # pull
