"""Model-stack unit tests: attention equivalences, MoE internals, RWKV/RG-LRU
recurrence properties, cache mechanics, and hypothesis invariants."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import ModelConfig, MoEConfig, forward, init_params, loss_fn
from repro.models.attention import _sdpa_chunked, _sdpa_dense, sdpa
from repro.models.rwkv6 import _wkv_with_initial_state
from repro.models.rglru import rg_lru


class TestChunkedAttention:
    @pytest.mark.parametrize("window", [None, 64, 256])
    def test_chunked_equals_dense(self, window):
        b, s, h, kvh, hd = 2, 1024, 4, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        pos = jnp.arange(s)
        dense = _sdpa_dense(q, k, v, q_positions=pos, k_positions=pos,
                            window=window, logit_softcap=None)
        chunked = _sdpa_chunked(q, k, v, q_positions=pos, k_positions=pos,
                                window=window, logit_softcap=None, block=256)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                                   rtol=2e-5, atol=2e-5)

    def test_chunked_gradients_match(self):
        """The checkpointed scan body must not change gradients."""
        b, s, h, hd = 1, 512, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        pos = jnp.arange(s)

        def loss(fn):
            return lambda q_: jnp.sum(
                fn(q_, k, v, q_positions=pos, k_positions=pos,
                   window=None, logit_softcap=None)
                ** 2
            )

        g_dense = jax.grad(loss(_sdpa_dense))(q)
        g_chunk = jax.grad(
            loss(lambda *a, **kw: _sdpa_chunked(*a, block=128, **kw))
        )(q)
        np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_dense),
                                   rtol=1e-4, atol=1e-4)

    def test_auto_dispatch(self):
        b, s, h, hd = 1, 2048, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
        pos = jnp.arange(s)
        auto = sdpa(q, k, v, q_positions=pos, k_positions=pos, impl="auto", block=512)
        naive = sdpa(q, k, v, q_positions=pos, k_positions=pos, impl="naive")
        np.testing.assert_allclose(np.asarray(auto), np.asarray(naive), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [None, 128])
    def test_chunked_kv_equals_dense(self, window):
        """The KV-block online-softmax scan (the SP-friendly schedule)."""
        from repro.models.attention import _sdpa_chunked_kv

        b, s, h, kvh, hd = 2, 1024, 4, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        pos = jnp.arange(s)
        dense = _sdpa_dense(q, k, v, q_positions=pos, k_positions=pos,
                            window=window, logit_softcap=None)
        ckv = _sdpa_chunked_kv(q, k, v, q_positions=pos, k_positions=pos,
                               window=window, logit_softcap=None, block=256)
        np.testing.assert_allclose(np.asarray(ckv), np.asarray(dense),
                                   rtol=2e-5, atol=2e-5)

    def test_chunked_kv_gradients_match(self):
        from repro.models.attention import _sdpa_chunked_kv

        b, s, h, hd = 1, 512, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
        pos = jnp.arange(s)

        def loss(fn):
            return lambda q_: jnp.sum(
                fn(q_, k, v, q_positions=pos, k_positions=pos,
                   window=None, logit_softcap=None) ** 2
            )

        g_dense = jax.grad(loss(_sdpa_dense))(q)
        g_ckv = jax.grad(
            loss(lambda *a, **kw: _sdpa_chunked_kv(*a, block=128, **kw))
        )(q)
        np.testing.assert_allclose(np.asarray(g_ckv), np.asarray(g_dense),
                                   rtol=1e-3, atol=1e-4)


def _moe_cfg(impl="einsum", cf=8.0):
    return ModelConfig(
        name="m", family="moe", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, capacity_factor=cf, impl=impl),
    )


class TestMoE:
    def test_einsum_equals_gather(self):
        cfg_e, cfg_g = _moe_cfg("einsum"), _moe_cfg("gather")
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg_e)
        toks = jax.random.randint(key, (2, 16), 0, 64)
        le, _ = forward(params, {"tokens": toks}, cfg_e)
        lg, _ = forward(params, {"tokens": toks}, cfg_g)
        np.testing.assert_allclose(np.asarray(le), np.asarray(lg), rtol=1e-4, atol=1e-4)

    def test_router_gradient_flows(self):
        """stop_gradient top_k must NOT stop router learning."""
        cfg = _moe_cfg()
        key = jax.random.PRNGKey(1)
        params = init_params(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, 64)
        grads = jax.grad(lambda p: loss_fn(p, {"tokens": toks, "labels": toks}, cfg)[0])(params)
        router_grads = [
            g for path, g in jax.tree_util.tree_leaves_with_path(grads)
            if "router" in jax.tree_util.keystr(path)
        ]
        assert router_grads and all(float(jnp.abs(g).max()) > 0 for g in router_grads)

    def test_gate_mass_conserved(self):
        """Per-token gate values sum to 1 after renormalization."""
        from repro.models.ffn import _router_probs

        cfg = _moe_cfg()
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (64, 32))
        router = jax.random.normal(key, (32, 4)) * 0.1
        probs, gates, idx = _router_probs({"router": router}, x, cfg.moe)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
        assert probs.shape == (64, 4) and idx.shape == (64, 2)
        # top-2 indices are distinct per token
        assert bool((idx[:, 0] != idx[:, 1]).all())

    def test_capacity_drops_bounded(self):
        """With cf=0.5 some tokens drop, output stays finite and sane."""
        cfg = _moe_cfg(cf=0.5)
        key = jax.random.PRNGKey(3)
        params = init_params(key, cfg)
        toks = jax.random.randint(key, (2, 32), 0, 64)
        logits, aux = forward(params, {"tokens": toks}, cfg)
        assert bool(jnp.isfinite(logits).all())
        assert float(aux) > 0  # load-balance loss active

    def test_aux_loss_uniform_is_one(self):
        """Perfectly uniform routing gives aux loss ~= 1 (Switch scaling)."""
        from repro.models.ffn import _aux_loss

        e, t = 4, 1024
        probs = jnp.full((t, e), 1.0 / e)
        idx = jnp.stack([jnp.arange(t) % e, (jnp.arange(t) + 1) % e], axis=1)
        val = _aux_loss(probs, idx, MoEConfig(num_experts=e, num_experts_per_tok=2))
        assert abs(float(val) - 1.0) < 1e-5


class TestRwkv:
    def test_scan_vs_stepwise(self):
        """T-step scan == T single-step calls (decode consistency)."""
        b, t, h, n = 1, 8, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) * 0.5 for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)) + 2.0)
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        s0 = jnp.zeros((b, h, n, n))
        out_scan, fin_scan = _wkv_with_initial_state(r, k, v, w, u, s0)
        state = s0
        outs = []
        for i in range(t):
            o, state = _wkv_with_initial_state(
                r[:, i:i+1], k[:, i:i+1], v[:, i:i+1], w[:, i:i+1], u, state
            )
            outs.append(o)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate(outs, 1)), np.asarray(out_scan), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(np.asarray(state), np.asarray(fin_scan), rtol=1e-5, atol=1e-6)

    @given(st.integers(min_value=1, max_value=20))
    @settings(max_examples=10, deadline=None)
    def test_decay_keeps_state_bounded(self, t):
        """w in (0,1) and bounded inputs -> state stays bounded (stability)."""
        b, h, n = 1, 1, 4
        key = jax.random.PRNGKey(t)
        ks = jax.random.split(key, 4)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))
        u = jnp.zeros((h, n))
        _, fin = _wkv_with_initial_state(r, k, v, w, u, jnp.zeros((b, h, n, n)))
        # geometric series bound: |state| <= max|kv| / (1 - max w)
        bound = float(jnp.abs(k).max() * jnp.abs(v).max()) * t + 1.0
        assert float(jnp.abs(fin).max()) <= bound


class TestRgLru:
    def test_scan_vs_stepwise(self):
        b, t, dr = 2, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (b, t, dr))
        r_gate = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, dr)))
        i_gate = jax.nn.sigmoid(jax.random.normal(ks[2], (b, t, dr)))
        lam = jax.random.normal(ks[3], (dr,))
        h_all, h_last = rg_lru(x, r_gate, i_gate, lam, h0=jnp.zeros((b, dr)))
        h = jnp.zeros((b, dr))
        for i in range(t):
            hi, h = rg_lru(x[:, i:i+1], r_gate[:, i:i+1], i_gate[:, i:i+1], lam, h0=h)
            np.testing.assert_allclose(np.asarray(hi[:, 0]), np.asarray(h_all[:, i]),
                                       rtol=2e-4, atol=2e-5)

    def test_contractive(self):
        """|a_t| < 1 everywhere: zero input decays the state."""
        b, t, dr = 1, 32, 8
        lam = jnp.full((dr,), 2.0)  # sigmoid(2) ~ 0.88 -> a ~ 0.88^8c...
        h0 = jnp.ones((b, dr))
        x = jnp.zeros((b, t, dr))
        gates = jnp.ones((b, t, dr)) * 0.5
        _, h_last = rg_lru(x, gates, gates, lam, h0=h0)
        assert float(jnp.abs(h_last).max()) < 1.0


class TestCacheMechanics:
    def test_rolling_window_slot_invariant(self):
        """Windowed cache: position p always lands at slot p % size."""
        from repro.models.attention import make_cache_from_prefill

        k = jnp.arange(2 * 12 * 2 * 16, dtype=jnp.float32).reshape(2, 12, 2, 16)
        cache = make_cache_from_prefill(k, k, jnp.arange(12), window=8, max_len=20)
        assert cache["k"].shape[1] == 8
        pos = np.asarray(cache["pos"])
        for slot, p in enumerate(pos):
            if p >= 0:
                assert p % 8 == slot

    def test_prefill_pad_slots_flagged(self):
        from repro.models.attention import make_cache_from_prefill

        k = jnp.ones((1, 3, 1, 4))
        cache = make_cache_from_prefill(k, k, jnp.arange(3), window=None, max_len=8)
        pos = np.asarray(cache["pos"])
        assert (pos[:3] == [0, 1, 2]).all() and (pos[3:] == -1).all()
