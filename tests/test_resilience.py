"""Resilience subsystem tests (ISSUE 7): gray-failure injection ->
SLA-probe detection -> graceful degradation, SRLG atomicity, and the full
pod-kill -> checkpoint-restore -> remesh -> deterministic-data-resume loop.

Layered like the subsystem itself:

* :class:`TestSlaProbe` — the threshold-with-hysteresis state machine and
  the calibrated per-pair bank (``repro.core.slaprobe``);
* :class:`TestDegradationApi` — netem brownouts resolve, replace (never
  compound), and restore exactly (``repro.core.wan``);
* :class:`TestSrlgAtomicity` — ``fail_group`` over an SRLG's member links
  is state-identical to sequential per-link failure;
* :class:`TestRunnerResilience` — ``run_scenario`` closes the loop:
  probes trip/recover, the policy adapts from the *next* step, pod loss
  is priced into the timeline, and the no-policy path stays untouched;
* :class:`TestFailureRecoveryLoop` — the runtime substrate end to end:
  kill a pod, detect by heartbeat, restore the latest pre-failure
  checkpoint, remesh, and resume the data pipeline deterministically.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.slaprobe import ProbeState, SlaProbe, SlaProbeBank
from repro.scenario import (
    DegradationPolicy,
    Scenario,
    ScenarioEvent,
    SyncOptions,
    TopologySpec,
    WorkloadSpec,
    get_scenario,
    run_scenario,
)


def _small_geo(num_pods=2, seed=5, **kw):
    return TopologySpec(num_pods=num_pods, workers_per_pod=2, seed=seed, **kw).build()


class TestSlaProbe:
    def test_trip_needs_consecutive_breaches(self):
        p = SlaProbe(pair=(1, 2), rate_floor_gbps=1.0, trip_after=2, recover_after=2)
        assert p.observe(0.0, rate_gbps=0.5, rtt_ms=10.0) == ProbeState.HEALTHY
        assert p.observe(1.0, rate_gbps=2.0, rtt_ms=10.0) == ProbeState.HEALTHY
        # a clean sample reset the streak; two in a row now trip
        p.observe(2.0, rate_gbps=0.5, rtt_ms=10.0)
        assert p.observe(3.0, rate_gbps=0.5, rtt_ms=10.0) == ProbeState.DEGRADED

    def test_recovery_hysteresis(self):
        p = SlaProbe(pair=(1, 2), rate_floor_gbps=1.0, trip_after=1, recover_after=2)
        p.observe(0.0, rate_gbps=0.0, rtt_ms=1.0)
        assert p.state == ProbeState.DEGRADED
        p.observe(1.0, rate_gbps=2.0, rtt_ms=1.0)
        assert p.state == ProbeState.DEGRADED  # one clean sample is noise
        p.observe(2.0, rate_gbps=2.0, rtt_ms=1.0)
        assert p.state == ProbeState.HEALTHY

    def test_rtt_ceiling_trips_alone(self):
        p = SlaProbe(pair=(1, 2), rate_floor_gbps=0.0, rtt_ceiling_ms=50.0, trip_after=1)
        assert p.observe(0.0, rate_gbps=0.0, rtt_ms=51.0) == ProbeState.DEGRADED

    def test_clock_must_be_monotonic(self):
        p = SlaProbe(pair=(1, 2))
        p.observe(5.0, rate_gbps=1.0, rtt_ms=1.0)
        with pytest.raises(ValueError):
            p.observe(4.0, rate_gbps=1.0, rtt_ms=1.0)

    def test_bank_calibration_and_transitions(self):
        bank = SlaProbeBank(rate_floor_frac=0.5, rtt_ceiling_frac=2.0, trip_after=1)
        bank.calibrate((1, 2), rate_gbps=2.0, rtt_ms=20.0)
        with pytest.raises(ValueError):
            bank.calibrate((1, 2), rate_gbps=2.0, rtt_ms=20.0)
        # healthy sample, then a breach, then recovery — every change recorded
        bank.observe((1, 2), 0.0, rate_gbps=2.0, rtt_ms=20.0)
        bank.observe((1, 2), 1.0, rate_gbps=0.5, rtt_ms=20.0)
        assert bank.tripped() == ((1, 2),) and bank.any_degraded
        bank.observe((1, 2), 2.0, rate_gbps=2.0, rtt_ms=20.0)
        bank.observe((1, 2), 3.0, rate_gbps=2.0, rtt_ms=20.0)
        assert bank.tripped() == ()
        assert [t.state for t in bank.transitions] == [
            ProbeState.DEGRADED,
            ProbeState.HEALTHY,
        ]

    def test_zero_rate_calibration_disables_rate_floor(self):
        """A pair that carries no baseline traffic must not trip on rate —
        only its RTT ceiling stays live (the runner's uncarried-pair rule)."""
        bank = SlaProbeBank(trip_after=1)
        bank.calibrate((1, 3), rate_gbps=0.0, rtt_ms=20.0)
        assert bank.observe((1, 3), 0.0, rate_gbps=0.0, rtt_ms=20.0) == ProbeState.HEALTHY
        assert bank.observe((1, 3), 1.0, rate_gbps=0.0, rtt_ms=100.0) == ProbeState.DEGRADED


class TestDegradationApi:
    def test_degrade_pair_resolves_and_restores_exactly(self):
        geo = _small_geo()
        link = next(iter(geo.fabric.wan_links))
        before = geo.netem.profile(*link)
        geo.netem.degrade_pair(1, 2, bandwidth_fraction=0.5, extra_delay_ms=3.0)
        after = geo.netem.profile(*link)
        assert after.bandwidth_gbps == pytest.approx(before.bandwidth_gbps * 0.5)
        assert after.delay_ms == pytest.approx(before.delay_ms + 3.0)
        assert geo.netem.degraded_pairs == ((1, 2),)
        geo.netem.restore_pair(1, 2)
        assert geo.netem.profile(*link) == before
        assert geo.netem.degraded_pairs == ()

    def test_redegrade_replaces_never_compounds(self):
        geo = _small_geo()
        link = next(iter(geo.fabric.wan_links))
        base = geo.netem.profile(*link)
        geo.netem.degrade_pair(1, 2, bandwidth_fraction=0.5)
        geo.netem.degrade_pair(1, 2, bandwidth_fraction=0.5)
        assert geo.netem.profile(*link).bandwidth_gbps == pytest.approx(
            base.bandwidth_gbps * 0.5  # not 0.25
        )
        geo.netem.restore_pair(1, 2)
        assert geo.netem.profile(*link) == base

    def test_degrade_link_wins_over_pair(self):
        geo = _small_geo()
        links = sorted(tuple(sorted(l)) for l in geo.fabric.wan_links)
        target, other = links[0], links[-1]
        geo.netem.degrade_pair(1, 2, bandwidth_fraction=0.5)
        geo.netem.degrade_link(*target, bandwidth_fraction=0.1)
        pair_prof = geo.netem.profile(*other)
        link_prof = geo.netem.profile(*target)
        assert link_prof.bandwidth_gbps < pair_prof.bandwidth_gbps
        geo.netem.restore_link_profile(*target)
        assert geo.netem.profile(*target) == pair_prof

    def test_restore_without_degradation_raises(self):
        geo = _small_geo()
        with pytest.raises(ValueError):
            geo.netem.restore_pair(1, 2)
        with pytest.raises(ValueError):
            geo.netem.restore_link_profile("d1s1", "d2s1")

    def test_brownout_raises_cost_and_rtt_without_bfd(self):
        """The gray regime: the link never goes down (no recovery timeline
        is even possible — no detector involvement), but costs rise."""
        geo = _small_geo()
        [a, b] = geo.pod_leaders()
        healthy_cost = geo.sync_cost("hier", 8_000_000, jitter=False).wan_seconds
        healthy_rtt = geo.netem.base_rtt_ms(a, b)
        geo.netem.degrade_pair(1, 2, bandwidth_fraction=0.25, extra_delay_ms=5.0)
        assert all(geo.fabric.link_up(*l) for l in geo.fabric.wan_links)
        assert geo.sync_cost("hier", 8_000_000, jitter=False).wan_seconds > healthy_cost
        assert geo.netem.base_rtt_ms(a, b) > healthy_rtt


class TestSrlgAtomicity:
    def test_fail_group_equals_sequential(self):
        spec = get_scenario("srlg_fiber_cut")
        pairs = spec.topology.srlg_pairs("subsea-1")
        geo_a, geo_b = spec.topology.build(), spec.topology.build()
        members = set(pairs)
        links = sorted(
            tuple(sorted(l))
            for l in geo_a.fabric.wan_links
            if geo_a.fabric.wan_pair(*l) in members
        )
        assert len({geo_a.fabric.wan_pair(*l) for l in links}) == len(pairs) == 2
        timeline, reroutes, resyncs = geo_a.detector.fail_group(links)
        seq_reroutes = [geo_b.fabric.fail_link(*l) for l in links]
        seq_resyncs = [geo_b.evpn.resync_incremental(s) for s in seq_reroutes]
        assert [dataclasses.asdict(s) for s in reroutes] == [
            dataclasses.asdict(s) for s in seq_reroutes
        ]
        assert [dataclasses.asdict(s) for s in resyncs] == [
            dataclasses.asdict(s) for s in seq_resyncs
        ]
        assert dict(geo_a.fabric.link_bytes) == dict(geo_b.fabric.link_bytes)
        # one shared detection window for the whole group
        assert timeline.recovery_ms > 0

    def test_restore_group_brings_all_links_back(self):
        spec = get_scenario("srlg_fiber_cut")
        geo = spec.topology.build()
        members = set(spec.topology.srlg_pairs("subsea-1"))
        links = sorted(
            tuple(sorted(l))
            for l in geo.fabric.wan_links
            if geo.fabric.wan_pair(*l) in members
        )
        geo.detector.fail_group(links)
        assert all(not geo.fabric.link_up(*l) for l in links)
        geo.detector.restore_group(links)
        assert all(geo.fabric.link_up(*l) for l in links)


def _healthy_scenario(**kw) -> Scenario:
    return Scenario(
        name="healthy",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=5),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=64_000_000,
            compute_seconds=0.3,
            overlap_fraction=0.5,
            steps=4,
        ),
        options=SyncOptions(jitter=False),
        **kw,
    )


class TestRunnerResilience:
    def test_policy_path_matches_legacy_on_healthy_fabric(self):
        """With no degradation to react to, the resilience costing path is
        step-for-step identical to the historical one — the policy only
        changes what happens *after* a probe trips."""
        legacy = run_scenario(_healthy_scenario())
        adapted = run_scenario(_healthy_scenario(policy=DegradationPolicy()))
        assert [s.seconds for s in legacy.steps] == [s.seconds for s in adapted.steps]
        assert [s.sync_seconds for s in legacy.steps] == [
            s.sync_seconds for s in adapted.steps
        ]
        assert adapted.steps[0].sync_seconds > 0  # sync genuinely exposed
        assert not adapted.probe_transitions
        assert not any(s.degraded for s in adapted.steps)

    def test_brownout_trips_probe_and_adapts_next_step(self):
        result = run_scenario(get_scenario("wan_brownout"))
        policy = result.scenario.policy
        degrade_at = next(
            e.at_step for e in result.scenario.events if e.kind == "degrade_pair"
        )
        trip_step = degrade_at + policy.trip_after - 1
        trips = [t for t in result.probe_transitions if t.state == ProbeState.DEGRADED]
        assert trips and trips[0].at_ms == trip_step * 1000.0
        # detect, then react: the tripping step itself is costed un-adapted
        assert result.steps[trip_step].degraded is False
        assert result.steps[trip_step + 1].degraded is True
        # hysteresis recovers after the restore event
        recovers = [t for t in result.probe_transitions if t.state == ProbeState.HEALTHY]
        assert recovers and not result.steps[-1].degraded
        # gray by construction: BFD saw nothing
        assert result.recoveries == []

    def test_brownout_policy_beats_no_policy(self):
        adapted = run_scenario(get_scenario("wan_brownout"))
        rode_out = run_scenario(get_scenario("wan_brownout", policy=None))
        assert adapted.total_seconds < rode_out.total_seconds

    def test_pod_fail_is_priced_into_the_timeline(self):
        result = run_scenario(get_scenario("pod_loss_recovery"))
        assert len(result.pod_recoveries) == 1
        rec = result.pod_recoveries[0]
        assert rec.pod == 2
        assert rec.detected_at_step > rec.failed_at_step
        pricing = result.scenario.policy
        anchor = (rec.failed_at_step // pricing.checkpoint_every) * pricing.checkpoint_every
        assert rec.plan.lost_steps == rec.detected_at_step - anchor
        # downtime lands on the detection step, nowhere else
        charged = [s.step for s in result.steps if s.downtime_seconds > 0]
        assert charged == [rec.detected_at_step]
        # a sole survivor has no WAN peer: post-remesh steps cost no sync
        post = [s for s in result.steps if s.step > rec.detected_at_step]
        assert post and all(s.sync_seconds == 0.0 for s in post)
        assert "collapsed" in rec.mesh.note

    def test_resilience_results_json_serializable(self):
        for name in ("wan_brownout", "srlg_fiber_cut", "pod_loss_recovery"):
            d = json.dumps(run_scenario(get_scenario(name)).to_dict())
            assert json.loads(d)["metrics"], name


class TestFailureRecoveryLoop:
    def test_kill_restore_remesh_resume(self, tmp_path):
        """The satellite's end-to-end drill, on the real runtime substrate:
        a pod dies mid-run; the heartbeat monitor detects it; training
        rolls back to the latest *pre-failure* checkpoint; the mesh
        collapses to the survivors; and the data loader reproduces the
        rollback step's batch exactly (no silent data skew)."""
        import jax

        from repro.checkpoint import CheckpointStore
        from repro.data import DataConfig, ShardedLoader
        from repro.runtime import HeartbeatMonitor, plan_recovery, plan_remesh

        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=13)
        loader = ShardedLoader(cfg)
        # pin the store's wall-clock seam to the drill's simulated time:
        # checkpoint metadata becomes a pure function of the script, so
        # the whole drill (timestamps included) replays byte-identically
        sim = {"now": 0.0}
        store = CheckpointStore(tmp_path, clock=lambda: sim["now"])
        tree = {"w": jax.numpy.arange(8, dtype=jax.numpy.float32)}
        mon = HeartbeatMonitor(["pod1", "pod2"], interval_ms=100.0, detect_mult=3)

        checkpoint_every, fail_at, batches = 4, 6, []
        detected_step = None
        for step in range(10):
            batches.append(loader.next_batch())
            now = sim["now"] = step * 100.0
            if step % checkpoint_every == 0:
                store.save(step, tree, metadata={"data_step": step})
            mon.heartbeat("pod1", now)
            if step < fail_at:
                mon.heartbeat("pod2", now)
            dead = mon.poll(now)
            if dead:
                detected_step = step
                break
        assert dead == ["pod2"]
        assert fail_at < detected_step < 10

        # the pod died *silently*: a checkpoint landed at step 8, after the
        # failure but before detection — blindly resuming from latest_step()
        # would bake the dead pod's stale state in.  Roll back to the last
        # checkpoint that predates the failure instead (the runner's anchor).
        assert store.latest_step() == 8
        anchor = (fail_at // checkpoint_every) * checkpoint_every
        assert anchor == 4 and anchor in store.steps()
        restored, meta = store.restore(anchor, tree)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))

        # the injected clock pinned every timestamp the store wrote: the
        # manifest's written_at and the commit-marker content are the
        # drill's simulated times, not wall time
        manifest = json.loads((store._dir(anchor) / "manifest.json").read_text())
        assert manifest["written_at"] == anchor * 100.0
        assert store._marker(anchor).read_text() == str(anchor * 100.0)
        assert json.loads(
            (store._dir(8) / "manifest.json").read_text()
        )["written_at"] == 800.0

        plan = plan_recovery(
            step=detected_step,
            last_checkpoint_step=anchor,
            step_time_s=1.0,
            detect_time_ms=mon.detect_time_ms(),
            checkpoint_bytes=1e8,
        )
        assert plan.lost_steps == detected_step - anchor
        mesh = plan_remesh(2, 1, data=4, model=2)
        assert mesh.shape == (4, 2)  # pod axis collapsed, survivors keep going

        # deterministic resume: the loader seeks to the restored data step
        resumed = ShardedLoader(cfg, start_step=meta["data_step"])
        np.testing.assert_array_equal(
            resumed.next_batch()["tokens"], batches[anchor]["tokens"]
        )
