"""Per-DC-pair asymmetric WANs + the sweep/campaign engine (ISSUE 6).

Covers the tentpole guarantees:

* **symmetric-default byte-identity** — a per-pair map holding one uniform
  profile (and the empty map) is bit-identical to the legacy two-class
  ``Netem`` across ``sync_cost`` (fluid + congestion + weighted branches,
  including the jitter RNG stream), ``step_time``,
  ``contended_transfer_time`` (the congestion-report arrays), and
  ``simulate_schedule``;
* **profile resolution** — ``netem.profile(u, v)`` precedence (per-link
  override > per-pair map > class default), asymmetry visible in RTT /
  roofline / sync costing, and ``normalize_wan_pairs`` validation;
* **``TopologySpec.wan_pairs`` JSON round-trip identity** — through an
  actual ``json.dumps``/``loads`` cycle, key normalization included;
* **sweep determinism** — the same sweep joined over 1 vs 2 process-pool
  workers is identical, ``random_campaign(seed)`` is a deterministic
  artifact of its seed, and dotted-field ``apply_overrides`` expansion
  validates co-dependent fields together.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.geo import GeoFabric, SyncOptions
from repro.core.wan import Netem, NetemProfile, PAPER_LAN, PAPER_WAN, normalize_wan_pairs
from repro.scenario import (
    Scenario,
    ScenarioEvent,
    Sweep,
    TopologySpec,
    WorkloadSpec,
    apply_overrides,
    fiber_latency_campaign,
    random_campaign,
    run_sweep,
)
from repro.scenario.sweep import overlap_benefit_curve

GRAD = 24_000_000


def _uniform_pairs(num_pods: int, profile: NetemProfile):
    return {
        (a, b): profile
        for a in range(1, num_pods + 1)
        for b in range(a + 1, num_pods + 1)
    }


class TestSymmetricByteIdentity:
    """A uniform per-pair map must be indistinguishable from the legacy
    two-class Netem — outputs *and* RNG stream."""

    @pytest.mark.parametrize("num_pods", [2, 3])
    def test_sync_cost_all_branches(self, num_pods):
        legacy = GeoFabric(num_pods, 2, seed=9)
        mapped = GeoFabric(
            num_pods, 2, seed=9, wan_pairs=_uniform_pairs(num_pods, PAPER_WAN)
        )
        for opts in (
            SyncOptions(),  # fluid + jitter: pins the RNG stream too
            SyncOptions(jitter=False),
            SyncOptions(jitter=False, congestion=True),
            SyncOptions(jitter=False, congestion=True, ecmp_weighted=True),
        ):
            for strategy in ("allreduce", "hier", "rs_ag_overlap"):
                a = legacy.sync_cost(strategy, GRAD, options=opts)
                b = mapped.sync_cost(strategy, GRAD, options=opts)
                assert a.wan_seconds == b.wan_seconds
                assert a.wan_bytes == b.wan_bytes
                assert a.bottleneck_link == b.bottleneck_link
                assert a.bottleneck_utilization == b.bottleneck_utilization
                assert [dataclasses.astuple(p) for p in a.phases] == [
                    dataclasses.astuple(p) for p in b.phases
                ]

    def test_step_time_and_jitter_stream(self):
        legacy = GeoFabric(2, 2, seed=3)
        mapped = GeoFabric(2, 2, seed=3, wan_pairs={(1, 2): PAPER_WAN})
        for _ in range(4):  # consecutive draws keep the streams aligned
            assert legacy.step_time(
                "allreduce", GRAD, 1.0, overlap_fraction=0.5
            ) == mapped.step_time("allreduce", GRAD, 1.0, overlap_fraction=0.5)

    def test_congestion_report_arrays(self):
        from repro.core.flows import ring_allreduce_flows

        legacy = GeoFabric(2, 2, seed=0)
        mapped = GeoFabric(2, 2, seed=0, wan_pairs={(2, 1): PAPER_WAN})
        flows = ring_allreduce_flows(legacy.workers(), GRAD, num_channels=4)
        a = legacy.timing.contended_transfer_time(flows)
        b = mapped.timing.contended_transfer_time(flows)
        np.testing.assert_array_equal(a.rates_gbps, b.rates_gbps)
        np.testing.assert_array_equal(a.completion_s, b.completion_s)
        np.testing.assert_array_equal(a.throughput_gbps, b.throughput_gbps)
        assert a.links == b.links

    def test_simulate_schedule(self):
        legacy = GeoFabric(2, 2, seed=0)
        mapped = GeoFabric(2, 2, seed=0, wan_pairs={(1, 2): PAPER_WAN})
        sched = legacy.build_schedule("rs_then_ag", GRAD)
        a = legacy.timing.contended_schedule_time(sched)
        b = mapped.timing.contended_schedule_time(sched)
        assert a.seconds == b.seconds
        np.testing.assert_array_equal(a.completion_s, b.completion_s)
        np.testing.assert_array_equal(a.peak_throughput_gbps, b.peak_throughput_gbps)

    def test_transfer_time_host_links_unified(self):
        geo = GeoFabric(2, 2, seed=0)
        host_link = ("d1h1", "d1l1")
        res = geo.timing.transfer_time({host_link: 10_000_000})
        lan_bw = geo.netem.lan.bandwidth_gbps
        assert res.seconds == 10_000_000 * 8.0 / (lan_bw * 1e9)

    def test_wan_roofline_identity_and_asymmetry(self):
        legacy = GeoFabric(3, 2, seed=0)
        mapped = GeoFabric(3, 2, seed=0, wan_pairs=_uniform_pairs(3, PAPER_WAN))
        assert legacy.wan_roofline_seconds(1e9, 8) == mapped.wan_roofline_seconds(1e9, 8)
        slow = GeoFabric(
            3, 2, seed=0,
            wan_pairs={(1, 2): NetemProfile(delay_ms=5.0, bandwidth_gbps=0.4)},
        )
        assert slow.wan_roofline_seconds(1e9, 8) > legacy.wan_roofline_seconds(1e9, 8)


class TestProfileResolution:
    def test_precedence_override_pair_class(self):
        geo = GeoFabric(2, 2, seed=0)
        pair_prof = NetemProfile(delay_ms=20.0, bandwidth_gbps=0.5)
        netem = Netem(
            geo.fabric, wan=PAPER_WAN, lan=PAPER_LAN, wan_pairs={(1, 2): pair_prof}
        )
        assert netem.profile("d1s1", "d2s2") == pair_prof
        assert netem.profile("d2s1", "d1s1") == pair_prof  # order-insensitive
        assert netem.profile("d1l1", "d1s1") == PAPER_LAN
        link_prof = NetemProfile(delay_ms=1.0, bandwidth_gbps=100.0)
        netem.override_link("d2s2", "d1s1", link_prof)
        assert netem.profile("d1s1", "d2s2") == link_prof
        assert netem.profile("d1s2", "d2s2") == pair_prof  # others keep the pair

    def test_unmapped_pair_falls_back_to_class_default(self):
        geo = GeoFabric(
            3, 2, seed=0,
            wan_pairs={(1, 2): NetemProfile(delay_ms=40.0, bandwidth_gbps=0.4)},
        )
        assert geo.netem.profile("d1s1", "d3s1") == PAPER_WAN
        r12 = geo.netem.base_rtt_ms("d1h1", "d2h1")
        r13 = geo.netem.base_rtt_ms("d1h1", "d3h1")
        assert r12 > r13  # the slow pair is visible end to end

    def test_asymmetry_moves_sync_cost(self):
        sym = GeoFabric(3, 2, seed=0)
        asym = GeoFabric(
            3, 2, seed=0,
            wan_pairs={(2, 3): NetemProfile(delay_ms=5.0, bandwidth_gbps=0.1)},
        )
        a = sym.sync_cost("allreduce", GRAD, jitter=False, congestion=True)
        b = asym.sync_cost("allreduce", GRAD, jitter=False, congestion=True)
        assert b.wan_seconds > a.wan_seconds

    def test_normalize_validation(self):
        with pytest.raises(ValueError, match="not a DC"):
            normalize_wan_pairs({(1, 1): PAPER_WAN})
        with pytest.raises(ValueError, match="same pair"):
            normalize_wan_pairs({(1, 2): PAPER_WAN, (2, 1): PAPER_LAN})
        with pytest.raises(ValueError, match="outside DCs"):
            normalize_wan_pairs({(1, 5): PAPER_WAN}, 3)
        with pytest.raises(TypeError):
            normalize_wan_pairs({(1, 2): "fast"})
        assert normalize_wan_pairs(None) == {}
        assert normalize_wan_pairs({(3, 1): PAPER_WAN}) == {(1, 3): PAPER_WAN}


class TestTopologySpecWanPairs:
    def test_json_round_trip_identity(self):
        spec = TopologySpec(
            num_pods=3,
            wan_pairs={
                (2, 1): NetemProfile(delay_ms=30.0, bandwidth_gbps=0.4),
                (1, 3): NetemProfile(delay_ms=4.0, bandwidth_gbps=2.0),
            },
        )
        restored = TopologySpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert restored == spec
        # keys were normalized + sorted, so reversed input compares equal
        assert spec.wan_pairs[0][0] == (1, 2)

    def test_scenario_round_trip_with_wan_pairs(self):
        s = Scenario(
            name="asym",
            topology=TopologySpec(
                num_pods=2, wan_pairs={(1, 2): NetemProfile(delay_ms=12.0)}
            ),
            workload=WorkloadSpec(strategy="allreduce", grad_bytes=GRAD),
        )
        assert Scenario.from_dict(json.loads(json.dumps(s.to_dict()))) == s

    def test_legacy_dict_without_wan_pairs_loads(self):
        d = TopologySpec().to_dict()
        d.pop("wan_pairs")
        assert TopologySpec.from_dict(d) == TopologySpec()

    def test_build_threads_pairs_to_netem(self):
        prof = NetemProfile(delay_ms=25.0, bandwidth_gbps=0.6)
        geo = TopologySpec(num_pods=2, wan_pairs={(1, 2): prof}).build()
        assert geo.netem.profile("d1s1", "d2s1") == prof

    def test_pairs_validated_against_topology(self):
        with pytest.raises(ValueError, match="outside DCs"):
            TopologySpec(num_pods=2, wan_pairs={(1, 3): PAPER_WAN})


class TestApplyOverrides:
    def test_dotted_fields(self):
        base = Scenario(name="b", workload=WorkloadSpec(strategy="hier", grad_bytes=1))
        out = apply_overrides(
            base,
            {
                "name": "v",
                "workload.overlap_fraction": 0.5,
                "topology.wan.delay_ms": 9.0,
                "options.congestion": True,
                "events": (ScenarioEvent(kind="straggler", slowdown=2.0),),
            },
        )
        assert out.name == "v"
        assert out.workload.overlap_fraction == 0.5
        assert out.topology.wan.delay_ms == 9.0
        assert out.options.congestion is True
        assert out.events[0].kind == "straggler"
        assert base.workload.overlap_fraction == 0.0  # base untouched

    def test_codependent_fields_validate_together(self):
        base = Scenario(name="b")  # 2 pods
        out = apply_overrides(
            base,
            {
                "topology.wan_pairs": {(1, 3): NetemProfile(delay_ms=15.0)},
                "topology.num_pods": 3,
            },
        )
        assert out.topology.num_pods == 3
        assert out.topology.wan_pairs[0][0] == (1, 3)

    def test_bad_paths_raise(self):
        base = Scenario(name="b")
        with pytest.raises(ValueError, match="bad override field"):
            apply_overrides(base, {"workload.nope": 1})
        with pytest.raises(ValueError, match="no field"):
            apply_overrides(base, {"nope.deeper": 1})
        with pytest.raises(ValueError, match="non-spec field"):
            apply_overrides(base, {"name.x": 1})


class TestSweepEngine:
    def _small_sweep(self) -> Sweep:
        return fiber_latency_campaign(rtt_ms=(2.0, 40.0), overlap_fractions=(0.0, 0.75))

    def test_variant_expansion_and_names(self):
        sweep = self._small_sweep()
        variants = sweep.variants()
        assert [v.name for v in variants] == [
            "rtt2ms_f00", "rtt2ms_f75", "rtt40ms_f00", "rtt40ms_f75",
        ]
        assert variants[-1].topology.wan_pairs[0][1].delay_ms == 20.0

    def test_worker_count_never_changes_results(self):
        sweep = self._small_sweep()
        serial = run_sweep(sweep)
        parallel = run_sweep(sweep, workers=2)
        assert [r.to_dict() for r in serial.rows] == [
            r.to_dict() for r in parallel.rows
        ]

    def test_benefit_curve_decays_with_rtt(self):
        curve = overlap_benefit_curve(run_sweep(self._small_sweep()))
        assert len(curve) == 2
        assert curve[1][1] < curve[0][1]

    def test_result_table_json_and_lookup(self):
        result = run_sweep(self._small_sweep())
        payload = json.loads(json.dumps(result.to_dict()))
        assert payload["campaign"] == "fiber_latency_campaign"
        assert len(payload["variants"]) == 4
        assert all("metrics" in v for v in payload["variants"])
        assert result.row("rtt2ms_f00").metrics["mean_step_seconds"] > 0
        assert len(result.metric("mean_step_seconds")) == 4

    def test_compare_gate_reads_campaign_table(self, tmp_path):
        from benchmarks.compare import compare

        result = run_sweep(self._small_sweep())
        for d in ("base", "new"):
            (tmp_path / d).mkdir()
            (tmp_path / d / "BENCH_campaign.json").write_text(
                json.dumps(result.to_dict())
            )
        _, regressions = compare(tmp_path / "base", tmp_path / "new")
        assert regressions == []

    def test_random_campaign_seed_determinism(self):
        a = random_campaign(seed=7, variants=3)
        b = random_campaign(seed=7, variants=3)
        assert a.overrides == b.overrides
        ra = run_sweep(a)
        rb = run_sweep(b, workers=2)
        assert [r.to_dict() for r in ra.rows] == [r.to_dict() for r in rb.rows]
        assert ra.seed == 7

    def test_degradation_axes_deterministic_and_worker_invariant(self):
        kw = dict(variants=4, degrade_probability=0.8, storm_probability=0.6)
        a = random_campaign(seed=21, **kw)
        b = random_campaign(seed=21, **kw)
        assert a.overrides == b.overrides
        kinds = {e.kind for ov in a.overrides for e in ov.get("events", ())}
        assert "degrade_pair" in kinds and "restore_degradation" in kinds
        assert "fail_switch" in kinds and "restore_switch" in kinds
        ra = run_sweep(a)
        rb = run_sweep(b, workers=2)
        assert [r.to_dict() for r in ra.rows] == [r.to_dict() for r in rb.rows]

    def test_degradation_axes_off_by_default_preserve_draw_stream(self):
        """Campaigns generated before the degradation/storm axes existed
        must replay byte-identically: probability 0 consumes no draws."""
        legacy = random_campaign(seed=6, variants=4)
        explicit = random_campaign(
            seed=6, variants=4, degrade_probability=0.0, storm_probability=0.0
        )
        assert legacy.overrides == explicit.overrides
        kinds = {e.kind for ov in legacy.overrides for e in ov.get("events", ())}
        assert kinds <= {"fail_link", "restore_link", "straggler"}

    def test_random_campaign_seeds_differ(self):
        a = random_campaign(seed=1, variants=3)
        b = random_campaign(seed=2, variants=3)
        assert a.overrides != b.overrides

    def test_random_campaign_specs_are_runnable_and_serializable(self):
        sweep = random_campaign(seed=3, variants=3)
        for v in sweep.variants():
            assert Scenario.from_dict(json.loads(json.dumps(v.to_dict()))) == v

    def test_serving_axis_deterministic_and_worker_invariant(self):
        """ISSUE 8 satellite: the ``serving_probability`` axis draws
        ServingSpecs deterministically and survives the process pool."""
        kw = dict(variants=3, serving_probability=1.0)
        a = random_campaign(seed=42, **kw)
        b = random_campaign(seed=42, **kw)
        assert a.overrides == b.overrides
        assert all("serving" in ov for ov in a.overrides)
        for v in a.variants():
            assert v.serving is not None
            assert Scenario.from_dict(json.loads(json.dumps(v.to_dict()))) == v
        ra = run_sweep(a)
        rb = run_sweep(b, workers=2)
        assert [r.to_dict() for r in ra.rows] == [r.to_dict() for r in rb.rows]
        assert all("serving_p99_ms" in r.metrics for r in ra.rows)

    def test_serving_axis_off_by_default_preserves_draw_stream(self):
        """Campaigns generated before the serving axis existed must
        replay byte-identically: probability 0 consumes no draws."""
        legacy = random_campaign(seed=6, variants=4)
        explicit = random_campaign(seed=6, variants=4, serving_probability=0.0)
        assert legacy.overrides == explicit.overrides
        assert all("serving" not in ov for ov in legacy.overrides)
