"""Declarative Scenario/Experiment API tests (ISSUE 5).

Covers the tentpole guarantees:

* **JSON round-trip identity** — ``Scenario.from_dict(s.to_dict()) == s``
  through an actual ``json.dumps``/``loads`` cycle, for specs exercising
  every field class (custom netem profiles, raw FabricConfig override,
  every event kind);
* **SyncOptions back-compat pins** — ``sync_cost(**kwargs)`` bit-identical
  to ``sync_cost(options=SyncOptions(...))`` including the jitter RNG
  stream, across fluid/contended/weighted branches and ``step_time``;
* **runner semantics** — per-step timeline, event application (flaps ->
  RecoveryTimeline/EvpnResyncStats rollups, tenant churn -> reachability,
  stragglers -> compute scaling), control-plane-only scenarios;
* **the library** — every named scenario builds, runs, and the
  JSON-serializable ones round-trip.
"""

import dataclasses
import json

import pytest

from repro.core.fabric import FabricConfig
from repro.core.geo import GeoFabric, SyncOptions
from repro.core.wan import NetemProfile
from repro.scenario import (
    DegradationPolicy,
    Scenario,
    ScenarioEvent,
    TopologySpec,
    WorkloadSpec,
    apply_overrides,
    get_scenario,
    run_scenario,
    scenario_names,
)


def _rich_scenario() -> Scenario:
    return Scenario(
        name="rich",
        topology=TopologySpec(
            num_pods=2,
            workers_per_pod=3,
            wan=NetemProfile(delay_ms=7.5, jitter_ms=0.5, bandwidth_gbps=1.6),
            lan=NetemProfile(delay_ms=0.01, bandwidth_gbps=25.0),
            num_channels=8,
            port_scheme="baseline",
            seed=11,
            fabric=FabricConfig(ecmp_hash_buckets=16),
        ),
        workload=WorkloadSpec(
            strategy="hier",
            grad_bytes=10_000_000,
            compute_seconds=1.5,
            overlap_fraction=0.25,
            steps=4,
        ),
        options=SyncOptions(sync_every=4, jitter=False, congestion=True),
        events=(
            ScenarioEvent(kind="fail_link", at_step=1, link=("d1s1", "d2s1")),
            ScenarioEvent(kind="restore_link", at_step=2, link=("d1s1", "d2s1")),
            ScenarioEvent(
                kind="tenant_detach", at_step=1, tenant="training", host="d1h2"
            ),
            ScenarioEvent(
                kind="tenant_attach", at_step=2, tenant="training", host="d1h2"
            ),
            ScenarioEvent(kind="straggler", at_step=3, slowdown=2.0, duration_steps=1),
        ),
        description="every field class exercised",
    )


class TestJsonRoundTrip:
    def test_round_trip_identity(self):
        s = _rich_scenario()
        d = json.loads(json.dumps(s.to_dict()))
        assert Scenario.from_dict(d) == s

    def test_default_scenario_round_trips(self):
        s = Scenario(name="defaults")
        assert Scenario.from_dict(json.loads(json.dumps(s.to_dict()))) == s

    def test_library_round_trips(self):
        for name in scenario_names():
            s = get_scenario(name)
            d = json.loads(json.dumps(s.to_dict()))
            assert Scenario.from_dict(d) == s, name

    def test_schedule_workload_not_serializable(self):
        from repro.core.schedule import CollectiveSchedule, Phase

        s = Scenario(
            name="sched",
            workload=WorkloadSpec(
                strategy=CollectiveSchedule("x", (Phase("p"),))
            ),
        )
        with pytest.raises(TypeError):
            s.to_dict()

    def test_event_validation(self):
        with pytest.raises(ValueError):
            ScenarioEvent(kind="nope")
        with pytest.raises(ValueError):
            ScenarioEvent(kind="fail_link")  # no link
        with pytest.raises(ValueError):
            ScenarioEvent(kind="tenant_attach", host="d1h1")  # no tenant
        with pytest.raises(ValueError):
            ScenarioEvent(kind="straggler", slowdown=0.5)

    def test_resilience_event_validation(self):
        with pytest.raises(ValueError):
            ScenarioEvent(kind="degrade_link")  # no link
        with pytest.raises(ValueError):
            ScenarioEvent(kind="degrade_pair")  # no pair
        with pytest.raises(ValueError):
            ScenarioEvent(kind="degrade_pair", pair=(1, 1))  # not a pair
        with pytest.raises(ValueError):
            ScenarioEvent(kind="degrade_pair", pair=(1, 2), bandwidth_fraction=0.0)
        with pytest.raises(ValueError):
            ScenarioEvent(kind="degrade_pair", pair=(1, 2), extra_loss=1.0)
        with pytest.raises(ValueError):
            ScenarioEvent(kind="restore_degradation")  # neither link nor pair
        with pytest.raises(ValueError):
            ScenarioEvent(  # both link and pair
                kind="restore_degradation", link=("a", "b"), pair=(1, 2)
            )
        with pytest.raises(ValueError):
            ScenarioEvent(kind="fail_switch")  # no node
        with pytest.raises(ValueError):
            ScenarioEvent(kind="fiber_cut")  # no srlg
        with pytest.raises(ValueError):
            ScenarioEvent(kind="pod_fail")  # no pod
        # pair keys normalize to sorted order, like TopologySpec.wan_pairs
        e = ScenarioEvent(kind="degrade_pair", pair=(2, 1), bandwidth_fraction=0.5)
        assert e.pair == (1, 2)


def _resilient_scenario() -> Scenario:
    """Every resilience extension in one spec: SRLGs, a policy, and every
    new event kind."""
    return Scenario(
        name="resilient",
        topology=TopologySpec(
            num_pods=4,
            workers_per_pod=2,
            seed=3,
            srlgs=(
                ("subsea-1", ((1, 2), (3, 4))),
                ("terrestrial", ((2, 3),)),
            ),
        ),
        workload=WorkloadSpec(strategy="hier", grad_bytes=8_000_000, steps=6),
        options=SyncOptions(jitter=False),
        events=(
            ScenarioEvent(
                kind="degrade_link",
                at_step=0,
                link=("d1s1", "d2s1"),
                bandwidth_fraction=0.5,
                extra_delay_ms=2.0,
                extra_loss=0.01,
            ),
            ScenarioEvent(kind="restore_degradation", at_step=1, link=("d1s1", "d2s1")),
            ScenarioEvent(
                kind="degrade_pair", at_step=1, pair=(1, 2), bandwidth_fraction=0.25
            ),
            ScenarioEvent(kind="restore_degradation", at_step=2, pair=(1, 2)),
            ScenarioEvent(kind="fail_switch", at_step=2, node="d1s1"),
            ScenarioEvent(kind="restore_switch", at_step=3, node="d1s1"),
            ScenarioEvent(kind="fiber_cut", at_step=3, srlg="subsea-1"),
            ScenarioEvent(kind="fiber_restore", at_step=4, srlg="subsea-1"),
            ScenarioEvent(kind="pod_fail", at_step=5, pod=4),
        ),
        policy=DegradationPolicy(
            fallback_strategy="hier", degraded_sync_every=8, int8_wan=True
        ),
        description="resilience extensions exercised end to end",
    )


class TestResilienceSpec:
    """ISSUE 7 spec extensions: SRLGs + DegradationPolicy + gray-failure
    events JSON round-trip, reject unknown keys, and stay reachable
    through sweep dotted overrides."""

    def test_resilient_round_trip_identity(self):
        s = _resilient_scenario()
        assert Scenario.from_dict(json.loads(json.dumps(s.to_dict()))) == s

    def test_srlg_lookup(self):
        topo = _resilient_scenario().topology
        assert topo.srlg_pairs("subsea-1") == ((1, 2), (3, 4))
        with pytest.raises(ValueError):
            topo.srlg_pairs("nonexistent")

    def test_from_dict_rejects_unknown_keys(self):
        s = _resilient_scenario()
        cases = [
            (Scenario, s.to_dict()),
            (TopologySpec, s.topology.to_dict()),
            (WorkloadSpec, s.workload.to_dict()),
            (SyncOptions, s.options.to_dict()),
            (ScenarioEvent, s.events[0].to_dict()),
            (DegradationPolicy, s.policy.to_dict()),
        ]
        for cls, d in cases:
            bad = dict(d)
            bad["not_a_field"] = 1
            with pytest.raises(ValueError, match="not_a_field"):
                cls.from_dict(bad)

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            DegradationPolicy(rate_floor_frac=1.5)
        with pytest.raises(ValueError):
            DegradationPolicy(rtt_ceiling_frac=0.5)
        with pytest.raises(ValueError):
            DegradationPolicy(trip_after=0)
        with pytest.raises(ValueError):
            DegradationPolicy(degraded_sync_every=0)
        with pytest.raises(ValueError):
            DegradationPolicy(checkpoint_every=0)

    def test_extensions_reachable_via_sweep_overrides(self):
        """Dotted overrides reach every new axis: the srlg declaration,
        the degradation policy, and gray-failure event scripts."""
        base = Scenario(
            name="base",
            topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=5),
            workload=WorkloadSpec(grad_bytes=4_000_000, steps=2),
            options=SyncOptions(jitter=False),
        )
        v = apply_overrides(
            base,
            {
                "topology.srlgs": (("g", ((1, 2),)),),
                "policy": DegradationPolicy(int8_wan=True),
                "events": (
                    ScenarioEvent(
                        kind="degrade_pair",
                        at_step=0,
                        pair=(1, 2),
                        bandwidth_fraction=0.5,
                    ),
                    ScenarioEvent(kind="fiber_cut", at_step=1, srlg="g"),
                ),
            },
        )
        assert v.topology.srlg_pairs("g") == ((1, 2),)
        assert v.policy.int8_wan is True
        assert {e.kind for e in v.events} == {"degrade_pair", "fiber_cut"}
        # and the varied spec still serializes (campaign artifact contract)
        assert Scenario.from_dict(json.loads(json.dumps(v.to_dict()))) == v


class TestSyncOptionsBackCompat:
    """The keyword path must stay bit-for-bit identical to the options
    path — including the jitter RNG stream (same draws, same order)."""

    CASES = (
        {"jitter": False},
        {"jitter": True},
        {"jitter": True, "congestion": True},
        {"jitter": False, "congestion": True, "ecmp_weighted": True},
        {"sync_every": 4, "int8_ratio": 0.5, "jitter": True},
    )

    def test_sync_cost_pin(self):
        a = GeoFabric(num_pods=2, workers_per_pod=2, seed=123)
        b = GeoFabric(num_pods=2, workers_per_pod=2, seed=123)
        for kw in self.CASES:
            for strategy in ("allreduce", "local_sgd", "rs_ag_overlap"):
                ca = a.sync_cost(strategy, 20_000_000, **kw)
                cb = b.sync_cost(strategy, 20_000_000, options=SyncOptions(**kw))
                assert ca.wan_seconds == cb.wan_seconds, (strategy, kw)
                assert ca.wan_bytes == cb.wan_bytes
                assert ca.sync_every == cb.sync_every
                assert ca.bottleneck_link == cb.bottleneck_link
                assert ca.bottleneck_utilization == cb.bottleneck_utilization
                assert [p.end_s for p in ca.phases] == [p.end_s for p in cb.phases]
        # streams fully consumed in lockstep: one more jittered call agrees
        assert (
            a.sync_cost("hier", 1_000_000, jitter=True).wan_seconds
            == b.sync_cost("hier", 1_000_000, options=SyncOptions()).wan_seconds
        )

    def test_step_time_pin(self):
        a = GeoFabric(num_pods=2, workers_per_pod=2, seed=9)
        b = GeoFabric(num_pods=2, workers_per_pod=2, seed=9)
        for frac in (0.0, 0.5, 1.0):
            sa = a.step_time(
                "hier", 50_000_000, 2.0, overlap_fraction=frac,
                jitter=True, congestion=True,
            )
            sb = b.step_time(
                "hier", 50_000_000, 2.0, overlap_fraction=frac,
                options=SyncOptions(jitter=True, congestion=True),
            )
            assert sa == sb

    def test_mixing_options_and_kwargs_raises(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2)
        with pytest.raises(TypeError):
            geo.sync_cost("hier", 1000, options=SyncOptions(), jitter=False)
        with pytest.raises(TypeError):
            geo.step_time(
                "hier", 1000, 1.0, options=SyncOptions(), congestion=True
            )

    def test_unknown_keyword_raises(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2)
        with pytest.raises(TypeError):
            geo.sync_cost("hier", 1000, jitters=False)

    def test_options_validation(self):
        with pytest.raises(ValueError):
            SyncOptions(sync_every=0)
        with pytest.raises(ValueError):
            SyncOptions(int8_ratio=0.0)
        assert SyncOptions.from_dict(SyncOptions(jitter=False).to_dict()) == SyncOptions(jitter=False)


class TestRunner:
    def test_per_step_timeline(self):
        s = Scenario(
            name="t",
            workload=WorkloadSpec(strategy="allreduce", grad_bytes=5_000_000, steps=3),
            options=SyncOptions(jitter=False),
        )
        r = run_scenario(s)
        assert len(r.steps) == 3
        assert [st.step for st in r.steps] == [0, 1, 2]
        # jitter-free, event-free: every step identical, equal to the rollup
        assert len({st.seconds for st in r.steps}) == 1
        assert r.steps[0].sync_seconds == pytest.approx(r.sync.amortized_seconds)
        assert r.total_seconds == pytest.approx(3 * r.steps[0].seconds)
        m = r.metrics()
        assert m["sync_wan_seconds"] == pytest.approx(r.sync.wan_seconds)

    def test_result_json_serializable(self):
        r = run_scenario(_rich_scenario())
        payload = json.dumps(r.to_dict())
        back = json.loads(payload)
        assert back["scenario"]["name"] == "rich"
        assert len(back["steps"]) == 4
        assert back["recoveries"] and back["metrics"]

    def test_straggler_scales_compute(self):
        base = Scenario(
            name="s",
            workload=WorkloadSpec(
                strategy="hier", grad_bytes=5_000_000,
                compute_seconds=1.0, steps=3,
            ),
            options=SyncOptions(jitter=False),
        )
        slow = dataclasses.replace(
            base,
            events=(ScenarioEvent(kind="straggler", at_step=1, slowdown=3.0),),
        )
        rb, rs = run_scenario(base), run_scenario(slow)
        assert rs.steps[1].straggler_factor == 3.0
        assert rs.steps[1].compute_seconds == pytest.approx(3.0)
        assert rs.steps[1].seconds > rb.steps[1].seconds
        # only the injected step is affected
        assert rs.steps[0].seconds == pytest.approx(rb.steps[0].seconds)
        assert rs.steps[2].seconds == pytest.approx(rb.steps[2].seconds)

    def test_link_flap_produces_rollups(self):
        s = Scenario(
            name="flap",
            workload=WorkloadSpec(strategy="hier", grad_bytes=5_000_000, steps=3),
            options=SyncOptions(jitter=False),
            events=(
                ScenarioEvent(kind="fail_link", at_step=1, link=("d1s1", "d2s1")),
                ScenarioEvent(kind="restore_link", at_step=2, link=("d1s1", "d2s1")),
            ),
        )
        r = run_scenario(s)
        assert len(r.recoveries) == 1
        assert r.recoveries[0].mechanism == "bfd"
        assert 50 < r.recoveries[0].recovery_ms < 1000  # BFD class
        assert len(r.reroutes) == 2
        assert len(r.evpn_resyncs) == 2  # fail + restore both resync
        assert r.metrics()["mean_recovery_ms"] == pytest.approx(
            r.recoveries[0].recovery_ms
        )
        # the sync keeps working through and after the flap
        assert all(st.sync_seconds > 0 for st in r.steps)

    def test_tenant_churn_changes_reachability(self):
        s = Scenario(
            name="churn",
            workload=WorkloadSpec(strategy=None, steps=0),
            events=(
                ScenarioEvent(
                    kind="tenant_detach", at_step=0, tenant="training", host="d2h2"
                ),
            ),
        )
        r = run_scenario(s)
        assert r.sync is None and r.steps == []
        assert not r.geo.tenancy.ping("d1h1", "d2h2")
        assert r.geo.tenancy.ping("d1h1", "d2h1")

    def test_events_extend_num_steps(self):
        s = Scenario(
            name="tail",
            workload=WorkloadSpec(strategy="hier", grad_bytes=1_000_000, steps=1),
            events=(
                ScenarioEvent(kind="fail_link", at_step=4, link=("d1s1", "d2s1")),
            ),
        )
        assert s.num_steps == 5
        r = run_scenario(s)
        assert len(r.steps) == 1  # workload steps only
        assert len(r.recoveries) == 1  # but the tail event still fired

    def test_new_tenant_attach_needs_vni(self):
        s = Scenario(
            name="vni",
            workload=WorkloadSpec(strategy=None, steps=0),
            topology=TopologySpec(default_tenant=False),
            events=(
                ScenarioEvent(
                    kind="tenant_attach", at_step=0, tenant="job-x", host="d1h1"
                ),
            ),
        )
        with pytest.raises(ValueError, match="vni"):
            run_scenario(s)

    def test_fabric_override_topology(self):
        s = Scenario(
            name="raw",
            topology=TopologySpec(fabric=FabricConfig()),
            workload=WorkloadSpec(strategy="hier", grad_bytes=1_000_000),
            options=SyncOptions(jitter=False),
        )
        r = run_scenario(s)
        # the paper's asymmetric Fig. 1 fabric: 9 hosts, d1h5 exists
        assert len(r.geo.workers()) == 9
        assert r.sync.wan_seconds > 0

    def test_model_workload_resolves_grad_bytes(self):
        from repro.scenario import model_grad_bytes

        w = WorkloadSpec(strategy="allreduce", model="distilgpt2-82m")
        nbytes = w.resolve_grad_bytes()
        assert nbytes == model_grad_bytes("distilgpt2-82m")
        assert nbytes == pytest.approx(82e6 * 4, rel=0.1)  # ~328 MB fp32


class TestTrainerScenario:
    def test_trainer_honors_spec_and_replays_events(self, tmp_path):
        """The spec is authoritative (explicit small step counts included)
        and its event script fires at step boundaries during real
        training."""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_host_mesh
        from repro.optim import AdamWConfig
        from repro.runtime import GeoTrainer, TrainerConfig

        spec = Scenario(
            name="drill",
            workload=WorkloadSpec(strategy="hier", steps=3),
            options=SyncOptions(jitter=False),
            events=(
                ScenarioEvent(kind="fail_link", at_step=1, link=("d1s1", "d2s1")),
                ScenarioEvent(kind="restore_link", at_step=2, link=("d1s1", "d2s1")),
            ),
        )
        trainer = GeoTrainer(
            get_smoke_config("distilgpt2-82m"),
            make_host_mesh(),
            trainer_cfg=TrainerConfig(
                seq_len=32, global_batch=4, steps=100, log_every=100,
                opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
            ),
            checkpoint_dir=str(tmp_path),
            scenario=spec,
        )
        assert trainer.tc.steps == 3  # spec beats the TrainerConfig default
        assert trainer.tc.strategy == "hier"
        result = trainer.run()
        assert len(result["metrics"]) == 3
        assert len(result["scenario_recoveries"]) == 1
        assert result["scenario_recoveries"][0]["mechanism"] == "bfd"
        assert result["scenario_evpn_resyncs"] == 2  # fail + restore
        # the flapped link healed: both directions up again
        assert trainer.geo.fabric.link_up("d1s1", "d2s1")


class TestLibrary:
    def test_names_cover_the_paper_studies(self):
        names = scenario_names()
        for expected in (
            "fig14_allreduce",
            "fig14_ps",
            "compute_overlap",
            "rs_ag_overlap",
            "rs_then_ag",
            "bfd_flap_storm",
            "multi_tenant_churn",
            "ecmp_collision",
        ):
            assert expected in names

    def test_unknown_scenario_raises(self):
        with pytest.raises(ValueError, match="unknown scenario"):
            get_scenario("psychic")

    def test_overlap_beats_serial(self):
        overlap = run_scenario(get_scenario("rs_ag_overlap")).sync
        serial = run_scenario(get_scenario("rs_then_ag")).sync
        assert overlap.wan_seconds < serial.wan_seconds

    def test_churn_scenario_surfaces_evpn_stats(self):
        r = run_scenario(get_scenario("multi_tenant_churn"))
        assert r.evpn_resyncs
        assert any(s.rebuilt > 0 for s in r.evpn_resyncs)  # isolation episode
        assert any(s.rebuilt == 0 for s in r.evpn_resyncs)  # harmless flap
        r.geo.tenancy.verify_isolation()

    def test_ecmp_collision_prices_the_allocator(self):
        base = run_scenario(get_scenario("ecmp_collision", port_scheme="baseline"))
        qp = run_scenario(get_scenario("ecmp_collision", port_scheme="qp_aware"))
        assert qp.sync.wan_seconds < base.sync.wan_seconds
        # the weighted model is what prices the difference: both specs
        # opted into ecmp_weighted congestion
        assert base.scenario.options.ecmp_weighted
        assert qp.scenario.options.ecmp_weighted
