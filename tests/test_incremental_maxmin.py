"""Incremental event-loop allocator tests (ISSUE 9 tentpole).

Four contracts:

* **Component labeling** — :func:`_label_components` partitions the
  flow x link membership rows into the transitive shared-link closure
  (the "affected frontier" unit of the incremental re-solve).
* **Component locality** — :func:`_multi_max_min_rates` solves every
  component independently: solving any union of whole components is
  bitwise the same as solving each alone, and the per-component fixed
  point matches the single-level :func:`_max_min_rates_arrays` reference
  within float tolerance (same water level, different summation order).
* **Byte-identity** — the property test the ISSUE names: random
  multi-phase DAGs (zero-byte flows included, ``ecmp_weighted`` on and
  off) simulated with the warm-started :class:`_IncrementalAllocator`
  produce *exactly* the timelines, rates history, and per-link peaks of
  the from-scratch :class:`_FullEpochAllocator` oracle.
* **Event-budget guard** — the stuck-simulator guard still trips: with a
  monkeypatched :func:`_event_budget` a legitimate multi-phase schedule
  must raise the ``event budget exceeded`` RuntimeError.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import congestion as cg
from repro.core.congestion import (
    _FullEpochAllocator,
    _IncrementalAllocator,
    _label_components,
    _max_min_rates_arrays,
    _multi_max_min_rates,
    simulate_schedule,
)
from repro.core.fabric import Fabric, FabricConfig
from repro.core.flows import Flow
from repro.core.ports import QueuePair
from repro.core.schedule import CollectiveSchedule, Phase
from repro.core.wan import Netem


def _flow(src, dst, nbytes=1_000_000, qpn=0x11, port=50_000):
    return Flow(src, dst, nbytes, QueuePair(0, qpn), port)


# -- component labeling ------------------------------------------------------


class TestLabelComponents:
    def test_disjoint_links_disjoint_components(self):
        # flows 0,1 share link 0; flow 2 alone on link 1
        mem_f = np.array([0, 1, 2])
        mem_l = np.array([0, 0, 1])
        comp, ncomp = _label_components(mem_f, mem_l, 3, 2)
        assert ncomp == 2
        assert comp[0] == comp[1] != comp[2]

    def test_transitive_merge_through_shared_link(self):
        # 0-1 share link 0, 1-2 share link 1 -> all one component
        mem_f = np.array([0, 1, 1, 2])
        mem_l = np.array([0, 0, 1, 1])
        comp, ncomp = _label_components(mem_f, mem_l, 3, 2)
        assert ncomp == 1
        assert len(set(comp.tolist())) == 1

    def test_absent_flows_get_minus_one(self):
        mem_f = np.array([1])
        mem_l = np.array([0])
        comp, ncomp = _label_components(mem_f, mem_l, 3, 1)
        assert ncomp == 1
        assert comp[0] == -1 and comp[2] == -1 and comp[1] == 0

    def test_empty_rows(self):
        comp, ncomp = _label_components(
            np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64), 4, 3
        )
        assert ncomp == 0
        assert (comp == -1).all()

    def test_long_chain_converges(self):
        # flow i shares link i with flow i+1: one chain component whose
        # label needs O(chain length) propagation passes
        n = 40
        mem_f = np.repeat(np.arange(n), 2)[1:-1]
        mem_l = np.repeat(np.arange(n - 1), 2)
        comp, ncomp = _label_components(mem_f, mem_l, n, n - 1)
        assert ncomp == 1
        assert len(set(comp.tolist())) == 1


# -- component locality of the multi solver ----------------------------------


@st.composite
def _random_matrix(draw):
    nflows = draw(st.integers(min_value=1, max_value=12))
    nlinks = draw(st.integers(min_value=1, max_value=8))
    rows = draw(
        st.lists(
            st.tuples(
                st.integers(min_value=0, max_value=nflows - 1),
                st.integers(min_value=0, max_value=nlinks - 1),
            ),
            min_size=1,
            max_size=40,
        )
    )
    caps = draw(
        st.lists(
            st.floats(min_value=0.1, max_value=100.0),
            min_size=nlinks,
            max_size=nlinks,
        )
    )
    # flow-major ascending rows, deduplicated — the CSR layout invariant
    uniq = sorted(set(rows))
    mem_f = np.array([r[0] for r in uniq], dtype=np.int64)
    mem_l = np.array([r[1] for r in uniq], dtype=np.int64)
    weighted = draw(st.booleans())
    weights = None
    if weighted:
        weights = np.array(
            draw(
                st.lists(
                    st.floats(min_value=0.1, max_value=1.0),
                    min_size=nflows,
                    max_size=nflows,
                )
            )
        )
    return mem_f, mem_l, np.array(caps), nflows, nlinks, weights


class TestMultiSolverLocality:
    @settings(max_examples=80, deadline=None)
    @given(_random_matrix())
    def test_union_of_components_equals_solo_solves(self, m):
        """The frontier re-freeze argument, as executable property: a
        component's rates are a pure function of its own rows."""
        mem_f, mem_l, caps, nflows, nlinks, weights = m
        comp, ncomp = _label_components(mem_f, mem_l, nflows, nlinks)
        joint = _multi_max_min_rates(
            mem_f, mem_l, caps, nflows, nlinks, comp, ncomp, weights
        )
        for c in range(ncomp):
            sel = comp[mem_f] == c
            c2, n2 = _label_components(mem_f[sel], mem_l[sel], nflows, nlinks)
            solo = _multi_max_min_rates(
                mem_f[sel], mem_l[sel], caps, nflows, nlinks, c2, n2, weights
            )
            members = np.nonzero(comp == c)[0]
            assert np.array_equal(solo[members], joint[members])

    @settings(max_examples=80, deadline=None)
    @given(_random_matrix())
    def test_same_fixed_point_as_single_level_reference(self, m):
        """Component-decomposed and global water-filling reach the same
        max-min fixed point (they differ only in summation partitions)."""
        mem_f, mem_l, caps, nflows, nlinks, weights = m
        comp, ncomp = _label_components(mem_f, mem_l, nflows, nlinks)
        multi = _multi_max_min_rates(
            mem_f, mem_l, caps, nflows, nlinks, comp, ncomp, weights
        )
        ref = _max_min_rates_arrays(
            mem_f.copy(), mem_l.copy(), caps, nflows, nlinks, weights
        )
        np.testing.assert_allclose(multi, ref, rtol=1e-9, atol=1e-12)

    def test_single_component_is_bitwise_the_reference(self):
        """With one component the multi solver IS the reference solver."""
        rng = np.random.default_rng(7)
        nflows, nlinks = 20, 1  # everything shares the one link
        mem_f = np.arange(nflows, dtype=np.int64)
        mem_l = np.zeros(nflows, dtype=np.int64)
        caps = rng.uniform(0.5, 2.0, size=nlinks)
        w = rng.uniform(0.1, 1.0, size=nflows)
        comp, ncomp = _label_components(mem_f, mem_l, nflows, nlinks)
        assert ncomp == 1
        multi = _multi_max_min_rates(
            mem_f, mem_l, caps, nflows, nlinks, comp, ncomp, w
        )
        ref = _max_min_rates_arrays(
            mem_f.copy(), mem_l.copy(), caps, nflows, nlinks, w
        )
        assert np.array_equal(multi, ref)


# -- incremental == full on random multi-phase DAGs --------------------------


def _fabric():
    return Fabric(
        FabricConfig(
            num_dcs=3,
            spines_per_dc=2,
            leaves_per_dc=2,
            hosts_per_leaf=((2, 2), (2, 1), (2, 2)),
        )
    )


#: host names are a pure function of FabricConfig — safe as a strategy const
_HOSTS = tuple(_fabric().hosts)


@st.composite
def _random_dag_schedule(draw, hosts=_HOSTS):
    """A random multi-phase DAG: random flows (zero-byte ones included),
    random dependencies on earlier phases, offsets, compute times."""
    nphases = draw(st.integers(min_value=2, max_value=5))
    phases = []
    qpn = 0x11
    for i in range(nphases):
        nflows = draw(st.integers(min_value=0, max_value=6))
        flows = []
        for _ in range(nflows):
            src = draw(st.sampled_from(hosts))
            dst = draw(st.sampled_from([h for h in hosts if h != src]))
            nbytes = draw(
                st.one_of(
                    st.just(0),  # zero-byte flows drain instantly
                    st.integers(min_value=1, max_value=50_000_000),
                )
            )
            flows.append(_flow(src, dst, nbytes, qpn=qpn))
            qpn += 1
        deps = ()
        if i > 0:
            deps = tuple(
                f"p{j}"
                for j in range(i)
                if draw(st.booleans())
            )
        phases.append(
            Phase(
                name=f"p{i}",
                flows=tuple(flows),
                deps=deps,
                start_offset_s=draw(
                    st.sampled_from([0.0, 0.05, 0.5])
                ),
                compute_seconds=draw(st.sampled_from([0.0, 0.2])),
            )
        )
    return CollectiveSchedule(name="dag", phases=tuple(phases))


class TestIncrementalByteIdentity:
    @settings(max_examples=40, deadline=None)
    @given(_random_dag_schedule())
    def test_random_dag_incremental_equals_full(self, sched):
        fabric = _fabric()
        netem = Netem(fabric)
        for ecmp_weighted in (False, True):
            inc = simulate_schedule(
                fabric, netem, sched,
                ecmp_weighted=ecmp_weighted, incremental=True,
            )
            full = simulate_schedule(
                fabric, netem, sched,
                ecmp_weighted=ecmp_weighted, incremental=False,
            )
            assert np.array_equal(inc.flow_start_s, full.flow_start_s)
            assert np.array_equal(inc.flow_drain_s, full.flow_drain_s)
            assert np.array_equal(inc.completion_s, full.completion_s)
            assert np.array_equal(
                inc.peak_throughput_gbps, full.peak_throughput_gbps
            )
            for a, b in zip(inc.phase_timings, full.phase_timings):
                assert (a.name, a.start_s, a.end_s) == (
                    b.name, b.start_s, b.end_s,
                )

    def test_module_flag_selects_allocator(self, monkeypatch):
        """``incremental=None`` defers to ``INCREMENTAL_EVENT_LOOP``."""
        fabric = _fabric()
        netem = Netem(fabric)
        hosts = list(fabric.hosts)
        sched = CollectiveSchedule(
            name="two",
            phases=(
                Phase(name="a", flows=(_flow(hosts[0], hosts[-1], 10_000_000),)),
                Phase(
                    name="b",
                    flows=(_flow(hosts[1], hosts[-2], 20_000_000, qpn=0x22),),
                ),
            ),
        )
        seen = []

        class SpyInc(_IncrementalAllocator):
            def __init__(self, *a, **kw):
                seen.append("inc")
                super().__init__(*a, **kw)

        class SpyFull(_FullEpochAllocator):
            def __init__(self, *a, **kw):
                seen.append("full")
                super().__init__(*a, **kw)

        monkeypatch.setattr(cg, "_IncrementalAllocator", SpyInc)
        monkeypatch.setattr(cg, "_FullEpochAllocator", SpyFull)
        simulate_schedule(fabric, netem, sched)
        monkeypatch.setattr(cg, "INCREMENTAL_EVENT_LOOP", False)
        simulate_schedule(fabric, netem, sched)
        assert seen == ["inc", "full"]

    def test_single_phase_fast_path_ignores_allocators(self):
        """Single-phase schedules bypass the event loop entirely — the
        static ``congestion_report`` fast path stays bit-exact regardless
        of the ``incremental`` knob."""
        fabric = _fabric()
        netem = Netem(fabric)
        hosts = list(fabric.hosts)
        sched = CollectiveSchedule.single(
            "one", (_flow(hosts[0], hosts[-1], 10_000_000),)
        )
        a = simulate_schedule(fabric, netem, sched, incremental=True)
        b = simulate_schedule(fabric, netem, sched, incremental=False)
        assert np.array_equal(a.flow_drain_s, b.flow_drain_s)
        assert np.array_equal(a.completion_s, b.completion_s)
        assert np.array_equal(a.peak_throughput_gbps, b.peak_throughput_gbps)


# -- event-budget guard ------------------------------------------------------


class TestEventBudgetGuard:
    @pytest.mark.parametrize("incremental", [True, False])
    def test_guard_trips_when_budget_shrunk(self, monkeypatch, incremental):
        """The regression the ISSUE pins: the stuck-simulator guard must
        still trip.  A legitimate schedule with the budget monkeypatched
        to one event raises rather than spinning."""
        fabric = _fabric()
        netem = Netem(fabric)
        hosts = list(fabric.hosts)
        sched = CollectiveSchedule(
            name="stuck",
            phases=(
                Phase(name="a", flows=(_flow(hosts[0], hosts[-1], 10_000_000),)),
                Phase(
                    name="b",
                    flows=(_flow(hosts[1], hosts[-2], 20_000_000, qpn=0x22),),
                    deps=("a",),
                ),
            ),
        )
        monkeypatch.setattr(cg, "_event_budget", lambda nflows, nphases: 1)
        with pytest.raises(RuntimeError, match="event budget exceeded"):
            simulate_schedule(fabric, netem, sched, incremental=incremental)

    def test_budget_formula(self):
        assert cg._event_budget(10, 3) == 4 * 13 + 64
