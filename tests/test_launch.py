"""Launch-layer units: HLO collective parsing, shapes/specs, mesh helpers."""

import jax
import pytest

from repro.configs import get_config
from repro.launch.hlo_stats import parse_collectives, shape_bytes
from repro.launch.mesh import batch_axes, chips_per_pod, num_pods
from repro.launch.shapes import SHAPES, decode_cache_specs, input_specs, params_specs


class TestShapeBytes:
    @pytest.mark.parametrize(
        "s,expected",
        [
            ("f32[128,1024]{1,0}", 128 * 1024 * 4),
            ("bf16[2,3,4]", 48),
            ("s8[100]", 100),
            ("pred[16]", 16),
            ("f32[]", 4),
            ("(f32[8], bf16[8])", 8 * 4 + 8 * 2),
        ],
    )
    def test_sizes(self, s, expected):
        assert shape_bytes(s) == expected


class TestParseCollectives:
    HLO = """
  %ag = f32[64,128]{1,0} all-gather(f32[4,128] %x), replica_groups={{0,1},{2,3}}, dimensions={0}
  %ar = bf16[256]{0} all-reduce(bf16[256] %y), replica_groups=[2,256]<=[512], to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[64] %z), replica_groups={{0,256}}, dimensions={0}
  %dot = f32[8,8] dot(f32[8,8] %a, f32[8,8] %b)
"""

    def test_counts_and_bytes(self):
        stats = parse_collectives(self.HLO)
        assert stats.count == 3
        assert stats.bytes_by_kind["all-gather"] == 64 * 128 * 4
        assert stats.bytes_by_kind["all-reduce"] == 256 * 2
        assert stats.bytes_by_kind["reduce-scatter"] == 32 * 4

    def test_cross_pod_classification(self):
        stats = parse_collectives(self.HLO, pod_size=256)
        # explicit {{0,256}} spans pods; {{0,1},{2,3}} does not;
        # iota [2,256]<=[512] groups of 256 stay within a pod
        assert stats.cross_pod_bytes == 32 * 4

    def test_iota_oversized_group_is_cross_pod(self):
        hlo = "%ar = f32[16] all-reduce(f32[16] %x), replica_groups=[1,512]<=[512]"
        stats = parse_collectives(hlo, pod_size=256)
        assert stats.cross_pod_bytes == 64

    def test_transposed_iota_pairs_across_pods(self):
        """[256,2]<=[2,256]T(1,0): groups pair device i with i+256 — the
        form GSPMD emits for manual-pod psums on the 2x16x16 mesh."""
        hlo = "%ar = f32[16] all-reduce(f32[16] %x), replica_groups=[256,2]<=[2,256]T(1,0)"
        stats = parse_collectives(hlo, pod_size=256)
        assert stats.cross_pod_bytes == 64
        assert stats.unclassified_bytes == 0


class TestInputSpecs:
    def test_train_specs_for_every_arch(self):
        for arch in ("olmo-1b", "rwkv6-7b", "phi-3-vision-4.2b", "musicgen-large"):
            cfg = get_config(arch)
            specs = input_specs(cfg, "train_4k")["batch"]
            # every leaf is an allocation-free ShapeDtypeStruct with the
            # assigned global batch / seq
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
                assert leaf.shape[0] == SHAPES["train_4k"].global_batch
            if cfg.frontend == "none":
                assert specs["tokens"].shape == (256, 4096)

    def test_decode_specs(self):
        cfg = get_config("olmo-1b")
        specs = input_specs(cfg, "decode_32k")
        assert specs["tokens_t"].shape == (128,)
        cache = decode_cache_specs(cfg, "decode_32k")
        k = cache["groups"]["slot0"]["k"]
        assert k.shape == (16, 128, 32768, 16, 128)  # (L, B, S, KVH, hd)

    def test_long_500k_rejected_for_full_attn(self):
        with pytest.raises(ValueError, match="quadratic"):
            input_specs(get_config("yi-34b"), "long_500k")

    def test_long_500k_state_is_o1_for_rwkv(self):
        cfg = get_config("rwkv6-7b")
        cache = decode_cache_specs(cfg, "long_500k")
        total = sum(s.size for s in jax.tree.leaves(cache))
        # recurrent state is independent of the 524288 context length
        assert total < 50e6

    def test_params_specs_no_allocation(self):
        specs = params_specs(get_config("arctic-480b"))  # 477B params, no memory
        n = sum(s.size for s in jax.tree.leaves(specs))
        assert n > 4e11


class TestServeCli:
    """ISSUE 8 satellite: serve.py's batch construction now lives in
    ``repro.launch.batches`` and is shared with the serving request
    model — the CLI must keep working through the shared helper."""

    def test_serve_smoke(self, capsys):
        from repro.launch import serve

        serve.main(
            ["--arch", "distilgpt2-82m", "--batch", "2", "--prompt-len", "8",
             "--gen", "2"]
        )
        out = capsys.readouterr().out
        assert "prefill: 2x8" in out
        assert "decode: 2 steps" in out
        assert "sample[0]:" in out

    def test_synthetic_prompt_batch_shapes(self):
        from repro.launch.batches import synthetic_prompt_batch

        cfg = get_config("distilgpt2-82m")
        key = jax.random.PRNGKey(0)
        batch = synthetic_prompt_batch(cfg, key, 2, 8)
        assert batch["tokens"].shape == (2, 8)
        # deterministic in the key
        again = synthetic_prompt_batch(cfg, key, 2, 8)
        assert (batch["tokens"] == again["tokens"]).all()

    def test_request_batch_reuses_helper(self):
        """The serving request model builds batches through the same
        helper, keyed by request id."""
        from repro.launch.batches import synthetic_prompt_batch
        from repro.serving import Request, request_batch

        cfg = get_config("distilgpt2-82m")
        req = Request(rid=7, step=0, home_dc=1, user=42, tokens=8)
        got = request_batch(cfg, req)
        want = synthetic_prompt_batch(cfg, jax.random.PRNGKey(7), 1, 8)
        assert (got["tokens"] == want["tokens"]).all()


class _FakeMesh:
    """Shape/axis view of a mesh (this process has 1 real device)."""

    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = axes


class TestMeshHelpers:
    def test_mesh_math(self):
        mesh = _FakeMesh((2, 2, 2), ("pod", "data", "model"))
        assert num_pods(mesh) == 2
        assert chips_per_pod(mesh) == 4
        assert batch_axes(mesh) == ("pod", "data")
        single = _FakeMesh((4, 2), ("data", "model"))
        assert num_pods(single) == 1
        assert batch_axes(single) == ("data",)
