"""Flow-level congestion model tests (paper §5.5 / Fig. 14, ISSUE 2).

Validates the vectorized max-min allocation against a straightforward
per-flow reference implementation, the paper's ~800 Mbit/s effective
spine-WAN throughput observable, and the WanTimingModel/GeoFabric wiring.
"""

import numpy as np
import pytest

from repro.core.congestion import (
    build_link_load_matrix,
    max_min_rates,
    route_and_analyze,
)
from repro.core.fabric import Fabric
from repro.core.flows import (
    Flow,
    all_to_all_flows,
    ring_allreduce_flows,
    route_flows_with_paths,
)
from repro.core.geo import GeoFabric
from repro.core.ports import QueuePair
from repro.core.wan import Netem, WanTimingModel


def _flow(src, dst, nbytes=1_000_000, port=50_000):
    return Flow(src, dst, nbytes, QueuePair(0, 1), port)


class TestMaxMinAllocation:
    def test_single_flow_gets_bottleneck_capacity(self):
        fabric = Fabric()
        netem = Netem(fabric)
        _, report = route_and_analyze(fabric, netem, [_flow("d1h1", "d2h1")])
        assert report.rates_gbps[0] == pytest.approx(0.8)  # WAN cap

    def test_intra_dc_flow_gets_lan_capacity(self):
        fabric = Fabric()
        netem = Netem(fabric)
        _, report = route_and_analyze(fabric, netem, [_flow("d1h1", "d1h2")])
        assert report.rates_gbps[0] == pytest.approx(10.0)

    def test_equal_shares_on_shared_bottleneck(self):
        """K flows between the same host pair share one WAN path's 0.8."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows = [_flow("d1h1", "d2h1", port=50_000) for _ in range(8)]
        _, report = route_and_analyze(fabric, netem, flows)
        # identical 5-tuples -> identical path -> strict 0.8/8 each
        assert report.rates_gbps == pytest.approx(np.full(8, 0.1))

    def test_saturated_wan_link_carries_exactly_capacity(self):
        """Paper §5.5: contended spine WAN links deliver ~800 Mbit/s
        effective throughput no matter the offered load."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows = all_to_all_flows(list(fabric.hosts), 50_000_000)
        _, report = route_and_analyze(fabric, netem, flows)
        assert report.effective_wan_gbps == pytest.approx(0.8, rel=1e-6)
        # and no link is ever allocated beyond its capacity
        assert np.all(report.throughput_gbps <= report.capacity_gbps * (1 + 1e-9))

    def test_max_min_fairness_property(self):
        """No flow can be raised without lowering a slower flow: every flow
        crosses at least one saturated link where it holds a maximal share."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows = all_to_all_flows(list(fabric.hosts), 10_000_000)
        _, paths = route_flows_with_paths(fabric, flows)
        matrix = build_link_load_matrix(fabric, netem, paths)
        rates = max_min_rates(matrix)
        sat = np.zeros(len(matrix.links), dtype=bool)
        thr = np.bincount(
            matrix.mem_link, weights=rates[matrix.mem_flow],
            minlength=len(matrix.links),
        )
        sat = thr >= matrix.capacity_gbps * (1 - 1e-6)
        for f in range(matrix.num_flows):
            on = matrix.mem_link[matrix.mem_flow == f]
            bott = on[sat[on]]
            assert bott.size, f"flow {f} crosses no saturated link"
            for l in bott.tolist():
                peers = rates[matrix.mem_flow[matrix.mem_link == l]]
                if rates[f] >= peers.max() - 1e-9:
                    break
            else:
                pytest.fail(f"flow {f} is not maximal on any of its bottlenecks")

    def test_empty_flow_set(self):
        fabric = Fabric()
        netem = Netem(fabric)
        _, report = route_and_analyze(fabric, netem, [])
        assert report.seconds == 0.0
        assert report.rates_gbps.size == 0

    def test_accepts_generator_input(self):
        fabric = Fabric()
        netem = Netem(fabric)
        _, report = route_and_analyze(
            fabric, netem, (_flow("d1h1", "d2h1") for _ in range(3))
        )
        assert report.rates_gbps.size == 3


class TestCompletionTimes:
    def test_transfer_plus_propagation(self):
        fabric = Fabric()
        netem = Netem(fabric)
        nbytes = 100_000_000
        _, report = route_and_analyze(
            fabric, netem, [_flow("d1h1", "d2h1", nbytes=nbytes)]
        )
        transfer = nbytes * 8 / (0.8e9)
        # one-way propagation ~11 ms across the single WAN hop (Fig. 8 / 2)
        assert report.propagation_ms[0] == pytest.approx(
            netem.base_rtt_ms("d1h1", "d2h1") / 2.0
        )
        assert report.completion_s[0] == pytest.approx(
            transfer + report.propagation_ms[0] / 1e3
        )

    def test_zero_byte_flow_costs_only_propagation(self):
        fabric = Fabric()
        netem = Netem(fabric)
        _, report = route_and_analyze(
            fabric, netem, [_flow("d1h1", "d2h1", nbytes=0)]
        )
        assert report.completion_s[0] == pytest.approx(
            report.propagation_ms[0] / 1e3
        )

    def test_zero_byte_flows_occupy_no_share(self):
        """ROADMAP open item (ISSUE 4 satellite): the static allocator must
        drop zero-byte chunk flows exactly like the event loop drains them
        free — adding a zero-byte flow changes nobody's rate, and the
        zero-byte flow itself gets no allocation."""
        fabric = Fabric()
        netem = Netem(fabric)
        live = [_flow("d1h1", "d2h1", port=50_000 + i) for i in range(4)]
        _, without = route_and_analyze(fabric, netem, live)
        _, with_zero = route_and_analyze(
            fabric, netem, live + [_flow("d1h1", "d2h1", nbytes=0)]
        )
        assert np.array_equal(with_zero.rates_gbps[:4], without.rates_gbps)
        assert with_zero.rates_gbps[4] == 0.0
        # per-link throughput carries no phantom zero-byte allocation
        assert np.all(
            with_zero.throughput_gbps <= with_zero.capacity_gbps * (1 + 1e-9)
        )

    def test_zero_byte_convention_matches_event_loop(self):
        """A single-phase schedule containing zero-byte chunks now costs
        the same through the static fast path and the forced event loop —
        the two conventions are unified."""
        from repro.core.congestion import simulate_schedule
        from repro.core.schedule import CollectiveSchedule, Phase

        fabric = Fabric()
        netem = Netem(fabric)
        # 1 byte over 4 channels: exact split yields zero-byte chunks
        flows = ring_allreduce_flows(sorted(fabric.hosts), 1)
        assert any(f.nbytes == 0 for f in flows)
        fast = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("p", flows)
        )
        looped = simulate_schedule(
            fabric,
            netem,
            CollectiveSchedule("p2", (Phase("p", flows), Phase("end", deps=("p",)))),
        )
        assert fast.seconds == pytest.approx(looped.seconds, rel=1e-9)
        assert np.allclose(fast.completion_s, looped.completion_s, rtol=1e-9)

    def test_contended_slower_than_ideal(self):
        """Contention can only slow a collective down vs the ideal fluid
        estimate of the same routed byte counters."""
        fabric = Fabric()
        netem = Netem(fabric)
        model = WanTimingModel(netem)
        flows = ring_allreduce_flows(list(fabric.hosts), 64_000_003)
        report = model.contended_transfer_time(flows)
        ideal = model.transfer_time(dict(fabric.link_bytes))
        assert report.seconds >= ideal.seconds * (1 - 1e-9)


class TestPathsRecording:
    def test_counters_match_plain_batched(self):
        fabric = Fabric()
        flows = all_to_all_flows(list(fabric.hosts), 3_000_007)
        a, paths = route_flows_with_paths(fabric, flows)
        fabric2 = Fabric()
        from repro.core.flows import route_flows_batched

        b = route_flows_batched(fabric2, flows)
        assert a == b
        assert paths.num_flows == len(flows)

    def test_paths_match_sequential_walk(self):
        fabric = Fabric()
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        _, paths = route_flows_with_paths(fabric, flows)
        ref = Fabric()
        for i, f in enumerate(flows):
            seq = ref.send(f.src, f.dst, f.nbytes, src_port=f.src_port)
            assert paths.flow_links(i) == list(zip(seq, seq[1:]))

    def test_paths_under_link_failure(self):
        fabric = Fabric()
        wan = sorted(fabric.wan_links[0])
        fabric.fail_link(wan[0], wan[1])
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        _, paths = route_flows_with_paths(fabric, flows)
        for i in range(len(flows)):
            assert (wan[0], wan[1]) not in paths.flow_links(i)
            assert (wan[1], wan[0]) not in paths.flow_links(i)


class TestGeoFabricCongestion:
    def test_strategy_ordering_survives_contention(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=3)
        cost = {
            s: geo.sync_cost(s, grad_bytes=312_000_000, jitter=False, congestion=True)
            for s in ("allreduce", "ps", "hier", "hier_int8")
        }
        assert cost["ps"].wan_seconds > cost["allreduce"].wan_seconds
        assert cost["hier"].wan_seconds < cost["allreduce"].wan_seconds
        assert cost["hier_int8"].wan_seconds < cost["hier"].wan_seconds

    def test_congested_at_least_ideal_transfer(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        ideal = geo.sync_cost("hier", grad_bytes=100_000_000, jitter=False)
        contended = geo.sync_cost(
            "hier", grad_bytes=100_000_000, jitter=False, congestion=True
        )
        assert contended.wan_bytes == ideal.wan_bytes  # same routed flows
        assert contended.wan_seconds > 0
