"""Distribution layer tests.

In-process tests cover sharding rules and compression (1 device is fine).
Multi-device behaviour (manual-pod shard_map, strategy equivalence) runs in
a subprocess with ``--xla_force_host_platform_device_count=8`` because the
main pytest process must keep seeing exactly one device (see dryrun notes).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.compression import (
    BLOCK,
    compressed_bytes,
    init_error_feedback,
    int8_compress,
    int8_decompress,
    topk_densify,
    topk_sparsify,
)
from repro.distributed.sync import wan_bytes_per_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr[-3000:]}"
    return out.stdout


# -- compression (in-process) ----------------------------------------------------


class TestInt8Compression:
    @given(
        st.sampled_from([(64,), (3, 100), (2, 256), (5, 7, 300)]),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bound(self, shape, seed):
        """|x - deq(q(x))| <= absmax/254 per block (half a quant step)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 3.0
        c = int8_compress(x)
        back = int8_decompress(c)
        assert back.shape == x.shape
        err = jnp.abs(back - x)
        bound = jnp.max(jnp.abs(x)) / 254.0 + 1e-7
        assert float(err.max()) <= float(bound) * 1.01

    def test_compression_ratio(self):
        x = jnp.ones((1024, 1024), jnp.float32)
        c = int8_compress(x)
        ratio = (x.size * 4) / compressed_bytes(c)
        assert ratio > 3.8  # ~4x minus scale overhead

    def test_zeros_safe(self):
        c = int8_compress(jnp.zeros((512,)))
        np.testing.assert_array_equal(np.asarray(int8_decompress(c)), 0.0)

    def test_preserves_leading_sharding_shape(self):
        """Blocks run along the last dim only — leading dims untouched."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
        c = int8_compress(x)
        assert c.values.shape == (8, 512)
        assert c.scales.shape == (8, 2)

    def test_error_feedback_converges(self):
        """With EF, the *accumulated* transmitted signal tracks the true
        gradient sum even though each step quantizes coarsely."""
        key = jax.random.PRNGKey(1)
        g_true = jax.random.normal(key, (4, BLOCK)) * 1e-3
        ef = init_error_feedback({"g": g_true})["g"]
        sent_total = jnp.zeros_like(g_true)
        for _ in range(50):
            boosted = g_true + ef
            c = int8_compress(boosted)
            sent = int8_decompress(c)
            ef = boosted - sent
            sent_total = sent_total + sent
        np.testing.assert_allclose(
            np.asarray(sent_total), np.asarray(g_true * 50), rtol=0.02, atol=1e-5
        )


class TestTopK:
    def test_roundtrip(self):
        x = jnp.arange(100.0).reshape(10, 10)
        vals, idx, shape = topk_sparsify(x, k_fraction=0.1)
        dense = topk_densify(vals, idx, shape)
        assert float(dense.sum()) == float(sum(range(90, 100)))
        assert dense.shape == x.shape


class TestWanBytes:
    def test_strategy_ordering(self):
        p = 328_000_000  # distilgpt2 fp32 bytes
        ar = wan_bytes_per_step(p, "allreduce")
        ps = wan_bytes_per_step(p, "ps")
        i8 = wan_bytes_per_step(p, "hier_int8")
        ls = wan_bytes_per_step(p, "local_sgd")
        assert ps > ar > i8 > ls == 0.0


# -- sharding rules (in-process, no devices needed) --------------------------------


class TestShardingRules:
    def _mesh(self):
        # 1-device "mesh" is enough to evaluate pure spec logic
        from repro.launch.mesh import make_mesh

        return make_mesh((1, 1, 1), ("pod", "data", "model"))

    def test_divisibility_fallback(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import params_pspecs
        from repro.launch.mesh import make_mesh
        # on a 1x1x1 mesh everything divides; use spec structure checks
        mesh = self._mesh()
        shapes = {"groups": {"slot0": {"attn": {
            "wq": jax.ShapeDtypeStruct((2, 64, 64), jnp.float32),
            "wo": jax.ShapeDtypeStruct((2, 64, 64), jnp.float32),
        }}}}
        specs = params_pspecs(shapes, mesh)
        wq = specs["groups"]["slot0"]["attn"]["wq"]
        assert wq == P(None, "data", "model")
        wo = specs["groups"]["slot0"]["attn"]["wo"]
        assert wo == P(None, "model", "data")

    def test_embed_never_data_sharded(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import params_pspecs

        mesh = self._mesh()
        specs = params_pspecs({"embed": jax.ShapeDtypeStruct((256, 64), jnp.float32)}, mesh)
        assert "data" not in jax.tree.leaves(specs["embed"]) if specs["embed"] else True
        assert specs["embed"] == P("model", None)

    def test_moe_expert_parallel(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import params_pspecs

        mesh = self._mesh()
        shapes = {"groups": {"slot0": {"ffn": {
            "w_up": jax.ShapeDtypeStruct((2, 8, 64, 128), jnp.float32),
            "w_down": jax.ShapeDtypeStruct((2, 8, 128, 64), jnp.float32),
            "router": jax.ShapeDtypeStruct((2, 64, 8), jnp.float32),
        }}}}
        specs = params_pspecs(shapes, mesh)
        assert specs["groups"]["slot0"]["ffn"]["w_up"] == P(None, "model", None, "data")
        assert specs["groups"]["slot0"]["ffn"]["w_down"] == P(None, "model", "data", None)

    def test_batch_pspec_divisibility(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import batch_pspecs
        from repro.launch.mesh import make_mesh

        mesh = self._mesh()
        shapes = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        specs = batch_pspecs(shapes, mesh)
        assert specs["tokens"][0] == ("pod", "data")
        odd = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
        # batch=1 divides a 1x1x1 mesh; structure is what matters here
        assert batch_pspecs(odd, mesh)["tokens"][0] == ("pod", "data")


# -- multi-device behaviour (subprocess) -------------------------------------------


@pytest.mark.slow
def test_strategies_on_fake_pods():
    """All five sync strategies compile and train on a 2x2x2 fake mesh, and
    the per-step loss trajectory of allreduce == hier == hier_int8 == ps."""
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.launch.shapes import params_specs
        from repro.models import init_params
        from repro.distributed import make_train_step, init_train_state
        from repro.optim import AdamWConfig

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke_config("distilgpt2-82m")
        key = jax.random.PRNGKey(0)
        B, S = 8, 16
        p_shapes = params_specs(cfg)
        b_shapes = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        results = {}
        for strategy in ("allreduce", "hier", "hier_int8", "ps", "local_sgd"):
            with mesh:
                step, _ = make_train_step(cfg, mesh, opt_cfg=AdamWConfig(warmup_steps=1),
                                          strategy=strategy, params_shapes=p_shapes,
                                          batch_shapes=b_shapes, donate=False)
                params = init_params(key, cfg)
                state = init_train_state(params, AdamWConfig(), strategy=strategy)
                toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
                batch = {"tokens": toks, "labels": toks}
                losses = []
                for _ in range(2):
                    params, state, m = step(params, state, batch)
                    losses.append(float(m["loss"]))
                results[strategy] = losses
                assert losses[1] < losses[0], (strategy, losses)
        for s in ("hier", "hier_int8", "ps"):
            assert abs(results[s][0] - results["allreduce"][0]) < 1e-3, (s, results)
        print("STRATEGIES_OK", results)
        """
    )
    assert "STRATEGIES_OK" in out


@pytest.mark.slow
def test_multi_pod_grads_match_single_device():
    """Gradient math is mesh-invariant: a 2-pod hier sync over the same
    global batch reproduces the single-device update."""
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.launch.shapes import params_specs
        from repro.models import init_params, loss_fn
        from repro.distributed import make_train_step, init_train_state
        from repro.optim import AdamWConfig, adamw_update, init_adamw

        cfg = get_smoke_config("olmo-1b")
        key = jax.random.PRNGKey(7)
        B, S = 8, 16
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        params = init_params(key, cfg)

        # single-device reference (loss averaged over the global batch)
        (_, _), g_ref = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        opt = AdamWConfig(warmup_steps=1)
        p_ref, _, _ = adamw_update(opt, g_ref, init_adamw(params), params)

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        p_shapes = params_specs(cfg)
        b_shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
        with mesh:
            step, _ = make_train_step(cfg, mesh, opt_cfg=opt, strategy="hier",
                                      params_shapes=p_shapes, batch_shapes=b_shapes,
                                      donate=False)
            state = init_train_state(params, opt, strategy="hier")
            p_out, _, m = step(params, state, batch)
        diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p_ref, p_out)
        worst = max(jax.tree.leaves(diffs))
        assert worst < 2e-5, f"max param divergence {worst}"
        print("MESH_INVARIANT_OK", worst)
        """
    )
    assert "MESH_INVARIANT_OK" in out
