"""WAN emulation + failure detection tests (paper §5.1, §5.3)."""

import numpy as np
import pytest

from repro.core.bfd import BfdSession, BfdState, FailureDetector
from repro.core.evpn import EvpnControlPlane
from repro.core.fabric import Fabric
from repro.core.geo import GeoFabric
from repro.core.wan import Netem, WanTimingModel, ping_rtt


class TestNetemRtt:
    def test_fig8_rtt_near_22ms(self):
        """Fig. 8: ~22 ms host-to-host RTT with 5 ms +/- 1 ms per WAN hop."""
        fabric = Fabric()
        netem = Netem(fabric, seed=42)
        rtt = ping_rtt(netem, "d1h1", "d2h1", count=200)
        assert 20.0 < rtt.mean() < 24.0
        assert rtt.std() < 3.0  # consistent with the configured jitter

    def test_intra_dc_rtt_sub_ms(self):
        fabric = Fabric()
        netem = Netem(fabric, seed=0)
        rtt = ping_rtt(netem, "d1h3", "d1h5", count=50)  # different leaves, same DC
        assert rtt.mean() < 2.0

    def test_jitter_free_base_rtt(self):
        fabric = Fabric()
        netem = Netem(fabric, seed=0)
        base = netem.base_rtt_ms("d1h1", "d2h1")
        assert 20.0 < base < 24.0
        assert netem.base_rtt_ms("d1h1", "d2h1") == base  # deterministic

    def test_reproducible_with_seed(self):
        fabric = Fabric()
        a = ping_rtt(Netem(fabric, seed=7), "d1h1", "d2h1", count=10)
        b = ping_rtt(Netem(Fabric(), seed=7), "d1h1", "d2h1", count=10)
        np.testing.assert_allclose(a, b)


class TestTimingModel:
    def test_bottleneck_dominates(self):
        fabric = Fabric()
        netem = Netem(fabric)
        model = WanTimingModel(netem)
        wan = sorted(fabric.wan_links[0])
        lan = ("d1l1", "d1s1")
        # 100 MB on an 800 Mbit/s WAN link ~ 1 s; 100 MB on 10G LAN ~ 80 ms
        res = model.transfer_time({(wan[0], wan[1]): 100_000_000, lan: 100_000_000})
        assert res.bottleneck_link == (wan[0], wan[1])
        assert 0.9 < res.seconds < 1.2

    def test_rtt_term_added(self):
        fabric = Fabric()
        model = WanTimingModel(Netem(fabric))
        base = model.transfer_time({("d1s1", "d2s1"): 1000}).seconds
        with_rtt = model.transfer_time({("d1s1", "d2s1"): 1000}, rtt_ms=22.0).seconds
        assert with_rtt == pytest.approx(base + 0.022)


class TestBfd:
    def test_detect_time(self):
        s = BfdSession("a", "b", interval_ms=10.0, detect_mult=3)
        assert s.detect_time_ms == 30.0

    def test_state_machine(self):
        s = BfdSession("a", "b")
        assert s.state == BfdState.DOWN
        s.bring_up(0.0)
        assert s.poll(25.0) == BfdState.UP  # within detect time
        s.on_rx(25.0)
        assert s.poll(50.0) == BfdState.UP  # refreshed
        assert s.poll(56.0) == BfdState.DOWN  # 31 ms silence

    def test_fig9_bfd_recovery_near_110ms(self):
        """Fig. 9: BFD(10 ms x 3) end-to-end recovery ~110 ms."""
        fabric = Fabric()
        evpn = EvpnControlPlane(fabric)
        det = FailureDetector(fabric, evpn)
        wan = sorted(fabric.wan_links[0])
        tl = det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        assert 90.0 < tl.recovery_ms < 130.0
        assert tl.detected_at_ms - tl.failure_at_ms == 30.0

    def test_fig13_bgp_recovery_near_180s(self):
        """Fig. 13: default BGP timers -> ~180 s recovery."""
        fabric = Fabric()
        det = FailureDetector(fabric)
        wan = sorted(fabric.wan_links[0])
        tl = det.fail_and_recover((wan[0], wan[1]), mechanism="bgp")
        assert 179.0 < tl.recovery_ms / 1e3 < 182.0

    def test_traffic_reroutes_after_failure(self):
        fabric = Fabric()
        det = FailureDetector(fabric)
        wan = sorted(fabric.wan_links[0])
        det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        # all WAN traffic must avoid the failed link but still arrive
        fabric.reset_counters()
        for port in range(49192, 49192 + 64):
            path = fabric.send("d1h1", "d2h1", 100, src_port=port)
            assert (wan[0], wan[1]) not in list(zip(path, path[1:]))
        det.restore((wan[0], wan[1]))

    def test_restore(self):
        fabric = Fabric()
        det = FailureDetector(fabric)
        wan = sorted(fabric.wan_links[0])
        det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        det.restore((wan[0], wan[1]))
        assert fabric.link_up(wan[0], wan[1])

    def test_unknown_mechanism(self):
        det = FailureDetector(Fabric())
        with pytest.raises(ValueError):
            det.fail_and_recover(("d1s1", "d2s1"), mechanism="psychic")


class TestGeoFabricFacade:
    def test_sync_strategy_ordering(self):
        """hier < allreduce < ps in WAN seconds, int8 < hier, local_sgd
        amortizes — the qualitative Fig. 14 + beyond-paper result."""
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=3)
        cost = {s: geo.sync_cost(s, grad_bytes=312_000_000, jitter=False)
                for s in ("allreduce", "ps", "hier", "hier_int8", "local_sgd")}
        assert cost["ps"].wan_seconds > cost["allreduce"].wan_seconds
        assert cost["hier"].wan_seconds < cost["allreduce"].wan_seconds
        assert cost["hier_int8"].wan_seconds < cost["hier"].wan_seconds
        assert cost["local_sgd"].amortized_seconds < cost["hier"].wan_seconds

    def test_wan_bytes_accounting(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        c = geo.sync_cost("hier", grad_bytes=100_000_000, jitter=False)
        # leader ring over 2 DCs: shard crosses WAN twice (there and back)
        assert c.wan_bytes == pytest.approx(2 * (100_000_000 // 4), rel=0.05)

    def test_more_pods(self):
        geo = GeoFabric(num_pods=3, workers_per_pod=2, seed=0)
        assert len(geo.pod_leaders()) == 3
        c = geo.sync_cost("hier", grad_bytes=10_000_000, jitter=False)
        assert c.wan_seconds > 0
