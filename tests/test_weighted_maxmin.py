"""ECMP-aware weighted max-min allocation tests (ISSUE 4 tentpole).

Three contracts:

* **Back-compat pin** — uniform weights (and ``weights=None``) reproduce
  the pre-weighting allocator byte-for-byte, across random flow x link
  matrices and real routed collectives.
* **Hash-slot derivation** — ``route_flows_with_paths`` records slot
  occupancy, ``ecmp_flow_weights`` turns the worst collision into a
  ``1/k`` weight, and a crafted 2-flows-on-one-uplink collision halves the
  colliding flows' rate relative to an uncollided flow sharing the same
  bottleneck.
* **Conservation** — the weighted allocation still fills every saturated
  link to exactly its capacity and never over-allocates any link.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.congestion import (
    _max_min_rates_arrays,
    build_link_load_matrix,
    concurrent_ecmp_flow_weights,
    congestion_report,
    ecmp_flow_weights,
    max_min_rates,
    route_and_analyze,
    simulate_schedule,
)
from repro.core.fabric import Fabric, FabricConfig
from repro.core.flows import (
    Flow,
    all_to_all_flows,
    ring_allreduce_flows,
    route_flows_with_paths,
)
from repro.core.geo import GeoFabric
from repro.core.ports import QueuePair
from repro.core.schedule import CollectiveSchedule, Phase
from repro.core.wan import Netem


def _flow(src, dst, nbytes=1_000_000, port=50_000):
    return Flow(src, dst, nbytes, QueuePair(0, 1), port)


@st.composite
def _random_matrix(draw):
    """A random membership (mem_f, mem_l, capacity, nflows, nlinks)."""
    nflows = draw(st.integers(min_value=1, max_value=12))
    nlinks = draw(st.integers(min_value=1, max_value=8))
    rows = draw(
        st.lists(
            st.tuples(
                st.integers(min_value=0, max_value=nflows - 1),
                st.integers(min_value=0, max_value=nlinks - 1),
            ),
            min_size=1,
            max_size=40,
        )
    )
    caps = draw(
        st.lists(
            st.floats(min_value=0.1, max_value=100.0),
            min_size=nlinks,
            max_size=nlinks,
        )
    )
    mem_f = np.array([r[0] for r in rows], dtype=np.int64)
    mem_l = np.array([r[1] for r in rows], dtype=np.int64)
    return mem_f, mem_l, np.array(caps), nflows, nlinks


class TestUniformWeightsPin:
    @settings(max_examples=60, deadline=None)
    @given(_random_matrix())
    def test_uniform_weights_byte_identical_to_unweighted(self, m):
        """The satellite property test: ones == None, byte-for-byte."""
        mem_f, mem_l, caps, nflows, nlinks = m
        unweighted = _max_min_rates_arrays(mem_f, mem_l, caps, nflows, nlinks)
        uniform = _max_min_rates_arrays(
            mem_f, mem_l, caps, nflows, nlinks, np.ones(nflows)
        )
        assert np.array_equal(unweighted, uniform)  # exact, not approx

    def test_uniform_pin_on_routed_collective(self):
        """End-to-end pin: a real routed collective's report is bit-identical
        whether weights are absent or explicitly uniform."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows = all_to_all_flows(list(fabric.hosts), 50_000_000)
        _, paths = route_flows_with_paths(fabric, flows)
        matrix = build_link_load_matrix(fabric, netem, paths)
        nb = [f.nbytes for f in flows]
        a = congestion_report(matrix, nb)
        b = congestion_report(matrix, nb, np.ones(len(flows)))
        assert np.array_equal(a.rates_gbps, b.rates_gbps)
        assert np.array_equal(a.completion_s, b.completion_s)
        assert np.array_equal(a.throughput_gbps, b.throughput_gbps)

    def test_collision_free_batch_weights_are_uniform(self):
        """Spread ports -> no slot collisions -> the derived vector is all
        ones, so the weighted model degenerates to the unweighted one."""
        fabric = Fabric()
        netem = Netem(fabric)
        for stride in range(211, 1000):
            flows = [
                _flow("d1h1", "d2h1", port=50_000 + stride * i) for i in range(3)
            ]
            _, paths = route_flows_with_paths(fabric, flows)
            if np.all(paths.slot_occ == 1):
                break
        else:
            pytest.fail("no collision-free port triple in the search range")
        assert np.array_equal(ecmp_flow_weights(paths), np.ones(3))
        f2 = Fabric()
        _, unweighted = route_and_analyze(f2, netem, flows)
        _, weighted = route_and_analyze(f2, netem, flows, ecmp_weighted=True)
        assert np.array_equal(unweighted.rates_gbps, weighted.rates_gbps)

    def test_rejects_bad_weights(self):
        fabric = Fabric()
        netem = Netem(fabric)
        flows = [_flow("d1h1", "d2h1")]
        _, paths = route_flows_with_paths(fabric, flows)
        matrix = build_link_load_matrix(fabric, netem, paths)
        with pytest.raises(ValueError):
            max_min_rates(matrix, np.zeros(1))
        with pytest.raises(ValueError):
            max_min_rates(matrix, np.ones(5))


def _colliding_and_clean_flows(fabric):
    """Two identical-5-tuple flows plus one distinct-port flow that the
    hash sends down the *same* path (shared WAN bottleneck, own slot)."""
    a = _flow("d1h1", "d2h1", port=50_000)
    _, ref = route_flows_with_paths(fabric, [a])
    ref_links = ref.flow_links(0)
    for port in range(50_001, 56_000):
        c = _flow("d1h1", "d2h1", port=port)
        _, paths = route_flows_with_paths(fabric, [a, a, c])
        if paths.flow_links(2) != ref_links:
            continue  # different ECMP path: no shared bottleneck
        occ = paths.slot_occ
        lo, hi = int(paths.ptr[2]), int(paths.ptr[3])
        if np.all(occ[lo:hi] == 1):  # c kept its own hash slot everywhere
            return [a, a, c], paths
    pytest.fail("no clean same-path port found in the search range")


class TestHashSkewWeights:
    def test_slot_occupancy_recorded_for_identical_tuples(self):
        fabric = Fabric()
        a = _flow("d1h1", "d2h1")
        _, paths = route_flows_with_paths(fabric, [a, a])
        # ECMP hops (leaf->spine, spine->WAN-spine) see both flows in one
        # slot; host-attach and fan-1 hops stay at occupancy 1
        assert int(paths.slot_occ.max()) == 2
        assert np.array_equal(ecmp_flow_weights(paths), [0.5, 0.5])

    def test_zero_byte_flows_occupy_no_hash_slot(self):
        """A zero-byte chunk flow transmits nothing, so it must not count
        as a slot collider: a live flow sharing its bucket with only a
        zero-byte ghost keeps weight 1.0 (same convention as the
        allocators, which drain zero-byte flows for free)."""
        fabric = Fabric()
        live = _flow("d1h1", "d2h1")
        ghost = _flow("d1h1", "d2h1", nbytes=0)  # identical tuple: same slot
        _, paths = route_flows_with_paths(fabric, [live, ghost])
        w = ecmp_flow_weights(paths)
        assert w[0] == 1.0
        # two live identical-tuple flows still collide
        _, paths2 = route_flows_with_paths(fabric, [live, live, ghost])
        assert np.array_equal(ecmp_flow_weights(paths2)[:2], [0.5, 0.5])

    def test_two_flow_hash_collision_halves_rate(self):
        """The ISSUE's skew case: two flows colliding into one uplink slot
        each run at half the rate of the uncollided flow sharing their
        bottleneck link — and the unweighted model can't see it."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows, paths = _colliding_and_clean_flows(fabric)
        assert np.allclose(ecmp_flow_weights(paths), [0.5, 0.5, 1.0])
        _, unweighted = route_and_analyze(fabric, netem, flows)
        _, weighted = route_and_analyze(fabric, netem, flows, ecmp_weighted=True)
        # unweighted: strict thirds of the 0.8 Gbit/s WAN bottleneck
        assert unweighted.rates_gbps == pytest.approx(np.full(3, 0.8 / 3))
        # weighted: the collided pair at 0.2 each, the clean flow at 0.4 —
        # the collision halves per-flow rate relative to the clean flow
        assert weighted.rates_gbps == pytest.approx([0.2, 0.2, 0.4])
        assert weighted.rates_gbps[0] == pytest.approx(
            weighted.rates_gbps[2] / 2
        )
        # the saturated WAN link still carries exactly its capacity
        assert weighted.effective_wan_gbps == pytest.approx(0.8)

    def test_weighted_never_overallocates(self):
        fabric = Fabric()
        netem = Netem(fabric)
        flows = all_to_all_flows(list(fabric.hosts), 40_000_000)
        _, report = route_and_analyze(fabric, netem, flows, ecmp_weighted=True)
        assert np.all(
            report.throughput_gbps <= report.capacity_gbps * (1 + 1e-9)
        )
        assert report.effective_wan_gbps == pytest.approx(0.8, rel=1e-6)

    def test_weighted_rates_proportional_on_shared_bottleneck(self):
        """On one saturated link, frozen-at-that-link flows' rates are
        proportional to their weights."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows, _ = _colliding_and_clean_flows(fabric)
        _, report = route_and_analyze(fabric, netem, flows, ecmp_weighted=True)
        w = report.weights
        assert report.rates_gbps / w == pytest.approx(
            np.full(3, report.rates_gbps[2] / w[2])
        )


class TestBucketSpaceKnob:
    """ISSUE 5 satellite: ``ECMP_HASH_BUCKETS`` promoted to a
    ``FabricConfig`` field — default pins byte-identity, non-default
    bucket counts model denser member tables."""

    def test_default_pins_byte_identity(self):
        flows = ring_allreduce_flows(sorted(Fabric().hosts), 64_000_000)
        f_implicit = Fabric()
        f_explicit = Fabric(FabricConfig(ecmp_hash_buckets=64))
        b1, p1 = route_flows_with_paths(f_implicit, flows)
        b2, p2 = route_flows_with_paths(f_explicit, flows)
        assert b1 == b2
        assert np.array_equal(p1.slot_occ, p2.slot_occ)
        assert np.array_equal(p1.slot_key, p2.slot_key)
        assert np.array_equal(ecmp_flow_weights(p1), ecmp_flow_weights(p2))

    def test_fewer_buckets_collide_at_least_as_much(self):
        """Shrinking the bucket space can only merge slots, never split
        them: every traversal's occupancy is >= the default's, and with
        one bucket every concurrent flow through a fan-out shares it."""
        flows = ring_allreduce_flows(sorted(Fabric().hosts), 64_000_000)
        _, p64 = route_flows_with_paths(Fabric(), flows)
        _, p1 = route_flows_with_paths(
            Fabric(FabricConfig(ecmp_hash_buckets=1)), flows
        )
        # same routing decisions (the hash modulo fan-out is untouched)...
        assert np.array_equal(p64.link_u, p1.link_u)
        assert np.array_equal(p64.link_v, p1.link_v)
        # ...but strictly denser slot sharing somewhere
        assert np.all(p1.slot_occ >= p64.slot_occ)
        assert int(p1.slot_occ.max()) > int(p64.slot_occ.max())
        w1, w64 = ecmp_flow_weights(p1), ecmp_flow_weights(p64)
        assert np.all(w1 <= w64)

    def test_bucket_count_validated(self):
        with pytest.raises(ValueError):
            Fabric(FabricConfig(ecmp_hash_buckets=0))


class TestConcurrentPhaseWeights:
    """ISSUE 5 satellite (ROADMAP item): ECMP weight derivation restricted
    to concurrently-active phases — one occupancy count no longer spans
    the whole schedule batch."""

    def _dup_schedules(self, fabric):
        """Two phases re-using the identical flow (same 5-tuple -> same
        hash slots), serialized vs overlapped."""
        flow = _flow("d1h1", "d2h1")
        serial = CollectiveSchedule(
            "serial", (Phase("a", (flow,)), Phase("b", (flow,), deps=("a",)))
        )
        par = CollectiveSchedule("par", (Phase("a", (flow,)), Phase("b", (flow,))))
        return serial, par

    def test_serialized_phases_not_down_weighted(self):
        """The satellite's acceptance case: two non-overlapping phases
        sharing hash slots are no longer down-weighted."""
        fabric = Fabric()
        netem = Netem(fabric)
        serial, par = self._dup_schedules(fabric)
        rep_serial = simulate_schedule(fabric, netem, serial, ecmp_weighted=True)
        assert np.array_equal(rep_serial.weights, np.ones(2))
        # the overlapped variant really does collide: both flows halve
        rep_par = simulate_schedule(fabric, netem, par, ecmp_weighted=True)
        assert np.array_equal(rep_par.weights, [0.5, 0.5])

    def test_serialized_cost_matches_unweighted(self):
        """With no concurrent collisions the weighted serialized schedule
        costs exactly its unweighted self."""
        fabric = Fabric()
        netem = Netem(fabric)
        serial, _ = self._dup_schedules(fabric)
        weighted = simulate_schedule(fabric, netem, serial, ecmp_weighted=True)
        unweighted = simulate_schedule(fabric, netem, serial, ecmp_weighted=False)
        assert weighted.seconds == unweighted.seconds
        assert np.array_equal(weighted.completion_s, unweighted.completion_s)

    def test_diamond_dag_concurrency(self):
        """In a diamond (a -> b, a -> c, b/c -> d) only b and c may
        overlap; a and d are serialized against everything."""
        flow = _flow("d1h1", "d2h1")
        s = CollectiveSchedule(
            "diamond",
            (
                Phase("a", (flow,)),
                Phase("b", (flow,), deps=("a",)),
                Phase("c", (flow,), deps=("a",)),
                Phase("d", (flow,), deps=("b", "c")),
            ),
        )
        conc = s.concurrency_matrix()
        names = [p.name for p in s.phases]
        bi, ci = names.index("b"), names.index("c")
        assert conc[bi, ci] and conc[ci, bi]
        ai, di = names.index("a"), names.index("d")
        for other in (bi, ci, di):
            assert not conc[ai, other]
        assert not conc[di, bi] and not conc[di, ci]
        assert np.all(np.diag(conc))
        fabric = Fabric()
        rep = simulate_schedule(fabric, Netem(fabric), s, ecmp_weighted=True)
        # only b and c (flows 1 and 2) collide
        assert np.array_equal(rep.weights, [1.0, 0.5, 0.5, 1.0])

    def test_concurrent_weights_respect_live_mask(self):
        """Zero-byte ghosts in a concurrent phase occupy no slot."""
        fabric = Fabric()
        netem = Netem(fabric)
        live = _flow("d1h1", "d2h1")
        ghost = _flow("d1h1", "d2h1", nbytes=0)
        s = CollectiveSchedule(
            "ghost", (Phase("a", (live,)), Phase("b", (ghost,)))
        )
        rep = simulate_schedule(fabric, netem, s, ecmp_weighted=True)
        assert rep.weights[0] == 1.0

    def test_single_phase_matches_whole_batch_derivation(self):
        """An all-True concurrency matrix reproduces ecmp_flow_weights
        for live flows — the restriction is a pure generalization."""
        fabric = Fabric()
        netem = Netem(fabric)
        flows = ring_allreduce_flows(sorted(fabric.hosts), 32_000_000)
        _, paths = route_flows_with_paths(fabric, flows)
        matrix = build_link_load_matrix(fabric, netem, paths)
        whole = ecmp_flow_weights(matrix)
        conc = np.ones((1, 1), dtype=bool)
        restricted = concurrent_ecmp_flow_weights(
            matrix,
            np.zeros(len(flows), dtype=np.int64),
            conc,
            live=np.array([f.nbytes > 0 for f in flows]),
        )
        assert np.array_equal(whole, restricted)


class TestWeightedPipelines:
    def test_simulate_schedule_single_phase_matches_static(self):
        fabric = Fabric()
        netem = Netem(fabric)
        flows = ring_allreduce_flows(sorted(fabric.hosts), 64_000_000)
        schedule = CollectiveSchedule.single("ring", flows)
        rep = simulate_schedule(fabric, netem, schedule, ecmp_weighted=True)
        _, ref = route_and_analyze(fabric, netem, flows, ecmp_weighted=True)
        assert rep.seconds == ref.seconds
        assert np.array_equal(rep.completion_s, ref.completion_s)
        assert np.array_equal(rep.weights, ref.weights)

    def test_event_loop_threads_weights(self):
        """Forcing the event loop (trailing flowless phase) stays within
        float noise of the weighted static model."""
        from repro.core.schedule import Phase

        fabric = Fabric()
        netem = Netem(fabric)
        flows = ring_allreduce_flows(sorted(fabric.hosts), 64_000_000)
        fast = simulate_schedule(
            fabric,
            netem,
            CollectiveSchedule.single("p", flows),
            ecmp_weighted=True,
        )
        looped = simulate_schedule(
            fabric,
            netem,
            CollectiveSchedule("p2", (Phase("p", flows), Phase("end", deps=("p",)))),
            ecmp_weighted=True,
        )
        assert looped.seconds == pytest.approx(fast.seconds, rel=1e-6)

    def test_sync_cost_surfaces_weighted_utilization(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        plain = geo.sync_cost(
            "allreduce", 100_000_000, jitter=False, congestion=True
        )
        weighted = geo.sync_cost(
            "allreduce",
            100_000_000,
            jitter=False,
            congestion=True,
            ecmp_weighted=True,
        )
        assert weighted.wan_bytes == plain.wan_bytes  # same routed flows
        assert 0.0 < weighted.bottleneck_utilization <= 1.0 + 1e-9
        # weighting can slow the slowest flow but never speeds it up
        assert weighted.wan_seconds >= plain.wan_seconds * (1 - 1e-9)
