"""CI bench-baseline regression gate tests (ISSUE 4 satellite).

Covers the acceptance demonstration: a synthetic 25% regression on a
gated metric makes ``benchmarks.compare`` exit non-zero, while a 10%
wobble and genuine improvements pass.
"""

import copy
import json


from benchmarks.compare import Delta, compare, main, metric_direction

BASE_SUITE = {
    "suite": "fig9_13_failover",
    "module": "benchmarks.bench_failover",
    "rows": [
        {
            "name": "fig9_bfd_recovery",
            "us_per_call": 88.0,
            "derived": "recovery=109ms",
            "metrics": {"recovery_ms": 109.0},
        },
        {
            "name": "congestion_spine_throughput",
            "us_per_call": 2100.0,
            "derived": "eff wan 800",
            "metrics": {"effective_wan_mbps": 800.0},
        },
    ],
}


def _dirs(tmp_path, mutate):
    base = tmp_path / "baselines"
    new = tmp_path / "new"
    base.mkdir(exist_ok=True)
    new.mkdir(exist_ok=True)
    (base / "BENCH_fig9_13_failover.json").write_text(json.dumps(BASE_SUITE))
    fresh = copy.deepcopy(BASE_SUITE)
    mutate(fresh)
    (new / "BENCH_fig9_13_failover.json").write_text(json.dumps(fresh))
    return base, new


class TestMetricDirection:
    def test_suffix_table(self):
        assert metric_direction("effective_wan_mbps") == "higher"
        assert metric_direction("flap_storm_speedup") == "higher"
        assert metric_direction("leaf_peak_improvement_pct") == "higher"
        assert metric_direction("recovery_ms") == "lower"
        assert metric_direction("evpn_mean_touched_frac") == "lower"
        assert metric_direction("leaf_qp_aware_factor") == "lower"
        assert metric_direction("step_f75_seconds") == "lower"
        assert metric_direction("mystery_quantity") == "pinned"

    def test_latency_percentiles_gate_lower(self):
        """ISSUE 8 satellite: serving latency percentiles are lower-is-
        better, so a p99 regression in BENCH_serving.json trips CI."""
        assert metric_direction("serving_p99_ms") == "lower"
        assert metric_direction("serving_p50_ms") == "lower"
        assert metric_direction("tail_p99") == "lower"
        assert metric_direction("tail_p50") == "lower"
        up = Delta(
            "serving", "flap", "x_p99", baseline=100.0, new=130.0, direction="lower"
        )
        assert up.regressed(0.20)

    def test_delta_directionality(self):
        up = Delta("s", "r", "x_ms", baseline=100.0, new=130.0, direction="lower")
        assert up.regressed(0.20)
        assert not up.regressed(0.35)
        down = Delta("s", "r", "x_mbps", baseline=800.0, new=560.0, direction="higher")
        assert down.regressed(0.20)
        improved = Delta("s", "r", "x_ms", baseline=100.0, new=50.0, direction="lower")
        assert not improved.regressed(0.20)
        pinned = Delta("s", "r", "x", baseline=100.0, new=130.0, direction="pinned")
        assert pinned.regressed(0.20)


class TestCompare:
    def test_synthetic_25pct_regression_fails(self, tmp_path):
        """The acceptance-criteria demonstration: recovery_ms +25%."""

        def worsen(payload):
            payload["rows"][0]["metrics"]["recovery_ms"] = 109.0 * 1.25

        base, new = _dirs(tmp_path, worsen)
        _, regressions = compare(base, new)
        assert len(regressions) == 1
        assert "recovery_ms" in regressions[0]
        # and the CLI exits non-zero, which is what fails the CI job
        assert main(["--baseline", str(base), "--new", str(new)]) == 1

    def test_10pct_wobble_passes(self, tmp_path):
        def wobble(payload):
            payload["rows"][0]["metrics"]["recovery_ms"] = 109.0 * 1.10
            payload["rows"][1]["metrics"]["effective_wan_mbps"] = 800.0 * 0.9

        base, new = _dirs(tmp_path, wobble)
        table, regressions = compare(base, new)
        assert regressions == []
        assert main(["--baseline", str(base), "--new", str(new)]) == 0
        assert "recovery_ms" in table  # delta table still reports it

    def test_improvement_passes_any_size(self, tmp_path):
        def improve(payload):
            payload["rows"][0]["metrics"]["recovery_ms"] = 40.0  # -63%
            payload["rows"][1]["metrics"]["effective_wan_mbps"] = 1600.0

        base, new = _dirs(tmp_path, improve)
        _, regressions = compare(base, new)
        assert regressions == []

    def test_missing_suite_fails(self, tmp_path):
        base, new = _dirs(tmp_path, lambda p: None)
        (new / "BENCH_fig9_13_failover.json").unlink()
        _, regressions = compare(base, new)
        assert len(regressions) == 1
        assert "missing" in regressions[0]

    def test_errored_suite_fails(self, tmp_path):
        def error(payload):
            payload.clear()
            payload.update({"suite": "fig9_13_failover", "error": "boom"})

        base, new = _dirs(tmp_path, error)
        _, regressions = compare(base, new)
        assert len(regressions) == 1
        assert "errored" in regressions[0]

    def test_us_per_call_never_gated(self, tmp_path):
        """Wall-clock noise must not trip the gate."""

        def slower_runner(payload):
            payload["rows"][0]["us_per_call"] = 88.0 * 50

        base, new = _dirs(tmp_path, slower_runner)
        _, regressions = compare(base, new)
        assert regressions == []

    def test_dropped_gated_metric_fails(self, tmp_path):
        """Renaming a row or dropping a gated metric must not silently
        disable its gate."""

        def drop_metric(payload):
            payload["rows"][0]["metrics"].pop("recovery_ms")

        base, new = _dirs(tmp_path, drop_metric)
        _, regressions = compare(base, new)
        assert len(regressions) == 1
        assert "missing from the new run" in regressions[0]

        def rename_row(payload):
            payload["rows"][1]["name"] = "renamed_row"

        base, new = _dirs(tmp_path, rename_row)
        table, regressions = compare(base, new)
        assert any("effective_wan_mbps" in r for r in regressions)
        assert "gated metric dropped" in table

    def test_new_metrics_without_baseline_pass(self, tmp_path):
        def add_metric(payload):
            payload["rows"][0]["metrics"]["brand_new_ms"] = 1.0

        base, new = _dirs(tmp_path, add_metric)
        _, regressions = compare(base, new)
        assert regressions == []

    def test_summary_file_written(self, tmp_path):
        base, new = _dirs(tmp_path, lambda p: None)
        summary = tmp_path / "summary.md"
        assert (
            main(
                [
                    "--baseline",
                    str(base),
                    "--new",
                    str(new),
                    "--summary",
                    str(summary),
                ]
            )
            == 0
        )
        assert "Bench baseline comparison" in summary.read_text()
