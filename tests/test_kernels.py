"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles (interpret mode).

Per the assignment: every kernel sweeps shapes/dtypes and asserts allclose
against its ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_fwd, flash_attention_ref
from repro.kernels.rwkv6_wkv import wkv6_fwd, wkv6_ref
from repro.kernels.wan_quant import (
    wan_dequant,
    wan_dequant_ref,
    wan_quant,
    wan_quant_ref,
)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(key, b, sq, sk, h, kvh, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, sk, hd)).astype(dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,s,h,kvh,hd,bq,bk",
        [
            (1, 128, 1, 1, 64, 128, 128),
            (2, 256, 4, 2, 64, 128, 128),
            (2, 256, 8, 1, 128, 128, 256),  # MQA, rectangular blocks
            (1, 512, 4, 4, 128, 256, 128),
        ],
    )
    def test_causal_sweep(self, dtype, b, s, h, kvh, hd, bq, bk):
        q, k, v = _qkv(jax.random.PRNGKey(0), b, s, s, h, kvh, hd, dtype)
        out = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
        )

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 256, 4, 2, 64, jnp.float32)
        out = flash_attention_fwd(
            q, k, v, causal=True, window=window, block_q=128, block_k=128, interpret=True
        )
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 256, 256, 2, 2, 64, jnp.float32)
        out = flash_attention_fwd(q, k, v, causal=False, block_q=128, block_k=128, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 128, 2, 1, 64, jnp.float32)
        out = flash_attention_fwd(
            q, k, v, causal=True, logit_softcap=30.0, block_q=128, block_k=128, interpret=True
        )
        ref = flash_attention_ref(q, k, v, causal=True, logit_softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_cross_attention_lengths(self):
        """Sq != Sk (prefill extending an existing cache)."""
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 128, 384, 2, 2, 64, jnp.float32)
        out = flash_attention_fwd(q, k, v, causal=False, block_q=128, block_k=128, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_ops_wrapper_model_layout(self):
        """[B, S, H, hd] wrapper matches the model's sdpa on the same mask."""
        from repro.models.attention import sdpa

        b, s, h, kvh, hd = 2, 256, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
        pos = jnp.arange(s)
        ref = sdpa(q, k, v, q_positions=pos, k_positions=pos, impl="naive")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_fallback_tiny_shapes(self):
        """Non-tileable shapes fall back to the reference implementation."""
        q, k, v = _qkv(jax.random.PRNGKey(6), 1, 48, 48, 2, 2, 32, jnp.float32)
        out = flash_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=True,
        )
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(jnp.swapaxes(out, 1, 2)), np.asarray(ref), rtol=2e-5, atol=2e-5
        )


class TestWanQuant:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("rows,lanes,rt", [(8, 256, 8), (64, 512, 32), (256, 1024, 256), (13, 256, 1)])
    def test_sweep_vs_ref(self, dtype, rows, lanes, rt):
        x = (jax.random.normal(jax.random.PRNGKey(rows), (rows, lanes)) * 5).astype(dtype)
        xf = x.astype(jnp.float32)
        q_k, s_k = wan_quant(xf, row_tile=rt, interpret=True)
        q_r, s_r = wan_quant_ref(xf)
        # scale division can differ by 1 ULP between kernel and ref, which
        # flips round-to-even on exact .5 boundaries -> allow |dq| <= 1 on
        # a vanishing fraction of lanes, exact everywhere else.
        dq = np.abs(np.asarray(q_k, np.int32) - np.asarray(q_r, np.int32))
        assert dq.max() <= 1
        assert (dq != 0).mean() < 1e-3
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)

    def test_dequant_matches_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(9), (32, 512))
        q, s = wan_quant_ref(x)
        back_k = wan_dequant(q, s, row_tile=32, interpret=True)
        back_r = wan_dequant_ref(q, s)
        np.testing.assert_allclose(np.asarray(back_k), np.asarray(back_r), rtol=1e-6)

    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(10), (64, 1024)) * 3
        q, s = wan_quant(x, row_tile=64, interpret=True)
        back = wan_dequant(q, s, row_tile=64, interpret=True)
        blocks = x.reshape(64, 4, 256)
        bound = jnp.abs(blocks).max(-1) / 127.0 * 0.5 + 1e-7
        err = jnp.abs(back - x).reshape(64, 4, 256).max(-1)
        assert bool((err <= bound * 1.01).all())

    def test_matches_distributed_compression(self):
        """The kernel and the sync-path jnp compressor agree bit-for-bit."""
        from repro.distributed.compression import int8_compress

        x = jax.random.normal(jax.random.PRNGKey(11), (16, 512))
        c = int8_compress(x)
        q_k, s_k = wan_quant(x, row_tile=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(q_k), np.asarray(c.values))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(c.scales), rtol=1e-6)


class TestWkv6:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,t,h,n,chunk", [(1, 32, 1, 8, 8), (2, 64, 3, 16, 16), (2, 128, 2, 64, 32)])
    def test_sweep_vs_ref(self, dtype, b, t, h, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(t), 6)
        r = (jax.random.normal(ks[0], (b, t, h, n)) * 0.5).astype(dtype)
        k = (jax.random.normal(ks[1], (b, t, h, n)) * 0.5).astype(dtype)
        v = (jax.random.normal(ks[2], (b, t, h, n)) * 0.5).astype(dtype)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)) + 2.0).astype(dtype)
        u = (jax.random.normal(ks[4], (h, n)) * 0.1).astype(jnp.float32)
        s0 = jax.random.normal(ks[5], (b, h, n, n)) * 0.1
        out_k, fin_k = wkv6_fwd(r, k, v, w, u, s0, chunk=chunk, interpret=True)
        out_r, fin_r = wkv6_ref(r, k, v, w, u, s0)
        tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), **tol)
        np.testing.assert_allclose(np.asarray(fin_k), np.asarray(fin_r), **tol)

    def test_state_carries_across_chunks(self):
        """Running T in one chunk == two chunks of T/2 (state continuity)."""
        b, t, h, n = 1, 64, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) * 0.5 for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)) + 2.0)
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        s0 = jnp.zeros((b, h, n, n))
        out_one, fin_one = wkv6_fwd(r, k, v, w, u, s0, chunk=64, interpret=True)
        out_two, fin_two = wkv6_fwd(r, k, v, w, u, s0, chunk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out_one), np.asarray(out_two), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fin_one), np.asarray(fin_two), rtol=1e-5, atol=1e-5)

    def test_matches_model_wkv(self):
        """Kernel == the model stack's wkv6 scan (repro.models.rwkv6)."""
        from repro.models.rwkv6 import _wkv_with_initial_state

        b, t, h, n = 2, 32, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) * 0.5 for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)) + 2.0)
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        s0 = jnp.zeros((b, h, n, n))
        out_k, fin_k = wkv6_fwd(r, k, v, w, u, s0, chunk=16, interpret=True)
        out_m, fin_m = _wkv_with_initial_state(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fin_k), np.asarray(fin_m), rtol=1e-4, atol=1e-5)
