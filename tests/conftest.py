"""Suite-wide fixtures/shims.

Installs the seeded-random ``hypothesis`` fallback before test modules are
collected when the real package is missing (see ISSUE 1: the suite must
not abort at collection on an optional dev dependency).
"""

from repro.testing.hypothesis_fallback import install

install()
