"""CollectiveSchedule API + event-driven time-varying congestion (ISSUE 3).

Covers the tentpole guarantees:

* the schedule DAG validates (topological order, cycles, unknown deps) and
  the strategy registry replaces the old closed if/elif;
* the event-driven simulator's property triangle — (a) a single-phase
  schedule reproduces the static ``congestion_report`` *exactly*, (b) two
  serial phases cost the sum of their standalone costs, (c) overlapped
  phases on disjoint links cost the max — under the seeded hypothesis
  fallback;
* ``GeoFabric.sync_cost`` string back-compat: unchanged ``wan_bytes`` and
  ``wan_seconds`` (vs the legacy sequential-route + fluid formula, and vs
  the single-shot contended model), with the bottleneck-bytes bug fixed;
* the ISSUE acceptance inequality: ``rs_ag_overlap`` on shared WAN
  bottlenecks costs strictly less than serial RS -> AG and strictly more
  than ``max(RS, AG)``.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.congestion import route_and_analyze, simulate_schedule
from repro.core.fabric import Fabric
from repro.core.flows import (
    all_gather_flows,
    hierarchical_all_to_all_flows,
    reduce_scatter_flows,
    ring_allreduce_flows,
    route_flows,
)
from repro.core.geo import GeoFabric
from repro.core.schedule import (
    SYNC_STRATEGIES,
    CollectiveSchedule,
    Phase,
    StrategyContext,
    build_schedule,
    get_strategy,
    register_strategy,
    strategy_names,
    with_compute_overlap,
)
from repro.core.wan import Netem, WanTimingModel


@pytest.fixture()
def fabric():
    return Fabric()  # the paper's Fig. 1 seed topology


@pytest.fixture()
def netem(fabric):
    return Netem(fabric)


class TestScheduleDag:
    def test_topological_order(self):
        s = CollectiveSchedule(
            "s",
            (
                Phase("c", deps=("b",), compute_seconds=1.0),
                Phase("a", compute_seconds=1.0),
                Phase("b", deps=("a",), compute_seconds=1.0),
            ),
        )
        assert s.phase_names == ("a", "b", "c")

    def test_cycle_rejected(self):
        with pytest.raises(ValueError, match="cycle"):
            CollectiveSchedule(
                "s",
                (Phase("a", deps=("b",)), Phase("b", deps=("a",))),
            )

    def test_unknown_dep_rejected(self):
        with pytest.raises(ValueError, match="unknown"):
            CollectiveSchedule("s", (Phase("a", deps=("nope",)),))

    def test_duplicate_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            CollectiveSchedule("s", (Phase("a"), Phase("a")))

    def test_empty_rejected(self):
        with pytest.raises(ValueError, match="no phases"):
            CollectiveSchedule("s", ())

    def test_serial_builder_chains_deps(self):
        s = CollectiveSchedule.serial("s", (("p1", ()), ("p2", ()), ("p3", ())))
        assert s.phase("p2").deps == ("p1",)
        assert s.phase("p3").deps == ("p2",)

    def test_single_is_single_phase(self, fabric):
        flows = ring_allreduce_flows(sorted(fabric.hosts), 1000)
        assert CollectiveSchedule.single("x", flows).is_single_phase
        two = CollectiveSchedule("y", (Phase("a"), Phase("b")))
        assert not two.is_single_phase

    def test_compute_overlap_wrapper(self):
        base = CollectiveSchedule("comm", (Phase("p", compute_seconds=1.0),))
        s = with_compute_overlap(base, 4.0, 0.25)
        assert s.phase("compute").compute_seconds == 4.0
        assert s.phase("p").start_offset_s == pytest.approx(3.0)
        with pytest.raises(ValueError):
            with_compute_overlap(base, 4.0, 1.5)
        with pytest.raises(ValueError):
            with_compute_overlap(s, 1.0)  # name collision


class TestRegistry:
    def test_paper_strategies_registered_first(self):
        names = strategy_names()
        assert names[: len(SYNC_STRATEGIES)] == SYNC_STRATEGIES
        for extra in ("rs_ag_overlap", "rs_then_ag", "ps_phased", "alltoall",
                      "hier_alltoall"):
            assert extra in names

    def test_unknown_strategy_raises(self):
        with pytest.raises(ValueError, match="unknown strategy"):
            get_strategy("psychic")
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        with pytest.raises(ValueError, match="unknown strategy"):
            geo.sync_cost("psychic", 1000, jitter=False)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("allreduce", lambda ctx, b, **kw: None)

    def test_custom_strategy_end_to_end(self):
        import repro.core.schedule as sched_mod

        name = "test_custom_ring"

        @register_strategy(name)
        def _custom(ctx: StrategyContext, grad_bytes: int, **_):
            return CollectiveSchedule.single(
                name,
                ring_allreduce_flows(list(ctx.pod_leaders), grad_bytes, **ctx.flow_kw),
            )

        try:
            geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
            c = geo.sync_cost(name, 10_000_000, jitter=False)
            assert c.strategy == name and c.wan_seconds > 0
        finally:
            del sched_mod._REGISTRY[name]

    def test_build_schedule_all_strategies(self):
        ctx = StrategyContext(pod_workers=(("d1h1", "d1h2"), ("d2h1", "d2h2")))
        for name in strategy_names():
            s = build_schedule(name, ctx, 1_000_000, sync_every=4, int8_ratio=0.5)
            assert isinstance(s, CollectiveSchedule)
            assert s.sync_every == (4 if name == "local_sgd" else 1)


class TestSimulatorProperties:
    """The ISSUE's (a)/(b)/(c) property triangle."""

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=100_000_000))
    def test_single_phase_equals_congestion_report_exactly(self, nbytes):
        fabric = Fabric()
        netem = Netem(fabric)
        flows = ring_allreduce_flows(sorted(fabric.hosts), nbytes)
        schedule = CollectiveSchedule.single("ring", flows)
        report = simulate_schedule(fabric, netem, schedule)
        _, ref = route_and_analyze(fabric, netem, flows)
        assert report.seconds == ref.seconds  # exact, not approx
        assert np.array_equal(report.completion_s, ref.completion_s)
        assert np.array_equal(report.peak_throughput_gbps, ref.throughput_gbps)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=100_000_000))
    def test_serial_phases_cost_sum_of_standalones(self, nbytes):
        fabric = Fabric()
        netem = Netem(fabric)
        workers = sorted(fabric.hosts)
        rs = reduce_scatter_flows(workers, nbytes)
        ag = all_gather_flows(workers, nbytes)
        serial = CollectiveSchedule.serial("serial", (("rs", rs), ("ag", ag)))
        got = simulate_schedule(fabric, netem, serial).seconds
        t_rs = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("rs", rs)
        ).seconds
        t_ag = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("ag", ag)
        ).seconds
        assert got == pytest.approx(t_rs + t_ag, rel=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=1, max_value=100_000_000),
        st.integers(min_value=1, max_value=100_000_000),
    )
    def test_disjoint_overlap_costs_max(self, b1, b2):
        fabric = Fabric()
        netem = Netem(fabric)
        # DC1-internal vs DC2-internal rings: no shared links at all
        dc1 = sorted(h for h in fabric.hosts if h.startswith("d1"))
        dc2 = sorted(h for h in fabric.hosts if h.startswith("d2"))
        f1 = ring_allreduce_flows(dc1, b1)
        f2 = ring_allreduce_flows(dc2, b2)
        overlap = CollectiveSchedule("olap", (Phase("p1", f1), Phase("p2", f2)))
        got = simulate_schedule(fabric, netem, overlap).seconds
        t1 = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("p1", f1)
        ).seconds
        t2 = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("p2", f2)
        ).seconds
        # rel=1e-5: the static standalone reference counts zero-byte chunk
        # flows as capacity users, the event loop drains them instantly —
        # a nanoseconds-scale artifact at pathological byte counts
        assert got == pytest.approx(max(t1, t2), rel=1e-5)

    def test_event_loop_matches_fast_path_on_symmetric_phase(self, fabric, netem):
        """Forcing the same flows through the event loop (via a trailing
        empty phase) reproduces the static fast path within float noise."""
        flows = ring_allreduce_flows(sorted(fabric.hosts), 64_000_000)
        fast = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("p", flows)
        )
        looped = simulate_schedule(
            fabric,
            netem,
            CollectiveSchedule("p2", (Phase("p", flows), Phase("end", deps=("p",)))),
        )
        assert looped.seconds == pytest.approx(fast.seconds, rel=1e-6)

    def test_compute_phase_sets_makespan(self, fabric, netem):
        s = CollectiveSchedule(
            "c",
            (Phase("a", compute_seconds=1.5), Phase("b", deps=("a",), compute_seconds=0.5)),
        )
        report = simulate_schedule(fabric, netem, s)
        assert report.seconds == pytest.approx(2.0)
        assert report.phase("b").start_s == pytest.approx(1.5)

    def test_start_offset_delays_phase(self, fabric, netem):
        flows = ring_allreduce_flows(sorted(fabric.hosts), 1_000_000)
        plain = simulate_schedule(
            fabric, netem, CollectiveSchedule.single("p", flows)
        ).seconds
        s = CollectiveSchedule(
            "off", (Phase("p", flows, start_offset_s=0.25), Phase("x"))
        )
        assert simulate_schedule(fabric, netem, s).seconds == pytest.approx(
            plain + 0.25, rel=1e-6
        )

    def test_mid_flight_arrival_squeezes_shares(self, fabric, netem):
        """A phase arriving mid-transfer slows the in-flight phase's flows:
        the overlapped makespan exceeds the no-contention max but stays
        below the serial sum — the time-varying behavior the static model
        cannot express."""
        w = ["d1h1", "d2h1"]
        f1 = ring_allreduce_flows(w, 50_000_000)
        f2 = all_gather_flows(w, 50_000_000)
        t1 = simulate_schedule(fabric, netem, CollectiveSchedule.single("a", f1)).seconds
        t2 = simulate_schedule(fabric, netem, CollectiveSchedule.single("b", f2)).seconds
        s = CollectiveSchedule(
            "mid", (Phase("a", f1), Phase("b", f2, start_offset_s=t1 / 2))
        )
        got = simulate_schedule(fabric, netem, s).seconds
        assert got > max(t1, t1 / 2 + t2) * (1 - 1e-9)
        assert got < t1 + t2

    def test_empty_schedule_flows(self, fabric, netem):
        s = CollectiveSchedule("none", (Phase("a"), Phase("b", deps=("a",))))
        assert simulate_schedule(fabric, netem, s).seconds == 0.0


class TestSyncCostBackCompat:
    """String strategies: unchanged wan_bytes/wan_seconds, bugfixed bottleneck."""

    @pytest.mark.parametrize("strategy", SYNC_STRATEGIES)
    def test_fluid_matches_legacy_formula(self, strategy):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=3)
        cost = geo.sync_cost(strategy, 312_000_000, jitter=False)
        # the pre-schedule pipeline: sequential reference routing + fluid
        # transfer over the aggregate counters + leader RTT
        schedule = geo.build_schedule(strategy, 312_000_000)
        link_bytes = route_flows(
            geo.fabric, schedule.all_flows(), check_reachability=geo.tenancy.reachable
        )
        rtt = geo.netem.base_rtt_ms(geo.pod_leaders()[0], geo.pod_leaders()[-1])
        legacy = WanTimingModel(geo.netem).transfer_time(link_bytes, rtt_ms=rtt)
        assert cost.wan_seconds == pytest.approx(legacy.seconds, rel=1e-12)
        assert cost.wan_bytes == sum(
            b for (u, v), b in link_bytes.items() if geo.fabric.is_wan_link(u, v)
        )
        assert cost.bottleneck_link == legacy.bottleneck_link
        assert cost.bottleneck_bytes == legacy.bottleneck_bytes
        assert cost.sync_every == (8 if strategy == "local_sgd" else 1)

    @pytest.mark.parametrize("strategy", ("allreduce", "hier"))
    def test_contended_matches_single_shot_model(self, strategy):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=3)
        cost = geo.sync_cost(strategy, 100_000_000, jitter=False, congestion=True)
        schedule = geo.build_schedule(strategy, 100_000_000)
        report = geo.timing.contended_transfer_time(
            schedule.all_flows(), check_reachability=geo.tenancy.reachable
        )
        assert cost.wan_seconds == report.seconds  # exact fast-path equality

    def test_congestion_branch_surfaces_real_bottleneck(self):
        """The old branch fabricated ``bottleneck_bytes=0``."""
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        c = geo.sync_cost("hier", 100_000_000, jitter=False, congestion=True)
        assert c.bottleneck_link is not None
        assert c.bottleneck_bytes > 0
        assert 0.0 < c.bottleneck_utilization <= 1.0 + 1e-9
        link_bytes = dict(geo.fabric.link_bytes)
        assert c.bottleneck_bytes == link_bytes[c.bottleneck_link]

    def test_string_strategy_requires_grad_bytes(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        with pytest.raises(ValueError, match="grad_bytes"):
            geo.sync_cost("allreduce", jitter=False)
        with pytest.raises(ValueError, match="grad_bytes"):
            geo.sync_cost("hier", 0, jitter=False)

    def test_lan_only_phase_pays_no_wan_rtt(self):
        """Fluid costing: a phase whose flows never cross the WAN (e.g.
        hier_alltoall's intra-DC dispatch) must not be inflated by the
        ~22 ms leader-to-leader RTT."""
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        lan_ring = ring_allreduce_flows(geo.workers(1), 1_000)
        c = geo.sync_cost(CollectiveSchedule.single("lan", lan_ring), jitter=False)
        assert c.wan_bytes == 0
        assert c.wan_seconds < 1e-3  # would be >= 22 ms with the RTT bug
        hier = geo.sync_cost("hier_alltoall", 64_000_000, jitter=False)
        dispatch = hier.phases[0]
        # dispatch duration == the RTT-free fluid transfer of its flows
        from repro.core.flows import route_flows_batched

        dflows = hierarchical_all_to_all_flows(
            [geo.workers(1), geo.workers(2)],
            64_000_000,
            phase="dispatch",
            num_channels=geo.num_channels,
            scheme=geo.port_scheme,
        )
        expected = geo.timing.transfer_time(
            route_flows_batched(geo.fabric, dflows)
        ).seconds
        assert dispatch.duration_s == pytest.approx(expected, rel=1e-12)

    def test_schedule_object_accepted_directly(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        flows = ring_allreduce_flows(geo.workers(), 10_000_000)
        c = geo.sync_cost(CollectiveSchedule.single("mine", flows), jitter=False)
        assert c.strategy == "mine" and c.wan_seconds > 0
        assert len(c.phases) == 1 and c.phases[0].name == "mine"

    def test_phase_breakdown_covers_makespan(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        for congestion in (False, True):
            c = geo.sync_cost(
                "rs_then_ag", 50_000_000, jitter=False, congestion=congestion
            )
            assert [p.name for p in c.phases] == ["rs", "ag"]
            assert c.phases[0].end_s == pytest.approx(c.phases[1].start_s)
            assert c.phases[1].end_s == pytest.approx(c.wan_seconds)


class TestOverlapAcceptance:
    """ISSUE 3 acceptance: max(RS, AG) < rs_ag_overlap < serial RS -> AG."""

    def test_overlap_strictly_between_max_and_serial(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=3)
        kw = dict(jitter=False, congestion=True)
        B = 312_000_000
        serial = geo.sync_cost("rs_then_ag", B, **kw).wan_seconds
        overlap = geo.sync_cost("rs_ag_overlap", B, **kw).wan_seconds
        ctx = geo.strategy_context()
        workers = list(ctx.workers)
        rs = geo.sync_cost(
            CollectiveSchedule.single(
                "rs", reduce_scatter_flows(workers, B, **ctx.flow_kw)
            ),
            **kw,
        ).wan_seconds
        ag = geo.sync_cost(
            CollectiveSchedule.single(
                "ag", all_gather_flows(workers, B, **ctx.flow_kw)
            ),
            **kw,
        ).wan_seconds
        assert overlap < serial
        assert overlap > max(rs, ag)

    def test_overlap_shares_wan_bottlenecks(self):
        """The premise of the gate: RS and AG traffic really does share
        WAN links on this fabric."""
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=3)
        ctx = geo.strategy_context()
        workers = list(ctx.workers)
        rs_links = set(
            k
            for k, v in route_flows(
                geo.fabric, reduce_scatter_flows(workers, 1_000_000, **ctx.flow_kw)
            ).items()
            if v and geo.fabric.is_wan_link(*k)
        )
        ag_links = set(
            k
            for k, v in route_flows(
                geo.fabric, all_gather_flows(workers, 1_000_000, **ctx.flow_kw)
            ).items()
            if v and geo.fabric.is_wan_link(*k)
        )
        assert rs_links & ag_links


class TestHierarchicalAllToAll:
    def test_phase_split_matches_both(self):
        pods = [["d1h1", "d1h2", "d1h3"], ["d2h1", "d2h2"]]
        both = hierarchical_all_to_all_flows(pods, 10_000_019)
        dispatch = hierarchical_all_to_all_flows(pods, 10_000_019, phase="dispatch")
        combine = hierarchical_all_to_all_flows(pods, 10_000_019, phase="combine")
        assert both == dispatch + combine  # stable QP identity

    def test_dispatch_is_lan_combine_is_wan(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        c = geo.sync_cost("hier_alltoall", 64_000_000, jitter=False, congestion=True)
        dispatch, combine = c.phases
        assert dispatch.name == "dispatch" and dispatch.wan_bytes == 0
        assert combine.name == "combine" and combine.wan_bytes > 0
        assert c.wan_bytes == combine.wan_bytes

    def test_same_wan_bytes_as_flat(self):
        """Tokens aren't reducible: the hierarchy concentrates WAN traffic
        on leaders (fewer contending WAN flows) but ships the same bytes."""
        geo = GeoFabric(num_pods=2, workers_per_pod=4, seed=0)
        hier = geo.sync_cost("hier_alltoall", 64_000_000, jitter=False)
        flat = geo.sync_cost("alltoall", 64_000_000, jitter=False)
        assert hier.wan_bytes == flat.wan_bytes

    def test_byte_conservation(self):
        pods = [["d1h1", "d1h2"], ["d2h1", "d2h2"], ["d3h1"]]
        B = 9_999_997
        combine = hierarchical_all_to_all_flows(pods, B, phase="combine")
        # every pod ships n_local * (B - own shard) in total over the WAN
        from repro.core.flows import split_bytes

        shards = split_bytes(B, len(pods))
        for p, members in enumerate(pods):
            sent = sum(
                f.nbytes for f in combine if f.src == members[0]
            )
            assert sent == len(members) * (B - shards[p])

    def test_single_pod_empty(self):
        assert hierarchical_all_to_all_flows([["d1h1", "d1h2"]], 1000) == []

    def test_bad_phase_rejected(self):
        with pytest.raises(ValueError):
            hierarchical_all_to_all_flows([["a"], ["b"]], 10, phase="sideways")


class TestStepTime:
    def test_no_overlap_is_compute_plus_comm(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        comm = geo.sync_cost("hier", 100_000_000, jitter=False).wan_seconds
        step = geo.step_time("hier", 100_000_000, 2.0, overlap_fraction=0.0, jitter=False)
        assert step == pytest.approx(2.0 + comm, rel=1e-9)

    def test_full_overlap_is_max(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        comm = geo.sync_cost("hier", 100_000_000, jitter=False).wan_seconds
        step = geo.step_time("hier", 100_000_000, 2.0, overlap_fraction=1.0, jitter=False)
        assert step == pytest.approx(max(2.0, comm), rel=1e-9)
        # comm larger than compute: can't be overlapped below its floor
        big = geo.sync_cost("allreduce", 312_000_000, jitter=False).wan_seconds
        step2 = geo.step_time(
            "allreduce", 312_000_000, 0.5, overlap_fraction=1.0, jitter=False
        )
        assert step2 == pytest.approx(max(0.5, big), rel=1e-9)

    @settings(max_examples=8, deadline=None)
    @given(st.floats(min_value=0.0, max_value=1.0))
    def test_monotone_in_overlap_fraction(self, frac):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        t = geo.step_time("hier", 100_000_000, 1.0, overlap_fraction=frac, jitter=False)
        t0 = geo.step_time("hier", 100_000_000, 1.0, overlap_fraction=0.0, jitter=False)
        t1 = geo.step_time("hier", 100_000_000, 1.0, overlap_fraction=1.0, jitter=False)
        assert t1 * (1 - 1e-9) <= t <= t0 * (1 + 1e-9)

    def test_local_sgd_amortizes_exposed_comm(self):
        geo = GeoFabric(num_pods=2, workers_per_pod=2, seed=0)
        comm = geo.sync_cost("local_sgd", 100_000_000, jitter=False).wan_seconds
        step = geo.step_time(
            "local_sgd", 100_000_000, 0.1, overlap_fraction=0.0, jitter=False,
            sync_every=8,
        )
        assert step == pytest.approx(0.1 + comm / 8, rel=1e-9)
