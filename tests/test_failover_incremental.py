"""Incremental failover re-convergence tests (ISSUE 2 tentpole).

The contract: after *any* sequence of ``fail_link``/``restore_link``
flaps, the incrementally maintained routing state must be byte-identical
to a freshly built :class:`Fabric` carrying the same down-link set — while
touching only the destinations whose BFS DAG crossed the flapped link and
keeping the batched engine's interned pair/CRC/seed state warm.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bfd import FailureDetector
from repro.core.fabric import Fabric, FabricConfig, FiveTuple, RerouteStats
from repro.core.flows import (
    all_to_all_flows,
    ring_allreduce_flows,
    route_flows_batched,
)

#: A 3-DC fabric small enough for per-example fresh rebuilds but with real
#: WAN path diversity (2 spines, 12 WAN links, 12 hosts).
MID = FabricConfig(
    num_dcs=3,
    spines_per_dc=2,
    leaves_per_dc=3,
    hosts_per_leaf=((2, 1, 1), (1, 2, 1), (1, 1, 2)),
)


def _flap_sequence(fabric: Fabric, moves):
    """Apply (link_index, fail?) moves; returns the resulting down set."""
    links = [tuple(sorted(l)) for l in fabric.all_links()]
    down = set()
    for idx, do_fail in moves:
        link = links[idx % len(links)]
        if do_fail:
            down.add(link)
            fabric.fail_link(*link)
        else:
            down.discard(link)
            fabric.restore_link(*link)
    return down


def _counters_or_error(fabric, flows):
    try:
        return route_flows_batched(fabric, flows), None
    except RuntimeError as exc:
        return None, str(exc)


class TestFlapEquivalence:
    """Satellite: property test for incremental-invalidation equivalence."""

    @settings(max_examples=40, deadline=None)
    @given(
        st.lists(
            st.tuples(st.integers(min_value=0, max_value=60), st.booleans()),
            min_size=0,
            max_size=12,
        )
    )
    def test_any_flap_sequence_matches_fresh_fabric(self, moves):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 1_234_567)
        route_flows_batched(fabric, flows)  # warm every cache pre-storm
        down = _flap_sequence(fabric, moves)

        fresh = Fabric(MID)
        for link in sorted(down):
            fresh.fail_link(*link)

        inc, inc_err = _counters_or_error(fabric, flows)
        ref, ref_err = _counters_or_error(fresh, flows)
        assert (inc_err is None) == (ref_err is None), (inc_err, ref_err)
        if inc_err is None:
            assert inc == ref

    def test_seed_topology_fail_restore_roundtrip(self):
        fabric = Fabric()
        flows = ring_allreduce_flows(list(fabric.hosts), 8_000_000)
        before = route_flows_batched(fabric, flows)
        wan = sorted(fabric.wan_links[0])
        fabric.fail_link(wan[0], wan[1])
        failed = route_flows_batched(fabric, flows)
        assert all(
            link != (wan[0], wan[1]) and link != (wan[1], wan[0])
            for link, b in failed.items()
            if b > 0
        )
        fabric.restore_link(wan[0], wan[1])
        assert route_flows_batched(fabric, flows) == before


class TestIncrementalScope:
    """Flaps touch only dependent destinations; warm state survives."""

    def test_wan_flap_patches_in_place(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        wan = sorted(fabric.wan_links[0])
        stats = fabric.fail_link(wan[0], wan[1])
        assert isinstance(stats, RerouteStats)
        # full ECMP spine diversity: every affected table is patched in
        # place, none needs a BFS rebuild
        assert stats.patched > 0
        assert stats.rebuilt == 0

    def test_unrelated_destinations_retained(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        cached_before = set(fabric._dist_cache)
        # d2<->d3 WAN link: destinations inside DC1 (and their distance
        # maps) are equidistant from both endpoints -> provably unaffected
        link = sorted(l for l in fabric.wan_links
                      if all(not n.startswith("d1") for n in l))[0]
        u, v = sorted(link)
        stats = fabric.fail_link(u, v)
        assert stats.retained > 0
        d1_leaves = {d for d in cached_before if d.startswith("d1l")}
        assert d1_leaves <= set(fabric._dist_cache)

    def test_pair_registry_stays_warm_across_flaps(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        pairs = dict(fabric._pair_cache)
        rows = list(fabric._pair_rows)
        zcols = set(fabric._zcol_cache)
        wan = sorted(fabric.wan_links[0])
        fabric.fail_link(wan[0], wan[1])
        fabric.restore_link(wan[0], wan[1])
        assert fabric._pair_cache == pairs
        assert fabric._pair_rows == rows
        assert set(fabric._zcol_cache) == zcols

    def test_host_link_flap_retains_everything(self):
        """Host attachment links carry no transit traffic: flapping one must
        not invalidate (or rebuild) any leaf-destination table."""
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        cached = set(fabric._dist_cache)
        leaf = fabric.hosts["d1h1"].leaf
        stats = fabric.fail_link("d1h1", leaf)
        assert stats.touched == 0
        assert stats.retained == len(cached)
        assert set(fabric._dist_cache) == cached
        fabric.restore_link("d1h1", leaf)
        # and routing is still byte-identical to a fresh build
        fresh = Fabric(MID)
        assert route_flows_batched(fabric, flows) == route_flows_batched(
            fresh, flows
        )

    def test_dist_only_cache_not_counted_as_patched(self):
        """A destination with a cached distance map but no compiled next-hop
        table needs no edit: it must show up as retained, not patched."""
        fabric = Fabric(MID)
        fabric.next_hops("d1l1", "d2l1")  # fills _dist_cache only
        assert "d2l1" in fabric._dist_cache
        assert "d2l1" not in fabric._nh_cache
        wan = sorted(fabric.wan_links[0])
        stats = fabric.fail_link(wan[0], wan[1])
        assert stats.patched == 0
        fabric.restore_link(wan[0], wan[1])

    def test_losing_last_next_hop_rebuilds(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        # cut d1l1's first uplink (patch), then its last (distance change)
        fabric.fail_link("d1l1", "d1s1")
        stats = fabric.fail_link("d1l1", "d1s2")
        assert stats.rebuilt > 0

    def test_flush_routing_state_full_invalidation(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        before = route_flows_batched(fabric, flows)
        fabric.flush_routing_state()
        assert not fabric._dist_cache and not fabric._nh_cache
        assert route_flows_batched(fabric, flows) == before  # rebuilt lazily


class TestLinkValidation:
    """Satellite: restore_link validates like fail_link."""

    def test_restore_unknown_link_raises(self):
        fabric = Fabric()
        with pytest.raises(KeyError, match="no such link"):
            fabric.restore_link("d1s1", "nonexistent")

    def test_fail_unknown_link_raises(self):
        fabric = Fabric()
        with pytest.raises(KeyError, match="no such link"):
            fabric.fail_link("d1s1", "nonexistent")

    def test_redundant_flaps_are_noops(self):
        fabric = Fabric()
        wan = sorted(fabric.wan_links[0])
        fabric.fail_link(wan[0], wan[1])
        again = fabric.fail_link(wan[0], wan[1])
        assert again.touched == 0
        fabric.restore_link(wan[0], wan[1])
        again = fabric.restore_link(wan[0], wan[1])
        assert again.touched == 0
        assert fabric.link_up(wan[0], wan[1])


class TestHopGuard:
    """Satellite: loop guard derived from topology, not a 64-hop constant."""

    def test_limit_scales_with_switch_count(self):
        small = Fabric()
        assert small._hop_limit == len(small.spines) + len(small.leaves) + 2
        big = Fabric(FabricConfig(
            num_dcs=8, spines_per_dc=4, leaves_per_dc=6,
            hosts_per_leaf=tuple(tuple(1 for _ in range(6)) for _ in range(8)),
        ))
        assert big._hop_limit > 64  # the old constant would be too tight

    def test_scaled_fabric_routes_without_false_loop(self):
        big = Fabric(FabricConfig(
            num_dcs=8, spines_per_dc=4, leaves_per_dc=6,
            hosts_per_leaf=tuple(tuple(1 for _ in range(6)) for _ in range(8)),
        ))
        tup = FiveTuple("a", "b", 50_000, 4791)
        path = big.route_flow(tup, "d1l1", "d8l6")
        assert path[0] == "d1l1" and path[-1] == "d8l6"


class TestFailureDetectorIntegration:
    def test_recovery_timeline_reports_reroute_stats(self):
        fabric = Fabric(MID)
        flows = all_to_all_flows(list(fabric.hosts), 999_999)
        route_flows_batched(fabric, flows)
        det = FailureDetector(fabric)
        wan = sorted(fabric.wan_links[0])
        tl = det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
        assert tl.reroute is not None
        assert tl.reroute.action == "fail"
        assert tl.reroute.patched > 0
        assert any("incremental" in msg for _, msg in tl.events)
        det.restore((wan[0], wan[1]))
