"""Geo-serving subsystem (ISSUE 8): open-loop traffic, session affinity
routing, co-scheduled pricing, and the declarative surface.

Covers the tentpole guarantees:

* **trace determinism** — ``generate_trace`` is a pure function of
  ``(spec, num_dcs, num_steps)``; rotating diurnal curves and both tail
  families behave as specified;
* **session/KV affinity** — routes are sticky, the steady
  ``remote_fraction`` class is a deterministic per-user hash, and
  failover (per-request and the step-boundary sweep) pays concrete
  WAN migration bytes exactly when the old KV is still reachable;
* **runner integration** — ``ServingSpec`` on a ``Scenario`` yields
  per-step rollups and gated metrics; co-scheduled training strictly
  inflates serving p99 vs a quiescent fabric; scenarios *without* a
  ``ServingSpec`` report no serving metrics at all;
* **declarative surface** — strict ``from_dict``, JSON round-trip, and
  sweep worker-count invariance.
"""

import json

import pytest

from repro.core.geo import GeoFabric
from repro.scenario import (
    Scenario,
    ServingSpec,
    Sweep,
    SyncOptions,
    TopologySpec,
    WorkloadSpec,
    get_scenario,
    model_kv_bytes,
    run_scenario,
    run_sweep,
)
from repro.serving import (
    MIGRATION_PHASE,
    SERVING_PHASE,
    FabricHealth,
    ServingEngine,
    SessionRouter,
    diurnal_factor,
    generate_trace,
    resolve_populations,
)

KV = 16_384  # explicit bytes/token: keeps unit tests off the model configs


def _spec(**kw) -> ServingSpec:
    base = dict(
        users=40_000,
        requests_per_user_step=1e-4,
        mean_tokens=64,
        session_tokens=256,
        kv_bytes_per_token=KV,
        seed=11,
    )
    base.update(kw)
    return ServingSpec(**base)


def _health(num_dcs=2, dead=(), bad=(), rtt=25.0) -> FabricHealth:
    alive = frozenset(d for d in range(1, num_dcs + 1) if d not in dead)
    pairs = {
        (a, b): rtt
        for a in range(1, num_dcs + 1)
        for b in range(a + 1, num_dcs + 1)
    }
    return FabricHealth(
        alive=alive, bad_pairs=frozenset(bad), rtt_ms=pairs
    )


class TestTraffic:
    def test_trace_is_pure_function_of_spec(self):
        spec = _spec()
        assert generate_trace(spec, 2, 6) == generate_trace(spec, 2, 6)
        assert generate_trace(spec, 2, 6) != generate_trace(
            _spec(seed=12), 2, 6
        )

    def test_populations_split_or_explicit(self):
        assert sum(resolve_populations(_spec(users=10_001), 4)) == 10_001
        explicit = _spec(users_per_dc=(5, 0, 7))
        assert resolve_populations(explicit, 3) == (5, 0, 7)
        with pytest.raises(ValueError, match="users_per_dc"):
            resolve_populations(explicit, 2)

    def test_diurnal_peak_rotates_across_dcs(self):
        spec = _spec(diurnal_amplitude=0.5, diurnal_period_steps=24)
        peak = {
            dc: max(range(24), key=lambda s: diurnal_factor(spec, s, dc, 4))
            for dc in (1, 2, 3, 4)
        }
        assert len(set(peak.values())) == 4  # no two DCs peak together
        for dc in (1, 2, 3, 4):
            lo = min(diurnal_factor(spec, s, dc, 4) for s in range(24))
            hi = max(diurnal_factor(spec, s, dc, 4) for s in range(24))
            assert 0.5 <= lo and hi <= 1.5

    @pytest.mark.parametrize("tail", ["lognormal", "pareto"])
    def test_tails_mean_and_floor(self, tail):
        spec = _spec(tail=tail, users=400_000, requests_per_user_step=2e-5)
        reqs = [r for step in generate_trace(spec, 2, 10) for r in step]
        assert len(reqs) > 50
        assert all(r.tokens >= 1 for r in reqs)
        mean = sum(r.tokens for r in reqs) / len(reqs)
        assert 0.5 * spec.mean_tokens < mean < 2.0 * spec.mean_tokens
        # heavy tail: the max is a clear multiple of the mean
        assert max(r.tokens for r in reqs) > 2 * mean

    def test_rids_unique_and_requests_pinned_to_population(self):
        spec = _spec()
        reqs = [r for step in generate_trace(spec, 3, 6) for r in step]
        assert len({r.rid for r in reqs}) == len(reqs)
        pops = resolve_populations(spec, 3)
        assert all(0 <= r.user < pops[r.home_dc - 1] for r in reqs)


class TestRouter:
    def test_home_affinity_is_sticky(self):
        router = SessionRouter(_spec(), num_dcs=2)
        h = _health()
        first = router.route(1, 42, h)
        assert first.serving_dc == 1 and not first.migrated
        again = router.route(1, 42, h)
        assert again.serving_dc == 1 and not again.migrated

    def test_remote_fraction_hash_is_deterministic(self):
        spec = _spec(remote_fraction=0.5)
        a = SessionRouter(spec, num_dcs=3)
        b = SessionRouter(spec, num_dcs=3)
        h = _health(num_dcs=3)
        routes_a = [a.route(1, u, h).serving_dc for u in range(200)]
        routes_b = [b.route(1, u, h).serving_dc for u in range(200)]
        assert routes_a == routes_b
        remote = sum(dc != 1 for dc in routes_a)
        assert 0 < remote < 200  # both classes present

    def test_all_remote_picks_lowest_rtt_healthy_dc(self):
        spec = _spec(remote_fraction=1.0)
        router = SessionRouter(spec, num_dcs=3)
        rtts = {(1, 2): 80.0, (1, 3): 20.0, (2, 3): 40.0}
        h = FabricHealth(
            alive=frozenset({1, 2, 3}),
            bad_pairs=frozenset(),
            rtt_ms=rtts,
        )
        assert router.route(1, 0, h).serving_dc == 3

    def test_dead_serving_dc_migrates_without_kv_source(self):
        spec = _spec(remote_fraction=1.0)
        router = SessionRouter(spec, num_dcs=2)
        assert router.route(1, 0, _health()).serving_dc == 2
        moved = router.route(1, 0, _health(dead=(2,)))
        assert moved.migrated and moved.serving_dc == 1
        assert moved.kv_source is None  # the cache died with DC 2

    def test_bad_pair_migrates_home_paying_kv(self):
        spec = _spec(remote_fraction=1.0)
        router = SessionRouter(spec, num_dcs=2)
        router.route(1, 0, _health())
        moved = router.route(1, 0, _health(bad=((1, 2),)))
        assert moved.migrated and moved.serving_dc == 1
        assert moved.kv_source == 2  # DC 2 is alive: KV transfers over WAN

    def test_failover_off_keeps_degraded_placement(self):
        spec = _spec(remote_fraction=1.0, failover=False)
        router = SessionRouter(spec, num_dcs=2)
        router.route(1, 0, _health())
        stuck = router.route(1, 0, _health(bad=((1, 2),)))
        assert stuck.serving_dc == 2 and not stuck.migrated
        assert router.rehome_all(_health(bad=((1, 2),))) == []

    def test_rehome_sweep_moves_idle_sessions(self):
        """The step-boundary sweep re-homes sessions that issue no
        request this step — live users feel a brownout regardless."""
        spec = _spec(remote_fraction=1.0)
        router = SessionRouter(spec, num_dcs=2)
        for u in range(5):
            router.route(1, u, _health())
        moves = router.rehome_all(_health(bad=((1, 2),)))
        assert [(m[0], m[1]) for m in moves] == [(1, u) for u in range(5)]
        assert all(m[3].migrated and m[3].kv_source == 2 for m in moves)
        # sweep already re-homed them: routing again migrates nothing
        assert not router.route(1, 0, _health(bad=((1, 2),))).migrated

    def test_nowhere_to_go_drops_the_session(self):
        router = SessionRouter(_spec(), num_dcs=2)
        router.route(1, 0, _health())
        assert router.route(1, 0, _health(dead=(1, 2))) is None


class TestEngine:
    def _engine(self, **kw):
        return ServingEngine(spec=_spec(**kw), num_dcs=2, num_steps=4)

    def test_plan_emits_request_flows_and_stats(self):
        geo = GeoFabric(2, 2, seed=3)
        eng = self._engine()
        plan = eng.plan_step(0, geo, _health())
        assert len(plan.placements) > 0 and plan.dropped == 0
        names = {p.name for p in plan.phases}
        assert SERVING_PHASE in names and MIGRATION_PHASE not in names
        stats = eng.finish_step(plan, report=None)
        assert stats.requests == len(plan.placements)
        assert stats.tokens == sum(r.tokens for r, _rt, _h in plan.placements)
        assert stats.p99_ms == 0.0  # no report: wire cost unpriced

    def test_migration_bytes_are_sessions_times_kv(self):
        geo = GeoFabric(2, 2, seed=3)
        eng = self._engine(remote_fraction=1.0)
        eng.plan_step(0, geo, _health())  # establish remote sessions
        plan = eng.plan_step(1, geo, _health(bad=((1, 2),)))
        assert plan.migrated_sessions > 0
        assert plan.migration_bytes == (
            plan.migrated_sessions * eng.session_kv_bytes
        )
        assert any(p.name == MIGRATION_PHASE for p in plan.phases)

    def test_two_engines_plan_identically(self):
        geo = GeoFabric(2, 2, seed=3)
        a, b = self._engine(), self._engine()
        for step in range(2):
            assert a.plan_step(step, geo, _health()) == b.plan_step(
                step, geo, _health()
            )


def _scenario(strategy, serving, steps=4, name="serving_unit") -> Scenario:
    return Scenario(
        name=name,
        topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=3),
        workload=WorkloadSpec(strategy=strategy, grad_bytes=96_000_000, steps=steps),
        options=SyncOptions(jitter=False),
        serving=serving,
    )


class TestRunnerIntegration:
    def test_serving_rollups_and_metrics(self):
        result = run_scenario(_scenario("allreduce", _spec()))
        assert [s.step for s in result.serving_steps] == [0, 1, 2, 3]
        m = result.metrics()
        assert m["serving_requests"] == sum(
            s.requests for s in result.serving_steps
        )
        for key in (
            "serving_p50_ms",
            "serving_p99_ms",
            "serving_slo_miss_frac",
            "serving_migrated_sessions",
            "serving_migration_bytes",
        ):
            assert key in m
        assert len(result.to_dict()["serving_steps"]) == 4

    def test_no_servingspec_means_no_serving_metrics(self):
        result = run_scenario(_scenario("allreduce", None))
        assert result.serving_steps == []
        assert not any(k.startswith("serving_") for k in result.metrics())
        assert result.to_dict()["serving_steps"] == []

    def test_training_strictly_inflates_serving_p99(self):
        """The co-scheduling tentpole: same trace, same fabric — adding
        the AllReduce must make every step's serving p99 worse."""
        spec = _spec(
            users=200_000,
            requests_per_user_step=5e-5,
            remote_fraction=0.3,
            seed=7,
        )

        def sc(strategy, name):
            return Scenario(
                name=name,
                topology=TopologySpec(
                    num_pods=2, workers_per_pod=2, num_channels=4, seed=3
                ),
                workload=WorkloadSpec(
                    strategy=strategy, grad_bytes=312_000_000, steps=4
                ),
                options=SyncOptions(jitter=False),
                serving=spec,
            )

        quiet = run_scenario(sc(None, "quiet"))
        busy = run_scenario(sc("allreduce", "busy"))
        q = [s.p99_ms for s in quiet.serving_steps]
        b = [s.p99_ms for s in busy.serving_steps]
        assert [s.requests for s in quiet.serving_steps] == [
            s.requests for s in busy.serving_steps
        ]
        assert all(bi > qi for qi, bi in zip(q, b))

    def test_serving_under_flap_migrates_and_recovers(self):
        result = run_scenario(get_scenario("serving_under_flap"))
        m = result.metrics()
        assert m["serving_migrated_sessions"] > 0
        assert m["serving_migration_bytes"] > 0
        mig_step = next(
            s.step for s in result.serving_steps if s.migrated_sessions > 0
        )
        assert all(
            s.slo_misses == 0
            for s in result.serving_steps
            if s.step >= mig_step
        )


class TestDeclarativeSurface:
    def test_scenario_json_round_trip(self):
        sc = _scenario("allreduce", _spec(users_per_dc=(7, 9)))
        assert Scenario.from_dict(json.loads(json.dumps(sc.to_dict()))) == sc
        assert sc.to_dict()["serving"]["users_per_dc"] == [7, 9]
        bare = _scenario("allreduce", None)
        assert bare.to_dict()["serving"] is None
        assert Scenario.from_dict(json.loads(json.dumps(bare.to_dict()))) == bare

    def test_from_dict_rejects_unknown_keys(self):
        d = _spec().to_dict()
        d["typo_field"] = 1
        with pytest.raises(ValueError, match="typo_field"):
            ServingSpec.from_dict(d)

    @pytest.mark.parametrize(
        "kw,msg",
        [
            (dict(tail="uniform"), "tail"),
            (dict(tail_alpha=1.0), "alpha"),
            (dict(diurnal_amplitude=1.5), "amplitude"),
            (dict(remote_fraction=-0.1), "remote_fraction"),
            (dict(slo_ms=0.0), "slo_ms"),
        ],
    )
    def test_validation(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            _spec(**kw)

    def test_kv_bytes_resolution(self):
        assert _spec().resolve_kv_bytes_per_token() == KV
        derived = _spec(kv_bytes_per_token=0, model="distilgpt2-82m")
        assert derived.resolve_kv_bytes_per_token() == model_kv_bytes(
            "distilgpt2-82m"
        )
        assert model_kv_bytes("distilgpt2-82m") == 18_432
        assert model_kv_bytes("distilgpt2-82m", tokens=3) == 3 * 18_432
        with pytest.raises(ValueError, match="kv_bytes_per_token"):
            _spec(kv_bytes_per_token=0).resolve_kv_bytes_per_token()

    def test_sweep_worker_counts_agree(self):
        base = _scenario(None, _spec(users=100_000), name="sw")
        sweep = Sweep(
            base=base,
            overrides=(
                {"name": "s1", "serving.seed": 1},
                {"name": "s2", "serving.seed": 2},
                {"name": "s3", "serving.remote_fraction": 0.4},
            ),
            name="serving_workers",
        )
        serial = run_sweep(sweep)
        parallel = run_sweep(sweep, workers=2)
        assert [r.to_dict() for r in serial.rows] == [
            r.to_dict() for r in parallel.rows
        ]
        assert all("serving_p99_ms" in r.metrics for r in serial.rows)
