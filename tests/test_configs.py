"""Config validation: exact param counts (eval_shape) vs published sizes."""

import jax
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, EXPECTED_PARAMS, get_config, get_smoke_config
from repro.launch.shapes import SHAPES, params_specs, shape_supported

LONG_CTX_ARCHS = {"mixtral-8x22b", "rwkv6-7b", "recurrentgemma-9b"}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert "distilgpt2-82m" in ALL_ARCHS  # the paper's own model


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    specs = params_specs(cfg)
    n = sum(s.size for s in jax.tree.leaves(specs))
    expected = EXPECTED_PARAMS[arch]
    assert abs(n - expected) / expected < 0.12, (
        f"{arch}: {n / 1e9:.2f}B params vs published {expected / 1e9:.2f}B"
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_exact_dims(arch):
    """The registry must carry the assignment's exact dims."""
    dims = {
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "distilgpt2-82m": (6, 768, 12, 12, 3072, 50257),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == dims


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.family == full.family
    assert smoke.pattern == full.pattern
    assert smoke.norm == full.norm
    assert smoke.activation == full.activation
    assert (smoke.moe is None) == (full.moe is None)
    assert smoke.frontend == full.frontend
    assert smoke.param_count() < 10e6  # genuinely reduced


def test_moe_flags():
    arctic = get_config("arctic-480b")
    assert arctic.moe.num_experts == 128 and arctic.moe.num_experts_per_tok == 2
    assert arctic.moe.parallel_dense  # dense residual
    mixtral = get_config("mixtral-8x22b")
    assert mixtral.moe.num_experts == 8 and mixtral.window is not None


def test_long_500k_eligibility():
    """Sub-quadratic archs run long_500k; pure full-attention archs skip."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        ok, why = shape_supported(cfg, "long_500k")
        assert ok == (arch in LONG_CTX_ARCHS), (arch, why)


def test_every_arch_runs_other_shapes():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = shape_supported(cfg, shape)
            assert ok


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
