"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and the absence of NaNs (assignment (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models import (
    IGNORE_LABEL,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill,
)

B, S = 2, 16


def make_batch(cfg, key):
    kt, ke = jax.random.split(key)
    batch = {}
    if cfg.frontend == "frame":
        batch["frame_embeds"] = jax.random.normal(ke, (B, S, cfg.frontend_dim))
        batch["labels"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    elif cfg.frontend == "patch":
        p = cfg.num_prefix_tokens
        batch["tokens"] = jax.random.randint(kt, (B, S - p), 0, cfg.vocab_size)
        batch["patch_embeds"] = jax.random.normal(ke, (B, p, cfg.frontend_dim))
        labels = np.full((B, S), IGNORE_LABEL, np.int32)
        labels[:, p:] = np.asarray(
            jax.random.randint(kt, (B, S - p), 0, cfg.vocab_size)
        )
        batch["labels"] = jnp.asarray(labels)
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
        batch["labels"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: NaN/inf in aux loss"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_finite_grads(arch):
    """One SGD step: loss and every gradient leaf finite; params update."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(q, b, cfg), has_aux=True
        )(p)
        new_p = jax.tree.map(lambda a, g: a - 1e-3 * g.astype(a.dtype), p, grads)
        return loss, grads, new_p

    loss, grads, new_params = step(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    for path, leaf in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite grad at {path}"
    # at least the embedding moved
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()), params, new_params)
    )
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_roundtrip(arch):
    """Greedy decode from a prefilled cache matches teacher-forced logits."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops make MoE decode diverge from batched forward by
        # design; covered with high capacity in tests/test_models.py
        pytest.skip("MoE capacity drops: covered separately")
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    logits_full, _ = forward(params, batch, cfg)
    if cfg.frontend == "frame":
        n0 = S - 2
        pre = {"frame_embeds": batch["frame_embeds"][:, :n0], "labels": batch["labels"][:, :n0]}
        last, cache = prefill(params, pre, cfg, max_len=S)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits_full[:, n0 - 1]), rtol=3e-2, atol=3e-2
        )
        for t in range(n0, S):
            step_in = batch["frame_embeds"][:, t : t + 1]
            lt, cache = decode_step(params, step_in, cache, cfg, jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(lt), np.asarray(logits_full[:, t]), rtol=3e-2, atol=3e-2
            )
    elif cfg.frontend == "patch":
        # decode over the text region only
        last, cache = prefill(params, batch, cfg, max_len=S + 4)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits_full[:, -1]), rtol=3e-2, atol=3e-2
        )
    else:
        n0 = S - 4
        pre = {"tokens": batch["tokens"][:, :n0]}
        last, cache = prefill(params, pre, cfg, max_len=S)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits_full[:, n0 - 1]), rtol=3e-2, atol=3e-2
        )
        for t in range(n0, S):
            lt, cache = decode_step(params, batch["tokens"][:, t], cache, cfg, jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(lt), np.asarray(logits_full[:, t]), rtol=3e-2, atol=3e-2,
                err_msg=f"{arch} t={t}",
            )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_cache_structure(arch):
    """init_decode_cache matches prefill's cache pytree structure."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    if cfg.frontend == "patch":
        pytest.skip("prefix cache length differs by num_prefix_tokens")
    _, cache = prefill(params, batch, cfg, max_len=S)
    fresh = init_decode_cache(cfg, B, S)
    assert jax.tree.structure(cache) == jax.tree.structure(fresh)
    for a, b_ in zip(jax.tree.leaves(cache), jax.tree.leaves(fresh)):
        assert a.shape == b_.shape, f"{arch}: {a.shape} vs {b_.shape}"
