"""Tests for the emulated spine-leaf multi-DC fabric (paper §4)."""

import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.fabric import (
    Fabric,
    FabricConfig,
    FiveTuple,
    ecmp_hash,
    vxlan_outer_tuple,
    VXLAN_DST_PORT,
)


@pytest.fixture()
def fabric():
    return Fabric()


class TestTopology:
    def test_paper_inventory(self, fabric):
        """Fig. 1 / Fig. 3: 2 DCs x (2 spines + 3 leaves), 5 + 4 hosts."""
        assert len(fabric.spines) == 4
        assert len(fabric.leaves) == 6
        assert len(fabric.hosts) == 9
        assert {h.dc for h in fabric.hosts.values()} == {1, 2}
        assert len([h for h in fabric.hosts.values() if h.dc == 1]) == 5
        assert len([h for h in fabric.hosts.values() if h.dc == 2]) == 4

    def test_wan_links_full_bipartite(self, fabric):
        # 2 spines per DC, 2 DCs -> 4 WAN links
        assert len(fabric.wan_links) == 4
        for link in fabric.wan_links:
            u, v = sorted(link)
            assert u.startswith("d1s") and v.startswith("d2s")

    def test_leaf_uplinks(self, fabric):
        for leaf in fabric.leaves:
            spines = [n for n in fabric.neighbors(leaf) if n in fabric.spines]
            assert len(spines) == 2  # each leaf dual-homed to both local spines

    def test_hosts_nontransit(self, fabric):
        """Traffic between two hosts never transits a third host."""
        path = fabric.route_flow(
            FiveTuple("a", "b", 50000, 4791), "d1l1", "d2l1"
        )
        assert not any(n in fabric.hosts for n in path)

    def test_validate_rejects_bad_config(self):
        with pytest.raises(ValueError):
            FabricConfig(num_dcs=2, hosts_per_leaf=((1,),)).validate()


class TestEcmpRouting:
    def test_path_is_shortest(self, fabric):
        tup = FiveTuple("192.168.1.1", "192.168.2.1", 49999, 4791)
        path = fabric.route_flow(tup, "d1l1", "d2l1")
        # leaf -> spine -> WAN spine -> leaf = 4 nodes / 3 hops
        assert len(path) == 4
        assert path[0] == "d1l1" and path[-1] == "d2l1"

    def test_deterministic(self, fabric):
        tup = FiveTuple("192.168.1.1", "192.168.2.1", 50123, 4791)
        assert fabric.route_flow(tup, "d1l1", "d2l1") == fabric.route_flow(tup, "d1l1", "d2l1")

    def test_port_diversity_spreads_paths(self, fabric):
        """Different source ports should reach different equal-cost paths."""
        paths = {
            tuple(fabric.route_flow(FiveTuple("a", "b", p, 4791), "d1l1", "d2l1"))
            for p in range(49192, 49192 + 256)
        }
        assert len(paths) > 1

    def test_identical_tuple_identical_path(self, fabric):
        """The collision mechanism: same 5-tuple -> same path, always."""
        tup = FiveTuple("x", "y", 55555, 4791)
        first = fabric.route_flow(tup, "d1l1", "d2l3")
        for _ in range(10):
            assert fabric.route_flow(tup, "d1l1", "d2l3") == first

    @given(st.integers(min_value=0, max_value=2**16 - 1), st.integers(min_value=1, max_value=8))
    def test_hash_in_range(self, port, n):
        tup = FiveTuple("1.1.1.1", "2.2.2.2", port, 4791)
        assert 0 <= ecmp_hash(tup, 0xABC, n) < n

    def test_failed_link_avoided(self, fabric):
        fabric.fail_link("d1l1", "d1s1")
        for p in range(49192, 49192 + 64):
            path = fabric.route_flow(FiveTuple("a", "b", p, 4791), "d1l1", "d2l1")
            assert ("d1l1", "d1s1") not in list(zip(path, path[1:]))
        fabric.restore_link("d1l1", "d1s1")

    def test_no_route_raises(self, fabric):
        for spine in ("d1s1", "d1s2"):
            fabric.fail_link("d1l1", spine)
        with pytest.raises(RuntimeError, match="no route"):
            fabric.route_flow(FiveTuple("a", "b", 50000, 4791), "d1l1", "d2l1")
        fabric.restore_link("d1l1", "d1s1")
        fabric.restore_link("d1l1", "d1s2")


class TestVxlanDataPlane:
    def test_outer_tuple_preserves_entropy(self):
        """RFC 7348: inner-flow hash becomes the outer UDP source port."""
        inner_a = FiveTuple("192.168.1.1", "192.168.1.2", 49192, 4791)
        inner_b = FiveTuple("192.168.1.1", "192.168.1.2", 49193, 4791)
        outer_a = vxlan_outer_tuple(inner_a, "1.1.10.1", "2.2.10.1")
        outer_b = vxlan_outer_tuple(inner_b, "1.1.10.1", "2.2.10.1")
        assert outer_a.dst_port == VXLAN_DST_PORT
        assert outer_a.src_port != outer_b.src_port  # entropy survived
        assert outer_a.src_ip == "1.1.10.1"

    def test_send_counts_bytes(self, fabric):
        fabric.reset_counters()
        path = fabric.send("d1h1", "d2h1", 1000, src_port=49192)
        assert path[0] == "d1h1" and path[-1] == "d2h1"
        assert sum(fabric.link_bytes.values()) == 1000 * (len(path) - 1)

    def test_same_leaf_local_bridging(self, fabric):
        fabric.reset_counters()
        # d1h1 and d1h2 both live on d1l1 (2 hosts on leaf 1)
        h1, h2 = "d1h1", "d1h2"
        assert fabric.hosts[h1].leaf == fabric.hosts[h2].leaf
        path = fabric.send(h1, h2, 500, src_port=49192)
        assert len(path) == 3  # host -> leaf -> host, no spine transit
        assert all(n not in fabric.spines for n in path)

    def test_uplink_byte_counters(self, fabric):
        fabric.reset_counters()
        for port in range(49192, 49192 + 32):
            fabric.send("d1h1", "d2h4", 10_000, src_port=port)
        leaf_up = fabric.uplink_bytes("d1l1", toward="spine")
        assert len(leaf_up) >= 1
        assert sum(leaf_up.values()) == 32 * 10_000
        wan_total = sum(
            b for (u, v), b in fabric.link_bytes.items() if fabric.is_wan_link(u, v)
        )
        assert wan_total == 32 * 10_000
