"""Analytical collision model tests (paper §3.3.2, Eqs. 3-11)."""

import math

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.collision import (
    collision_index,
    collision_reduction,
    compare_schemes,
    expected_collisions,
    monte_carlo_collisions,
)
from repro.core.ports import ALIASING_STRIDE


def normalized(dist):
    arr = np.asarray(dist, dtype=np.float64)
    return arr / arr.sum()


class TestClosedForms:
    def test_uniform_minimizes_index(self):
        """Eq. 6 discussion: sum p^2 is minimized at p = 1/K."""
        k = 4
        uniform = collision_index([1 / k] * k)
        assert uniform == pytest.approx(1 / k)
        skewed = collision_index([0.7, 0.1, 0.1, 0.1])
        assert skewed > uniform

    @given(st.lists(st.floats(min_value=0.01, max_value=1.0), min_size=2, max_size=16))
    def test_index_bounds(self, raw):
        p = normalized(raw)
        idx = collision_index(p)
        assert 1 / len(p) - 1e-9 <= idx <= 1.0 + 1e-9

    def test_expected_collisions_eq5(self):
        """E[C] = C(N,2) sum p^2 for concrete values."""
        p = [0.5, 0.5]
        assert expected_collisions(4, p) == pytest.approx(math.comb(4, 2) * 0.5)
        assert expected_collisions(2, [1.0]) == 1.0  # both flows on the one path

    def test_delta_c_eq10(self):
        base = [0.7, 0.1, 0.1, 0.1]
        prop = [0.25] * 4
        got = collision_reduction(base, prop)
        expect = 1 - 0.25 / (0.49 + 0.03)
        assert got == pytest.approx(expect)

    def test_delta_c_zero_when_equal(self):
        p = [0.4, 0.3, 0.2, 0.1]
        assert collision_reduction(p, p) == pytest.approx(0.0)

    def test_eq11_condition(self):
        """Proposed wins iff sum(p_prop^2) < sum(p_base^2)."""
        base, prop = [0.7, 0.3], [0.5, 0.5]
        assert collision_reduction(base, prop) > 0
        assert collision_reduction(prop, base) < 0

    def test_unnormalized_rejected(self):
        with pytest.raises(ValueError):
            collision_index([0.5, 0.2])


class TestMonteCarlo:
    def test_analytic_matches_empirical_for_uniform(self):
        """Under high-entropy allocation, E[C] from the pooled distribution
        matches the Monte-Carlo collision count (independence holds)."""
        r = monte_carlo_collisions(
            num_qps=8, num_paths=4, scheme="qp_aware", trials=3000, qp_stride=1, seed=0
        )
        assert r.mean_pairwise_collisions == pytest.approx(r.analytic_expected, rel=0.15)

    def test_correlated_baseline_worse_than_uniform(self):
        """The production pathology: aliased QP numbers collapse onto few
        paths, so collisions exceed the uniform-hash expectation."""
        r = monte_carlo_collisions(
            num_qps=8, num_paths=4, scheme="baseline",
            trials=1500, qp_stride=ALIASING_STRIDE, seed=1,
        )
        uniform_expectation = math.comb(8, 2) / 4
        assert r.mean_pairwise_collisions > 1.5 * uniform_expectation

    @pytest.mark.parametrize("num_qps", [4, 8, 16, 32])
    def test_qp_aware_reduces_collisions_under_aliasing(self, num_qps):
        """The paper's headline: binning reduces collisions for correlated
        QPs across all channel counts studied (4..32)."""
        r = compare_schemes(
            num_qps=num_qps, num_paths=4, trials=800,
            qp_stride=ALIASING_STRIDE, seed=2,
        )
        assert r["delta_c_empirical"] > 0.25

    def test_neutral_under_high_entropy(self):
        """§3.3.2: the mechanism does not improve *ideal* ECMP hashing."""
        r = compare_schemes(num_qps=16, num_paths=4, trials=1500, qp_stride=1, seed=3)
        assert abs(r["delta_c_empirical"]) < 0.15

    def test_path_distribution_valid(self):
        r = monte_carlo_collisions(
            num_qps=4, num_paths=8, scheme="baseline", trials=200, seed=0
        )
        assert r.path_distribution.shape == (8,)
        assert r.path_distribution.sum() == pytest.approx(1.0)
