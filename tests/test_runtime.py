"""Runtime substrate tests: data, checkpoint, failure, straggler, elastic,
and the end-to-end GeoTrainer loop (single device)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, CheckpointStore
from repro.configs import get_smoke_config
from repro.data import DataConfig, ShardedLoader, loader_for_model
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.runtime import (
    ElasticCoordinator,
    GeoTrainer,
    HeartbeatMonitor,
    StragglerMonitor,
    TrainerConfig,
    optimal_checkpoint_interval,
    plan_recovery,
    plan_remesh,
)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=4, seed=7)
        a = ShardedLoader(cfg).next_batch()
        b = ShardedLoader(cfg).next_batch()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_resume_seek(self):
        """start_step=k reproduces the k-th batch exactly (O(1) seek)."""
        cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=4, seed=1)
        l1 = ShardedLoader(cfg)
        batches = [l1.next_batch() for _ in range(5)]
        l2 = ShardedLoader(cfg, start_step=3)
        np.testing.assert_array_equal(l2.next_batch()["tokens"], batches[3]["tokens"])

    def test_host_sharding_disjoint(self):
        cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=8, seed=2)
        h0 = ShardedLoader(cfg, host_index=0, num_hosts=2).next_batch()
        h1 = ShardedLoader(cfg, host_index=1, num_hosts=2).next_batch()
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_learnable_structure(self):
        """The Markov source has real bigram structure (non-uniform)."""
        cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=16, seed=3)
        toks = ShardedLoader(cfg).next_batch()["tokens"]
        # top-1 unigram frequency clearly above uniform (Zipf emission,
        # flattened by mixing over hidden states)
        counts = np.bincount(toks.reshape(-1), minlength=64)
        assert counts.max() / counts.sum() > 2.0 / 64

    def test_frontend_contracts(self):
        model_cfg = get_smoke_config("phi-3-vision-4.2b")
        loader = loader_for_model(model_cfg, seq_len=16, global_batch=2)
        b = loader.next_batch()
        assert b["tokens"].shape == (2, 16 - model_cfg.num_prefix_tokens)
        assert b["patch_embeds"].shape == (2, model_cfg.num_prefix_tokens, model_cfg.frontend_dim)
        assert (b["labels"][:, : model_cfg.num_prefix_tokens] == -100).all()


class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
        }

    def test_roundtrip(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = self._tree()
        store.save(5, tree, metadata={"data_step": 5})
        restored, meta = store.restore(5, tree)
        assert meta["data_step"] == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, tmp_path):
        store = CheckpointStore(tmp_path, keep=2)
        tree = self._tree()
        for s in (1, 2, 3, 4):
            store.save(s, tree)
        assert store.latest_step() == 4
        assert store.steps() == [3, 4]  # GC kept last 2

    def test_corruption_detected(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = self._tree()
        info = store.save(1, tree)
        # flip bytes in one array file
        target = next(info.path.glob("arr_*.npy"))
        raw = bytearray(target.read_bytes())
        raw[-1] ^= 0xFF
        target.write_bytes(bytes(raw))
        with pytest.raises((IOError, ValueError)):
            store.restore(1, tree)

    def test_uncommitted_invisible(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = self._tree()
        store.save(1, tree)
        # fake a crashed writer: directory without marker
        (tmp_path / "step_00000009").mkdir()
        assert store.latest_step() == 1

    def test_async(self, tmp_path):
        store = CheckpointStore(tmp_path)
        ck = AsyncCheckpointer(store)
        tree = self._tree()
        ck.save(7, tree)
        ck.wait()
        restored, _ = store.restore(7, tree)
        np.testing.assert_array_equal(
            np.asarray(tree["w"]), np.asarray(restored["w"])
        )


class TestFailure:
    def test_heartbeat_detection(self):
        mon = HeartbeatMonitor(["pod0", "pod1"], interval_ms=10, detect_mult=3)
        mon.heartbeat("pod0", 100.0)
        mon.heartbeat("pod1", 100.0)
        assert mon.poll(120.0) == []
        mon.heartbeat("pod0", 125.0)
        dead = mon.poll(135.0)  # pod1 silent for 35ms > 30ms detect time
        assert dead == ["pod1"]
        assert mon.alive() == ["pod0"]

    def test_suspect_recovers_to_healthy(self):
        """A SUSPECT worker whose heartbeats resume must return to HEALTHY
        on the next poll — even when the rx path touched the BFD session
        directly instead of going through heartbeat() (regression: poll
        had no SUSPECT -> HEALTHY edge, so the state stuck forever)."""
        from repro.runtime.failure import WorkerState

        mon = HeartbeatMonitor(["pod0"], interval_ms=10, detect_mult=3)
        mon.heartbeat("pod0", 100.0)
        mon.poll(120.0)  # 20ms > 1.5 * interval -> SUSPECT
        assert mon.workers["pod0"].state == WorkerState.SUSPECT
        # heartbeats resume via the raw session (no state reset side effect)
        mon.workers["pod0"].session.on_rx(125.0)
        mon.poll(130.0)
        assert mon.workers["pod0"].state == WorkerState.HEALTHY
        assert mon.alive() == ["pod0"]

    def test_recovery_plan_economics(self):
        plan = plan_recovery(
            step=100, last_checkpoint_step=90, step_time_s=2.0,
            detect_time_ms=300.0, checkpoint_bytes=1e9,
        )
        assert plan.lost_steps == 10
        assert plan.lost_work_s == 20.0
        assert plan.total_downtime_s > 30.0  # remesh dominates
        assert plan.total_cost_s == plan.total_downtime_s + 20.0

    def test_young_daly(self):
        # sqrt(2 * 10s * 3600s) = ~268s -> / 2s per step = 134 steps
        n = optimal_checkpoint_interval(step_time_s=2.0, save_overhead_s=10.0, mtbf_s=3600.0)
        assert 120 < n < 150


class TestStraggler:
    def test_detection_ladder(self):
        mon = StragglerMonitor(["a", "b", "c"], min_samples=3)
        for _ in range(6):
            mon.record("a", 1.0)
            mon.record("b", 1.05)
            mon.record("c", 1.8)
        reports = mon.reports()
        assert len(reports) == 1
        assert reports[0].worker == "c" and reports[0].action == "rebalance"
        for _ in range(20):
            mon.record("c", 30.0)
        assert any(r.action == "exclude" for r in mon.reports())

    def test_sync_efficiency(self):
        mon = StragglerMonitor(["a", "b"], min_samples=1)
        for _ in range(5):
            mon.record("a", 1.0)
            mon.record("b", 2.0)
        assert 0.4 < mon.sync_efficiency() < 0.9


class TestElastic:
    def test_plan_collapse_to_single(self):
        plan = plan_remesh(2, 1, data=16, model=16)
        assert plan.axes == ("data", "model")
        assert plan.shape == (16, 16)

    def test_plan_shrink(self):
        plan = plan_remesh(4, 3, data=16, model=16)
        assert plan.shape == (3, 16, 16)

    def test_coordinator_events(self):
        coord = ElasticCoordinator(["pod0", "pod1"], data=2, model=2)
        plan = coord.on_pod_lost("pod1", step=50)
        assert plan.npods == 1
        plan = coord.on_pod_joined("pod2", step=80)
        assert plan.npods == 2
        assert [e.kind for e in coord.events] == ["pod_lost", "pod_joined"]

    def test_no_survivors_rejected(self):
        with pytest.raises(ValueError):
            plan_remesh(1, 0, data=2, model=2)


class TestGeoTrainerEndToEnd:
    def test_loss_decreases_and_resumes(self, tmp_path):
        """Train 8 steps, kill, resume from checkpoint: loss continuous."""
        cfg = get_smoke_config("distilgpt2-82m")
        mesh = make_host_mesh()  # single device
        tc = TrainerConfig(
            seq_len=32, global_batch=4, steps=8, strategy="allreduce",
            checkpoint_every=4, log_every=100,
            opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
        )
        trainer = GeoTrainer(cfg, mesh, trainer_cfg=tc, checkpoint_dir=str(tmp_path))
        result = trainer.run()
        losses = [m["loss"] for m in result["metrics"]]
        assert losses[-1] < losses[0], losses
        assert result["last_checkpoint"] == 8

        # resume: a new trainer restores step 8 and continues to 12
        tc2 = dataclasses.replace(tc, steps=12)
        trainer2 = GeoTrainer(cfg, mesh, trainer_cfg=tc2, checkpoint_dir=str(tmp_path))
        result2 = trainer2.run()
        assert result2["metrics"][0]["step"] == 8  # resumed, not restarted
        assert result2["metrics"][-1]["loss"] < losses[0]

    def test_failure_drill(self, tmp_path):
        cfg = get_smoke_config("distilgpt2-82m")
        mesh = make_host_mesh()
        tc = TrainerConfig(
            seq_len=32, global_batch=4, steps=6, strategy="allreduce",
            checkpoint_every=2, log_every=100,
        )
        trainer = GeoTrainer(cfg, mesh, trainer_cfg=tc, checkpoint_dir=str(tmp_path))
        # pretend there are 2 pods for the monitor
        trainer.heartbeats = HeartbeatMonitor(["pod0", "pod1"], interval_ms=10)
        trainer.stragglers = StragglerMonitor(["pod0", "pod1"])
        result = trainer.run(inject_failure_at=3)
        assert result["recovery_drills"], "failure injection should trigger a drill"
        drill = result["recovery_drills"][0]
        assert "pod1" in drill["dead"]
        assert drill["plan"]["lost_steps"] >= 0
