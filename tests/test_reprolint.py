"""reprolint tests (ISSUE 10): every rule gets a fixture pair (one
snippet proving it fires, one proving it stays quiet), the engine's
suppression/baseline/ratchet mechanics are unit-tested, and — mirroring
``tests/test_docs.py``'s contract for ``check_links.py`` — a tier-1 test
asserts the repo itself is clean under the committed baseline via the
exact command CI runs.

The acceptance demonstrations are here too: seeding an upward import
into ``repro.core.fabric`` or deleting ``_FullEpochAllocator`` from
``repro.core.congestion`` must produce a finding.
"""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.reprolint import baseline as baseline_mod  # noqa: E402
from tools.reprolint import lint_source, rule_ids  # noqa: E402
from tools.reprolint import reporters  # noqa: E402
from tools.reprolint.core import Finding, module_name_for  # noqa: E402

SIM = "src/repro/core/example.py"  # a simulator-layer path for fixtures


def findings(source, relpath=SIM, rule=None):
    out = lint_source(source, relpath)
    return [f for f in out if rule is None or f.rule == rule]


# -- engine ------------------------------------------------------------------


class TestEngine:
    @pytest.mark.parametrize(
        "relpath,module",
        [
            ("src/repro/core/fabric.py", "repro.core.fabric"),
            ("src/repro/core/__init__.py", "repro.core"),
            ("src/repro/__init__.py", "repro"),
            ("benchmarks/bench_sweeps.py", "benchmarks.bench_sweeps"),
            ("tests/test_docs.py", "tests.test_docs"),
            ("examples/quickstart.py", "examples.quickstart"),
        ],
    )
    def test_module_name_for(self, relpath, module):
        assert module_name_for(relpath) == module

    def test_rule_registry_is_the_documented_set(self):
        assert set(rule_ids()) == {
            "layer-dag",
            "sibling-stack",
            "wall-clock",
            "rng-discipline",
            "set-iteration",
            "spec-frozen",
            "spec-from-dict",
            "from-dict-strict",
            "oracle-retention",
            "unused-suppression",
        }

    def test_suppression_same_line_and_line_above(self):
        base = "import numpy as np\nrng = np.random.default_rng()"
        assert findings(base, rule="rng-discipline")
        same = base + "  # reprolint: allow[rng-discipline]"
        assert not findings(same, rule="rng-discipline")
        above = (
            "import numpy as np\n"
            "# reprolint: allow[rng-discipline]\n"
            "rng = np.random.default_rng()"
        )
        assert not findings(above, rule="rng-discipline")

    def test_suppression_is_per_rule(self):
        src = (
            "import numpy as np\n"
            "rng = np.random.default_rng()  # reprolint: allow[wall-clock]\n"
        )
        # wrong rule id: the finding survives AND the comment is unused
        assert findings(src, rule="rng-discipline")
        assert findings(src, rule="unused-suppression")

    def test_unused_suppression_reported(self):
        src = "x = 1  # reprolint: allow[rng-discipline]\n"
        (f,) = findings(src, rule="unused-suppression")
        assert "suppresses nothing" in f.message

    def test_unknown_rule_id_reported(self):
        src = "x = 1  # reprolint: allow[no-such-rule]\n"
        (f,) = findings(src, rule="unused-suppression")
        assert "unknown rule id" in f.message

    def test_multi_rule_allow_comment(self):
        src = (
            "import numpy as np\n"
            "import time\n"
            "# reprolint: allow[rng-discipline, wall-clock]\n"
            "x = np.random.default_rng(), time.time()\n"
        )
        assert not findings(src, rule="rng-discipline")
        assert not findings(src, rule="wall-clock")
        assert not findings(src, rule="unused-suppression")


# -- layering ----------------------------------------------------------------


class TestLayerDag:
    def test_upward_import_fires(self):
        src = "from repro.scenario.spec import Scenario\n"
        (f,) = findings(src, "src/repro/core/fabric.py", "layer-dag")
        assert "upward import" in f.message and "repro.scenario.spec" in f.message

    def test_scenario_into_sweep_fires(self):
        src = "from repro.scenario.sweep import run_sweep\n"
        assert findings(src, "src/repro/scenario/runner.py", "layer-dag")

    def test_downward_and_same_layer_quiet(self):
        src = (
            "from repro.core.geo import GeoFabric\n"
            "from repro.core.fabric import Fabric\n"
            "from repro.scenario.spec import Scenario\n"
        )
        assert not findings(src, "src/repro/scenario/runner.py", "layer-dag")

    def test_lazy_upward_import_quiet(self):
        src = "def f():\n    from repro.serving.engine import ServingEngine\n"
        assert not findings(src, "src/repro/scenario/runner.py", "layer-dag")

    def test_type_checking_guard_quiet(self):
        src = (
            "from typing import TYPE_CHECKING\n"
            "if TYPE_CHECKING:\n"
            "    from repro.scenario.spec import Scenario\n"
        )
        assert not findings(src, "src/repro/core/fabric.py", "layer-dag")

    def test_unlayered_module_quiet(self):
        src = "from repro.scenario.sweep import run_sweep\n"
        assert not findings(src, "benchmarks/bench_x.py", "layer-dag")

    def test_from_package_import_submodule_attributed(self):
        # `from repro.scenario import sweep` pulls a layer-3 module into
        # layer 2 even though the package surface itself is layer 3
        src = "from repro.scenario import sweep\n"
        (f,) = findings(src, "src/repro/scenario/library.py", "layer-dag")
        assert "repro.scenario.sweep" in f.message


class TestSiblingStack:
    def test_eager_jax_in_simulator_fires(self):
        (f,) = findings("import jax\n", SIM, "sibling-stack")
        assert "sibling" in f.message

    def test_eager_runtime_import_fires(self):
        src = "from repro.runtime.failure import plan_recovery\n"
        assert findings(src, "src/repro/scenario/runner.py", "sibling-stack")

    def test_lazy_import_quiet(self):
        src = (
            "def plan():\n"
            "    import jax\n"
            "    from repro.runtime.failure import plan_recovery\n"
        )
        assert not findings(src, "src/repro/scenario/runner.py", "sibling-stack")

    def test_executable_stack_module_quiet(self):
        # repro.launch is unlayered: it may import jax eagerly
        assert not findings("import jax\n", "src/repro/launch/mesh.py", "sibling-stack")


# -- determinism -------------------------------------------------------------


class TestWallClock:
    def test_time_time_call_fires(self):
        src = "import time\nt0 = time.time()\n"
        (f,) = findings(src, SIM, "wall-clock")
        assert "time.time()" in f.message

    def test_from_import_alias_fires(self):
        src = "from time import perf_counter as pc\nt = pc()\n"
        assert findings(src, SIM, "wall-clock")

    def test_datetime_now_fires(self):
        src = "from datetime import datetime\nd = datetime.now()\n"
        assert findings(src, SIM, "wall-clock")

    def test_reference_seam_quiet(self):
        # the CheckpointStore pattern: a default-parameter *reference*
        # is the sanctioned injection seam — only calls are flagged
        src = (
            "import time\n"
            "def __init__(self, clock=time.time):\n"
            "    self.clock = clock\n"
        )
        assert not findings(src, "src/repro/checkpoint/store.py", "wall-clock")

    def test_time_sleep_quiet(self):
        assert not findings("import time\ntime.sleep(1)\n", SIM, "wall-clock")

    def test_runtime_allowlisted(self):
        src = "import time\nt0 = time.time()\n"
        assert not findings(src, "src/repro/runtime/trainer.py", "wall-clock")


class TestRngDiscipline:
    def test_unseeded_default_rng_fires(self):
        src = "import numpy as np\nrng = np.random.default_rng()\n"
        (f,) = findings(src, SIM, "rng-discipline")
        assert "unseeded" in f.message

    def test_none_seed_fires(self):
        src = "import numpy as np\nrng = np.random.default_rng(None)\n"
        assert findings(src, SIM, "rng-discipline")

    def test_seeded_default_rng_quiet(self):
        src = "import numpy as np\nrng = np.random.default_rng(seed)\n"
        assert not findings(src, SIM, "rng-discipline")

    def test_ambient_np_random_fires(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        (f,) = findings(src, SIM, "rng-discipline")
        assert "ambient" in f.message

    def test_from_import_ambient_fires(self):
        src = "from numpy.random import shuffle\nshuffle(xs)\n"
        assert findings(src, SIM, "rng-discipline")

    def test_stdlib_random_fires(self):
        src = "import random\nx = random.random()\n"
        assert findings(src, SIM, "rng-discipline")

    def test_seeded_random_instance_quiet(self):
        src = "import random\nrng = random.Random(7)\nx = rng.random()\n"
        assert not findings(src, SIM, "rng-discipline")

    def test_generator_methods_quiet(self):
        src = (
            "import numpy as np\n"
            "rng = np.random.default_rng(0)\n"
            "x = rng.normal(size=3)\n"
        )
        assert not findings(src, SIM, "rng-discipline")

    def test_checkpoint_allowlisted(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        assert not findings(src, "src/repro/checkpoint/store.py", "rng-discipline")


class TestSetIteration:
    def test_for_over_set_call_fires(self):
        src = "for x in set(xs):\n    pass\n"
        (f,) = findings(src, SIM, "set-iteration")
        assert "sorted" in f.message

    def test_comprehension_over_set_literal_fires(self):
        src = "ys = [f(x) for x in {a, b, c}]\n"
        assert findings(src, SIM, "set-iteration")

    def test_list_wrapped_set_fires(self):
        src = "for x in list(set(xs)):\n    pass\n"
        assert findings(src, SIM, "set-iteration")

    def test_sorted_set_quiet(self):
        src = "for x in sorted(set(xs)):\n    pass\n"
        assert not findings(src, SIM, "set-iteration")

    def test_plain_iterable_quiet(self):
        src = "for x in xs:\n    pass\n"
        assert not findings(src, SIM, "set-iteration")

    def test_out_of_scope_quiet(self):
        src = "for x in set(xs):\n    pass\n"
        assert not findings(src, "benchmarks/bench_x.py", "set-iteration")


# -- spec contracts ----------------------------------------------------------


SPEC = "src/repro/scenario/example.py"


class TestSpecContracts:
    def test_unfrozen_spec_fires(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass\n"
            "class FooSpec:\n    x: int = 0\n"
        )
        (f,) = findings(src, SPEC, "spec-frozen")
        assert "frozen=True" in f.message

    def test_frozen_without_true_fires(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(order=True)\n"
            "class FooOptions:\n    x: int = 0\n"
        )
        assert findings(src, SPEC, "spec-frozen")

    def test_frozen_spec_quiet(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class FooSpec:\n    x: int = 0\n"
        )
        assert not findings(src, SPEC, "spec-frozen")

    def test_non_dataclass_and_private_quiet(self):
        src = (
            "from dataclasses import dataclass\n"
            "class BarSpec:\n    pass\n"
            "@dataclass\n"
            "class _HiddenSpec:\n    x: int = 0\n"
        )
        assert not findings(src, SPEC, "spec-frozen")

    def test_missing_from_dict_fires(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class FooSpec:\n    x: int = 0\n"
        )
        (f,) = findings(src, SPEC, "spec-from-dict")
        assert "from_dict" in f.message

    def test_classmethod_from_dict_quiet(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class FooSpec:\n"
            "    x: int = 0\n"
            "    @classmethod\n"
            "    def from_dict(cls, d):\n"
            "        _reject_unknown_keys(cls, d)\n"
            "        return cls(**d)\n"
        )
        assert not findings(src, SPEC, "spec-from-dict")
        assert not findings(src, SPEC, "from-dict-strict")

    def test_module_level_from_dict_quiet(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class FooSpec:\n    x: int = 0\n"
            "def from_dict(d):\n"
            "    _reject_unknown_keys(FooSpec, d)\n"
            "    return FooSpec(**d)\n"
        )
        assert not findings(src, SPEC, "spec-from-dict")

    def test_lenient_from_dict_fires(self):
        src = (
            "class Foo:\n"
            "    @classmethod\n"
            "    def from_dict(cls, d):\n"
            "        return cls(**d)\n"
        )
        (f,) = findings(src, SPEC, "from-dict-strict")
        assert "unknown keys" in f.message

    def test_explicit_raise_is_strict(self):
        src = (
            "import dataclasses\n"
            "class Foo:\n"
            "    @classmethod\n"
            "    def from_dict(cls, d):\n"
            "        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}\n"
            "        if unknown:\n"
            "            raise ValueError(f'unknown {unknown}')\n"
            "        return cls(**d)\n"
        )
        assert not findings(src, SPEC, "from-dict-strict")

    def test_out_of_scope_quiet(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass\n"
            "class FooSpec:\n    x: int = 0\n"
        )
        assert not findings(src, "benchmarks/bench_x.py", "spec-frozen")


# -- oracle retention --------------------------------------------------------


class TestOracleRetention:
    def test_missing_oracle_fires(self):
        src = "class _IncrementalAllocator:\n    pass\n"
        out = findings(src, "src/repro/core/congestion.py", "oracle-retention")
        assert any("_FullEpochAllocator" in f.message for f in out)

    def test_declared_pair_quiet(self):
        src = (
            "INCREMENTAL_EVENT_LOOP = True\n"
            "class _FullEpochAllocator:\n    pass\n"
            "class _IncrementalAllocator:\n    pass\n"
        )
        assert not findings(src, "src/repro/core/congestion.py", "oracle-retention")

    def test_undeclared_fast_path_fires(self):
        src = "def resolve_batched(x):\n    return x\n"
        (f,) = findings(src, "src/repro/core/newmod.py", "oracle-retention")
        assert "no oracle declared" in f.message

    def test_method_fast_path_detected(self):
        src = (
            "class Fabric:\n"
            "    def route_flows_batched(self, flows):\n"
            "        return flows\n"
        )
        out = findings(src, "src/repro/core/fabric.py", "oracle-retention")
        assert any("route_flow" in f.message for f in out)

    def test_stale_map_entry_fires(self):
        # module lost both the fast path and the oracle: the map entry
        # itself is now stale and must be pruned
        src = "x = 1\n"
        out = findings(src, "src/repro/core/congestion.py", "oracle-retention")
        assert any("prune the entry" in f.message for f in out)

    def test_out_of_scope_quiet(self):
        src = "def resolve_batched(x):\n    return x\n"
        assert not findings(src, "benchmarks/bench_x.py", "oracle-retention")


# -- acceptance demonstrations ----------------------------------------------


class TestSeededDemonstrations:
    """The CI lint job must catch exactly these regressions."""

    def test_upward_import_into_fabric_fails(self):
        src = (REPO / "src/repro/core/fabric.py").read_text()
        seeded = src.replace(
            "import zlib", "import zlib\nfrom repro.scenario.spec import Scenario", 1
        )
        assert seeded != src
        out = [
            f
            for f in lint_source(seeded, "src/repro/core/fabric.py")
            if f.rule == "layer-dag"
        ]
        assert out and "upward import" in out[0].message

    def test_deleting_full_epoch_allocator_fails(self):
        src = (REPO / "src/repro/core/congestion.py").read_text()
        seeded = src.replace("class _FullEpochAllocator", "class _Gone", 1)
        assert seeded != src
        out = [
            f
            for f in lint_source(seeded, "src/repro/core/congestion.py")
            if f.rule == "oracle-retention"
        ]
        assert any("_FullEpochAllocator" in f.message for f in out)

    def test_real_fabric_and_congestion_are_clean(self):
        for rel in ("src/repro/core/fabric.py", "src/repro/core/congestion.py"):
            assert lint_source((REPO / rel).read_text(), rel) == []


# -- baseline + ratchet ------------------------------------------------------


def _finding(rule="spec-from-dict", path="src/repro/x.py", context="class XSpec:"):
    return Finding(rule=rule, path=path, line=10, message="m", context=context)


class TestBaseline:
    def test_split_grandfathers_matches(self):
        f = _finding()
        entries = [{"rule": f.rule, "path": f.path, "context": f.context}]
        new, grand, stale = baseline_mod.split([f], entries)
        assert (new, grand, stale) == ([], [f], [])

    def test_split_flags_new_and_stale(self):
        f = _finding()
        entries = [{"rule": f.rule, "path": "src/repro/gone.py", "context": "c"}]
        new, grand, stale = baseline_mod.split([f], entries)
        assert new == [f] and grand == []
        assert stale == [(f.rule, "src/repro/gone.py", "c")]

    def test_multiset_semantics(self):
        # two identical findings, one baseline entry: one is new
        f = _finding()
        entries = [{"rule": f.rule, "path": f.path, "context": f.context}]
        new, grand, _ = baseline_mod.split([f, f], entries)
        assert len(new) == 1 and len(grand) == 1

    def test_line_drift_does_not_invalidate(self):
        f = _finding()
        drifted = Finding(f.rule, f.path, line=99, message="m", context=f.context)
        entries = [{"rule": f.rule, "path": f.path, "context": f.context}]
        new, grand, stale = baseline_mod.split([drifted], entries)
        assert not new and not stale

    def test_dump_load_round_trip(self, tmp_path):
        f = _finding()
        p = tmp_path / "baseline.json"
        baseline_mod.dump([f], p)
        entries = baseline_mod.load(p)
        assert entries == [
            {"rule": f.rule, "path": f.path, "context": f.context}
        ]

    def test_ratchet_only_shrinks(self):
        old = [{"rule": "r", "path": "a.py", "context": "c"}]
        assert baseline_mod.ratchet_errors(old, old) == []
        assert baseline_mod.ratchet_errors([], old) == []  # shrink: fine
        grown = old + [{"rule": "r2", "path": "b.py", "context": "c2"}]
        errors = baseline_mod.ratchet_errors(grown, old)
        assert len(errors) == 1 and "baseline grew" in errors[0]

    def test_at_git_ref_missing_file_is_none(self):
        # A ref from before the baseline existed must yield None (skip the
        # ratchet), not an empty baseline the current one "grew" from.
        first = subprocess.run(
            ["git", "rev-list", "--max-parents=0", "HEAD"],
            cwd=REPO, capture_output=True, text=True, check=True,
        ).stdout.split()[0]
        assert baseline_mod.at_git_ref(first, REPO) is None

    def test_at_git_ref_reads_committed_baseline(self):
        entries = baseline_mod.at_git_ref("HEAD", REPO)
        if entries is None:
            pytest.skip("baseline not committed at HEAD yet")
        assert entries == baseline_mod.load(REPO / "tools/reprolint/baseline.json")


class TestReporters:
    def test_text(self):
        f = _finding()
        assert reporters.text([f]) == "src/repro/x.py:10: [spec-from-dict] m"

    def test_json_round_trips(self):
        f = _finding()
        (row,) = json.loads(reporters.as_json([f]))
        assert row == {
            "rule": f.rule,
            "path": f.path,
            "line": 10,
            "message": "m",
            "context": f.context,
        }

    def test_github_annotation_shape(self):
        f = Finding("r", "a.py", 3, "bad % thing\nline2", "ctx")
        out = reporters.github([f])
        assert out.startswith("::error file=a.py,line=3,title=reprolint[r]::")
        assert "\n" not in out and "%0A" in out and "%25" in out


# -- the repo itself is clean (tier-1 mirror of the CI lint step) ------------


class TestRepoIsClean:
    def test_repo_clean_under_committed_baseline(self):
        """Exactly what the CI lint job runs."""
        proc = subprocess.run(
            [
                sys.executable,
                "-m",
                "tools.reprolint",
                "src",
                "benchmarks",
                "tests",
                "examples",
            ],
            cwd=REPO,
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_committed_baseline_is_the_grandfathered_set(self):
        entries = baseline_mod.load(REPO / baseline_mod.DEFAULT_BASELINE)
        # the one grandfathered finding: ShapeSpec (executable stack,
        # never JSON round-tripped) has no from_dict.  Shrink-only.
        assert entries == [
            {
                "rule": "spec-from-dict",
                "path": "src/repro/launch/shapes.py",
                "context": "class ShapeSpec:",
            }
        ]

    def test_list_rules_cli(self):
        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", "--list-rules"],
            cwd=REPO,
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 0
        for rid in rule_ids():
            assert rid in proc.stdout
