"""Quickstart: the whole stack in ~60 seconds on CPU.

1. Declare the experiment once — a ``repro.scenario.Scenario`` carries the
   topology, the workload and the costing options; build the emulated 2-DC
   EVPN-VXLAN fabric from it and ping across the WAN.
2. Allocate queue-pair source ports both ways (Algorithm 1 vs stock RXE).
3. Cost every registered WAN sync schedule (paper strategies + phased/
   overlapped ones) for a real model's gradients under the event-driven
   congestion model by editing the scenario's workload — per-phase
   timelines for multi-phase schedules.
4. Train a smoke-scale model for a few steps with the geo trainer, driven
   by the same scenario spec.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro.configs import get_smoke_config
from repro.core import (
    allocate_ports,
    make_correlated_queue_pairs,
    strategy_names,
)
from repro.launch.mesh import make_host_mesh
from repro.runtime import GeoTrainer, TrainerConfig
from repro.scenario import (
    Scenario,
    SyncOptions,
    TopologySpec,
    WorkloadSpec,
    run_scenario,
)

#: The whole experiment as one declarative spec: 2 DCs x 2 workers, the
#: smoke model's gradients, contended congestion costing, 20 train steps.
QUICKSTART = Scenario(
    name="quickstart",
    topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=0),
    workload=WorkloadSpec(strategy="allreduce", grad_bytes=0, steps=20),
    options=SyncOptions(jitter=False, congestion=True),
    description="The README's 60-second tour, as a spec.",
)


def main() -> None:
    # -- 1. fabric, from the spec --------------------------------------------
    geo = QUICKSTART.topology.build()
    rtt = geo.rtt_ms(count=20)
    print(f"[fabric] 2 DCs up; inter-DC RTT {rtt.mean():.1f} ms (paper ~22 ms)")

    # -- 2. Algorithm 1 ------------------------------------------------------
    qps = make_correlated_queue_pairs(8, base_number=1234)
    base = allocate_ports(qps, scheme="baseline")
    ours = allocate_ports(qps, scheme="qp_aware")
    print(f"[ports] stock RXE:   {sorted(base)} ({len(set(base))} distinct)")
    print(f"[ports] Algorithm 1: {sorted(ours)} ({len(set(ours))} distinct)")

    # -- 3. WAN sync costing: one spec edit per strategy ----------------------
    # (a paper-scale spec would just say WorkloadSpec(model="distilgpt2-82m");
    # the smoke config derives its reduced gradient volume here)
    import jax

    from repro.launch.shapes import params_specs

    cfg = get_smoke_config("distilgpt2-82m")
    grad_bytes = sum(s.size * 4 for s in jax.tree.leaves(params_specs(cfg)))
    print(f"[sync]  gradient volume {grad_bytes / 1e6:.1f} MB across the WAN:")
    for strategy in strategy_names():
        spec = dataclasses.replace(
            QUICKSTART,
            workload=WorkloadSpec(strategy=strategy, grad_bytes=grad_bytes, steps=1),
        )
        c = run_scenario(spec, geo=geo).sync
        phased = (
            " | ".join(f"{p.name} {p.duration_s * 1e3:.1f}ms" for p in c.phases)
            if len(c.phases) > 1
            else ""
        )
        print(f"        {strategy:14s} {c.amortized_seconds * 1e3:8.1f} ms/step "
              f"({c.wan_bytes / 1e6:6.1f} MB on WAN links)"
              + (f"  [{phased}]" if phased else ""))

    # -- 4. train: the trainer consumes the same scenario ---------------------
    from repro.optim import AdamWConfig

    trainer = GeoTrainer(
        cfg, make_host_mesh(),
        trainer_cfg=TrainerConfig(seq_len=64, global_batch=4, log_every=5,
                                  opt=AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=400)),
        checkpoint_dir="/tmp/repro_quickstart_ckpt",
        scenario=QUICKSTART,
    )
    result = trainer.run()
    losses = [m["loss"] for m in result["metrics"]]
    if losses:
        print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps "
              f"(checkpointed at step {result['last_checkpoint']})")
    else:
        print(f"[train] nothing to do: restored checkpoint already at step "
              f"{result['last_checkpoint']} (delete the checkpoint dir to retrain)")


if __name__ == "__main__":
    main()
