"""ECMP path-diversity study: sweep bin counts and QP correlation models.

Extends the paper's §5.2 experiment: where Figs. 11/12 fix k=4 bins, this
sweeps k in {1 (=baseline), 2, 4, 8, 16}, both QP-allocation pathologies,
and both measurement points, printing the full load-factor grid — the
experiment you'd run to pick k for a new fabric (the paper: "our
preliminary analysis showed 4 bins provided the most stable improvement").

Run:  PYTHONPATH=src python examples/ecmp_study.py
"""

import numpy as np

from repro.core.fabric import Fabric
from repro.core.flows import Flow, route_flows_batched
from repro.core.metrics import load_factor
from repro.core.ports import (
    make_correlated_queue_pairs,
    make_queue_pairs,
    qp_aware_ports,
)

TRIALS = 80
QPS = (4, 8, 16, 32)


def measure(fabric, qps_list, k):
    """Mean leaf load factor for one allocator config."""
    out = []
    for qps in qps_list:
        ports = qp_aware_ports(qps, k=k) if k > 1 else [
            # k=1 degenerates to the stock hash over the full range
            49192 + (p - 49192) % 16384 for p in qp_aware_ports(qps, k=1)
        ]
        flows = [Flow("d1h1", "d2h2", 1_000_000, qp, port)
                 for qp, port in zip(qps, ports)]
        route_flows_batched(fabric, flows)
        links = dict(fabric.uplink_bytes("d1l1", toward="spine"))
        for spine in ("d1s1", "d1s2"):
            links.setdefault(("d1l1", spine), 0)
        out.append(load_factor(links, threshold=-1).load_factor)
    return float(np.mean(out))


def main() -> None:
    fabric = Fabric()
    rng = np.random.default_rng(7)
    for model_name, make in (
        ("correlated (production pathology)", make_correlated_queue_pairs),
        ("sequential (high entropy)", lambda n, base_number: make_queue_pairs(n, base_number=base_number)),
    ):
        print(f"\n=== QP model: {model_name} ===")
        print(f"{'QPs':>5s} " + " ".join(f"k={k:<6d}" for k in (1, 2, 4, 8, 16)))
        for n in QPS:
            qps_list = [make(n, base_number=int(rng.integers(0, 2**31))) for _ in range(TRIALS)]
            row = [measure(fabric, qps_list, k) for k in (1, 2, 4, 8, 16)]
            best = min(range(len(row)), key=lambda i: row[i])
            cells = " ".join(
                f"{v:.3f}{'*' if i == best else ' '}" for i, v in enumerate(row)
            )
            print(f"{n:5d} {cells}")
    print("\n(* = lowest load factor; paper fixed k=4 as most stable)")


if __name__ == "__main__":
    main()
