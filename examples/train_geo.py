"""End-to-end driver: train the paper's model across two emulated DCs.

Trains distilgpt2-82m (the paper's Fig-14 workload) with the full
substrate — synthetic WikiText-like pipeline, AdamW, async checksummed
checkpoints, BFD-style heartbeats, straggler monitor — under a chosen WAN
sync strategy, and reports the per-step WAN economics from the emulated
EVPN-VXLAN fabric alongside the training curve.

The experiment is one declarative ``repro.scenario.Scenario`` (topology +
workload + costing options) handed to the trainer; the CLI flags are spec
edits.  Default is a few hundred steps of the reduced config
(CPU-friendly); ``--paper-scale`` trains the real 82M model.

Run:  PYTHONPATH=src python examples/train_geo.py --steps 200
      PYTHONPATH=src python examples/train_geo.py --paper-scale --steps 30
      PYTHONPATH=src python examples/train_geo.py --strategy hier_int8
      PYTHONPATH=src python examples/train_geo.py --inject-failure-at 50
"""

import argparse

from repro.configs import get_config, get_smoke_config
from repro.core.schedule import SYNC_STRATEGIES
from repro.launch.mesh import make_host_mesh
from repro.runtime import GeoTrainer, TrainerConfig
from repro.optim import AdamWConfig
from repro.scenario import Scenario, SyncOptions, TopologySpec, WorkloadSpec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    # the distributed step builders implement the paper strategies; the WAN
    # estimator additionally accepts any registered schedule strategy
    ap.add_argument("--strategy", default="hier", choices=list(SYNC_STRATEGIES))
    ap.add_argument("--paper-scale", action="store_true",
                    help="the real 82M model (slower on CPU)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_geo")
    args = ap.parse_args()

    cfg = get_config("distilgpt2-82m") if args.paper_scale else get_smoke_config("distilgpt2-82m")
    scenario = Scenario(
        name="train_geo",
        topology=TopologySpec(num_pods=2, workers_per_pod=2, seed=0),
        workload=WorkloadSpec(strategy=args.strategy, steps=args.steps),
        options=SyncOptions(jitter=False),
        description="Fig-14-style geo training, declaratively specified.",
    )
    trainer = GeoTrainer(
        cfg, make_host_mesh(),
        trainer_cfg=TrainerConfig(
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            steps=args.steps,
            log_every=max(args.steps // 20, 1),
            opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        ),
        checkpoint_dir=args.checkpoint_dir,
        scenario=scenario,
    )
    result = trainer.run(inject_failure_at=args.inject_failure_at)
    losses = [m["loss"] for m in result["metrics"]]
    wan = result["metrics"][-1]["wan_s_est"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"WAN sync estimate [{args.strategy}]: {wan:.3f} s/step "
          f"(fabric: 2 DCs, 800 Mbit/s x 4 WAN links, 22 ms RTT)")
    print(f"sync efficiency: {result['sync_efficiency']:.2f}; "
          f"last checkpoint: step {result['last_checkpoint']}")
    for drill in result["recovery_drills"]:
        p = drill["plan"]
        print(f"recovery drill @step {drill['step']}: detected {drill['dead']} in "
              f"{p['detection_s'] * 1e3:.0f} ms; lost {p['lost_steps']} steps; "
              f"downtime {p['detection_s'] + p['restore_s'] + p['remesh_s']:.1f} s")


if __name__ == "__main__":
    main()
