"""Failover drill: WAN link dies mid-training; the job survives.

The paper's §5.3 at the system level: continuous training over the
emulated fabric, a WAN link failure injected mid-run, BFD-speed detection
vs BGP-timer detection compared end to end — including what each costs in
lost training work (runtime/failure.py's recovery economics), and the
fabric-level proof that traffic rerouted with zero blackholing.

Run:  PYTHONPATH=src python examples/failover_drill.py
"""

from repro.core.bfd import FailureDetector
from repro.core.evpn import EvpnControlPlane
from repro.core.fabric import Fabric
from repro.runtime.failure import (
    HeartbeatMonitor,
    optimal_checkpoint_interval,
    plan_recovery,
)


def main() -> None:
    fabric = Fabric()
    evpn = EvpnControlPlane(fabric)
    det = FailureDetector(fabric, evpn)
    wan = tuple(sorted(fabric.wan_links[0]))
    step_time_s, ckpt_bytes = 8.0, 3 * 328e6  # an 82M fp32 job

    print("=== network layer (paper Figs. 9/13) ===")
    for mech in ("bfd", "bgp"):
        tl = det.fail_and_recover(wan, mechanism=mech)
        det.restore(wan)
        unit = "ms" if mech == "bfd" else "s"
        val = tl.recovery_ms if mech == "bfd" else tl.recovery_ms / 1e3
        print(f"{mech.upper():4s}: link {wan[0]}<->{wan[1]} recovery {val:.0f} {unit}")
        for t, event in tl.events:
            print(f"      t={t:10.1f} ms  {event}")

    print("\n=== reroute proof ===")
    det.fail_and_recover(wan, mechanism="bfd")
    fabric.reset_counters()
    for port in range(49192, 49192 + 64):
        path = fabric.send("d1h1", "d2h1", 1000, src_port=port)
        assert (wan[0], wan[1]) not in list(zip(path, path[1:]))
    det.restore(wan)
    print("64/64 post-failure flows rerouted; 0 blackholed")

    print("\n=== training layer (the BFD insight applied upward) ===")
    mon = HeartbeatMonitor(["pod0", "pod1"], interval_ms=100, detect_mult=3)
    for detect_ms, label in ((mon.detect_time_ms(), "heartbeats (BFD-style)"),
                             (180_000.0, "RPC hold-timeout (BGP-style)")):
        plan = plan_recovery(
            step=1000, last_checkpoint_step=985, step_time_s=step_time_s,
            detect_time_ms=detect_ms, checkpoint_bytes=ckpt_bytes,
        )
        print(f"{label:28s}: detect {plan.detection_s:7.2f}s + restore "
              f"{plan.restore_s:.2f}s + remesh {plan.remesh_s:.0f}s "
              f"+ lost work {plan.lost_work_s:.0f}s = {plan.total_cost_s:.0f}s")

    interval = optimal_checkpoint_interval(
        step_time_s=step_time_s, save_overhead_s=1.0, mtbf_s=6 * 3600
    )
    print(f"\nYoung/Daly checkpoint cadence for this job: every {interval} steps")


if __name__ == "__main__":
    main()
