"""Fiber-latency campaign: overlap benefit vs per-DC-pair WAN RTT.

Thin wrapper over ``repro.scenario.fiber_latency_campaign`` (same pattern
as ``examples/train_geo.py``): one declarative sweep — per-pair RTT
(``TopologySpec.wan_pairs``, the asymmetric-WAN axis) crossed with the
compute/communication overlap fraction — executed serially or over a
process pool, printing the joined table and the Papavasileiou-style
overlap-benefit-vs-RTT curve ("Modeling the Impact of Fiber Latency on
Compute-Communication Overlap").

Run:  PYTHONPATH=src python examples/sweep_fiber_latency.py
      PYTHONPATH=src python examples/sweep_fiber_latency.py --workers 4
      PYTHONPATH=src python examples/sweep_fiber_latency.py \
          --rtt-ms 2 10 30 60 120 --overlap 0 0.5 1.0
"""

import argparse

from repro.scenario import fiber_latency_campaign, run_sweep
from repro.scenario.sweep import overlap_benefit_curve


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rtt-ms", type=float, nargs="+", default=[2.0, 10.0, 30.0, 60.0],
                    help="per-DC-pair WAN RTTs to sweep (ms)")
    ap.add_argument("--overlap", type=float, nargs="+", default=[0.0, 0.75],
                    help="overlap fractions to sweep (must include 0 for the curve)")
    ap.add_argument("--compute-seconds", type=float, default=0.35)
    ap.add_argument("--grad-bytes", type=int, default=48_000_000)
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool size; 0/1 = serial (identical table)")
    args = ap.parse_args()

    sweep = fiber_latency_campaign(
        rtt_ms=tuple(args.rtt_ms),
        overlap_fractions=tuple(args.overlap),
        grad_bytes=args.grad_bytes,
        compute_seconds=args.compute_seconds,
    )
    result = run_sweep(sweep, workers=args.workers)

    print(f"{len(result.rows)} variants ({sweep.name})")
    print(f"{'variant':>16} {'step_s':>8} {'sync_s':>8}")
    for row in result.rows:
        print(f"{row.name:>16} {row.metrics['mean_step_seconds']:8.3f} "
              f"{row.metrics['sync_wan_seconds']:8.3f}")

    print("\noverlap benefit vs per-pair RTT (fraction of the no-overlap "
          "step time recovered):")
    for rtt, benefit in overlap_benefit_curve(result):
        bar = "#" * int(round(benefit * 60))
        print(f"  rtt {rtt:6.1f} ms  benefit {benefit:6.3f}  {bar}")


if __name__ == "__main__":
    main()
