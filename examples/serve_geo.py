"""Geo-serving walkthrough: millions of users on the training fabric.

Runs the ``serving_under_flap`` library scenario — inference traffic
co-scheduled with hierarchical training through one gray-failure arc
(WAN brownout -> SLA-probe trip -> session failover -> recovery) — and
prints the per-step serving story: request counts, latency percentiles,
SLO misses, and the migration wave with its concrete WAN KV bytes.

Then it bridges sim to silicon: the first trace request of the peak step
is materialized as a real model batch via ``repro.serving.request_batch``
(the same helper ``repro.launch.serve`` uses) and run through prefill.

Run:  PYTHONPATH=src python examples/serve_geo.py
"""

from repro.scenario import get_scenario, run_scenario


def main() -> None:
    scenario = get_scenario("serving_under_flap")
    print(f"=== {scenario.name}: {scenario.description}\n")
    result = run_scenario(scenario)

    print(f"{'step':>4s} {'reqs':>5s} {'remote':>6s} {'p50 ms':>8s} "
          f"{'p99 ms':>9s} {'miss':>5s} {'migrated':>8s} {'KV MB':>7s}")
    for s in result.serving_steps:
        flag = " <- failover wave" if s.migrated_sessions else ""
        print(f"{s.step:>4d} {s.requests:>5d} {s.remote_requests:>6d} "
              f"{s.p50_ms:>8.1f} {s.p99_ms:>9.1f} {s.slo_misses:>5d} "
              f"{s.migrated_sessions:>8d} {s.migration_bytes / 1e6:>7.1f}{flag}")

    m = result.metrics()
    print(f"\n{int(m['serving_requests'])} requests, "
          f"p99 {m['serving_p99_ms']:.0f} ms, "
          f"{m['serving_slo_miss_frac']:.1%} SLO misses, "
          f"{int(m['serving_migrated_sessions'])} sessions migrated "
          f"({m['serving_migration_bytes'] / 1e6:.0f} MB of KV over the WAN)")

    # sim -> silicon: serve the peak step's first request for real
    peak = max(result.serving_steps, key=lambda s: s.requests)
    from repro.serving import generate_trace

    engine_trace = generate_trace(
        scenario.serving, scenario.topology.num_pods, scenario.workload.steps
    )
    req = engine_trace[peak.step][0]
    print(f"\nmaterializing request rid={req.rid} "
          f"({req.tokens} tokens, home DC {req.home_dc}) as a model batch:")
    import jax

    from repro.configs import get_smoke_config
    from repro.models import init_params, prefill
    from repro.serving import request_batch

    cfg = get_smoke_config("distilgpt2-82m")
    batch = request_batch(cfg, req)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits, _cache = prefill(params, batch, cfg, max_len=req.tokens + 8)
    print(f"prefill logits: {logits.shape}")


if __name__ == "__main__":
    main()
