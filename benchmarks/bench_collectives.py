"""Scaled-topology collective sweep + batched-router speedup (beyond paper).

Where Figs. 11/12 fix the 9-host Fig. 1 fabric and a single flow pattern,
this suite scales the topology to 4 DCs x 4 spines x 8 leaves x 4
hosts/leaf (128 hosts, 64 WAN links per DC pair) and sweeps every
collective pattern in :mod:`repro.core.flows` — ring all-reduce, parameter
server, reduce-scatter, all-gather, MoE all-to-all, and GeoPipe-style
pipeline P2P — under both port-allocation schemes, reporting the CONGA
load factor (Eq. 12) and the collision-index skew ``sum p^2`` (Eq. 11)
over the WAN links.

Also measures the batched routing engine against the sequential per-flow
walk on a >=10k-flow all-to-all workload (steady state, next-hop tables
warm) and asserts the two produce byte-identical counters.

The SCALED64 tier (ISSUE 9, :mod:`benchmarks.scaled64`) scales further:
64 DCs, 256 hosts, and the ~100k-flow leader-ring workload routed through
the fabric in one batch — the topology-scale end of the same sweep.  The
event-loop side of the tier (incremental vs from-scratch allocator) is
gated in :mod:`benchmarks.bench_scenarios`.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.collision import collision_index
from repro.core.fabric import Fabric, FabricConfig
from repro.core.flows import (
    Flow,
    all_gather_flows,
    all_to_all_flows,
    parameter_server_flows,
    pipeline_p2p_flows,
    reduce_scatter_flows,
    ring_allreduce_flows,
    route_flows,
    route_flows_batched,
)
from repro.core.metrics import load_factor

from .common import BenchRow, timed

#: 4-DC scaled fabric: 16 spines, 32 leaves, 128 hosts, 6 DC pairs x 16
#: spine-pair WAN links = 96 WAN links.
SCALED = FabricConfig(
    num_dcs=4,
    spines_per_dc=4,
    leaves_per_dc=8,
    hosts_per_leaf=tuple(tuple(4 for _ in range(8)) for _ in range(4)),
)

GRAD_BYTES = 64_000_003  # deliberately not divisible by channel counts
SPEEDUP_WORKERS = 64  # every other host -> 64*63*4 = 15_876 flows (>=10k)
MIN_SPEEDUP = 10.0


def _patterns(fabric: Fabric, scheme: str) -> Dict[str, List[Flow]]:
    hosts = list(fabric.hosts)
    kw = dict(scheme=scheme, num_channels=8)
    by_dc: Dict[int, List[str]] = {}
    for name, h in fabric.hosts.items():
        by_dc.setdefault(h.dc, []).append(name)
    stages = [by_dc[dc] for dc in sorted(by_dc)]  # one pipeline stage per DC
    return {
        "ring": ring_allreduce_flows(hosts, GRAD_BYTES, **kw),
        "ps": parameter_server_flows(hosts[0], hosts[1:], GRAD_BYTES, **kw),
        "reduce_scatter": reduce_scatter_flows(hosts, GRAD_BYTES, **kw),
        "all_gather": all_gather_flows(hosts, GRAD_BYTES, **kw),
        "all_to_all": all_to_all_flows(hosts[::4], GRAD_BYTES, **kw),
        "pipeline_p2p": pipeline_p2p_flows(
            stages, GRAD_BYTES // 32, num_microbatches=4, **kw
        ),
    }


def _wan_metrics(fabric: Fabric) -> Tuple[float, float]:
    """(load factor, collision-index skew) over every WAN link direction."""
    wan: Dict[Tuple[str, str], int] = {}
    for link in fabric.wan_links:
        u, v = sorted(link)
        wan[(u, v)] = fabric.link_bytes.get((u, v), 0)
        wan[(v, u)] = fabric.link_bytes.get((v, u), 0)
    lf = load_factor(wan, threshold=-1).load_factor
    values = np.array(list(wan.values()), dtype=np.float64)
    total = values.sum()
    skew = collision_index(values / total) if total > 0 else 0.0
    return lf, skew


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    fabric = Fabric(SCALED)

    for scheme in ("baseline", "qp_aware"):
        for name, flows in _patterns(fabric, scheme).items():
            (lf, skew), us = timed(
                lambda f=flows: (
                    route_flows_batched(fabric, f),
                    _wan_metrics(fabric),
                )[1]
            )
            uniform = 1.0 / (2 * len(fabric.wan_links))
            rows.append(
                BenchRow(
                    name=f"collective_{name}_{scheme}",
                    us_per_call=us / max(len(flows), 1),
                    derived=(
                        f"{len(flows)} flows | WAN load_factor={lf:.3f} "
                        f"skew={skew:.5f} (uniform={uniform:.5f})"
                    ),
                )
            )

    # batched vs sequential on a >=10k-flow workload, steady state: route
    # once untimed so both engines' one-time caches (BFS distances /
    # next-hop tables / pair keys) are warm, then take best-of-3 of a full
    # pass each (shared CI runners jitter single measurements).
    flows = all_to_all_flows(
        list(fabric.hosts)[: SPEEDUP_WORKERS * 2 : 2], GRAD_BYTES, num_channels=8
    )
    seq_counters = route_flows(fabric, flows)
    bat_counters = route_flows_batched(fabric, flows)
    seq_s = min(timed(lambda: route_flows(fabric, flows))[1] for _ in range(3))
    bat_s = min(timed(lambda: route_flows_batched(fabric, flows))[1] for _ in range(3))
    if seq_counters != bat_counters:
        raise AssertionError("batched router diverged from sequential reference")
    speedup = seq_s / bat_s
    rows.append(
        BenchRow(
            name="batched_vs_sequential_router",
            us_per_call=bat_s / len(flows),
            derived=(
                f"{len(flows)} flows | seq {seq_s / 1e6:.3f}s batched "
                f"{bat_s / 1e6:.3f}s = {speedup:.1f}x (byte-identical; "
                f"target >={MIN_SPEEDUP:.0f}x)"
            ),
        )
    )
    if speedup < MIN_SPEEDUP:
        raise AssertionError(
            f"batched router speedup {speedup:.1f}x below {MIN_SPEEDUP:.0f}x target"
        )

    # SCALED64 routing row: the 64-DC leader-ring workload through the
    # batched router (machine-independent shape facts gated; wall-clock
    # reported per flow, never gated).
    from .scaled64 import build_scaled64

    fabric64, _, sched64 = build_scaled64()
    flows64 = sched64.all_flows()
    _, us = timed(lambda: route_flows_batched(fabric64, flows64))
    lf64, skew64 = _wan_metrics(fabric64)
    rows.append(
        BenchRow(
            name="scaled64_ring_routing",
            us_per_call=us / len(flows64),
            derived=(
                f"{len(flows64)} flows over {len(fabric64.hosts)} hosts / "
                f"{len(fabric64.wan_links)} WAN links | load_factor={lf64:.3f} "
                f"skew={skew64:.5f}"
            ),
            metrics={
                "scaled64_num_flows": float(len(flows64)),
                "scaled64_wan_load_factor": lf64,
            },
        )
    )
    return rows
